//! # BucketServe
//!
//! A reproduction of *BucketServe: Bucket-Based Dynamic Batching for Smart and
//! Efficient LLM Inference Serving* (CS.DC 2025) as a three-layer
//! Rust + JAX + Pallas serving framework.
//!
//! The crate is organized as:
//!
//! * [`util`] — zero-dependency substrates built for the offline image:
//!   JSON, PRNG + distributions, statistics, CLI parsing, logging, and a
//!   mini property-testing framework.
//! * [`config`] — the typed configuration system (JSON files + CLI overrides).
//! * [`workload`] — synthetic Alpaca / LongBench / Mixed request generators
//!   and arrival processes (the paper's datasets are substituted per
//!   DESIGN.md §2).
//! * [`cluster`] — the simulated GPU cluster substrate: an A100 roofline
//!   cost model, NVLink transfer model, and the discrete-event engine.
//! * [`coordinator`] — **the paper's contribution**, an event-driven,
//!   sharded, preemptive scheduling core in ten modules:
//!   [`coordinator::bucket`] (Request Bucketing Manager, Algorithm 1),
//!   [`coordinator::batcher`] (Dynamic Batching Controller, Eqs. 1–6),
//!   [`coordinator::priority`] (SLO-deadline urgency scoring: online TTFT
//!   slack, offline starvation aging),
//!   [`coordinator::preempt`] (urgency-triggered prefill abort and decode
//!   KV eviction with checkpoint-and-restore),
//!   [`coordinator::events`] (the typed event queue the serving loop pops
//!   in timestamp order, with tombstone cancellation),
//!   [`coordinator::fleet`] (prefill/decode instance state machines with
//!   KV reservations),
//!   [`coordinator::shard`] (per-decode-instance scheduler shards with
//!   KV-aware work-stealing),
//!   [`coordinator::balance`] (arrival placement and load-balancing
//!   policies),
//!   [`coordinator::monitor`] (Global Monitor: per-shard sliding-window
//!   metrics, aggregated), and
//!   [`coordinator::scheduler`] (the thin P/D orchestrator + the
//!   [`coordinator::PrefillPlanner`] plug-in point the baselines reuse).
//! * [`runtime`] — the PJRT runtime that loads `artifacts/*.hlo.txt`
//!   (AOT-lowered JAX + Pallas) and serves them from the request path.
//! * [`baselines`] — UELLM-like (aggregated, static batching) and
//!   DistServe-like (disaggregated FCFS, no bucketing) comparators.
//! * [`server`] — the gateway: threaded admission/routing plus a
//!   newline-delimited-JSON TCP front end.
//! * [`metrics`] — throughput/latency/SLO/utilization accounting shared by
//!   every system and bench.
//!
//! Python (JAX + Pallas) appears only at build time; see `python/compile/`.

pub mod util;
pub mod config;
pub mod workload;
pub mod cluster;
pub mod coordinator;
pub mod runtime;
pub mod baselines;
pub mod server;
pub mod metrics;

pub use config::SystemConfig;
pub use coordinator::BucketServe;
pub use workload::{Request, RequestClass};

/// Microsecond-resolution timestamp/duration used across virtual and wall
/// clocks (u64 µs ≈ 584k years of range — enough for any trace).
pub type Micros = u64;

/// Convert microseconds to (fractional) seconds.
#[inline]
pub fn secs(us: Micros) -> f64 {
    us as f64 / 1e6
}

/// Convert (fractional) seconds to microseconds.
#[inline]
pub fn micros(s: f64) -> Micros {
    (s * 1e6).round() as Micros
}
