//! Metrics: run-report summarization shared by the CLI, examples, and the
//! figure benches.
//!
//! A [`crate::coordinator::RunReport`] is the raw record of one serving
//! run (completions with timestamps, fleet busy time, per-subsystem
//! counters); [`Summary`] flattens it into the one-row-per-run shape
//! every figure bench prints — both as an aligned table and as one JSON
//! object per line on stdout, which is what trajectory tooling scrapes.
//!
//! # Output-stability contract
//!
//! The Summary JSON is treated as a stable artifact: a default-config run
//! must serialize byte-identically across refactors (pinned by the
//! `shards_1_summary_json_is_byte_identical_to_legacy` integration
//! test). Subsystems that are off by default therefore emit their
//! columns *only when armed*:
//!
//! * `n_shards`/`steals`/`shard_routed` — only when `sharding` actually
//!   splits the coordinator (`n_shards > 1`);
//! * `prefill_aborts`/`decode_evictions`/`wasted_*`/`evicted_kv_tokens`/
//!   `recompute_tokens` — only when `preempt.enabled`;
//! * the TBT block (`tbt_attain_*`, `tbt_p50/p99_*`, `tbt_violations_*`,
//!   `admission_deferrals`, `tbt_evictions` + its
//!   `tbt_evicted_kv_tokens`/`tbt_recompute_tokens` cost books) — only
//!   when `admission.enabled`. The underlying gap *measurement* runs in
//!   every run (so paired on/off comparisons can read the disabled side
//!   off the `RunReport`), but disabled JSON stays legacy-shaped and
//!   skips even the percentile sort.
//! * the prefix-cache block (`prefix_hit_rate`, `prefix_hits`/
//!   `prefix_misses`/`prefix_hit_tokens`, `prefix_evictions` +
//!   `prefix_evicted_tokens`, `prefix_resident_tokens`) — only when
//!   `prefix.enabled`.
//! * the chunked-prefill block (`chunk_sliced_batches`, `chunk_slices`,
//!   `chunk_yields`, `chunk_hybrid_iters`, `chunk_max_slice_tokens`) —
//!   only when `chunk.enabled`.
//! * the realtime block (`client_aborts`, `stream_drops`) — only for
//!   runs driven by the realtime serving path
//!   ([`crate::coordinator::PdScheduler::run_realtime`]); virtual-time
//!   replay never emits it.
//! * `error` — only on abnormal termination; its presence means the row
//!   must not be read as a clean result.
//!
//! The parallel executor's counters (`executor_threads`,
//! `executor_sync_points`, `executor_parallel_events`) are deliberately
//! **never** serialized here: the executor's contract is that a
//! `threads = N` run's Summary JSON is byte-identical to the sequential
//! run's for the same seed, which an executor block would break by
//! construction. They live on `RunReport` only; the `shard_scaling`
//! bench surfaces them per row.
//!
//! Adding a new always-on column is a breaking change to every pinned
//! baseline; gate it or extend the integration test deliberately.

use crate::coordinator::RunReport;
use crate::config::SloSpec;
use crate::util::json::Json;
use crate::util::stats::Samples;
use crate::workload::RequestClass;

/// A flattened summary of one run (one row of a figure bench).
#[derive(Debug, Clone)]
pub struct Summary {
    pub system: String,
    pub n_requests: usize,
    pub makespan_s: f64,
    pub throughput_tps: f64,
    pub output_tps: f64,
    pub server_rps: f64,
    pub gpu_util: f64,
    pub slo_attainment: f64,
    /// Per-class SLO attainment (1.0 when the class is absent).
    pub slo_online: f64,
    pub slo_offline: f64,
    pub n_online: usize,
    pub n_offline: usize,
    pub mean_ttft_ms: f64,
    pub p99_ttft_ms: f64,
    pub mean_e2e_ms: f64,
    pub p99_e2e_ms: f64,
    pub mean_tbt_ms: f64,
    pub mean_waste_ratio: f64,
    pub peak_batch: usize,
    pub max_buckets: usize,
    pub bucket_overhead_ms: f64,
    /// Scheduler shards the run used (1 = the unsharded global queue).
    pub n_shards: usize,
    /// Requests migrated between shards by work-stealing.
    pub steals: u64,
    /// Per-shard arrivals routed by the placement policy.
    pub shard_routed: Vec<u64>,
    /// Whether the preemption subsystem was armed (gates the preempt
    /// JSON block so disabled runs stay byte-identical to legacy output).
    pub preempt_enabled: bool,
    /// Prefill batches aborted mid-flight by preemption.
    pub prefill_aborts: u64,
    /// Decode sequences evicted (checkpoint-and-restore) by preemption.
    pub decode_evictions: u64,
    /// GPU time burned by aborted prefill batches, ms.
    pub wasted_prefill_ms: f64,
    /// Padded prefill tokens whose FLOPs were discarded by aborts.
    pub wasted_prefill_tokens: u64,
    /// Full-context KV tokens released by decode evictions.
    pub evicted_kv_tokens: u64,
    /// Context tokens evicted sequences replayed at re-prefill.
    pub recompute_tokens: u64,
    /// Whether the TBT-aware admission subsystem was armed (gates the
    /// TBT JSON block so disabled runs stay byte-identical to legacy
    /// output; the fields below are computed either way).
    pub admission_enabled: bool,
    /// Formed batches deferred by the TBT admission gate.
    pub admission_deferrals: u64,
    /// Offline decode sequences shed by the TBT eviction trigger.
    pub tbt_evictions: u64,
    /// Full-context KV tokens released by TBT evictions.
    pub tbt_evicted_kv_tokens: u64,
    /// Context tokens TBT-evicted sequences replay at re-prefill.
    pub tbt_recompute_tokens: u64,
    /// Per-class TBT attainment: fraction of observed inter-token gaps
    /// within the per-token budget (1.0 when the class produced none).
    pub tbt_attain_online: f64,
    pub tbt_attain_offline: f64,
    /// Per-class inter-token gap percentiles, ms (0 when absent).
    pub tbt_p50_online_ms: f64,
    pub tbt_p99_online_ms: f64,
    pub tbt_p50_offline_ms: f64,
    pub tbt_p99_offline_ms: f64,
    /// Per-class inter-token gaps exceeding their budget.
    pub tbt_violations_online: u64,
    pub tbt_violations_offline: u64,
    /// Whether the prefix-cache subsystem was armed (gates the prefix
    /// JSON block so disabled runs stay byte-identical to legacy output).
    pub prefix_enabled: bool,
    /// Cache acquisitions that found resident blocks / found none.
    pub prefix_hits: u64,
    pub prefix_misses: u64,
    /// Prompt tokens served from cache (prefill compute saved).
    pub prefix_hit_tokens: u64,
    /// LRU evictions and the KV tokens they released.
    pub prefix_evictions: u64,
    pub prefix_evicted_tokens: u64,
    /// Cache-resident KV tokens at run end.
    pub prefix_resident_tokens: u64,
    /// Whether the chunked-prefill subsystem was armed (gates the chunk
    /// JSON block so disabled runs stay byte-identical to legacy output).
    pub chunk_enabled: bool,
    /// Prefill batches executed as a sequence of slices.
    pub chunk_sliced_batches: u64,
    /// Prefill slices launched (each its own kernel, each one event).
    pub chunk_slices: u64,
    /// Slice boundaries where the batch parked to let online work run.
    pub chunk_yields: u64,
    /// Decode iterations priced as hybrid (co-resident with a slice).
    pub chunk_hybrid_iters: u64,
    /// Largest per-slice token volume (batch width × slice span).
    pub chunk_max_slice_tokens: u64,
    /// Whether the run was driven by the realtime serving path (gates
    /// the realtime JSON block so replay runs stay byte-identical).
    pub realtime_enabled: bool,
    /// Requests aborted mid-flight by client disconnects.
    pub client_aborts: u64,
    /// Streamed token lines shed by full per-client stream buffers.
    pub stream_drops: u64,
    /// Abnormal-termination diagnostics from the run (scheduler stall);
    /// a summary carrying this must not be read as a clean result.
    pub error: Option<String>,
}

impl Summary {
    pub fn from_report(system: &str, r: &RunReport, slo: &SloSpec) -> Summary {
        let mut ttft = Samples::new();
        let mut e2e = Samples::new();
        let mut tbt = Samples::new();
        let mut waste = Samples::new();
        for c in &r.completions {
            ttft.push(c.ttft() as f64 / 1e3);
            e2e.push(c.e2e() as f64 / 1e3);
            tbt.push(c.tbt() / 1e3);
            waste.push(c.waste_ratio());
        }
        // One Samples per class for the gap percentiles (sorted once per
        // class, not once per percentile), and only when the admission
        // subsystem will actually emit them: the raw gap vectors hold
        // one entry per generated token, and sorting them for every
        // legacy bench row whose JSON drops the fields would be pure
        // per-row tax — paired disabled-side comparisons read the
        // RunReport (gap vectors, attainment helpers) instead.
        let gap_samples = |class: RequestClass| {
            let mut s = Samples::new();
            for &g in r.tbt_gaps_class(class) {
                s.push(g as f64 / 1e3);
            }
            s
        };
        let (mut gaps_online, mut gaps_offline) = if r.admission_enabled {
            (
                gap_samples(RequestClass::Online),
                gap_samples(RequestClass::Offline),
            )
        } else {
            (Samples::new(), Samples::new())
        };
        let pct = |s: &mut Samples, q: f64| {
            if s.is_empty() {
                0.0
            } else {
                s.percentile(q)
            }
        };
        Summary {
            system: system.to_string(),
            n_requests: r.completions.len(),
            makespan_s: r.makespan_us as f64 / 1e6,
            throughput_tps: r.throughput_tps(),
            output_tps: r.output_tps(),
            server_rps: r.server_rps(),
            gpu_util: r.gpu_util(),
            slo_attainment: r.slo_attainment(slo.ttft_us, slo.tbt_us),
            slo_online: r.slo_attainment_class(
                RequestClass::Online,
                slo.ttft_us,
                slo.tbt_us,
            ),
            slo_offline: r.slo_attainment_class(
                RequestClass::Offline,
                slo.ttft_us,
                slo.tbt_us,
            ),
            n_online: r.n_class(RequestClass::Online),
            n_offline: r.n_class(RequestClass::Offline),
            mean_ttft_ms: ttft.mean(),
            p99_ttft_ms: ttft.percentile(99.0),
            mean_e2e_ms: e2e.mean(),
            p99_e2e_ms: e2e.percentile(99.0),
            mean_tbt_ms: tbt.mean(),
            mean_waste_ratio: waste.mean(),
            peak_batch: r.peak_batch,
            max_buckets: r.max_buckets,
            bucket_overhead_ms: r.bucket_overhead_ns as f64 / 1e6,
            n_shards: r.n_shards.max(1),
            steals: r.steals,
            shard_routed: r.shard_routed.clone(),
            preempt_enabled: r.preempt_enabled,
            prefill_aborts: r.prefill_aborts,
            decode_evictions: r.decode_evictions,
            wasted_prefill_ms: r.wasted_prefill_us as f64 / 1e3,
            wasted_prefill_tokens: r.wasted_prefill_tokens,
            evicted_kv_tokens: r.evicted_kv_tokens,
            recompute_tokens: r.recompute_tokens,
            admission_enabled: r.admission_enabled,
            admission_deferrals: r.admission_deferrals,
            tbt_evictions: r.tbt_evictions,
            tbt_evicted_kv_tokens: r.tbt_evicted_kv_tokens,
            tbt_recompute_tokens: r.tbt_recompute_tokens,
            tbt_attain_online: r.tbt_attainment_class(RequestClass::Online),
            tbt_attain_offline: r.tbt_attainment_class(RequestClass::Offline),
            tbt_p50_online_ms: pct(&mut gaps_online, 50.0),
            tbt_p99_online_ms: pct(&mut gaps_online, 99.0),
            tbt_p50_offline_ms: pct(&mut gaps_offline, 50.0),
            tbt_p99_offline_ms: pct(&mut gaps_offline, 99.0),
            tbt_violations_online: r.tbt_violations_online,
            tbt_violations_offline: r.tbt_violations_offline,
            prefix_enabled: r.prefix_enabled,
            prefix_hits: r.prefix_hits,
            prefix_misses: r.prefix_misses,
            prefix_hit_tokens: r.prefix_hit_tokens,
            prefix_evictions: r.prefix_evictions,
            prefix_evicted_tokens: r.prefix_evicted_tokens,
            prefix_resident_tokens: r.prefix_resident_tokens,
            chunk_enabled: r.chunk_enabled,
            chunk_sliced_batches: r.chunk_sliced_batches,
            chunk_slices: r.chunk_slices,
            chunk_yields: r.chunk_yields,
            chunk_hybrid_iters: r.chunk_hybrid_iters,
            chunk_max_slice_tokens: r.chunk_max_slice_tokens,
            realtime_enabled: r.realtime_enabled,
            client_aborts: r.client_aborts,
            stream_drops: r.stream_drops,
            error: r.error.clone(),
        }
    }

    /// Fraction of cache acquisitions that found at least one resident
    /// block (0 when the cache saw no traffic).
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.prefix_hits + self.prefix_misses;
        if total == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / total as f64
        }
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("system", Json::from(self.system.as_str())),
            ("n_requests", Json::from(self.n_requests)),
            ("makespan_s", Json::num(self.makespan_s)),
            ("throughput_tps", Json::num(self.throughput_tps)),
            ("output_tps", Json::num(self.output_tps)),
            ("server_rps", Json::num(self.server_rps)),
            ("gpu_util", Json::num(self.gpu_util)),
            ("slo_attainment", Json::num(self.slo_attainment)),
            ("slo_online", Json::num(self.slo_online)),
            ("slo_offline", Json::num(self.slo_offline)),
            ("n_online", Json::from(self.n_online)),
            ("n_offline", Json::from(self.n_offline)),
            ("mean_ttft_ms", Json::num(self.mean_ttft_ms)),
            ("p99_ttft_ms", Json::num(self.p99_ttft_ms)),
            ("mean_e2e_ms", Json::num(self.mean_e2e_ms)),
            ("p99_e2e_ms", Json::num(self.p99_e2e_ms)),
            ("mean_tbt_ms", Json::num(self.mean_tbt_ms)),
            ("mean_waste_ratio", Json::num(self.mean_waste_ratio)),
            ("peak_batch", Json::from(self.peak_batch)),
            ("max_buckets", Json::from(self.max_buckets)),
            ("bucket_overhead_ms", Json::num(self.bucket_overhead_ms)),
        ];
        // Sharding block only when sharding is actually on: a default
        // (shards = 1) run's Summary JSON stays byte-identical to the
        // pre-sharding scheduler's output.
        if self.n_shards > 1 {
            fields.push(("n_shards", Json::from(self.n_shards)));
            fields.push(("steals", Json::from(self.steals)));
            fields.push((
                "shard_routed",
                Json::Arr(
                    self.shard_routed.iter().map(|&n| Json::from(n)).collect(),
                ),
            ));
        }
        // Preemption block only when the subsystem is armed: a default
        // (preempt disabled) run's Summary JSON stays byte-identical to
        // the pre-preemption scheduler's output.
        if self.preempt_enabled {
            fields.push(("prefill_aborts", Json::from(self.prefill_aborts)));
            fields.push(("decode_evictions", Json::from(self.decode_evictions)));
            fields.push(("wasted_prefill_ms", Json::num(self.wasted_prefill_ms)));
            fields.push((
                "wasted_prefill_tokens",
                Json::from(self.wasted_prefill_tokens),
            ));
            fields.push(("evicted_kv_tokens", Json::from(self.evicted_kv_tokens)));
            fields.push(("recompute_tokens", Json::from(self.recompute_tokens)));
        }
        // TBT-admission block only when the subsystem is armed: a default
        // (admission disabled) run's Summary JSON stays byte-identical to
        // the pre-admission scheduler's output. Gap measurement itself is
        // always on — paired comparisons read the disabled side from the
        // RunReport instead.
        if self.admission_enabled {
            fields.push((
                "admission_deferrals",
                Json::from(self.admission_deferrals),
            ));
            fields.push(("tbt_evictions", Json::from(self.tbt_evictions)));
            fields.push((
                "tbt_evicted_kv_tokens",
                Json::from(self.tbt_evicted_kv_tokens),
            ));
            fields.push((
                "tbt_recompute_tokens",
                Json::from(self.tbt_recompute_tokens),
            ));
            fields.push(("tbt_attain_online", Json::num(self.tbt_attain_online)));
            fields.push((
                "tbt_attain_offline",
                Json::num(self.tbt_attain_offline),
            ));
            fields.push(("tbt_p50_online_ms", Json::num(self.tbt_p50_online_ms)));
            fields.push(("tbt_p99_online_ms", Json::num(self.tbt_p99_online_ms)));
            fields.push((
                "tbt_p50_offline_ms",
                Json::num(self.tbt_p50_offline_ms),
            ));
            fields.push((
                "tbt_p99_offline_ms",
                Json::num(self.tbt_p99_offline_ms),
            ));
            fields.push((
                "tbt_violations_online",
                Json::from(self.tbt_violations_online),
            ));
            fields.push((
                "tbt_violations_offline",
                Json::from(self.tbt_violations_offline),
            ));
        }
        // Prefix-cache block only when the subsystem is armed: a default
        // (prefix disabled) run's Summary JSON stays byte-identical to
        // the pre-prefix scheduler's output.
        if self.prefix_enabled {
            fields.push(("prefix_hit_rate", Json::num(self.prefix_hit_rate())));
            fields.push(("prefix_hits", Json::from(self.prefix_hits)));
            fields.push(("prefix_misses", Json::from(self.prefix_misses)));
            fields.push((
                "prefix_hit_tokens",
                Json::from(self.prefix_hit_tokens),
            ));
            fields.push(("prefix_evictions", Json::from(self.prefix_evictions)));
            fields.push((
                "prefix_evicted_tokens",
                Json::from(self.prefix_evicted_tokens),
            ));
            fields.push((
                "prefix_resident_tokens",
                Json::from(self.prefix_resident_tokens),
            ));
        }
        // Chunked-prefill block only when the subsystem is armed: a
        // default (chunk disabled) run's Summary JSON stays byte-identical
        // to the pre-chunking scheduler's output.
        if self.chunk_enabled {
            fields.push((
                "chunk_sliced_batches",
                Json::from(self.chunk_sliced_batches),
            ));
            fields.push(("chunk_slices", Json::from(self.chunk_slices)));
            fields.push(("chunk_yields", Json::from(self.chunk_yields)));
            fields.push((
                "chunk_hybrid_iters",
                Json::from(self.chunk_hybrid_iters),
            ));
            fields.push((
                "chunk_max_slice_tokens",
                Json::from(self.chunk_max_slice_tokens),
            ));
        }
        // Realtime block only for runs driven by the live serving path:
        // virtual-time replay output stays byte-identical.
        if self.realtime_enabled {
            fields.push(("client_aborts", Json::from(self.client_aborts)));
            fields.push(("stream_drops", Json::from(self.stream_drops)));
        }
        if let Some(e) = &self.error {
            fields.push(("error", Json::from(e.as_str())));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::System;
    use crate::config::SystemConfig;
    use crate::workload::{Dataset, RequestClass, Trace};

    #[test]
    fn summary_fields_consistent() {
        let cfg = SystemConfig::default();
        let trace =
            Trace::batch(Dataset::Alpaca, 40, RequestClass::Offline, 4096, 1);
        let r = System::BucketServe.run_sim(&cfg, &trace);
        let s = Summary::from_report("BucketServe", &r, &cfg.slo);
        assert_eq!(s.n_requests, 40);
        assert!(s.throughput_tps > 0.0);
        assert!(s.gpu_util > 0.0 && s.gpu_util <= 1.0);
        assert!(s.p99_e2e_ms >= s.mean_e2e_ms * 0.5);
        assert!((0.0..=1.0).contains(&s.slo_attainment));
        // JSON serialization parses back.
        let j = s.to_json().to_string();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.get("n_requests").as_usize(), Some(40));
        // Per-class attainment appears in the JSON output; this trace is
        // all-offline, so online defaults to perfect and counts split.
        assert_eq!(parsed.get("n_offline").as_usize(), Some(40));
        assert_eq!(parsed.get("n_online").as_usize(), Some(0));
        assert_eq!(s.slo_online, 1.0);
        assert!((0.0..=1.0).contains(&s.slo_offline));
        assert!(!parsed.get("slo_online").is_null());
        assert!(!parsed.get("slo_offline").is_null());
    }

    #[test]
    fn sharding_block_only_when_sharded() {
        let cfg = SystemConfig::default();
        let trace =
            Trace::batch(Dataset::Alpaca, 20, RequestClass::Offline, 4096, 5);
        // Default config: single shard → no sharding keys in the JSON.
        let r = System::BucketServe.run_sim(&cfg, &trace);
        assert_eq!(r.n_shards, 1);
        let s = Summary::from_report("BucketServe", &r, &cfg.slo);
        let j = s.to_json();
        assert!(j.get("n_shards").is_null());
        assert!(j.get("steals").is_null());
        assert!(j.get("shard_routed").is_null());
        // Sharded run: the block appears and is parseable.
        let mut cfg = SystemConfig::default();
        cfg.fleet.n_decode = 2;
        cfg.sharding.shards = 0;
        cfg.sharding.steal = true;
        let r = System::BucketServe.run_sim(&cfg, &trace);
        assert_eq!(r.n_shards, 2);
        let s = Summary::from_report("BucketServe", &r, &cfg.slo);
        let parsed = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("n_shards").as_usize(), Some(2));
        assert!(!parsed.get("steals").is_null());
        let routed = parsed.get("shard_routed").as_arr().unwrap();
        assert_eq!(routed.len(), 2);
        let total: u64 = routed.iter().filter_map(|v| v.as_u64()).sum();
        assert_eq!(total, 20);
    }

    #[test]
    fn preempt_block_only_when_enabled() {
        let cfg = SystemConfig::default();
        let trace =
            Trace::batch(Dataset::Alpaca, 20, RequestClass::Offline, 4096, 9);
        // Default config: preemption off → no preempt keys in the JSON.
        let r = System::BucketServe.run_sim(&cfg, &trace);
        assert!(!r.preempt_enabled);
        let s = Summary::from_report("BucketServe", &r, &cfg.slo);
        let j = s.to_json();
        assert!(j.get("prefill_aborts").is_null());
        assert!(j.get("decode_evictions").is_null());
        assert!(j.get("wasted_prefill_tokens").is_null());
        // Enabled run: the block appears (zeros included — "armed but
        // never fired" is a result worth reporting) and parses back.
        let mut cfg = SystemConfig::default();
        cfg.preempt.enabled = true;
        let r = System::BucketServe.run_sim(&cfg, &trace);
        assert!(r.preempt_enabled);
        let s = Summary::from_report("BucketServe", &r, &cfg.slo);
        let parsed = Json::parse(&s.to_json().to_string()).unwrap();
        assert!(!parsed.get("prefill_aborts").is_null());
        assert!(!parsed.get("decode_evictions").is_null());
        assert!(!parsed.get("evicted_kv_tokens").is_null());
        assert!(!parsed.get("recompute_tokens").is_null());
        // An all-offline batch trace has no online requests: the urgency
        // trigger can never fire, so every counter is zero.
        assert_eq!(parsed.get("prefill_aborts").as_u64(), Some(0));
        assert_eq!(parsed.get("decode_evictions").as_u64(), Some(0));
    }

    #[test]
    fn tbt_block_only_when_admission_enabled() {
        let cfg = SystemConfig::default();
        let trace = Trace::mixed_classes(
            Dataset::Alpaca, 10, 8.0, Dataset::Alpaca, 10, 4096, 13,
        );
        // Default config: admission off → no TBT keys in the JSON; the
        // cheap attainment fields are still computed from the measured
        // gaps, but the per-token percentile sort is skipped (paired
        // comparisons read the disabled side off the RunReport).
        let r = System::BucketServe.run_sim(&cfg, &trace);
        assert!(!r.admission_enabled);
        assert!(
            !r.tbt_gaps_online_us.is_empty(),
            "gaps measured even when admission is off"
        );
        let s = Summary::from_report("BucketServe", &r, &cfg.slo);
        let j = s.to_json();
        assert!(j.get("tbt_attain_online").is_null());
        assert!(j.get("admission_deferrals").is_null());
        assert!(j.get("tbt_p99_online_ms").is_null());
        assert!((0.0..=1.0).contains(&s.tbt_attain_online));
        assert_eq!(s.tbt_p50_online_ms, 0.0, "percentiles gated off");
        // Enabled run: the block appears and parses back.
        let mut cfg = SystemConfig::default();
        cfg.admission.enabled = true;
        let r = System::BucketServe.run_sim(&cfg, &trace);
        assert!(r.admission_enabled);
        let s = Summary::from_report("BucketServe", &r, &cfg.slo);
        let parsed = Json::parse(&s.to_json().to_string()).unwrap();
        assert!(!parsed.get("admission_deferrals").is_null());
        assert!(!parsed.get("tbt_evictions").is_null());
        assert!(!parsed.get("tbt_evicted_kv_tokens").is_null());
        assert!(!parsed.get("tbt_recompute_tokens").is_null());
        assert!(!parsed.get("tbt_attain_online").is_null());
        assert!(!parsed.get("tbt_p99_offline_ms").is_null());
        assert!(!parsed.get("tbt_violations_online").is_null());
        assert!(s.tbt_p50_online_ms > 0.0, "percentiles computed when on");
    }

    #[test]
    fn prefix_block_only_when_enabled() {
        let cfg = SystemConfig::default();
        let trace = Trace::multi_turn(Dataset::Alpaca, 4, 4, 6.0, 4096, 17);
        // Default config: prefix cache off → no prefix keys in the JSON,
        // even on a lineage-stamped trace.
        let r = System::BucketServe.run_sim(&cfg, &trace);
        assert!(!r.prefix_enabled);
        let s = Summary::from_report("BucketServe", &r, &cfg.slo);
        let j = s.to_json();
        assert!(j.get("prefix_hit_rate").is_null());
        assert!(j.get("prefix_hits").is_null());
        assert!(j.get("prefix_resident_tokens").is_null());
        assert_eq!(s.prefix_hit_rate(), 0.0, "no traffic → rate 0");
        // Enabled run: the block appears, parses back, and the hit rate
        // is consistent with its counters.
        let mut cfg = SystemConfig::default();
        cfg.prefix.enabled = true;
        let r = System::BucketServe.run_sim(&cfg, &trace);
        assert!(r.prefix_enabled);
        let s = Summary::from_report("BucketServe", &r, &cfg.slo);
        let parsed = Json::parse(&s.to_json().to_string()).unwrap();
        assert!(!parsed.get("prefix_hit_rate").is_null());
        assert!(!parsed.get("prefix_misses").is_null());
        assert!(!parsed.get("prefix_evictions").is_null());
        assert!(!parsed.get("prefix_evicted_tokens").is_null());
        assert!(!parsed.get("prefix_resident_tokens").is_null());
        let hits = parsed.get("prefix_hits").as_u64().unwrap();
        assert!(hits > 0, "multi-turn sessions must hit the cache");
        assert!(s.prefix_hit_rate() > 0.0 && s.prefix_hit_rate() <= 1.0);
    }

    #[test]
    fn chunk_block_only_when_enabled() {
        let cfg = SystemConfig::default();
        let trace = Trace::mixed_classes(
            Dataset::Alpaca, 10, 6.0, Dataset::LongBench, 10, 4096, 19,
        );
        // Default config: chunking off → no chunk keys in the JSON, even
        // on a trace with prompts well past any slice size.
        let r = System::BucketServe.run_sim(&cfg, &trace);
        assert!(!r.chunk_enabled);
        let s = Summary::from_report("BucketServe", &r, &cfg.slo);
        let j = s.to_json();
        assert!(j.get("chunk_sliced_batches").is_null());
        assert!(j.get("chunk_slices").is_null());
        assert!(j.get("chunk_yields").is_null());
        assert!(j.get("chunk_hybrid_iters").is_null());
        assert!(j.get("chunk_max_slice_tokens").is_null());
        // Enabled run: the block appears (zeros included — "armed but
        // never sliced" is a result worth reporting) and parses back,
        // and on LongBench prompts the slicer actually fires.
        let mut cfg = SystemConfig::default();
        cfg.chunk.enabled = true;
        cfg.chunk.slice_tokens = 512;
        let r = System::BucketServe.run_sim(&cfg, &trace);
        assert!(r.chunk_enabled);
        let s = Summary::from_report("BucketServe", &r, &cfg.slo);
        let parsed = Json::parse(&s.to_json().to_string()).unwrap();
        assert!(!parsed.get("chunk_sliced_batches").is_null());
        assert!(!parsed.get("chunk_yields").is_null());
        assert!(!parsed.get("chunk_hybrid_iters").is_null());
        let sliced = parsed.get("chunk_sliced_batches").as_u64().unwrap();
        let slices = parsed.get("chunk_slices").as_u64().unwrap();
        assert!(sliced > 0, "LongBench prompts must trigger slicing");
        assert!(slices >= 2 * sliced, "a sliced batch has >= 2 slices");
        assert!(
            parsed.get("chunk_max_slice_tokens").as_u64().unwrap() <= 512,
            "slice volume bounded by chunk.slice_tokens"
        );
    }

    #[test]
    fn realtime_block_only_when_realtime() {
        let cfg = SystemConfig::default();
        let trace =
            Trace::batch(Dataset::Alpaca, 10, RequestClass::Offline, 4096, 21);
        // Virtual-time replay: no realtime keys in the JSON.
        let r = System::BucketServe.run_sim(&cfg, &trace);
        assert!(!r.realtime_enabled);
        let s = Summary::from_report("BucketServe", &r, &cfg.slo);
        let j = s.to_json();
        assert!(j.get("client_aborts").is_null());
        assert!(j.get("stream_drops").is_null());
        // A realtime-flagged report emits the block (zeros included).
        let r = RunReport {
            realtime_enabled: true,
            client_aborts: 3,
            ..Default::default()
        };
        let s = Summary::from_report("Realtime", &r, &cfg.slo);
        let parsed = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("client_aborts").as_u64(), Some(3));
        assert_eq!(parsed.get("stream_drops").as_u64(), Some(0));
    }

    #[test]
    fn per_class_summary_on_mixed_trace() {
        let cfg = SystemConfig::default();
        let trace = Trace::mixed_classes(
            Dataset::Alpaca, 15, 8.0, Dataset::Alpaca, 25, 4096, 3,
        );
        let r = System::BucketServe.run_sim(&cfg, &trace);
        let s = Summary::from_report("BucketServe", &r, &cfg.slo);
        assert_eq!(s.n_online, 15);
        assert_eq!(s.n_offline, 25);
        assert!((0.0..=1.0).contains(&s.slo_online));
        assert!((0.0..=1.0).contains(&s.slo_offline));
    }
}
