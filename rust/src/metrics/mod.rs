//! Metrics: run-report summarization shared by the CLI, examples, and the
//! figure benches.

use crate::coordinator::RunReport;
use crate::config::SloSpec;
use crate::util::json::Json;
use crate::util::stats::Samples;
use crate::workload::RequestClass;

/// A flattened summary of one run (one row of a figure bench).
#[derive(Debug, Clone)]
pub struct Summary {
    pub system: String,
    pub n_requests: usize,
    pub makespan_s: f64,
    pub throughput_tps: f64,
    pub output_tps: f64,
    pub server_rps: f64,
    pub gpu_util: f64,
    pub slo_attainment: f64,
    /// Per-class SLO attainment (1.0 when the class is absent).
    pub slo_online: f64,
    pub slo_offline: f64,
    pub n_online: usize,
    pub n_offline: usize,
    pub mean_ttft_ms: f64,
    pub p99_ttft_ms: f64,
    pub mean_e2e_ms: f64,
    pub p99_e2e_ms: f64,
    pub mean_tbt_ms: f64,
    pub mean_waste_ratio: f64,
    pub peak_batch: usize,
    pub max_buckets: usize,
    pub bucket_overhead_ms: f64,
    /// Scheduler shards the run used (1 = the unsharded global queue).
    pub n_shards: usize,
    /// Requests migrated between shards by work-stealing.
    pub steals: u64,
    /// Per-shard arrivals routed by the placement policy.
    pub shard_routed: Vec<u64>,
    /// Whether the preemption subsystem was armed (gates the preempt
    /// JSON block so disabled runs stay byte-identical to legacy output).
    pub preempt_enabled: bool,
    /// Prefill batches aborted mid-flight by preemption.
    pub prefill_aborts: u64,
    /// Decode sequences evicted (checkpoint-and-restore) by preemption.
    pub decode_evictions: u64,
    /// GPU time burned by aborted prefill batches, ms.
    pub wasted_prefill_ms: f64,
    /// Padded prefill tokens whose FLOPs were discarded by aborts.
    pub wasted_prefill_tokens: u64,
    /// Full-context KV tokens released by decode evictions.
    pub evicted_kv_tokens: u64,
    /// Context tokens evicted sequences replayed at re-prefill.
    pub recompute_tokens: u64,
    /// Abnormal-termination diagnostics from the run (scheduler stall);
    /// a summary carrying this must not be read as a clean result.
    pub error: Option<String>,
}

impl Summary {
    pub fn from_report(system: &str, r: &RunReport, slo: &SloSpec) -> Summary {
        let mut ttft = Samples::new();
        let mut e2e = Samples::new();
        let mut tbt = Samples::new();
        let mut waste = Samples::new();
        for c in &r.completions {
            ttft.push(c.ttft() as f64 / 1e3);
            e2e.push(c.e2e() as f64 / 1e3);
            tbt.push(c.tbt() / 1e3);
            waste.push(c.waste_ratio());
        }
        Summary {
            system: system.to_string(),
            n_requests: r.completions.len(),
            makespan_s: r.makespan_us as f64 / 1e6,
            throughput_tps: r.throughput_tps(),
            output_tps: r.output_tps(),
            server_rps: r.server_rps(),
            gpu_util: r.gpu_util(),
            slo_attainment: r.slo_attainment(slo.ttft_us, slo.tbt_us),
            slo_online: r.slo_attainment_class(
                RequestClass::Online,
                slo.ttft_us,
                slo.tbt_us,
            ),
            slo_offline: r.slo_attainment_class(
                RequestClass::Offline,
                slo.ttft_us,
                slo.tbt_us,
            ),
            n_online: r.n_class(RequestClass::Online),
            n_offline: r.n_class(RequestClass::Offline),
            mean_ttft_ms: ttft.mean(),
            p99_ttft_ms: ttft.percentile(99.0),
            mean_e2e_ms: e2e.mean(),
            p99_e2e_ms: e2e.percentile(99.0),
            mean_tbt_ms: tbt.mean(),
            mean_waste_ratio: waste.mean(),
            peak_batch: r.peak_batch,
            max_buckets: r.max_buckets,
            bucket_overhead_ms: r.bucket_overhead_ns as f64 / 1e6,
            n_shards: r.n_shards.max(1),
            steals: r.steals,
            shard_routed: r.shard_routed.clone(),
            preempt_enabled: r.preempt_enabled,
            prefill_aborts: r.prefill_aborts,
            decode_evictions: r.decode_evictions,
            wasted_prefill_ms: r.wasted_prefill_us as f64 / 1e3,
            wasted_prefill_tokens: r.wasted_prefill_tokens,
            evicted_kv_tokens: r.evicted_kv_tokens,
            recompute_tokens: r.recompute_tokens,
            error: r.error.clone(),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("system", Json::from(self.system.as_str())),
            ("n_requests", Json::from(self.n_requests)),
            ("makespan_s", Json::num(self.makespan_s)),
            ("throughput_tps", Json::num(self.throughput_tps)),
            ("output_tps", Json::num(self.output_tps)),
            ("server_rps", Json::num(self.server_rps)),
            ("gpu_util", Json::num(self.gpu_util)),
            ("slo_attainment", Json::num(self.slo_attainment)),
            ("slo_online", Json::num(self.slo_online)),
            ("slo_offline", Json::num(self.slo_offline)),
            ("n_online", Json::from(self.n_online)),
            ("n_offline", Json::from(self.n_offline)),
            ("mean_ttft_ms", Json::num(self.mean_ttft_ms)),
            ("p99_ttft_ms", Json::num(self.p99_ttft_ms)),
            ("mean_e2e_ms", Json::num(self.mean_e2e_ms)),
            ("p99_e2e_ms", Json::num(self.p99_e2e_ms)),
            ("mean_tbt_ms", Json::num(self.mean_tbt_ms)),
            ("mean_waste_ratio", Json::num(self.mean_waste_ratio)),
            ("peak_batch", Json::from(self.peak_batch)),
            ("max_buckets", Json::from(self.max_buckets)),
            ("bucket_overhead_ms", Json::num(self.bucket_overhead_ms)),
        ];
        // Sharding block only when sharding is actually on: a default
        // (shards = 1) run's Summary JSON stays byte-identical to the
        // pre-sharding scheduler's output.
        if self.n_shards > 1 {
            fields.push(("n_shards", Json::from(self.n_shards)));
            fields.push(("steals", Json::from(self.steals)));
            fields.push((
                "shard_routed",
                Json::Arr(
                    self.shard_routed.iter().map(|&n| Json::from(n)).collect(),
                ),
            ));
        }
        // Preemption block only when the subsystem is armed: a default
        // (preempt disabled) run's Summary JSON stays byte-identical to
        // the pre-preemption scheduler's output.
        if self.preempt_enabled {
            fields.push(("prefill_aborts", Json::from(self.prefill_aborts)));
            fields.push(("decode_evictions", Json::from(self.decode_evictions)));
            fields.push(("wasted_prefill_ms", Json::num(self.wasted_prefill_ms)));
            fields.push((
                "wasted_prefill_tokens",
                Json::from(self.wasted_prefill_tokens),
            ));
            fields.push(("evicted_kv_tokens", Json::from(self.evicted_kv_tokens)));
            fields.push(("recompute_tokens", Json::from(self.recompute_tokens)));
        }
        if let Some(e) = &self.error {
            fields.push(("error", Json::from(e.as_str())));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::System;
    use crate::config::SystemConfig;
    use crate::workload::{Dataset, RequestClass, Trace};

    #[test]
    fn summary_fields_consistent() {
        let cfg = SystemConfig::default();
        let trace =
            Trace::batch(Dataset::Alpaca, 40, RequestClass::Offline, 4096, 1);
        let r = System::BucketServe.run_sim(&cfg, &trace);
        let s = Summary::from_report("BucketServe", &r, &cfg.slo);
        assert_eq!(s.n_requests, 40);
        assert!(s.throughput_tps > 0.0);
        assert!(s.gpu_util > 0.0 && s.gpu_util <= 1.0);
        assert!(s.p99_e2e_ms >= s.mean_e2e_ms * 0.5);
        assert!((0.0..=1.0).contains(&s.slo_attainment));
        // JSON serialization parses back.
        let j = s.to_json().to_string();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.get("n_requests").as_usize(), Some(40));
        // Per-class attainment appears in the JSON output; this trace is
        // all-offline, so online defaults to perfect and counts split.
        assert_eq!(parsed.get("n_offline").as_usize(), Some(40));
        assert_eq!(parsed.get("n_online").as_usize(), Some(0));
        assert_eq!(s.slo_online, 1.0);
        assert!((0.0..=1.0).contains(&s.slo_offline));
        assert!(!parsed.get("slo_online").is_null());
        assert!(!parsed.get("slo_offline").is_null());
    }

    #[test]
    fn sharding_block_only_when_sharded() {
        let cfg = SystemConfig::default();
        let trace =
            Trace::batch(Dataset::Alpaca, 20, RequestClass::Offline, 4096, 5);
        // Default config: single shard → no sharding keys in the JSON.
        let r = System::BucketServe.run_sim(&cfg, &trace);
        assert_eq!(r.n_shards, 1);
        let s = Summary::from_report("BucketServe", &r, &cfg.slo);
        let j = s.to_json();
        assert!(j.get("n_shards").is_null());
        assert!(j.get("steals").is_null());
        assert!(j.get("shard_routed").is_null());
        // Sharded run: the block appears and is parseable.
        let mut cfg = SystemConfig::default();
        cfg.fleet.n_decode = 2;
        cfg.sharding.shards = 0;
        cfg.sharding.steal = true;
        let r = System::BucketServe.run_sim(&cfg, &trace);
        assert_eq!(r.n_shards, 2);
        let s = Summary::from_report("BucketServe", &r, &cfg.slo);
        let parsed = Json::parse(&s.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("n_shards").as_usize(), Some(2));
        assert!(!parsed.get("steals").is_null());
        let routed = parsed.get("shard_routed").as_arr().unwrap();
        assert_eq!(routed.len(), 2);
        let total: u64 = routed.iter().filter_map(|v| v.as_u64()).sum();
        assert_eq!(total, 20);
    }

    #[test]
    fn preempt_block_only_when_enabled() {
        let cfg = SystemConfig::default();
        let trace =
            Trace::batch(Dataset::Alpaca, 20, RequestClass::Offline, 4096, 9);
        // Default config: preemption off → no preempt keys in the JSON.
        let r = System::BucketServe.run_sim(&cfg, &trace);
        assert!(!r.preempt_enabled);
        let s = Summary::from_report("BucketServe", &r, &cfg.slo);
        let j = s.to_json();
        assert!(j.get("prefill_aborts").is_null());
        assert!(j.get("decode_evictions").is_null());
        assert!(j.get("wasted_prefill_tokens").is_null());
        // Enabled run: the block appears (zeros included — "armed but
        // never fired" is a result worth reporting) and parses back.
        let mut cfg = SystemConfig::default();
        cfg.preempt.enabled = true;
        let r = System::BucketServe.run_sim(&cfg, &trace);
        assert!(r.preempt_enabled);
        let s = Summary::from_report("BucketServe", &r, &cfg.slo);
        let parsed = Json::parse(&s.to_json().to_string()).unwrap();
        assert!(!parsed.get("prefill_aborts").is_null());
        assert!(!parsed.get("decode_evictions").is_null());
        assert!(!parsed.get("evicted_kv_tokens").is_null());
        assert!(!parsed.get("recompute_tokens").is_null());
        // An all-offline batch trace has no online requests: the urgency
        // trigger can never fire, so every counter is zero.
        assert_eq!(parsed.get("prefill_aborts").as_u64(), Some(0));
        assert_eq!(parsed.get("decode_evictions").as_u64(), Some(0));
    }

    #[test]
    fn per_class_summary_on_mixed_trace() {
        let cfg = SystemConfig::default();
        let trace = Trace::mixed_classes(
            Dataset::Alpaca, 15, 8.0, Dataset::Alpaca, 25, 4096, 3,
        );
        let r = System::BucketServe.run_sim(&cfg, &trace);
        let s = Summary::from_report("BucketServe", &r, &cfg.slo);
        assert_eq!(s.n_online, 15);
        assert_eq!(s.n_offline, 25);
        assert!((0.0..=1.0).contains(&s.slo_online));
        assert!((0.0..=1.0).contains(&s.slo_offline));
    }
}
