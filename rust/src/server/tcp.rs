//! Newline-delimited-JSON TCP front end.
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! → {"op":"ping"}                                   ← {"ok":true,"op":"pong"}
//! → {"op":"req","input_len":N,"output_len":M,
//!    "class":"online"|"offline"}                    ← {"ok":true,"id":K}
//! → {"op":"run"}                                    ← one {"id":..,"ttft_ms":..,
//!                                                       "e2e_ms":..} per
//!                                                      completion, then
//!                                                      {"ok":true,"summary":{...}}
//! → {"op":"quit"}                                   ← {"ok":true} and close
//! ```
//!
//! The server replays accumulated arrivals through the configured system
//! (a replay gateway: requests are stamped on receipt, scheduled exactly
//! as the live arrival sequence). For wall-clock serving — tokens
//! streamed as they are produced, `submit`/`health`/`loads` ops,
//! disconnect-abort — see [`super::realtime::RealtimeServer`]
//! (`bucketserve serve --realtime`).

use super::gateway::Gateway;
use crate::baselines::System;
use crate::cluster::sim::SimEngine;
use crate::config::SystemConfig;
use crate::metrics::Summary;
use crate::util::json::Json;
use crate::workload::RequestClass;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

/// The TCP server.
pub struct Server {
    cfg: SystemConfig,
    system: System,
}

impl Server {
    pub fn new(cfg: SystemConfig, system: System) -> Server {
        Server { cfg, system }
    }

    /// Bind and serve until a client sends `{"op":"shutdown"}`.
    /// Returns the bound address via the callback before blocking.
    pub fn serve(&self, addr: &str, mut on_bound: impl FnMut(String)) -> anyhow::Result<()> {
        let listener = TcpListener::bind(addr)?;
        on_bound(listener.local_addr()?.to_string());
        for stream in listener.incoming() {
            let stream = stream?;
            match self.handle(stream) {
                Ok(shutdown) => {
                    if shutdown {
                        break;
                    }
                }
                Err(e) => crate::log_warn!("client error: {e}"),
            }
        }
        Ok(())
    }

    /// Handle one connection; Ok(true) = shutdown requested.
    fn handle(&self, stream: TcpStream) -> anyhow::Result<bool> {
        let mut gateway = Gateway::new(self.cfg.clone(), self.system);
        let reader = BufReader::new(stream.try_clone()?);
        let mut writer = stream;
        for line in reader.lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let msg = match Json::parse(&line) {
                Ok(j) => j,
                Err(e) => {
                    send(&mut writer, &err_json(&format!("bad json: {e}")))?;
                    continue;
                }
            };
            match msg.get("op").as_str() {
                Some("ping") => send(
                    &mut writer,
                    &Json::obj(vec![
                        ("ok", Json::from(true)),
                        ("op", Json::from("pong")),
                        ("system", Json::from(self.system.name())),
                    ]),
                )?,
                Some("req") => {
                    let class = match msg.get("class").as_str() {
                        Some("offline") => RequestClass::Offline,
                        _ => RequestClass::Online,
                    };
                    let input = msg.get("input_len").as_u64().unwrap_or(0) as u32;
                    let output = msg.get("output_len").as_u64().unwrap_or(0) as u32;
                    let arrival = msg.get("arrival").as_u64();
                    match gateway.submit(class, input, output, arrival) {
                        Some(id) => send(
                            &mut writer,
                            &Json::obj(vec![
                                ("ok", Json::from(true)),
                                ("id", Json::from(id)),
                            ]),
                        )?,
                        None => {
                            send(&mut writer, &err_json("rejected"))?
                        }
                    }
                }
                Some("run") => {
                    let mut engine = SimEngine::new(&self.cfg);
                    let report = gateway.run(&mut engine);
                    for c in &report.completions {
                        send(
                            &mut writer,
                            &Json::obj(vec![
                                ("id", Json::from(c.id)),
                                ("ttft_ms", Json::num(c.ttft() as f64 / 1e3)),
                                ("e2e_ms", Json::num(c.e2e() as f64 / 1e3)),
                                ("output_len", Json::from(c.output_len as u64)),
                            ]),
                        )?;
                    }
                    let summary =
                        Summary::from_report(self.system.name(), &report, &self.cfg.slo);
                    send(
                        &mut writer,
                        &Json::obj(vec![
                            ("ok", Json::from(true)),
                            ("summary", summary.to_json()),
                        ]),
                    )?;
                }
                Some("quit") => {
                    send(&mut writer, &Json::obj(vec![("ok", Json::from(true))]))?;
                    return Ok(false);
                }
                Some("shutdown") => {
                    send(&mut writer, &Json::obj(vec![("ok", Json::from(true))]))?;
                    return Ok(true);
                }
                other => send(
                    &mut writer,
                    &err_json(&format!("unknown op {other:?}")),
                )?,
            }
        }
        Ok(false)
    }
}

fn err_json(msg: &str) -> Json {
    Json::obj(vec![("ok", Json::from(false)), ("error", Json::from(msg))])
}

fn send(w: &mut TcpStream, j: &Json) -> anyhow::Result<()> {
    writeln!(w, "{j}")?;
    Ok(())
}

/// A line-protocol client (used by tests and the CLI's `client` command).
pub struct TcpClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl TcpClient {
    pub fn connect(addr: &str) -> anyhow::Result<TcpClient> {
        let stream = TcpStream::connect(addr)?;
        Ok(TcpClient {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    /// Send one message, read one reply.
    pub fn call(&mut self, msg: &Json) -> anyhow::Result<Json> {
        writeln!(self.writer, "{msg}")?;
        self.read_line()
    }

    /// Read a single reply line.
    pub fn read_line(&mut self) -> anyhow::Result<Json> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        anyhow::ensure!(n > 0, "server closed connection");
        Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("reply: {e}"))
    }

    pub fn send_only(&mut self, msg: &Json) -> anyhow::Result<()> {
        writeln!(self.writer, "{msg}")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spawn_server(system: System) -> (String, std::thread::JoinHandle<()>) {
        let cfg = SystemConfig::default();
        let (tx, rx) = std::sync::mpsc::channel();
        let handle = std::thread::spawn(move || {
            let server = Server::new(cfg, system);
            server
                .serve("127.0.0.1:0", move |addr| {
                    let _ = tx.send(addr);
                })
                .unwrap();
        });
        let addr = rx.recv().unwrap();
        (addr, handle)
    }

    #[test]
    fn ping_and_request_round_trip() {
        let (addr, handle) = spawn_server(System::BucketServe);
        let mut c = TcpClient::connect(&addr).unwrap();

        let pong = c
            .call(&Json::obj(vec![("op", Json::from("ping"))]))
            .unwrap();
        assert_eq!(pong.get("ok").as_bool(), Some(true));
        assert_eq!(pong.get("op").as_str(), Some("pong"));

        for i in 0..5u64 {
            let reply = c
                .call(&Json::obj(vec![
                    ("op", Json::from("req")),
                    ("input_len", Json::from(100 + i)),
                    ("output_len", Json::from(10u64)),
                    ("class", Json::from("online")),
                    ("arrival", Json::from(i * 1000)),
                ]))
                .unwrap();
            assert_eq!(reply.get("ok").as_bool(), Some(true), "{reply}");
        }

        // Run: 5 completion lines + summary.
        c.send_only(&Json::obj(vec![("op", Json::from("run"))])).unwrap();
        let mut lines = Vec::new();
        loop {
            let j = c.read_line().unwrap();
            let done = !j.get("summary").is_null();
            lines.push(j);
            if done {
                break;
            }
        }
        assert_eq!(lines.len(), 6);
        let summary = lines.last().unwrap().get("summary");
        assert_eq!(summary.get("n_requests").as_usize(), Some(5));

        // Shutdown.
        let bye = c
            .call(&Json::obj(vec![("op", Json::from("shutdown"))]))
            .unwrap();
        assert_eq!(bye.get("ok").as_bool(), Some(true));
        handle.join().unwrap();
    }

    #[test]
    fn rejects_bad_input() {
        let (addr, handle) = spawn_server(System::DistServe);
        let mut c = TcpClient::connect(&addr).unwrap();
        let reply = c
            .call(&Json::obj(vec![
                ("op", Json::from("req")),
                ("input_len", Json::from(0u64)),
                ("output_len", Json::from(1u64)),
            ]))
            .unwrap();
        assert_eq!(reply.get("ok").as_bool(), Some(false));
        let bad = c.call(&Json::str("not an op")).unwrap();
        assert_eq!(bad.get("ok").as_bool(), Some(false));
        c.call(&Json::obj(vec![("op", Json::from("shutdown"))])).unwrap();
        handle.join().unwrap();
    }
}
