//! In-process gateway: request admission, class routing, and run dispatch.
//!
//! The gateway is the "application layer → middleware" boundary of the
//! paper's three-tier architecture (Fig. 4): it stamps arrivals, routes by
//! task class, and hands the accumulated trace to a serving system. Online
//! and offline requests keep their class so the scheduler can apply
//! SLO-oriented vs. throughput-oriented policies.

use crate::baselines::System;
use crate::cluster::Engine;
use crate::config::SystemConfig;
use crate::coordinator::RunReport;
use crate::util::clock::{Clock, WallClock};
use crate::workload::{Request, RequestClass, Trace};
use crate::Micros;

/// Collects requests and dispatches runs.
pub struct Gateway {
    cfg: SystemConfig,
    system: System,
    clock: Box<dyn Clock>,
    pending: Vec<Request>,
    next_id: u64,
    pub accepted: u64,
    pub rejected: u64,
}

impl Gateway {
    pub fn new(cfg: SystemConfig, system: System) -> Gateway {
        Gateway::with_clock(cfg, system, Box::new(WallClock::new()))
    }

    /// Gateway over an injected clock — lets tests stamp arrivals
    /// deterministically without sleeping.
    pub fn with_clock(
        cfg: SystemConfig,
        system: System,
        clock: Box<dyn Clock>,
    ) -> Gateway {
        Gateway {
            cfg,
            system,
            clock,
            pending: Vec::new(),
            next_id: 0,
            accepted: 0,
            rejected: 0,
        }
    }

    /// Arrival timestamp on the gateway's clock (wall time since
    /// construction unless a test injected a manual clock).
    pub fn now(&self) -> Micros {
        self.clock.now_us()
    }

    /// Admit one request; returns its assigned id, or None if rejected
    /// (zero-length prompt or generation budget, or `input_len +
    /// output_len` past the model's context limit — the full sequence
    /// must fit, not just the prompt).
    pub fn submit(
        &mut self,
        class: RequestClass,
        input_len: u32,
        output_len: u32,
        arrival: Option<Micros>,
    ) -> Option<u64> {
        if input_len == 0 || output_len == 0 {
            self.rejected += 1;
            return None;
        }
        let max = self.cfg.model.max_seq;
        if input_len as u64 + output_len as u64 > max as u64 {
            self.rejected += 1;
            return None;
        }
        let id = self.next_id;
        self.next_id += 1;
        self.accepted += 1;
        self.pending.push(Request::new(
            id,
            class,
            input_len,
            output_len,
            arrival.unwrap_or_else(|| self.now()),
        ));
        Some(id)
    }

    /// Number of requests waiting for the next run.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Drain the accumulated requests as a replayable trace.
    pub fn drain_trace(&mut self) -> Trace {
        let mut requests = std::mem::take(&mut self.pending);
        requests.sort_by_key(|r| r.arrival);
        Trace { requests }
    }

    /// Run the configured system over the accumulated requests.
    pub fn run(&mut self, engine: &mut dyn Engine) -> RunReport {
        let trace = self.drain_trace();
        match self.system {
            System::BucketServe => crate::coordinator::BucketServe::new(
                self.cfg.clone(),
            )
            .run(&trace, engine),
            System::DistServe => {
                crate::baselines::DistServe::new(self.cfg.clone())
                    .run(&trace, engine)
            }
            System::Uellm => {
                crate::baselines::Uellm::new(self.cfg.clone()).run(&trace, engine)
            }
        }
    }

    pub fn system(&self) -> System {
        self.system
    }

    pub fn config(&self) -> &SystemConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::sim::SimEngine;

    #[test]
    fn submit_assigns_monotonic_ids() {
        let mut g = Gateway::new(SystemConfig::default(), System::BucketServe);
        let a = g.submit(RequestClass::Online, 100, 10, Some(0)).unwrap();
        let b = g.submit(RequestClass::Online, 200, 10, Some(1)).unwrap();
        assert!(b > a);
        assert_eq!(g.pending(), 2);
        assert_eq!(g.accepted, 2);
    }

    #[test]
    fn rejects_invalid_requests() {
        let mut g = Gateway::new(SystemConfig::default(), System::BucketServe);
        assert!(g.submit(RequestClass::Online, 0, 10, Some(0)).is_none());
        assert!(g.submit(RequestClass::Online, 10, 0, Some(0)).is_none());
        assert!(g
            .submit(RequestClass::Online, 100_000, 10, Some(0))
            .is_none());
        assert_eq!(g.rejected, 3);
        assert_eq!(g.pending(), 0);
    }

    #[test]
    fn run_serves_pending_requests() {
        let cfg = SystemConfig::default();
        let mut g = Gateway::new(cfg.clone(), System::BucketServe);
        for i in 0..10 {
            g.submit(RequestClass::Online, 100 + i, 20, Some(i as u64 * 1000))
                .unwrap();
        }
        let mut engine = SimEngine::new(&cfg);
        let report = g.run(&mut engine);
        assert_eq!(report.completions.len(), 10);
        assert_eq!(g.pending(), 0);
    }

    #[test]
    fn rejects_combined_length_past_context_limit() {
        let cfg = SystemConfig::default();
        let max = cfg.model.max_seq;
        let mut g = Gateway::new(cfg, System::BucketServe);
        // Exactly at the limit: admitted, output budget untouched.
        let id = g.submit(RequestClass::Online, max - 10, 10, Some(0));
        assert!(id.is_some());
        let t = g.drain_trace();
        assert_eq!(t.requests[0].output_len, 10);
        // One token over the limit: rejected.
        assert!(g
            .submit(RequestClass::Online, max - 10, 11, Some(0))
            .is_none());
        // Prompt alone at the limit leaves no room to generate.
        assert!(g.submit(RequestClass::Online, max, 1, Some(0)).is_none());
        assert_eq!(g.rejected, 2);
    }

    #[test]
    fn manual_clock_stamps_arrivals_deterministically() {
        use crate::util::clock::ManualClock;
        let clock = ManualClock::new();
        let mut g = Gateway::with_clock(
            SystemConfig::default(),
            System::BucketServe,
            Box::new(clock.clone()),
        );
        clock.set(5_000);
        g.submit(RequestClass::Online, 100, 10, None).unwrap();
        clock.advance(2_500);
        g.submit(RequestClass::Online, 100, 10, None).unwrap();
        let t = g.drain_trace();
        assert_eq!(t.requests[0].arrival, 5_000);
        assert_eq!(t.requests[1].arrival, 7_500);
    }

    #[test]
    fn trace_sorted_by_arrival() {
        let mut g = Gateway::new(SystemConfig::default(), System::DistServe);
        g.submit(RequestClass::Offline, 10, 5, Some(500)).unwrap();
        g.submit(RequestClass::Offline, 10, 5, Some(100)).unwrap();
        let t = g.drain_trace();
        assert!(t.requests[0].arrival <= t.requests[1].arrival);
    }
}
