//! Realtime TCP front end: wall-clock serving with streamed delivery.
//!
//! Unlike [`super::tcp::Server`] — which *accumulates* requests and
//! replays them as a trace on `{"op":"run"}` — this server feeds every
//! arrival straight into a continuously running
//! [`PdScheduler::run_realtime`] loop over a [`RealtimeEngine`], and
//! streams tokens back as they are produced.
//!
//! Protocol (one JSON object per line; one in-flight stream per
//! connection — open more connections for concurrency):
//!
//! ```text
//! → {"op":"ping"}                         ← {"ok":true,"op":"pong","realtime":true}
//! → {"op":"submit","input_len":N,
//!    "output_len":M,
//!    "class":"online"|"offline"}          ← {"ok":true,"id":K}, then one
//!                                            {"id":K,"seq":n,"at_us":t} line per
//!                                            token, then {"id":K,"done":true,
//!                                            "output_len":..,"ttft_us":..,
//!                                            "e2e_us":..} (or {"id":K,
//!                                            "aborted":true})
//! → {"op":"health"}                       ← {"ok":true,"in_flight":..,"queued":..,
//!                                            "completions":..,"client_aborts":..}
//! → {"op":"loads"}                        ← {"ok":true, kv/queue occupancy,
//!                                            per-shard + per-instance arrays,
//!                                            running online attainment}
//! → {"op":"quit"}                         ← {"ok":true} and close
//! → {"op":"shutdown"}                     ← {"ok":true}; drain and stop serving
//! ```
//!
//! Lifecycle: a connection that dies mid-stream has its sink marked
//! disconnected and an abort command sent on its behalf; the scheduler
//! releases the request's KV/prefix reservations at the next touchpoint
//! and charges `client_aborts` (see [`crate::coordinator::live`]).
//!
//! [`PdScheduler::run_realtime`]: crate::coordinator::PdScheduler::run_realtime
//! [`RealtimeEngine`]: crate::cluster::realtime::RealtimeEngine

use super::gateway::Gateway;
use crate::baselines::System;
use crate::cluster::realtime::RealtimeEngine;
use crate::config::SystemConfig;
use crate::coordinator::scheduler::BucketPlanner;
use crate::coordinator::{LiveCmd, PdScheduler, StreamMsg, StreamSink};
use crate::metrics::Summary;
use crate::util::json::Json;
use crate::workload::RequestClass;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

/// How long an introspection op waits for the serving loop's reply.
const REPLY_TIMEOUT: Duration = Duration::from_secs(5);
/// Sink poll cadence while pumping a stream to the socket.
const PUMP_TICK: Duration = Duration::from_millis(100);

/// The realtime TCP server: accept loop + scheduler thread.
pub struct RealtimeServer {
    cfg: SystemConfig,
}

impl RealtimeServer {
    pub fn new(cfg: SystemConfig) -> RealtimeServer {
        RealtimeServer { cfg }
    }

    /// Bind, run the serving loop, and accept clients until one sends
    /// `{"op":"shutdown"}`. Returns the drained run's summary.
    pub fn serve(
        &self,
        addr: &str,
        mut on_bound: impl FnMut(String),
    ) -> anyhow::Result<Summary> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        on_bound(local.to_string());

        let (tx, rx) = mpsc::channel::<LiveCmd>();
        let sched_cfg = self.cfg.clone();
        let sched = thread::spawn(move || {
            let mut engine = RealtimeEngine::new(&sched_cfg);
            let mut sched = PdScheduler::new(&sched_cfg, || {
                Box::new(BucketPlanner::new(&sched_cfg))
            });
            sched.run_realtime(&mut engine, rx)
        });

        // Validation + id assignment reuse the gateway (one per server:
        // ids stay unique across connections).
        let gateway = Arc::new(Mutex::new(Gateway::new(
            self.cfg.clone(),
            System::BucketServe,
        )));
        let stop = Arc::new(AtomicBool::new(false));
        let stream_buf = self.cfg.realtime.stream_buf.max(1) as usize;
        let mut conns = Vec::new();
        for stream in listener.incoming() {
            if stop.load(Ordering::SeqCst) {
                break;
            }
            let stream = stream?;
            let tx = tx.clone();
            let gateway = Arc::clone(&gateway);
            let stop = Arc::clone(&stop);
            conns.push(thread::spawn(move || {
                if let Err(e) =
                    handle_conn(stream, &tx, &gateway, stream_buf, &stop, local)
                {
                    crate::log_warn!("realtime client error: {e}");
                }
            }));
        }
        for c in conns {
            let _ = c.join();
        }
        // Last sender gone: even without an explicit shutdown op the
        // serving loop drains and exits.
        drop(tx);
        let report = sched
            .join()
            .map_err(|_| anyhow::anyhow!("serving loop panicked"))?;
        Ok(Summary::from_report("bucketserve-realtime", &report, &self.cfg.slo))
    }
}

/// Handle one connection until quit/shutdown/EOF.
fn handle_conn(
    stream: TcpStream,
    tx: &Sender<LiveCmd>,
    gateway: &Mutex<Gateway>,
    stream_buf: usize,
    stop: &AtomicBool,
    local: SocketAddr,
) -> anyhow::Result<()> {
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let msg = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                send(&mut writer, &err_json(&format!("bad json: {e}")))?;
                continue;
            }
        };
        match msg.get("op").as_str() {
            Some("ping") => send(
                &mut writer,
                &Json::obj(vec![
                    ("ok", Json::from(true)),
                    ("op", Json::from("pong")),
                    ("realtime", Json::from(true)),
                ]),
            )?,
            Some("submit") => {
                let class = match msg.get("class").as_str() {
                    Some("offline") => RequestClass::Offline,
                    _ => RequestClass::Online,
                };
                let input = msg.get("input_len").as_u64().unwrap_or(0) as u32;
                let output = msg.get("output_len").as_u64().unwrap_or(0) as u32;
                // Arrival 0 is a placeholder: the serving loop re-stamps
                // it on its own wall clock at ingest.
                let req = {
                    let mut g = gateway.lock().unwrap();
                    match g.submit(class, input, output, Some(0)) {
                        Some(_) => g.drain_trace().requests.pop(),
                        None => None,
                    }
                };
                let Some(req) = req else {
                    send(&mut writer, &err_json("rejected"))?;
                    continue;
                };
                let id = req.id;
                let sink = StreamSink::new(stream_buf);
                let cmd = LiveCmd::Submit { req, sink: sink.clone() };
                if tx.send(cmd).is_err() {
                    send(&mut writer, &err_json("serving loop stopped"))?;
                    continue;
                }
                send(
                    &mut writer,
                    &Json::obj(vec![
                        ("ok", Json::from(true)),
                        ("id", Json::from(id)),
                    ]),
                )?;
                if !pump_stream(&mut writer, &sink)? {
                    // Socket died mid-stream: convert to a client abort
                    // and stop serving this connection.
                    sink.mark_disconnected();
                    let _ = tx.send(LiveCmd::Abort(id));
                    return Ok(());
                }
            }
            Some("health") => {
                let (rtx, rrx) = mpsc::channel();
                if tx.send(LiveCmd::Health { reply: rtx }).is_err() {
                    send(&mut writer, &err_json("serving loop stopped"))?;
                    continue;
                }
                match rrx.recv_timeout(REPLY_TIMEOUT) {
                    Ok(h) => send(
                        &mut writer,
                        &Json::obj(vec![
                            ("ok", Json::from(true)),
                            ("in_flight", Json::from(h.in_flight)),
                            ("queued", Json::from(h.queued)),
                            ("completions", Json::from(h.completions)),
                            ("client_aborts", Json::from(h.client_aborts)),
                        ]),
                    )?,
                    Err(_) => send(&mut writer, &err_json("health timeout"))?,
                }
            }
            Some("loads") => {
                let (rtx, rrx) = mpsc::channel();
                if tx.send(LiveCmd::Loads { reply: rtx }).is_err() {
                    send(&mut writer, &err_json("serving loop stopped"))?;
                    continue;
                }
                match rrx.recv_timeout(REPLY_TIMEOUT) {
                    Ok(l) => send(&mut writer, &loads_json(&l))?,
                    Err(_) => send(&mut writer, &err_json("loads timeout"))?,
                }
            }
            Some("quit") => {
                send(&mut writer, &Json::obj(vec![("ok", Json::from(true))]))?;
                return Ok(());
            }
            Some("shutdown") => {
                let _ = tx.send(LiveCmd::Shutdown);
                send(&mut writer, &Json::obj(vec![("ok", Json::from(true))]))?;
                stop.store(true, Ordering::SeqCst);
                // Wake the acceptor so it observes the stop flag.
                let _ = TcpStream::connect(local);
                return Ok(());
            }
            other => {
                send(&mut writer, &err_json(&format!("unknown op {other:?}")))?
            }
        }
    }
    Ok(())
}

/// Forward one request's stream to the socket until its final line.
/// Ok(true) = stream finished; Ok(false) = the socket died mid-stream.
fn pump_stream(
    writer: &mut TcpStream,
    sink: &StreamSink,
) -> anyhow::Result<bool> {
    loop {
        match sink.recv_timeout(PUMP_TICK) {
            Some(msg) => {
                let (line, last) = stream_line(&msg);
                if send(writer, &line).is_err() {
                    return Ok(false);
                }
                if last {
                    return Ok(true);
                }
            }
            None => {
                if sink.finished() {
                    return Ok(true);
                }
            }
        }
    }
}

/// NDJSON encoding of one stream line; `true` when it ends the stream.
fn stream_line(msg: &StreamMsg) -> (Json, bool) {
    match msg {
        StreamMsg::Token { id, seq, at_us } => (
            Json::obj(vec![
                ("id", Json::from(*id)),
                ("seq", Json::from(*seq as u64)),
                ("at_us", Json::from(*at_us)),
            ]),
            false,
        ),
        StreamMsg::Done { completion: c } => (
            Json::obj(vec![
                ("id", Json::from(c.id)),
                ("done", Json::from(true)),
                ("output_len", Json::from(c.output_len as u64)),
                ("ttft_us", Json::from(c.ttft())),
                ("e2e_us", Json::from(c.e2e())),
            ]),
            true,
        ),
        StreamMsg::Aborted { id } => (
            Json::obj(vec![
                ("id", Json::from(*id)),
                ("aborted", Json::from(true)),
            ]),
            true,
        ),
    }
}

fn loads_json(l: &crate::coordinator::LoadsInfo) -> Json {
    let shards = l
        .view
        .shards
        .iter()
        .enumerate()
        .map(|(i, s)| {
            Json::obj(vec![
                ("shard", Json::from(i)),
                ("queue_depth", Json::from(s.queue_depth)),
                ("kv_tokens_in_use", Json::from(s.kv_tokens_in_use)),
                ("kv_token_budget", Json::from(s.kv_token_budget)),
            ])
        })
        .collect();
    let instances = l
        .instances
        .iter()
        .map(|i| {
            Json::obj(vec![
                ("instance", Json::from(i.instance)),
                ("active", Json::from(i.active)),
                ("pending", Json::from(i.pending)),
                ("reserved_tokens", Json::from(i.reserved_tokens)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("ok", Json::from(true)),
        ("kv_tokens_in_use", Json::from(l.view.kv_tokens_in_use)),
        ("kv_token_budget", Json::from(l.view.kv_token_budget)),
        ("prefill_queue", Json::from(l.view.prefill_queue)),
        ("decode_active", Json::from(l.view.decode_active)),
        ("arrival_rps", Json::num(l.view.arrival_rps)),
        ("ttft_attainment_online", Json::num(l.ttft_attainment_online)),
        ("tbt_attainment_online", Json::num(l.tbt_attainment_online)),
        ("shards", Json::Arr(shards)),
        ("instances", Json::Arr(instances)),
    ])
}

fn err_json(msg: &str) -> Json {
    Json::obj(vec![("ok", Json::from(false)), ("error", Json::from(msg))])
}

fn send(w: &mut TcpStream, j: &Json) -> std::io::Result<()> {
    writeln!(w, "{j}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::TcpClient;

    fn paced_cfg() -> SystemConfig {
        let mut cfg = SystemConfig::default();
        cfg.realtime.pace = 50_000.0;
        cfg
    }

    fn spawn_realtime(cfg: SystemConfig) -> (String, thread::JoinHandle<Summary>) {
        let (btx, brx) = mpsc::channel();
        let handle = thread::spawn(move || {
            RealtimeServer::new(cfg)
                .serve("127.0.0.1:0", move |a| {
                    let _ = btx.send(a);
                })
                .unwrap()
        });
        (brx.recv().unwrap(), handle)
    }

    #[test]
    fn streams_one_request_end_to_end() {
        let (addr, handle) = spawn_realtime(paced_cfg());
        let mut c = TcpClient::connect(&addr).unwrap();

        let pong = c
            .call(&Json::obj(vec![("op", Json::from("ping"))]))
            .unwrap();
        assert_eq!(pong.get("realtime").as_bool(), Some(true));

        let ack = c
            .call(&Json::obj(vec![
                ("op", Json::from("submit")),
                ("input_len", Json::from(64u64)),
                ("output_len", Json::from(4u64)),
                ("class", Json::from("online")),
            ]))
            .unwrap();
        assert_eq!(ack.get("ok").as_bool(), Some(true), "{ack}");
        let id = ack.get("id").as_u64().unwrap();

        let mut seqs = Vec::new();
        loop {
            let j = c.read_line().unwrap();
            assert_eq!(j.get("id").as_u64(), Some(id));
            if j.get("done").as_bool() == Some(true) {
                assert_eq!(j.get("output_len").as_u64(), Some(4));
                break;
            }
            assert!(j.get("aborted").is_null(), "{j}");
            seqs.push(j.get("seq").as_u64().unwrap());
        }
        assert!(!seqs.is_empty(), "at least the first token is streamed");
        assert!(seqs.windows(2).all(|w| w[0] < w[1]), "{seqs:?}");

        let health = c
            .call(&Json::obj(vec![("op", Json::from("health"))]))
            .unwrap();
        assert_eq!(health.get("completions").as_u64(), Some(1));
        assert_eq!(health.get("client_aborts").as_u64(), Some(0));

        c.call(&Json::obj(vec![("op", Json::from("shutdown"))])).unwrap();
        let summary = handle.join().unwrap();
        assert_eq!(summary.n_requests, 1);
    }

    #[test]
    fn rejects_oversized_and_unknown_ops() {
        let (addr, handle) = spawn_realtime(paced_cfg());
        let mut c = TcpClient::connect(&addr).unwrap();
        let reply = c
            .call(&Json::obj(vec![
                ("op", Json::from("submit")),
                ("input_len", Json::from(1_000_000u64)),
                ("output_len", Json::from(8u64)),
            ]))
            .unwrap();
        assert_eq!(reply.get("ok").as_bool(), Some(false));
        let bad = c
            .call(&Json::obj(vec![("op", Json::from("no-such-op"))]))
            .unwrap();
        assert_eq!(bad.get("ok").as_bool(), Some(false));
        c.call(&Json::obj(vec![("op", Json::from("shutdown"))])).unwrap();
        handle.join().unwrap();
    }
}
