//! Serving front end.
//!
//! * [`gateway`] — in-process gateway: collects requests (wall-clock
//!   arrival stamping, class routing) into a replayable [`Trace`] and runs
//!   a chosen system over a chosen engine.
//! * [`tcp`] — newline-delimited-JSON TCP protocol over the gateway: the
//!   `bucketserve serve` subcommand and its client.
//! * [`realtime`] — the wall-clock serving path (`bucketserve serve
//!   --realtime`): arrivals feed a continuously running scheduler over
//!   the [`RealtimeEngine`], tokens stream back per line, client
//!   disconnects abort in-flight work, and `health`/`loads` expose live
//!   occupancy.
//!
//! [`Trace`]: crate::workload::Trace
//! [`RealtimeEngine`]: crate::cluster::realtime::RealtimeEngine

pub mod gateway;
pub mod realtime;
pub mod tcp;

pub use gateway::Gateway;
pub use realtime::RealtimeServer;
pub use tcp::{Server, TcpClient};
