//! Serving front end.
//!
//! * [`gateway`] — in-process gateway: collects requests (wall-clock
//!   arrival stamping, class routing) into a replayable [`Trace`] and runs
//!   a chosen system over a chosen engine.
//! * [`tcp`] — newline-delimited-JSON TCP protocol over the gateway: the
//!   `bucketserve serve` subcommand and its client.
//!
//! [`Trace`]: crate::workload::Trace

pub mod gateway;
pub mod tcp;

pub use gateway::Gateway;
pub use tcp::{Server, TcpClient};
