//! `RealtimeEngine`: the wall-clock execution substrate for the live
//! serving path.
//!
//! Wraps [`SimEngine`]'s cost oracle but *blocks* for each step's
//! duration, so the scheduler's realtime drive mode experiences genuine
//! wall-clock execution (timestamps come from the wall, not the event
//! clock). Two deliberate differences from the simulator:
//!
//! * **Pace.** Durations are divided by `realtime.pace` before sleeping
//!   and before being returned, so tests and the loopback bench compress
//!   time (e.g. `pace = 1000` runs a 24 ms decode iteration as a 24 µs
//!   sleep). All wall-clock metrics of a paced run are in compressed
//!   time; callers that score SLO attainment scale the SLO budgets by
//!   the same factor. `pace = 1.0` is true wall-clock.
//! * **Observed projection.** `projected_decode_us` does **not** consult
//!   the cost model — a real engine has none. It serves the
//!   EWMA-fitted [`ObservedDecodeModel`] fed by this engine's own
//!   completed iterations, which is what lets TBT admission and
//!   preemption run on engines whose latency is only measurable. The
//!   model handle is shared ([`RealtimeEngine::observed`]) so the
//!   server's `loads` introspection can read the live fit.

use std::sync::{Arc, Mutex};

use super::sim::SimEngine;
use super::{DecodeBatch, Engine, PrefillBatch};
use crate::config::{ModelSpec, SystemConfig};
use crate::coordinator::monitor::ObservedDecodeModel;
use crate::workload::RequestId;
use crate::Micros;

/// Shared handle onto the observed decode-latency model: written by the
/// engine on every completed iteration, read by admission projections
/// and the server's `loads` op.
pub type SharedDecodeModel = Arc<Mutex<ObservedDecodeModel>>;

/// Wall-clock engine: simulated costs executed as (paced) real sleeps.
#[derive(Debug)]
pub struct RealtimeEngine {
    sim: SimEngine,
    pace: f64,
    observed: SharedDecodeModel,
}

impl RealtimeEngine {
    pub fn new(cfg: &SystemConfig) -> RealtimeEngine {
        let pace = cfg.realtime.pace;
        RealtimeEngine {
            sim: SimEngine::new(cfg),
            pace: if pace.is_finite() && pace > 0.0 { pace } else { 1.0 },
            observed: Arc::new(Mutex::new(ObservedDecodeModel::new(
                cfg.realtime.ewma_alpha,
            ))),
        }
    }

    /// Clone of the shared observed-latency model handle.
    pub fn observed(&self) -> SharedDecodeModel {
        Arc::clone(&self.observed)
    }

    /// A simulated duration compressed by the pace factor (min 1 µs so a
    /// step is never free).
    fn scaled(&self, us: Micros) -> Micros {
        ((us as f64 / self.pace).round() as Micros).max(1)
    }

    fn block_for(us: Micros) {
        std::thread::sleep(std::time::Duration::from_micros(us));
    }
}

impl Engine for RealtimeEngine {
    fn model(&self) -> &ModelSpec {
        self.sim.model()
    }

    fn realtime(&self) -> bool {
        true
    }

    fn prefill(&mut self, batch: &PrefillBatch) -> anyhow::Result<Micros> {
        let us = self.scaled(self.sim.prefill(batch)?);
        Self::block_for(us);
        Ok(us)
    }

    fn prefill_slice(
        &mut self,
        batch: &PrefillBatch,
        from: u32,
        to: u32,
    ) -> anyhow::Result<Micros> {
        // Same oracle as the simulator's sliced pricing, executed as a
        // paced sleep — the realtime path inherits chunking for free.
        let us = self.scaled(self.sim.prefill_slice(batch, from, to)?);
        Self::block_for(us);
        Ok(us)
    }

    fn decode_step(&mut self, batch: &DecodeBatch) -> anyhow::Result<Micros> {
        let us = self.scaled(self.sim.decode_step(batch)?);
        Self::block_for(us);
        self.observed.lock().unwrap().observe(batch.total_ctx(), us);
        Ok(us)
    }

    fn hybrid_decode_step(
        &mut self,
        batch: &DecodeBatch,
    ) -> anyhow::Result<Micros> {
        let us = self.scaled(self.sim.hybrid_decode_step(batch)?);
        Self::block_for(us);
        // Hybrid iterations are deliberately *not* fed to the observed
        // EWMA: it projects plain-iteration cost for admission, and
        // mixing in weight-sharing samples would bias it optimistic.
        Ok(us)
    }

    fn projected_decode_us(&self, _n: usize, total_ctx: u64) -> Micros {
        self.observed.lock().unwrap().projected_us(total_ctx)
    }

    fn kv_transfer(&mut self, tokens: u64) -> Micros {
        // Modeled as an async NVLink push: charged to the hand-off
        // timeline, not blocked on.
        self.scaled(self.sim.kv_transfer(tokens))
    }

    fn decode_mem_budget(&self) -> u64 {
        self.sim.decode_mem_budget()
    }

    fn release(&mut self, id: RequestId) {
        self.sim.release(id);
    }

    fn checkpoint(&mut self, generated: u32) -> Micros {
        self.scaled(self.sim.checkpoint(generated))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::DecodeSeq;
    use crate::cluster::PrefillItem;

    fn fast_cfg() -> SystemConfig {
        let mut cfg = SystemConfig::default();
        cfg.realtime.pace = 10_000.0; // ~24 ms iterations become ~2 µs
        cfg
    }

    #[test]
    fn is_realtime_and_paces_durations() {
        let cfg = fast_cfg();
        let mut e = RealtimeEngine::new(&cfg);
        assert!(e.realtime());
        let b = PrefillBatch {
            items: vec![PrefillItem { id: 0, len: 100, tokens: vec![] }],
            padded_len: 128,
        };
        let sim_us = SimEngine::new(&cfg).prefill(&b).unwrap();
        let rt_us = e.prefill(&b).unwrap();
        assert!(rt_us >= 1);
        assert!(
            rt_us <= sim_us / 1_000,
            "paced duration {rt_us} not compressed vs simulated {sim_us}"
        );
    }

    #[test]
    fn projection_comes_from_observed_iterations_not_the_cost_model() {
        let cfg = fast_cfg();
        let mut e = RealtimeEngine::new(&cfg);
        assert_eq!(
            e.projected_decode_us(4, 4 * 512),
            0,
            "before any iteration there is nothing to project from"
        );
        let d = DecodeBatch {
            seqs: (0..4).map(|i| DecodeSeq { id: i, ctx_len: 512 }).collect(),
        };
        let stepped = e.decode_step(&d).unwrap();
        let projected = e.projected_decode_us(4, 4 * 512);
        assert_eq!(projected, stepped, "one sample -> projection is that sample");
        // The shared handle sees the same fit.
        assert_eq!(e.observed().lock().unwrap().samples(), 1);
    }
}
