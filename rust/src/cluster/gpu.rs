//! A100 roofline cost model: the simulated-GPU substrate.
//!
//! The paper's scheduling decisions depend on two phase-level facts the
//! model must reproduce (paper §II-A1):
//!
//! * **Prefill is compute-bound** — time scales with padded batch FLOPs
//!   (linear projections ∝ N·S_pad·P plus quadratic attention), so padding
//!   waste translates directly into wasted GPU time.
//! * **Decode is bandwidth-bound** — each iteration streams the weights
//!   once plus every active sequence's KV cache, so batching amortizes the
//!   weight reads and utilization rises with batch size.
//!
//! Constants default to A100-40GB SXM (312 TFLOP/s BF16, 1.555 TB/s HBM,
//! 300 GB/s NVLink) with achievable-efficiency derates; the *shape* of
//! every figure depends only on these scaling laws, not the absolute
//! constants (DESIGN.md §2).

use crate::config::{GpuSpec, ModelSpec};
use crate::Micros;

/// Analytic phase-cost model for one GPU instance.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub model: ModelSpec,
    pub gpu: GpuSpec,
    /// Tensor-parallel degree (weights and work sharded across TP peers).
    pub tp: u32,
}

impl CostModel {
    pub fn new(model: ModelSpec, gpu: GpuSpec, tp: u32) -> CostModel {
        assert!(tp >= 1);
        CostModel { model, gpu, tp }
    }

    /// FLOPs of prefilling one sequence of `s` (padded) tokens:
    /// 2·P per token for the dense projections + 4·L·(H·D)·s² attention.
    pub fn prefill_flops(&self, s: u32) -> f64 {
        let s = s as f64;
        let dense = 2.0 * self.model.n_params * s;
        let hidden = (self.model.n_heads * self.model.head_dim) as f64;
        let attn = 4.0 * self.model.n_layers as f64 * hidden * s * s;
        dense + attn
    }

    /// Duration of a prefill batch: N sequences all padded to `s_pad`.
    pub fn prefill_time(&self, n: usize, s_pad: u32) -> Micros {
        let flops = self.prefill_flops(s_pad) * n as f64;
        let rate = self.gpu.flops * self.gpu.compute_eff * self.tp as f64;
        let us = flops / rate * 1e6;
        us as Micros + self.gpu.step_overhead_us
    }

    /// FLOPs of prefilling token positions `[from, to)` of one sequence
    /// whose full (padded) length reaches at least `to`: the dense term
    /// is linear in the slice width, while causal attention charges the
    /// quadratic *difference* — each new token attends over the whole
    /// prefix, so later slices are dearer. Slices telescope exactly:
    /// summing `[0,a) + [a,b) + ... + [z,s)` gives
    /// [`CostModel::prefill_flops`]`(s)`.
    pub fn prefill_flops_range(&self, from: u32, to: u32) -> f64 {
        let (from, to) = (from as f64, to as f64);
        let dense = 2.0 * self.model.n_params * (to - from);
        let hidden = (self.model.n_heads * self.model.head_dim) as f64;
        let attn =
            4.0 * self.model.n_layers as f64 * hidden * (to * to - from * from);
        dense + attn
    }

    /// Duration of one chunked-prefill slice: N sequences each advancing
    /// token positions `[from, to)`. Identical rate model to
    /// [`CostModel::prefill_time`]; each slice pays the fixed step
    /// overhead, so an S-slice batch costs `(S − 1) · step_overhead_us`
    /// more than its monolithic run — the chunking tax.
    pub fn prefill_slice_time(&self, n: usize, from: u32, to: u32) -> Micros {
        let flops = self.prefill_flops_range(from, to) * n as f64;
        let rate = self.gpu.flops * self.gpu.compute_eff * self.tp as f64;
        let us = flops / rate * 1e6;
        us as Micros + self.gpu.step_overhead_us
    }

    /// Duration of a decode iteration run as a *hybrid batch* on an
    /// instance already streaming a prefill slice's weight pass: the
    /// bandwidth side drops the weight-read term (the slice pays it) and
    /// reads only live KV; the compute side is unchanged.
    pub fn hybrid_decode_step_time(&self, n: usize, total_ctx: u64) -> Micros {
        if n == 0 {
            return 0;
        }
        let kv_bytes = (total_ctx * self.model.kv_bytes_per_token()) as f64;
        let t_mem =
            kv_bytes / (self.gpu.membw * self.gpu.membw_eff * self.tp as f64);
        let t_comp = 2.0 * self.model.n_params * n as f64
            / (self.gpu.flops * self.gpu.compute_eff * self.tp as f64);
        let us = t_mem.max(t_comp) * 1e6;
        us as Micros + self.gpu.step_overhead_us
    }

    /// Duration of one decode iteration over sequences with context lengths
    /// summing to `total_ctx` tokens (N = `n` sequences).
    ///
    /// Bandwidth side: weights are read once per iteration (amortized over
    /// the batch) plus every live KV byte. Compute side: 2·P FLOPs/token.
    pub fn decode_step_time(&self, n: usize, total_ctx: u64) -> Micros {
        if n == 0 {
            return 0;
        }
        let weight_bytes = self.model.weight_bytes() as f64 / self.tp as f64;
        let kv_bytes = (total_ctx * self.model.kv_bytes_per_token()) as f64;
        let t_mem =
            (weight_bytes + kv_bytes) / (self.gpu.membw * self.gpu.membw_eff * self.tp as f64);
        let t_comp = 2.0 * self.model.n_params * n as f64
            / (self.gpu.flops * self.gpu.compute_eff * self.tp as f64);
        let us = t_mem.max(t_comp) * 1e6;
        us as Micros + self.gpu.step_overhead_us
    }

    /// NVLink hand-off of a `tokens`-token KV cache (paper §III: prefill →
    /// decode transfer), plus a fixed coordination latency.
    pub fn kv_transfer_time(&self, tokens: u64) -> Micros {
        let bytes = (tokens * self.model.kv_bytes_per_token()) as f64;
        let us = bytes / self.gpu.nvlink * 1e6;
        us as Micros + 20
    }

    /// Checkpoint cost of an evicted decode sequence: only the generated
    /// token ids (4 B each) leave the device — recompute-from-checkpoint
    /// discards the KV instead of migrating it, which is the whole point
    /// of the eviction — plus the same fixed coordination latency as a
    /// KV hand-off. The restore side needs no extra model: the requeued
    /// entry's prompt grows by `generated`, so the standard
    /// [`CostModel::prefill_time`] already prices the replayed context.
    pub fn checkpoint_time(&self, generated_tokens: u32) -> Micros {
        let bytes = generated_tokens as f64 * 4.0;
        let us = bytes / self.gpu.nvlink * 1e6;
        us as Micros + 20
    }

    /// M_remain (Eq. 5 input): GPU memory left after weights + a fixed
    /// activation reservation.
    pub fn mem_remaining(&self) -> u64 {
        let weights = self.model.weight_bytes() / self.tp as u64;
        let activations = 2 * (1u64 << 30); // 2 GiB working set
        self.gpu.mem_bytes.saturating_sub(weights + activations)
    }

    /// Tokens/second of decode at batch size `n` with mean context `ctx`
    /// (for roofline sanity checks).
    pub fn decode_tokens_per_sec(&self, n: usize, ctx: u32) -> f64 {
        let dur = self.decode_step_time(n, n as u64 * ctx as u64);
        n as f64 / (dur as f64 / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn cm() -> CostModel {
        let c = SystemConfig::default();
        CostModel::new(c.model, c.gpu, 1)
    }

    #[test]
    fn prefill_scales_linearly_with_batch() {
        let m = cm();
        let t1 = m.prefill_time(1, 512) - m.gpu.step_overhead_us;
        let t4 = m.prefill_time(4, 512) - m.gpu.step_overhead_us;
        let ratio = t4 as f64 / t1 as f64;
        assert!((ratio - 4.0).abs() < 0.1, "ratio {ratio}");
    }

    #[test]
    fn prefill_superlinear_in_seq_len() {
        // Attention's s² term: doubling s should more than double time.
        let m = cm();
        let t1 = m.prefill_time(1, 1024) - m.gpu.step_overhead_us;
        let t2 = m.prefill_time(1, 2048) - m.gpu.step_overhead_us;
        assert!(t2 as f64 > 2.0 * t1 as f64);
        assert!((t2 as f64) < 4.0 * t1 as f64);
    }

    #[test]
    fn prefill_magnitude_sane_for_13b() {
        // ~512-token prompt on A100 at 55% of 312 TF: ~80–200 ms.
        let m = cm();
        let t = m.prefill_time(1, 512);
        assert!(t > 50_000 && t < 300_000, "prefill 512 = {t} µs");
    }

    #[test]
    fn decode_is_bandwidth_bound_and_batching_amortizes() {
        let m = cm();
        // Per-token cost must fall as batch size grows (weight reads shared).
        let t1 = m.decode_step_time(1, 512);
        let t8 = m.decode_step_time(8, 8 * 512);
        let per1 = t1 as f64;
        let per8 = t8 as f64 / 8.0;
        assert!(per8 < per1 * 0.5, "per1 {per1} per8 {per8}");
    }

    #[test]
    fn decode_magnitude_sane_for_13b() {
        // Single-seq decode step ≈ weights 26 GB / ~1.1 TB/s ≈ 24 ms.
        let m = cm();
        let t = m.decode_step_time(1, 512);
        assert!(t > 10_000 && t < 60_000, "decode = {t} µs");
    }

    #[test]
    fn decode_time_grows_with_context() {
        let m = cm();
        let short = m.decode_step_time(16, 16 * 128);
        let long = m.decode_step_time(16, 16 * 4096);
        assert!(long > short);
    }

    #[test]
    fn slice_flops_telescope_to_full_prefill() {
        // Σ prefill_flops_range over a partition of [0, s) must equal
        // prefill_flops(s) exactly (same f64 expression, telescoped), so
        // chunking never changes total FLOPs — only adds per-slice
        // launch overhead.
        let m = cm();
        let s = 4096u32;
        let cuts = [0u32, 512, 1024, 2048, 3000, 4096];
        let sum: f64 = cuts
            .windows(2)
            .map(|w| m.prefill_flops_range(w[0], w[1]))
            .sum();
        let full = m.prefill_flops(s);
        assert!(
            (sum - full).abs() / full < 1e-12,
            "sliced {sum} vs full {full}"
        );
        // And a whole-range slice is exactly the monolithic prefill.
        assert_eq!(m.prefill_slice_time(4, 0, s), m.prefill_time(4, s));
    }

    #[test]
    fn sliced_prefill_costs_one_overhead_per_slice() {
        // Duration side of the telescope: an S-slice run costs the
        // monolithic duration plus (S − 1) launch overheads, up to
        // per-slice µs truncation.
        let m = cm();
        let s = 4096u32;
        let cuts = [0u32, 1024, 2048, 3072, 4096];
        let sliced: Micros =
            cuts.windows(2).map(|w| m.prefill_slice_time(2, w[0], w[1])).sum();
        let full = m.prefill_time(2, s);
        let expect = full + 3 * m.gpu.step_overhead_us;
        let diff = sliced.abs_diff(expect);
        assert!(diff <= 4, "sliced {sliced} vs expected {expect}");
        // Later slices are dearer (causal attention over the prefix).
        assert!(
            m.prefill_slice_time(1, 3072, 4096)
                > m.prefill_slice_time(1, 0, 1024)
        );
    }

    #[test]
    fn hybrid_decode_drops_the_weight_read() {
        let m = cm();
        // Bandwidth-bound regime: sharing the weight pass must be a
        // large win (the weight read dominates a small batch's t_mem).
        let plain = m.decode_step_time(1, 512);
        let hybrid = m.hybrid_decode_step_time(1, 512);
        assert!(
            hybrid < plain / 2,
            "hybrid {hybrid} vs plain {plain}: weight read not dropped"
        );
        // Never cheaper than the compute floor + overhead, never free.
        assert!(hybrid > m.gpu.step_overhead_us);
        assert_eq!(m.hybrid_decode_step_time(0, 0), 0);
        // KV reads still scale with context.
        assert!(
            m.hybrid_decode_step_time(16, 16 * 4096)
                > m.hybrid_decode_step_time(16, 16 * 128)
        );
    }

    #[test]
    fn kv_transfer_reasonable() {
        // 1024 tokens · 0.8 MB/token ≈ 0.82 GB over 300 GB/s ≈ 2.8 ms.
        let m = cm();
        let t = m.kv_transfer_time(1024);
        assert!(t > 1_000 && t < 10_000, "transfer {t} µs");
    }

    #[test]
    fn checkpoint_is_orders_cheaper_than_kv_migration() {
        // Evicting by checkpoint moves ~4 B/token of ids; migrating the
        // KV would move ~0.8 MB/token. The gap is what makes
        // recompute-from-checkpoint the right eviction mechanism.
        let m = cm();
        let ckpt = m.checkpoint_time(1024);
        let kv = m.kv_transfer_time(1024);
        assert!(ckpt >= 20, "fixed coordination latency applies");
        assert!(ckpt < 100, "token-id checkpoint is near-instant: {ckpt} µs");
        assert!(kv > 50 * ckpt, "ckpt {ckpt} µs vs KV hand-off {kv} µs");
    }

    #[test]
    fn mem_remaining_positive_for_13b_on_40g() {
        let m = cm();
        let rem = m.mem_remaining();
        // 40 GB − 26 GB weights − 2 GB activations ≈ 12 GB.
        assert!(rem > 10 * (1u64 << 30) && rem < 14 * (1u64 << 30));
    }

    #[test]
    fn tp_shards_weights_and_speeds_up() {
        let c = SystemConfig::default();
        let m1 = CostModel::new(c.model.clone(), c.gpu.clone(), 1);
        let m2 = CostModel::new(c.model, c.gpu, 2);
        assert!(m2.decode_step_time(4, 2048) < m1.decode_step_time(4, 2048));
        assert!(m2.mem_remaining() > m1.mem_remaining());
    }
}
