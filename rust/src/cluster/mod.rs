//! Execution substrates: the engine abstraction and the simulated cluster.
//!
//! The paper's testbed (4× A100-40GB + NVLink serving Llama2-13B on vLLM)
//! is unavailable here, so the coordinator runs against one of two
//! implementations of [`Engine`]:
//!
//! * [`sim::SimEngine`] — an analytic A100 roofline cost model
//!   ([`gpu::CostModel`]) driven in virtual time; used for every
//!   paper-scale figure.
//! * [`crate::runtime::PjrtEngine`] — real execution of the AOT-compiled
//!   JAX+Pallas artifacts on the PJRT CPU client, in wall time; used by the
//!   end-to-end examples.
//! * [`realtime::RealtimeEngine`] — the simulator's cost oracle executed
//!   as wall-clock blocking sleeps (optionally pace-compressed), with
//!   `projected_decode_us` served from an EWMA over *observed* iteration
//!   latencies instead of the cost model; drives the live serving path
//!   ([`crate::server::realtime`]).
//!
//! The scheduler is engine-agnostic: it plans batches, asks the engine for
//! durations (simulated or measured), and owns all queueing/timeline logic.

pub mod gpu;
pub mod realtime;
pub mod sim;

use crate::config::ModelSpec;
use crate::workload::RequestId;
use crate::Micros;

/// One request's slot in a prefill batch.
#[derive(Debug, Clone)]
pub struct PrefillItem {
    pub id: RequestId,
    /// True prompt length (≤ `PrefillBatch::padded_len`).
    pub len: u32,
    /// Prompt token ids (real-engine runs only; empty in simulation).
    pub tokens: Vec<u32>,
}

/// A formed prefill batch: every sequence padded to `padded_len`
/// (the bucket upper bound — and, on the real engine, the compiled
/// executable's static shape).
#[derive(Debug, Clone)]
pub struct PrefillBatch {
    pub items: Vec<PrefillItem>,
    pub padded_len: u32,
}

impl PrefillBatch {
    pub fn n(&self) -> usize {
        self.items.len()
    }

    /// Σ true lengths (useful tokens).
    pub fn useful_tokens(&self) -> u64 {
        self.items.iter().map(|i| i.len as u64).sum()
    }

    /// N · S_pad (slot tokens actually computed).
    pub fn padded_tokens(&self) -> u64 {
        self.items.len() as u64 * self.padded_len as u64
    }

    /// Eq. 2: (S_max − S_avg) / S_max over the *padded* batch.
    pub fn waste_ratio(&self) -> f64 {
        if self.items.is_empty() || self.padded_len == 0 {
            return 0.0;
        }
        let avg = self.useful_tokens() as f64 / self.items.len() as f64;
        (self.padded_len as f64 - avg) / self.padded_len as f64
    }

    /// Fraction of prefill compute spent on real tokens.
    pub fn efficiency(&self) -> f64 {
        if self.padded_tokens() == 0 {
            return 1.0;
        }
        self.useful_tokens() as f64 / self.padded_tokens() as f64
    }
}

/// One active sequence in a decode iteration.
#[derive(Debug, Clone, Copy)]
pub struct DecodeSeq {
    pub id: RequestId,
    /// Current context length (prompt + generated so far).
    pub ctx_len: u32,
}

/// One continuous-batching decode iteration.
#[derive(Debug, Clone, Default)]
pub struct DecodeBatch {
    pub seqs: Vec<DecodeSeq>,
}

impl DecodeBatch {
    pub fn n(&self) -> usize {
        self.seqs.len()
    }

    pub fn total_ctx(&self) -> u64 {
        self.seqs.iter().map(|s| s.ctx_len as u64).sum()
    }
}

/// Execution substrate the coordinator schedules onto.
pub trait Engine {
    /// Cost-model parameters of the served model (Eq. 1 constants).
    fn model(&self) -> &ModelSpec;

    /// True when durations come from wall-clock blocking execution (the
    /// serving loop then waits in real time for arrivals).
    fn realtime(&self) -> bool {
        false
    }

    /// Execute (or cost) one prefill batch; returns its duration.
    fn prefill(&mut self, batch: &PrefillBatch) -> anyhow::Result<Micros>;

    /// Execute (or cost) one *slice* of a chunked prefill batch: token
    /// positions `[from, to)` of every sequence in `batch` (causal
    /// attention makes later slices dearer — they attend over the whole
    /// prefix). Engines without slice pricing fall back to the full
    /// batch cost per slice, which makes chunking strictly pessimal
    /// there rather than silently wrong.
    fn prefill_slice(
        &mut self,
        batch: &PrefillBatch,
        from: u32,
        to: u32,
    ) -> anyhow::Result<Micros> {
        let _ = (from, to);
        self.prefill(batch)
    }

    /// Execute (or cost) one decode iteration; returns its duration.
    fn decode_step(&mut self, batch: &DecodeBatch) -> anyhow::Result<Micros>;

    /// Execute (or cost) one decode iteration that piggybacks on a
    /// co-resident prefill slice as a hybrid batch: the slice's weight
    /// pass is already streaming, so the iteration pays only for its KV
    /// reads. Engines without hybrid pricing fall back to the plain
    /// iteration cost (chunking's hybrid benefit simply vanishes).
    fn hybrid_decode_step(
        &mut self,
        batch: &DecodeBatch,
    ) -> anyhow::Result<Micros> {
        self.decode_step(batch)
    }

    /// Pure cost *projection* of one decode iteration over `n` sequences
    /// whose context lengths sum to `total_ctx` tokens — what the
    /// TBT-aware admission layer asks before committing a batch to an
    /// instance ("what would the iteration time become?"). Unlike
    /// [`Engine::decode_step`] this must execute nothing and mutate no
    /// accounting. Defaults to 0 ("no projection available"), under
    /// which the admission triggers only react to sequences that are
    /// already past their inter-token deadline.
    fn projected_decode_us(&self, _n: usize, _total_ctx: u64) -> Micros {
        0
    }

    /// Duration of the prefill→decode KV hand-off for `tokens` cache tokens.
    fn kv_transfer(&mut self, tokens: u64) -> Micros;

    /// Per-decode-instance KV memory budget, bytes (M_remain of Eq. 5 —
    /// the scheduler applies the 0.9 safety factor itself).
    fn decode_mem_budget(&self) -> u64;

    /// Drop any per-request engine state (KV cache) for a finished request.
    fn release(&mut self, _id: RequestId) {}

    /// Duration of checkpointing an evicted decode sequence's generation
    /// progress (`generated` token ids — the recompute-from-checkpoint
    /// state; the KV itself is discarded, not migrated) so the sequence
    /// can re-enter the queue. Defaults to free: the checkpoint is tiny,
    /// and engines without an explicit transfer model may treat it as
    /// instantaneous.
    fn checkpoint(&mut self, _generated: u32) -> Micros {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch(lens: &[u32], pad: u32) -> PrefillBatch {
        PrefillBatch {
            items: lens
                .iter()
                .enumerate()
                .map(|(i, &len)| PrefillItem { id: i as u64, len, tokens: vec![] })
                .collect(),
            padded_len: pad,
        }
    }

    #[test]
    fn waste_ratio_matches_eq2() {
        // S_max = 128, lengths 64 and 128 → S_avg = 96, waste = 32/128.
        let b = batch(&[64, 128], 128);
        assert!((b.waste_ratio() - 0.25).abs() < 1e-12);
        assert!((b.efficiency() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn homogeneous_batch_zero_waste() {
        let b = batch(&[128, 128, 128], 128);
        assert_eq!(b.waste_ratio(), 0.0);
        assert_eq!(b.efficiency(), 1.0);
    }

    #[test]
    fn empty_batch_safe() {
        let b = batch(&[], 128);
        assert_eq!(b.waste_ratio(), 0.0);
        assert_eq!(b.efficiency(), 1.0);
    }

    #[test]
    fn decode_batch_totals() {
        let d = DecodeBatch {
            seqs: vec![
                DecodeSeq { id: 1, ctx_len: 100 },
                DecodeSeq { id: 2, ctx_len: 50 },
            ],
        };
        assert_eq!(d.total_ctx(), 150);
        assert_eq!(d.n(), 2);
    }
}
