//! `SimEngine`: the virtual-time execution substrate.
//!
//! Wraps [`super::gpu::CostModel`] behind the [`Engine`] trait. All state a
//! discrete-event run needs beyond durations (instance timelines, queues)
//! lives in the scheduler; the engine is a pure cost oracle plus release
//! bookkeeping, which keeps simulated and real runs on the identical
//! scheduling code path.

use super::gpu::CostModel;
use super::{DecodeBatch, Engine, PrefillBatch};
use crate::config::{ModelSpec, SystemConfig};
use crate::Micros;

/// Simulated engine (virtual time).
#[derive(Debug, Clone)]
pub struct SimEngine {
    cost: CostModel,
    /// Counts engine calls for overhead-accounting asserts in tests.
    pub prefill_calls: u64,
    pub decode_calls: u64,
}

impl SimEngine {
    pub fn new(cfg: &SystemConfig) -> SimEngine {
        SimEngine {
            cost: CostModel::new(cfg.model.clone(), cfg.gpu.clone(), cfg.fleet.tp),
            prefill_calls: 0,
            decode_calls: 0,
        }
    }

    pub fn cost_model(&self) -> &CostModel {
        &self.cost
    }
}

impl Engine for SimEngine {
    fn model(&self) -> &ModelSpec {
        &self.cost.model
    }

    fn prefill(&mut self, batch: &PrefillBatch) -> anyhow::Result<Micros> {
        self.prefill_calls += 1;
        Ok(self.cost.prefill_time(batch.n(), batch.padded_len))
    }

    fn prefill_slice(
        &mut self,
        batch: &PrefillBatch,
        from: u32,
        to: u32,
    ) -> anyhow::Result<Micros> {
        // Each slice is its own kernel launch: counted like a prefill
        // call, priced on the [from, to) range.
        self.prefill_calls += 1;
        Ok(self.cost.prefill_slice_time(batch.n(), from, to))
    }

    fn decode_step(&mut self, batch: &DecodeBatch) -> anyhow::Result<Micros> {
        self.decode_calls += 1;
        Ok(self.cost.decode_step_time(batch.n(), batch.total_ctx()))
    }

    fn hybrid_decode_step(
        &mut self,
        batch: &DecodeBatch,
    ) -> anyhow::Result<Micros> {
        self.decode_calls += 1;
        Ok(self.cost.hybrid_decode_step_time(batch.n(), batch.total_ctx()))
    }

    fn projected_decode_us(&self, n: usize, total_ctx: u64) -> Micros {
        // Same oracle as decode_step, but a pure projection: no call
        // counting, so admission probing cannot skew the overhead
        // accounting tests.
        self.cost.decode_step_time(n, total_ctx)
    }

    fn kv_transfer(&mut self, tokens: u64) -> Micros {
        self.cost.kv_transfer_time(tokens)
    }

    fn decode_mem_budget(&self) -> u64 {
        self.cost.mem_remaining()
    }

    fn checkpoint(&mut self, generated: u32) -> Micros {
        self.cost.checkpoint_time(generated)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{DecodeSeq, PrefillItem};

    #[test]
    fn engine_delegates_to_cost_model() {
        let cfg = SystemConfig::default();
        let mut e = SimEngine::new(&cfg);
        let b = PrefillBatch {
            items: vec![PrefillItem { id: 0, len: 100, tokens: vec![] }],
            padded_len: 128,
        };
        let t = e.prefill(&b).unwrap();
        assert_eq!(t, e.cost_model().prefill_time(1, 128));
        let d = DecodeBatch { seqs: vec![DecodeSeq { id: 0, ctx_len: 128 }] };
        let td = e.decode_step(&d).unwrap();
        assert_eq!(td, e.cost_model().decode_step_time(1, 128));
        assert_eq!(e.prefill_calls, 1);
        assert_eq!(e.decode_calls, 1);
    }

    #[test]
    fn slice_and_hybrid_delegate_to_cost_model() {
        let cfg = SystemConfig::default();
        let mut e = SimEngine::new(&cfg);
        let b = PrefillBatch {
            items: vec![
                PrefillItem { id: 0, len: 2000, tokens: vec![] },
                PrefillItem { id: 1, len: 2048, tokens: vec![] },
            ],
            padded_len: 2048,
        };
        let t = e.prefill_slice(&b, 512, 1024).unwrap();
        assert_eq!(t, e.cost_model().prefill_slice_time(2, 512, 1024));
        assert_eq!(e.prefill_calls, 1, "each slice is a kernel launch");
        let d = DecodeBatch { seqs: vec![DecodeSeq { id: 9, ctx_len: 700 }] };
        let h = e.hybrid_decode_step(&d).unwrap();
        assert_eq!(h, e.cost_model().hybrid_decode_step_time(1, 700));
        assert!(h < e.cost_model().decode_step_time(1, 700));
        assert_eq!(e.decode_calls, 1);
    }

    #[test]
    fn not_realtime() {
        let e = SimEngine::new(&SystemConfig::default());
        assert!(!e.realtime());
    }

    #[test]
    fn projection_matches_decode_cost_without_executing() {
        let cfg = SystemConfig::default();
        let mut e = SimEngine::new(&cfg);
        let projected = e.projected_decode_us(4, 4 * 512);
        assert_eq!(projected, e.cost_model().decode_step_time(4, 4 * 512));
        assert_eq!(e.decode_calls, 0, "projection must not count as a call");
        let d = DecodeBatch {
            seqs: (0..4).map(|i| DecodeSeq { id: i, ctx_len: 512 }).collect(),
        };
        assert_eq!(e.decode_step(&d).unwrap(), projected);
        assert_eq!(e.decode_calls, 1);
    }
}
