//! Typed configuration system.
//!
//! A [`SystemConfig`] describes everything a run needs: the served model's
//! cost parameters, the GPU fleet, the scheduler knobs (bucketing θ, memory
//! reserve, policies), and SLO targets. Configs load from JSON files and
//! accept `--key value` CLI overrides (dotted paths, e.g.
//! `--scheduler.theta 0.6`).
//!
//! Defaults reproduce the paper's testbed: Llama2-13B-class model on
//! 4× A100-40GB (2 prefill + 2 decode instances), FP16 KV cache.

use crate::util::cli::Args;
use crate::util::json::Json;

/// Cost-model description of the served model (Eq. 1 parameters).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    /// Total parameter count (weights), used by the compute/bandwidth model.
    pub n_params: f64,
    /// L in Eq. 1.
    pub n_layers: u32,
    /// H in Eq. 1.
    pub n_heads: u32,
    /// D in Eq. 1.
    pub head_dim: u32,
    /// B in Eq. 1 (2 = FP16).
    pub bytes_per_el: u32,
    /// Context limit; LongBench-style requests are truncated to this.
    pub max_seq: u32,
}

impl ModelSpec {
    /// Llama2-13B (the paper's main offline model).
    pub fn llama2_13b() -> ModelSpec {
        ModelSpec {
            n_params: 13e9,
            n_layers: 40,
            n_heads: 40,
            head_dim: 128,
            bytes_per_el: 2,
            max_seq: 4096,
        }
    }

    /// The tiny AOT-compiled model actually executed on PJRT-CPU
    /// (mirrors python/compile/model.py's ModelConfig defaults).
    pub fn tiny_pjrt() -> ModelSpec {
        ModelSpec {
            n_params: 1_115_264.0,
            n_layers: 4,
            n_heads: 4,
            head_dim: 32,
            bytes_per_el: 4, // f32 on CPU
            max_seq: 256,
        }
    }

    /// KV-cache bytes per token (Eq. 1 without S·N): `2·L·H·D·B`.
    pub fn kv_bytes_per_token(&self) -> u64 {
        2 * self.n_layers as u64
            * self.n_heads as u64
            * self.head_dim as u64
            * self.bytes_per_el as u64
    }

    /// Weight bytes (for residency accounting).
    pub fn weight_bytes(&self) -> u64 {
        (self.n_params * self.bytes_per_el as f64) as u64
    }
}

/// One GPU's capability envelope (A100-40GB defaults).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub mem_bytes: u64,
    /// Peak dense FP16/BF16 throughput.
    pub flops: f64,
    /// HBM bandwidth, bytes/s.
    pub membw: f64,
    /// NVLink bandwidth to peers, bytes/s.
    pub nvlink: f64,
    /// Fixed per-kernel-launch/step overhead, µs.
    pub step_overhead_us: u64,
    /// Achievable fraction of peak compute (prefill).
    pub compute_eff: f64,
    /// Achievable fraction of peak bandwidth (decode).
    pub membw_eff: f64,
}

impl GpuSpec {
    pub fn a100_40g() -> GpuSpec {
        GpuSpec {
            mem_bytes: 40 * (1u64 << 30),
            flops: 312e12,
            membw: 1.555e12,
            nvlink: 300e9,
            step_overhead_us: 150,
            compute_eff: 0.55,
            membw_eff: 0.70,
        }
    }
}

/// Fleet topology: disaggregated prefill/decode instances.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSpec {
    pub n_prefill: u32,
    pub n_decode: u32,
    /// Tensor-parallel degree per instance (weights are sharded across it).
    pub tp: u32,
}

impl FleetSpec {
    /// The paper's 4-GPU node: 2 prefill + 2 decode (DistServe-recommended
    /// split for 13B, which the paper says it adopts).
    pub fn paper_node() -> FleetSpec {
        FleetSpec { n_prefill: 2, n_decode: 2, tp: 1 }
    }
}

/// Intra-bucket ordering policy (paper §II-B / §IV).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// First-come-first-served (online default).
    Fcfs,
    /// Shortest-job-first (offline, RPS-oriented).
    Sjf,
    /// Longest-job-first (offline, token-throughput-oriented).
    Ljf,
}

impl Policy {
    pub fn parse(s: &str) -> Policy {
        match s.to_ascii_lowercase().as_str() {
            "sjf" => Policy::Sjf,
            "ljf" => Policy::Ljf,
            _ => Policy::Fcfs,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Fcfs => "fcfs",
            Policy::Sjf => "sjf",
            Policy::Ljf => "ljf",
        }
    }
}

/// Scheduler knobs (Algorithm 1 + Eqs. 5–6).
#[derive(Debug, Clone, PartialEq)]
pub struct SchedulerSpec {
    /// Split threshold θ (fraction of a bucket's requests below midpoint).
    pub theta: f64,
    /// Fraction of remaining memory reserved for system overheads (Eq. 5
    /// keeps 10% → safe factor 0.9).
    pub mem_safety: f64,
    /// L_max: upper bound of the initial single bucket.
    pub l_max: u32,
    /// Hard cap on requests per formed batch (0 = only memory-limited).
    pub max_batch: u32,
    /// Intra-bucket ordering for offline tasks.
    pub policy: Policy,
    /// Minimum bucket width; bisection stops below this.
    pub min_bucket_width: u32,
    /// Global Monitor sliding-window length, µs (arrival-rate estimation).
    pub monitor_window_us: u64,
}

impl Default for SchedulerSpec {
    fn default() -> Self {
        SchedulerSpec {
            theta: 0.5,
            mem_safety: 0.9,
            l_max: 4096,
            max_batch: 0,
            policy: Policy::Fcfs,
            min_bucket_width: 16,
            monitor_window_us: 10_000_000,
        }
    }
}

/// Arrival→shard placement policy for the sharded coordinator
/// (interpreted by [`crate::coordinator::balance`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Shard with the fewest queued requests (join-shortest-queue).
    LeastLoaded,
    /// Shard with the smallest KV commitment: reserved decode tokens
    /// plus the queued full-context footprint.
    JoinShortestKv,
    /// Stateless splitmix hash of the request id (cheapest; relies on
    /// work-stealing to fix the imbalance it leaves behind).
    Hash,
    /// Route to the decode instance holding the longest resident prefix
    /// match for the request's lineage (requires `prefix.enabled`);
    /// requests without a match fall back to [`Placement::JoinShortestKv`].
    PrefixAffinity,
}

impl Placement {
    pub fn parse(s: &str) -> Placement {
        match s.to_ascii_lowercase().as_str() {
            "kv" | "shortest_kv" | "join_shortest_kv" => Placement::JoinShortestKv,
            "hash" => Placement::Hash,
            "prefix" | "prefix_affinity" => Placement::PrefixAffinity,
            _ => Placement::LeastLoaded,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Placement::LeastLoaded => "least_loaded",
            Placement::JoinShortestKv => "join_shortest_kv",
            Placement::Hash => "hash",
            Placement::PrefixAffinity => "prefix_affinity",
        }
    }
}

/// Coordinator sharding: per-decode-instance scheduler shards, each with
/// its own bucket queue and KV admission, balanced by work-stealing
/// (consumed by [`crate::coordinator::shard::ShardSet`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ShardingSpec {
    /// Scheduler shard count: 1 = the single global queue (legacy
    /// behavior, the default), 0 = one shard per decode instance, any
    /// other value is clamped to `[1, n_decode]` at runtime.
    pub shards: u32,
    /// Arrival placement policy (inert with one shard).
    pub placement: Placement,
    /// Work-stealing between shards at decode-iteration boundaries.
    pub steal: bool,
}

impl Default for ShardingSpec {
    fn default() -> Self {
        ShardingSpec {
            shards: 1,
            placement: Placement::LeastLoaded,
            steal: false,
        }
    }
}

/// Priority-aware scheduling knobs (paper §III's SLO-protection layer);
/// consumed by [`crate::coordinator::priority::PriorityScorer`].
#[derive(Debug, Clone, PartialEq)]
pub struct PrioritySpec {
    /// Master switch; off = pure earliest-arrival (FCFS) drain order.
    pub enabled: bool,
    /// Base weight of the online (latency-SLO-bound) class.
    pub online_weight: f64,
    /// Base weight of the offline (throughput) class.
    pub offline_weight: f64,
    /// Starvation aging: score an offline request gains per queued second.
    pub aging_rate: f64,
    /// Fraction of the TTFT budget consumed beyond which an online request
    /// becomes urgent and overrides offline aging entirely.
    pub urgency_threshold: f64,
}

impl Default for PrioritySpec {
    fn default() -> Self {
        PrioritySpec {
            enabled: true,
            online_weight: 1.0,
            offline_weight: 0.1,
            aging_rate: 0.02,
            urgency_threshold: 0.75,
        }
    }
}

/// Preemption knobs: urgency-triggered prefill abort-and-requeue and
/// decode KV eviction with checkpoint-and-restore (consumed by
/// [`crate::coordinator::preempt::PreemptionEngine`]). Off by default —
/// with the master switch off the scheduler takes no preemption path at
/// all and its output (including Summary JSON) is byte-identical to the
/// pre-preemption system.
#[derive(Debug, Clone, PartialEq)]
pub struct PreemptSpec {
    /// Master switch; off = no preemption checks anywhere.
    pub enabled: bool,
    /// Fraction of the TTFT budget a queued online request must have
    /// consumed before it can trigger preemption. Should sit at or above
    /// the priority layer's `urgency_threshold`: preemption is the
    /// last-resort escalation after plan-time reordering has already
    /// failed to find the request a slot.
    pub urgency_threshold: f64,
    /// Abort an in-flight prefill batch only while its progress fraction
    /// is below this — past it, letting the batch finish wastes less
    /// FLOP-time than discarding and re-running it.
    pub max_abort_progress: f64,
    /// Ceiling on decode sequences evicted per trigger (bounds the
    /// recompute debt a single urgent request can create).
    pub max_evictions: u32,
}

impl Default for PreemptSpec {
    fn default() -> Self {
        PreemptSpec {
            enabled: false,
            urgency_threshold: 0.9,
            max_abort_progress: 0.5,
            max_evictions: 4,
        }
    }
}

/// TBT-aware decode admission knobs: per-iteration deferral of new batch
/// admission and TBT-triggered eviction of offline decode work (consumed
/// by [`crate::coordinator::admission::AdmissionEngine`]). Off by default
/// — with the master switch off the scheduler takes no admission path at
/// all and its output (including Summary JSON) is byte-identical to the
/// pre-admission system.
#[derive(Debug, Clone, PartialEq)]
pub struct AdmissionSpec {
    /// Master switch; off = no TBT-aware admission anywhere.
    pub enabled: bool,
    /// Trigger (a): defer admission of a formed batch onto a decode
    /// instance whose projected iteration time would push a resident
    /// online sequence past its inter-token budget (the batch retargets
    /// to the shard's next-best instance or returns to the queue).
    pub defer: bool,
    /// Trigger (b): at an iteration boundary, evict least-urgent offline
    /// actives (checkpoint-and-restore, the preemption machinery) from an
    /// instance whose projected iteration would blow an online active's
    /// inter-token budget.
    pub evict: bool,
    /// Safety margin: triggers compare against `(1 − slack_margin) ×`
    /// the per-token budget, so a batch is deferred (or offline work
    /// shed) slightly *before* the projection reaches the deadline.
    pub slack_margin: f64,
    /// Offline per-token budget as a multiple of `slo.tbt_us` (offline
    /// throughput work has no interactive reader but still gets a lax
    /// pacing bound so starvation is visible in the TBT metrics).
    pub offline_tbt_factor: f64,
    /// Ceiling on offline sequences shed per TBT trigger (bounds the
    /// recompute debt one at-risk online sequence can create per
    /// boundary).
    pub max_evictions: u32,
}

impl Default for AdmissionSpec {
    fn default() -> Self {
        AdmissionSpec {
            enabled: false,
            defer: true,
            evict: true,
            slack_margin: 0.1,
            offline_tbt_factor: 8.0,
            max_evictions: 2,
        }
    }
}

/// Prefix-cache knobs: a simulated radix-style KV prefix cache per decode
/// instance (consumed by [`crate::coordinator::prefix::PrefixCache`]).
/// When enabled, requests carrying prefix lineage (stamped by
/// `Trace::multi_turn` or loaded from trace JSON) prefill only their
/// uncached suffix, share the cached prefix's KV footprint, and — under
/// `sharding.placement = prefix_affinity` — route to the instance holding
/// their longest resident prefix. Off by default — with the master switch
/// off the scheduler takes no prefix path at all and its output
/// (including Summary JSON) is byte-identical to the pre-prefix system.
#[derive(Debug, Clone, PartialEq)]
pub struct PrefixSpec {
    /// Master switch; off = no prefix-cache bookkeeping anywhere.
    pub enabled: bool,
    /// Cache granularity in tokens: prefixes are shared in whole blocks,
    /// so only `floor(prefix_len / block) * block` tokens are reusable.
    pub block: u32,
    /// Fraction of each decode instance's KV token budget the prefix
    /// cache may occupy before LRU eviction of unpinned blocks kicks in.
    pub cache_frac: f64,
}

impl Default for PrefixSpec {
    fn default() -> Self {
        PrefixSpec { enabled: false, block: 32, cache_frac: 0.5 }
    }
}

/// Chunked (sliced) prefill knobs: prefill batches whose padded token
/// volume exceeds `slice_tokens` execute as a sequence of slices, each
/// ending in a `PrefillSliceEnd` event, so urgent online work can
/// interleave at slice boundaries and decode iterations can piggyback on
/// prefill slices as hybrid batches (Slice-Level Scheduling,
/// arxiv 2406.13511; consumed by the scheduler's sliced dispatch path).
/// Off by default — with the master switch off the scheduler takes no
/// slicing path at all and its output (including Summary JSON) is
/// byte-identical to the pre-chunking system.
#[derive(Debug, Clone, PartialEq)]
pub struct ChunkSpec {
    /// Master switch; off = every prefill batch runs monolithically.
    pub enabled: bool,
    /// Per-slice token budget: a batch of N sequences advances
    /// `max(1, slice_tokens / N)` positions per slice, so each slice
    /// computes at most ~`slice_tokens` padded tokens. Batches that fit
    /// in one slice run exactly as before.
    pub slice_tokens: u32,
    /// Price decode iterations that overlap a co-resident prefill slice
    /// as hybrid batches (the slice's weight pass is shared, dropping
    /// the decode iteration's weight-read term).
    pub hybrid: bool,
    /// Yield the prefill slot at a slice boundary when urgent online
    /// work is queued on the owning shard (the sliced batch parks and
    /// resumes from its cursor once the urgent work has dispatched).
    /// False = slices run back-to-back (pure TBT/hybrid benefit).
    pub interleave: bool,
}

impl Default for ChunkSpec {
    fn default() -> Self {
        ChunkSpec {
            enabled: false,
            slice_tokens: 2048,
            hybrid: true,
            interleave: true,
        }
    }
}

/// Prefill-planner family: which queue discipline each scheduler shard
/// runs behind the [`crate::coordinator::scheduler::PrefillPlanner`]
/// trait. The choice changes only *how* batches form — sharding,
/// work-stealing, preemption, admission, prefix caching, chunking, and
/// the plan/commit parallel executor compose with any family unchanged.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlannerFamily {
    /// Adaptive length-bucketing (Algorithm 1) — the paper's planner and
    /// the default.
    Bucket,
    /// Plain arrival-order FIFO (the DistServe-style baseline planner).
    Fcfs,
    /// Deadline-lookahead: push each request toward its latest feasible
    /// start and form batches backwards from the earliest deadline
    /// ([`crate::coordinator::lookahead::LookaheadPlanner`]).
    Lookahead,
}

impl PlannerFamily {
    pub fn parse(s: &str) -> PlannerFamily {
        match s.to_ascii_lowercase().as_str() {
            "fcfs" => PlannerFamily::Fcfs,
            "lookahead" | "deadline" => PlannerFamily::Lookahead,
            _ => PlannerFamily::Bucket,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PlannerFamily::Bucket => "bucket",
            PlannerFamily::Fcfs => "fcfs",
            PlannerFamily::Lookahead => "lookahead",
        }
    }
}

/// Planner-family selection plus the deadline-lookahead knobs (consumed
/// by [`crate::coordinator::lookahead::LookaheadPlanner`]). The default
/// family is `bucket`, under which every other knob here is inert —
/// output (including Summary JSON) stays byte-identical to the
/// pre-planner-block system.
#[derive(Debug, Clone, PartialEq)]
pub struct PlannerSpec {
    /// Which planner family each scheduler shard runs.
    pub family: PlannerFamily,
    /// Lookahead window: how many earliest-deadline candidates one plan
    /// round examines (bounds per-dispatch work at O(window)).
    pub window: u32,
    /// Commit margin, µs: a batch whose *whole* window still has at
    /// least this much slack before its latest feasible start is held
    /// back so it can accumulate more members; smaller = more eager.
    pub commit_margin_us: u64,
    /// Aging horizon, µs, anchoring offline requests' synthetic
    /// deadlines (`arrival + horizon`): offline work never waits more
    /// than about this long before the planner treats it as due.
    pub offline_horizon_us: u64,
}

impl Default for PlannerSpec {
    fn default() -> Self {
        PlannerSpec {
            family: PlannerFamily::Bucket,
            window: 32,
            commit_margin_us: 50_000,
            offline_horizon_us: 10_000_000,
        }
    }
}

/// Parallel-executor knobs (consumed by
/// [`crate::coordinator::executor`]): how many worker threads the serving
/// loop fans decode-iteration boundaries out to. `threads = 1` (the
/// default) is the sequential scheduler; `0` means one worker per
/// scheduler shard; any other value clamps to `[1, n_shards]`. Whatever
/// the resolved count, the schedule — and the Summary JSON — is pinned
/// byte-identical to the sequential run: the executor changes *where*
/// boundary accounting executes, never what it computes.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecutorSpec {
    /// Worker threads: 1 = sequential (default), 0 = one per shard.
    pub threads: u32,
    /// Offload per-shard prefill *planning* (bucket adjust, drain sorts,
    /// batch formation) to the worker threads behind the plan/commit
    /// protocol (default true). Only meaningful when the executor is
    /// parallel (`threads != 1`); false keeps boundary accounting
    /// parallel but plans inline on the merge loop — the bench axis for
    /// isolating planning offload. Either setting is byte-identical.
    pub plan_offload: bool,
}

impl Default for ExecutorSpec {
    fn default() -> Self {
        // `EXECUTOR_THREADS=N` flips any default-config run — the whole
        // test suite included — onto the parallel executor. Safe because
        // parallel output is pinned byte-identical to sequential; CI runs
        // the full suite this way to catch concurrency regressions.
        let threads = std::env::var("EXECUTOR_THREADS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1);
        ExecutorSpec { threads, plan_offload: true }
    }
}

impl ExecutorSpec {
    /// Resolved worker count over `n_shards` scheduler shards: 0 = one
    /// per shard, anything else clamps to `[1, n_shards]` (a worker
    /// without a shard to serve would never receive work).
    pub fn resolve(&self, n_shards: usize) -> usize {
        let n_shards = n_shards.max(1);
        match self.threads {
            0 => n_shards,
            t => (t as usize).min(n_shards),
        }
    }
}

/// Realtime serving knobs (consumed by [`crate::server::realtime`] and
/// the scheduler's wall-clock drive mode): streaming delivery buffers,
/// the observed-latency EWMA that replaces the cost model's decode
/// projection on real engines, and shutdown drain behavior. These only
/// apply to the realtime path — virtual-time replay never reads them,
/// so every existing Summary JSON stays byte-identical.
#[derive(Debug, Clone, PartialEq)]
pub struct RealtimeSpec {
    /// Per-connection stream buffer depth (token lines). When a slow
    /// client falls this far behind, the oldest undelivered token lines
    /// are dropped (counted as `stream_drops`); the final summary line
    /// is never dropped.
    pub stream_buf: u32,
    /// EWMA smoothing factor for the observed decode-iteration latency
    /// model feeding `projected_decode_us` (0 < alpha <= 1; higher =
    /// faster adaptation, noisier projection).
    pub ewma_alpha: f64,
    /// On shutdown, how long to keep draining in-flight requests before
    /// aborting the remainder (ms).
    pub drain_timeout_ms: u64,
    /// Wall-clock pace factor for the realtime *simulated* engine: it
    /// sleeps `simulated_duration / pace` per step, so e.g. 100.0 runs
    /// 100x faster than real time (tests and the loopback bench use
    /// high pace; 1.0 = true wall-clock).
    pub pace: f64,
}

impl Default for RealtimeSpec {
    fn default() -> Self {
        RealtimeSpec {
            stream_buf: 64,
            ewma_alpha: 0.2,
            drain_timeout_ms: 5_000,
            pace: 1.0,
        }
    }
}

/// SLO targets for online requests (DistServe-style TTFT + TBT).
#[derive(Debug, Clone, PartialEq)]
pub struct SloSpec {
    /// Time-to-first-token budget, µs.
    pub ttft_us: u64,
    /// Per-output-token budget (time between tokens), µs.
    pub tbt_us: u64,
}

impl Default for SloSpec {
    fn default() -> Self {
        // 400 ms TTFT, 100 ms TBT — typical interactive chat targets used
        // by DistServe-class evaluations.
        SloSpec { ttft_us: 400_000, tbt_us: 100_000 }
    }
}

/// Top-level configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemConfig {
    pub model: ModelSpec,
    pub gpu: GpuSpec,
    pub fleet: FleetSpec,
    pub scheduler: SchedulerSpec,
    pub sharding: ShardingSpec,
    pub slo: SloSpec,
    pub priority: PrioritySpec,
    pub preempt: PreemptSpec,
    pub admission: AdmissionSpec,
    pub prefix: PrefixSpec,
    pub chunk: ChunkSpec,
    pub planner: PlannerSpec,
    pub executor: ExecutorSpec,
    pub realtime: RealtimeSpec,
    pub seed: u64,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            model: ModelSpec::llama2_13b(),
            gpu: GpuSpec::a100_40g(),
            fleet: FleetSpec::paper_node(),
            scheduler: SchedulerSpec::default(),
            sharding: ShardingSpec::default(),
            slo: SloSpec::default(),
            priority: PrioritySpec::default(),
            preempt: PreemptSpec::default(),
            admission: AdmissionSpec::default(),
            prefix: PrefixSpec::default(),
            chunk: ChunkSpec::default(),
            planner: PlannerSpec::default(),
            executor: ExecutorSpec::default(),
            realtime: RealtimeSpec::default(),
            seed: 42,
        }
    }
}

impl SystemConfig {
    /// Config matched to the tiny PJRT-CPU model (for end-to-end examples):
    /// bucket bounds clamp to the compiled shape menu.
    pub fn tiny_pjrt() -> SystemConfig {
        let mut c = SystemConfig::default();
        c.model = ModelSpec::tiny_pjrt();
        c.fleet = FleetSpec { n_prefill: 1, n_decode: 1, tp: 1 };
        c.scheduler.l_max = 256;
        c.scheduler.max_batch = 8;
        c.scheduler.min_bucket_width = 32;
        c
    }

    /// Load from a JSON file, then apply CLI overrides.
    pub fn load(path: &str, args: &Args) -> anyhow::Result<SystemConfig> {
        let text = std::fs::read_to_string(path)?;
        let json = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        let mut cfg = SystemConfig::from_json(&json);
        cfg.apply_overrides(args);
        Ok(cfg)
    }

    /// Construct from parsed JSON; missing fields keep defaults.
    pub fn from_json(j: &Json) -> SystemConfig {
        let mut c = SystemConfig::default();
        let m = j.get("model");
        if !m.is_null() {
            let d = &mut c.model;
            if let Some(v) = m.get("n_params").as_f64() { d.n_params = v; }
            if let Some(v) = m.get("n_layers").as_u64() { d.n_layers = v as u32; }
            if let Some(v) = m.get("n_heads").as_u64() { d.n_heads = v as u32; }
            if let Some(v) = m.get("head_dim").as_u64() { d.head_dim = v as u32; }
            if let Some(v) = m.get("bytes_per_el").as_u64() { d.bytes_per_el = v as u32; }
            if let Some(v) = m.get("max_seq").as_u64() { d.max_seq = v as u32; }
        }
        let g = j.get("gpu");
        if !g.is_null() {
            let d = &mut c.gpu;
            if let Some(v) = g.get("mem_bytes").as_u64() { d.mem_bytes = v; }
            if let Some(v) = g.get("flops").as_f64() { d.flops = v; }
            if let Some(v) = g.get("membw").as_f64() { d.membw = v; }
            if let Some(v) = g.get("nvlink").as_f64() { d.nvlink = v; }
            if let Some(v) = g.get("step_overhead_us").as_u64() { d.step_overhead_us = v; }
            if let Some(v) = g.get("compute_eff").as_f64() { d.compute_eff = v; }
            if let Some(v) = g.get("membw_eff").as_f64() { d.membw_eff = v; }
        }
        let f = j.get("fleet");
        if !f.is_null() {
            if let Some(v) = f.get("n_prefill").as_u64() { c.fleet.n_prefill = v as u32; }
            if let Some(v) = f.get("n_decode").as_u64() { c.fleet.n_decode = v as u32; }
            if let Some(v) = f.get("tp").as_u64() { c.fleet.tp = v as u32; }
        }
        let s = j.get("scheduler");
        if !s.is_null() {
            let d = &mut c.scheduler;
            if let Some(v) = s.get("theta").as_f64() { d.theta = v; }
            if let Some(v) = s.get("mem_safety").as_f64() { d.mem_safety = v; }
            if let Some(v) = s.get("l_max").as_u64() { d.l_max = v as u32; }
            if let Some(v) = s.get("max_batch").as_u64() { d.max_batch = v as u32; }
            if let Some(v) = s.get("policy").as_str() { d.policy = Policy::parse(v); }
            if let Some(v) = s.get("min_bucket_width").as_u64() { d.min_bucket_width = v as u32; }
            if let Some(v) = s.get("monitor_window_us").as_u64() { d.monitor_window_us = v; }
        }
        let sh = j.get("sharding");
        if !sh.is_null() {
            let d = &mut c.sharding;
            if let Some(v) = sh.get("shards").as_u64() { d.shards = v as u32; }
            if let Some(v) = sh.get("placement").as_str() { d.placement = Placement::parse(v); }
            if let Some(v) = sh.get("steal").as_bool() { d.steal = v; }
        }
        let p = j.get("priority");
        if !p.is_null() {
            let d = &mut c.priority;
            if let Some(v) = p.get("enabled").as_bool() { d.enabled = v; }
            if let Some(v) = p.get("online_weight").as_f64() { d.online_weight = v; }
            if let Some(v) = p.get("offline_weight").as_f64() { d.offline_weight = v; }
            if let Some(v) = p.get("aging_rate").as_f64() { d.aging_rate = v; }
            if let Some(v) = p.get("urgency_threshold").as_f64() { d.urgency_threshold = v; }
        }
        let pr = j.get("preempt");
        if !pr.is_null() {
            let d = &mut c.preempt;
            if let Some(v) = pr.get("enabled").as_bool() { d.enabled = v; }
            if let Some(v) = pr.get("urgency_threshold").as_f64() { d.urgency_threshold = v; }
            if let Some(v) = pr.get("max_abort_progress").as_f64() { d.max_abort_progress = v; }
            if let Some(v) = pr.get("max_evictions").as_u64() { d.max_evictions = v as u32; }
        }
        let ad = j.get("admission");
        if !ad.is_null() {
            let d = &mut c.admission;
            if let Some(v) = ad.get("enabled").as_bool() { d.enabled = v; }
            if let Some(v) = ad.get("defer").as_bool() { d.defer = v; }
            if let Some(v) = ad.get("evict").as_bool() { d.evict = v; }
            if let Some(v) = ad.get("slack_margin").as_f64() { d.slack_margin = v; }
            if let Some(v) = ad.get("offline_tbt_factor").as_f64() { d.offline_tbt_factor = v; }
            if let Some(v) = ad.get("max_evictions").as_u64() { d.max_evictions = v as u32; }
        }
        let px = j.get("prefix");
        if !px.is_null() {
            let d = &mut c.prefix;
            if let Some(v) = px.get("enabled").as_bool() { d.enabled = v; }
            if let Some(v) = px.get("block").as_u64() { d.block = v as u32; }
            if let Some(v) = px.get("cache_frac").as_f64() { d.cache_frac = v; }
        }
        let ch = j.get("chunk");
        if !ch.is_null() {
            let d = &mut c.chunk;
            if let Some(v) = ch.get("enabled").as_bool() { d.enabled = v; }
            if let Some(v) = ch.get("slice_tokens").as_u64() { d.slice_tokens = v as u32; }
            if let Some(v) = ch.get("hybrid").as_bool() { d.hybrid = v; }
            if let Some(v) = ch.get("interleave").as_bool() { d.interleave = v; }
        }
        let pl = j.get("planner");
        if !pl.is_null() {
            let d = &mut c.planner;
            if let Some(v) = pl.get("family").as_str() { d.family = PlannerFamily::parse(v); }
            if let Some(v) = pl.get("window").as_u64() { d.window = v as u32; }
            if let Some(v) = pl.get("commit_margin_us").as_u64() { d.commit_margin_us = v; }
            if let Some(v) = pl.get("offline_horizon_us").as_u64() { d.offline_horizon_us = v; }
        }
        let ex = j.get("executor");
        if !ex.is_null() {
            if let Some(v) = ex.get("threads").as_u64() {
                c.executor.threads = v as u32;
            }
            if let Some(v) = ex.get("plan_offload").as_bool() {
                c.executor.plan_offload = v;
            }
        }
        let rt = j.get("realtime");
        if !rt.is_null() {
            let d = &mut c.realtime;
            if let Some(v) = rt.get("stream_buf").as_u64() { d.stream_buf = v as u32; }
            if let Some(v) = rt.get("ewma_alpha").as_f64() { d.ewma_alpha = v; }
            if let Some(v) = rt.get("drain_timeout_ms").as_u64() { d.drain_timeout_ms = v; }
            if let Some(v) = rt.get("pace").as_f64() { d.pace = v; }
        }
        let o = j.get("slo");
        if !o.is_null() {
            if let Some(v) = o.get("ttft_us").as_u64() { c.slo.ttft_us = v; }
            if let Some(v) = o.get("tbt_us").as_u64() { c.slo.tbt_us = v; }
        }
        if let Some(v) = j.get("seed").as_u64() { c.seed = v; }
        c
    }

    /// Apply dotted CLI overrides (`--scheduler.theta 0.6`, `--seed 7`, ...).
    pub fn apply_overrides(&mut self, args: &Args) {
        for (k, v) in args.overrides() {
            match k {
                "scheduler.theta" => set_f64(&mut self.scheduler.theta, v),
                "scheduler.mem_safety" => set_f64(&mut self.scheduler.mem_safety, v),
                "scheduler.l_max" => set_u32(&mut self.scheduler.l_max, v),
                "scheduler.max_batch" => set_u32(&mut self.scheduler.max_batch, v),
                "scheduler.min_bucket_width" => set_u32(&mut self.scheduler.min_bucket_width, v),
                "scheduler.monitor_window_us" => {
                    if let Ok(x) = v.parse() { self.scheduler.monitor_window_us = x; }
                }
                "scheduler.policy" => self.scheduler.policy = Policy::parse(v),
                "sharding.shards" => set_u32(&mut self.sharding.shards, v),
                "sharding.placement" => {
                    self.sharding.placement = Placement::parse(v)
                }
                "sharding.steal" => set_bool(&mut self.sharding.steal, v),
                "priority.enabled" => set_bool(&mut self.priority.enabled, v),
                "priority.online_weight" => set_f64(&mut self.priority.online_weight, v),
                "priority.offline_weight" => set_f64(&mut self.priority.offline_weight, v),
                "priority.aging_rate" => set_f64(&mut self.priority.aging_rate, v),
                "priority.urgency_threshold" => {
                    set_f64(&mut self.priority.urgency_threshold, v)
                }
                "preempt.enabled" => set_bool(&mut self.preempt.enabled, v),
                "preempt.urgency_threshold" => {
                    set_f64(&mut self.preempt.urgency_threshold, v)
                }
                "preempt.max_abort_progress" => {
                    set_f64(&mut self.preempt.max_abort_progress, v)
                }
                "preempt.max_evictions" => {
                    set_u32(&mut self.preempt.max_evictions, v)
                }
                "admission.enabled" => {
                    set_bool(&mut self.admission.enabled, v)
                }
                "admission.defer" => set_bool(&mut self.admission.defer, v),
                "admission.evict" => set_bool(&mut self.admission.evict, v),
                "admission.slack_margin" => {
                    set_f64(&mut self.admission.slack_margin, v)
                }
                "admission.offline_tbt_factor" => {
                    set_f64(&mut self.admission.offline_tbt_factor, v)
                }
                "admission.max_evictions" => {
                    set_u32(&mut self.admission.max_evictions, v)
                }
                "prefix.enabled" => set_bool(&mut self.prefix.enabled, v),
                "prefix.block" => set_u32(&mut self.prefix.block, v),
                "prefix.cache_frac" => set_f64(&mut self.prefix.cache_frac, v),
                "chunk.enabled" => set_bool(&mut self.chunk.enabled, v),
                "chunk.slice_tokens" => {
                    set_u32(&mut self.chunk.slice_tokens, v)
                }
                "chunk.hybrid" => set_bool(&mut self.chunk.hybrid, v),
                "chunk.interleave" => set_bool(&mut self.chunk.interleave, v),
                "planner.family" => {
                    self.planner.family = PlannerFamily::parse(v)
                }
                "planner.window" => set_u32(&mut self.planner.window, v),
                "planner.commit_margin_us" => {
                    if let Ok(x) = v.parse() { self.planner.commit_margin_us = x; }
                }
                "planner.offline_horizon_us" => {
                    if let Ok(x) = v.parse() { self.planner.offline_horizon_us = x; }
                }
                "executor.threads" => set_u32(&mut self.executor.threads, v),
                "executor.plan_offload" => {
                    set_bool(&mut self.executor.plan_offload, v)
                }
                "realtime.stream_buf" => {
                    set_u32(&mut self.realtime.stream_buf, v)
                }
                "realtime.ewma_alpha" => {
                    set_f64(&mut self.realtime.ewma_alpha, v)
                }
                "realtime.drain_timeout_ms" => {
                    if let Ok(x) = v.parse() { self.realtime.drain_timeout_ms = x; }
                }
                "realtime.pace" => set_f64(&mut self.realtime.pace, v),
                "fleet.n_prefill" => set_u32(&mut self.fleet.n_prefill, v),
                "fleet.n_decode" => set_u32(&mut self.fleet.n_decode, v),
                "slo.ttft_us" => { if let Ok(x) = v.parse() { self.slo.ttft_us = x; } }
                "slo.tbt_us" => { if let Ok(x) = v.parse() { self.slo.tbt_us = x; } }
                "seed" => { if let Ok(x) = v.parse() { self.seed = x; } }
                _ => {}
            }
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::obj(vec![
                ("n_params", Json::num(self.model.n_params)),
                ("n_layers", Json::from(self.model.n_layers as u64)),
                ("n_heads", Json::from(self.model.n_heads as u64)),
                ("head_dim", Json::from(self.model.head_dim as u64)),
                ("bytes_per_el", Json::from(self.model.bytes_per_el as u64)),
                ("max_seq", Json::from(self.model.max_seq as u64)),
            ])),
            ("gpu", Json::obj(vec![
                ("mem_bytes", Json::from(self.gpu.mem_bytes)),
                ("flops", Json::num(self.gpu.flops)),
                ("membw", Json::num(self.gpu.membw)),
                ("nvlink", Json::num(self.gpu.nvlink)),
                ("step_overhead_us", Json::from(self.gpu.step_overhead_us)),
                ("compute_eff", Json::num(self.gpu.compute_eff)),
                ("membw_eff", Json::num(self.gpu.membw_eff)),
            ])),
            ("fleet", Json::obj(vec![
                ("n_prefill", Json::from(self.fleet.n_prefill as u64)),
                ("n_decode", Json::from(self.fleet.n_decode as u64)),
                ("tp", Json::from(self.fleet.tp as u64)),
            ])),
            ("scheduler", Json::obj(vec![
                ("theta", Json::num(self.scheduler.theta)),
                ("mem_safety", Json::num(self.scheduler.mem_safety)),
                ("l_max", Json::from(self.scheduler.l_max as u64)),
                ("max_batch", Json::from(self.scheduler.max_batch as u64)),
                ("policy", Json::from(self.scheduler.policy.name())),
                ("min_bucket_width", Json::from(self.scheduler.min_bucket_width as u64)),
                ("monitor_window_us", Json::from(self.scheduler.monitor_window_us)),
            ])),
            ("sharding", Json::obj(vec![
                ("shards", Json::from(self.sharding.shards as u64)),
                ("placement", Json::from(self.sharding.placement.name())),
                ("steal", Json::from(self.sharding.steal)),
            ])),
            ("priority", Json::obj(vec![
                ("enabled", Json::from(self.priority.enabled)),
                ("online_weight", Json::num(self.priority.online_weight)),
                ("offline_weight", Json::num(self.priority.offline_weight)),
                ("aging_rate", Json::num(self.priority.aging_rate)),
                ("urgency_threshold", Json::num(self.priority.urgency_threshold)),
            ])),
            ("preempt", Json::obj(vec![
                ("enabled", Json::from(self.preempt.enabled)),
                ("urgency_threshold", Json::num(self.preempt.urgency_threshold)),
                ("max_abort_progress", Json::num(self.preempt.max_abort_progress)),
                ("max_evictions", Json::from(self.preempt.max_evictions as u64)),
            ])),
            ("admission", Json::obj(vec![
                ("enabled", Json::from(self.admission.enabled)),
                ("defer", Json::from(self.admission.defer)),
                ("evict", Json::from(self.admission.evict)),
                ("slack_margin", Json::num(self.admission.slack_margin)),
                ("offline_tbt_factor", Json::num(self.admission.offline_tbt_factor)),
                ("max_evictions", Json::from(self.admission.max_evictions as u64)),
            ])),
            ("prefix", Json::obj(vec![
                ("enabled", Json::from(self.prefix.enabled)),
                ("block", Json::from(self.prefix.block as u64)),
                ("cache_frac", Json::num(self.prefix.cache_frac)),
            ])),
            ("chunk", Json::obj(vec![
                ("enabled", Json::from(self.chunk.enabled)),
                ("slice_tokens", Json::from(self.chunk.slice_tokens as u64)),
                ("hybrid", Json::from(self.chunk.hybrid)),
                ("interleave", Json::from(self.chunk.interleave)),
            ])),
            ("planner", Json::obj(vec![
                ("family", Json::from(self.planner.family.name())),
                ("window", Json::from(self.planner.window as u64)),
                ("commit_margin_us", Json::from(self.planner.commit_margin_us)),
                ("offline_horizon_us", Json::from(self.planner.offline_horizon_us)),
            ])),
            ("executor", Json::obj(vec![
                ("threads", Json::from(self.executor.threads as u64)),
                ("plan_offload", Json::from(self.executor.plan_offload)),
            ])),
            ("realtime", Json::obj(vec![
                ("stream_buf", Json::from(self.realtime.stream_buf as u64)),
                ("ewma_alpha", Json::num(self.realtime.ewma_alpha)),
                ("drain_timeout_ms", Json::from(self.realtime.drain_timeout_ms)),
                ("pace", Json::num(self.realtime.pace)),
            ])),
            ("slo", Json::obj(vec![
                ("ttft_us", Json::from(self.slo.ttft_us)),
                ("tbt_us", Json::from(self.slo.tbt_us)),
            ])),
            ("seed", Json::from(self.seed)),
        ])
    }
}

fn set_f64(slot: &mut f64, v: &str) {
    if let Ok(x) = v.parse() {
        *slot = x;
    }
}

fn set_u32(slot: &mut u32, v: &str) {
    if let Ok(x) = v.parse() {
        *slot = x;
    }
}

/// Boolean override parser shared by every on/off knob: unrecognized
/// values keep the default, so a typo can never silently flip a
/// subsystem switch (the knob-specific tests pin this).
fn set_bool(slot: &mut bool, v: &str) {
    match v.to_ascii_lowercase().as_str() {
        "true" | "1" | "yes" | "on" => *slot = true,
        "false" | "0" | "no" | "off" => *slot = false,
        _ => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_testbed() {
        let c = SystemConfig::default();
        assert_eq!(c.fleet.n_prefill + c.fleet.n_decode, 4);
        assert_eq!(c.model.n_layers, 40);
        assert_eq!(c.scheduler.theta, 0.5);
        assert_eq!(c.scheduler.mem_safety, 0.9);
    }

    #[test]
    fn kv_bytes_per_token_llama13b() {
        // 2 * 40 * 40 * 128 * 2 = 819,200 bytes/token.
        assert_eq!(ModelSpec::llama2_13b().kv_bytes_per_token(), 819_200);
    }

    #[test]
    fn json_round_trip() {
        let c = SystemConfig::default();
        let j = c.to_json();
        let c2 = SystemConfig::from_json(&Json::parse(&j.to_string()).unwrap());
        assert_eq!(c, c2);
    }

    #[test]
    fn partial_json_keeps_defaults() {
        let j = Json::parse(r#"{"scheduler":{"theta":0.75}}"#).unwrap();
        let c = SystemConfig::from_json(&j);
        assert_eq!(c.scheduler.theta, 0.75);
        assert_eq!(c.scheduler.mem_safety, 0.9);
        assert_eq!(c.model.n_layers, 40);
    }

    #[test]
    fn cli_overrides() {
        let args = Args::parse(
            ["--scheduler.theta", "0.6", "--fleet.n_prefill", "3",
             "--scheduler.policy", "ljf", "--seed", "7"]
                .iter()
                .map(|s| s.to_string()),
        );
        let mut c = SystemConfig::default();
        c.apply_overrides(&args);
        assert_eq!(c.scheduler.theta, 0.6);
        assert_eq!(c.fleet.n_prefill, 3);
        assert_eq!(c.scheduler.policy, Policy::Ljf);
        assert_eq!(c.seed, 7);
    }

    #[test]
    fn policy_parse() {
        assert_eq!(Policy::parse("SJF"), Policy::Sjf);
        assert_eq!(Policy::parse("weird"), Policy::Fcfs);
    }

    #[test]
    fn priority_defaults_on_and_overridable() {
        let c = SystemConfig::default();
        assert!(c.priority.enabled, "priority-aware scheduling is the default");
        assert!(c.priority.online_weight > c.priority.offline_weight);
        assert_eq!(c.scheduler.monitor_window_us, 10_000_000);

        let args = Args::parse(
            ["--priority.enabled", "false", "--priority.aging_rate", "0.5",
             "--scheduler.monitor_window_us", "2000000"]
                .iter()
                .map(|s| s.to_string()),
        );
        let mut c = SystemConfig::default();
        c.apply_overrides(&args);
        assert!(!c.priority.enabled);
        assert_eq!(c.priority.aging_rate, 0.5);
        assert_eq!(c.scheduler.monitor_window_us, 2_000_000);

        // A typo'd boolean must not silently flip the switch.
        let args = Args::parse(
            ["--priority.enabled", "ture"].iter().map(|s| s.to_string()),
        );
        let mut c = SystemConfig::default();
        c.apply_overrides(&args);
        assert!(c.priority.enabled, "unrecognized value keeps the default");
    }

    #[test]
    fn sharding_defaults_preserve_legacy_behavior() {
        let c = SystemConfig::default();
        assert_eq!(c.sharding.shards, 1, "default is the single global queue");
        assert!(!c.sharding.steal);
        assert_eq!(c.sharding.placement, Placement::LeastLoaded);
    }

    #[test]
    fn sharding_json_and_cli_overrides() {
        let j = Json::parse(
            r#"{"sharding":{"shards":0,"placement":"hash","steal":true}}"#,
        )
        .unwrap();
        let c = SystemConfig::from_json(&j);
        assert_eq!(c.sharding.shards, 0);
        assert_eq!(c.sharding.placement, Placement::Hash);
        assert!(c.sharding.steal);

        let args = Args::parse(
            ["--sharding.shards", "4", "--sharding.placement", "kv",
             "--sharding.steal", "on"]
                .iter()
                .map(|s| s.to_string()),
        );
        let mut c = SystemConfig::default();
        c.apply_overrides(&args);
        assert_eq!(c.sharding.shards, 4);
        assert_eq!(c.sharding.placement, Placement::JoinShortestKv);
        assert!(c.sharding.steal);

        // A typo'd boolean must not flip the steal switch.
        let args = Args::parse(
            ["--sharding.steal", "yep"].iter().map(|s| s.to_string()),
        );
        let mut c = SystemConfig::default();
        c.apply_overrides(&args);
        assert!(!c.sharding.steal);
    }

    #[test]
    fn placement_parse() {
        assert_eq!(Placement::parse("HASH"), Placement::Hash);
        assert_eq!(Placement::parse("join_shortest_kv"), Placement::JoinShortestKv);
        assert_eq!(Placement::parse("prefix"), Placement::PrefixAffinity);
        assert_eq!(Placement::parse("weird"), Placement::LeastLoaded);
        for p in [
            Placement::LeastLoaded,
            Placement::JoinShortestKv,
            Placement::Hash,
            Placement::PrefixAffinity,
        ] {
            assert_eq!(Placement::parse(p.name()), p, "name/parse round-trip");
        }
    }

    #[test]
    fn prefix_defaults_off_and_overridable() {
        let c = SystemConfig::default();
        assert!(!c.prefix.enabled, "prefix cache must be opt-in");
        assert!(c.prefix.block >= 1);
        assert!((0.0..=1.0).contains(&c.prefix.cache_frac));

        let args = Args::parse(
            ["--prefix.enabled", "on", "--prefix.block", "64",
             "--prefix.cache_frac", "0.25",
             "--sharding.placement", "prefix_affinity"]
                .iter()
                .map(|s| s.to_string()),
        );
        let mut c = SystemConfig::default();
        c.apply_overrides(&args);
        assert!(c.prefix.enabled);
        assert_eq!(c.prefix.block, 64);
        assert_eq!(c.prefix.cache_frac, 0.25);
        assert_eq!(c.sharding.placement, Placement::PrefixAffinity);

        // A typo'd boolean must not silently arm the subsystem.
        let args = Args::parse(
            ["--prefix.enabled", "yep"].iter().map(|s| s.to_string()),
        );
        let mut c = SystemConfig::default();
        c.apply_overrides(&args);
        assert!(!c.prefix.enabled);
    }

    #[test]
    fn prefix_json_block_parses() {
        let j = Json::parse(
            r#"{"prefix":{"enabled":true,"block":16}}"#,
        )
        .unwrap();
        let c = SystemConfig::from_json(&j);
        assert!(c.prefix.enabled);
        assert_eq!(c.prefix.block, 16);
        // Untouched fields keep defaults.
        assert_eq!(c.prefix.cache_frac, 0.5);
    }

    #[test]
    fn preempt_defaults_off_and_overridable() {
        let c = SystemConfig::default();
        assert!(!c.preempt.enabled, "preemption must be opt-in");
        assert!(c.preempt.urgency_threshold >= c.priority.urgency_threshold);
        assert!((0.0..=1.0).contains(&c.preempt.max_abort_progress));
        assert!(c.preempt.max_evictions >= 1);

        let args = Args::parse(
            ["--preempt.enabled", "on", "--preempt.urgency_threshold", "0.8",
             "--preempt.max_abort_progress", "0.3",
             "--preempt.max_evictions", "8"]
                .iter()
                .map(|s| s.to_string()),
        );
        let mut c = SystemConfig::default();
        c.apply_overrides(&args);
        assert!(c.preempt.enabled);
        assert_eq!(c.preempt.urgency_threshold, 0.8);
        assert_eq!(c.preempt.max_abort_progress, 0.3);
        assert_eq!(c.preempt.max_evictions, 8);

        // A typo'd boolean must not silently enable preemption.
        let args = Args::parse(
            ["--preempt.enabled", "yep"].iter().map(|s| s.to_string()),
        );
        let mut c = SystemConfig::default();
        c.apply_overrides(&args);
        assert!(!c.preempt.enabled);
    }

    #[test]
    fn preempt_json_block_parses() {
        let j = Json::parse(
            r#"{"preempt":{"enabled":true,"max_evictions":2}}"#,
        )
        .unwrap();
        let c = SystemConfig::from_json(&j);
        assert!(c.preempt.enabled);
        assert_eq!(c.preempt.max_evictions, 2);
        // Untouched fields keep defaults.
        assert_eq!(c.preempt.urgency_threshold, 0.9);
        assert_eq!(c.preempt.max_abort_progress, 0.5);
    }

    #[test]
    fn admission_defaults_off_and_overridable() {
        let c = SystemConfig::default();
        assert!(!c.admission.enabled, "TBT admission must be opt-in");
        assert!(c.admission.defer && c.admission.evict);
        assert!((0.0..1.0).contains(&c.admission.slack_margin));
        assert!(c.admission.offline_tbt_factor >= 1.0);
        assert!(c.admission.max_evictions >= 1);

        let args = Args::parse(
            ["--admission.enabled", "on", "--admission.defer", "off",
             "--admission.slack_margin", "0.25",
             "--admission.offline_tbt_factor", "4",
             "--admission.max_evictions", "8"]
                .iter()
                .map(|s| s.to_string()),
        );
        let mut c = SystemConfig::default();
        c.apply_overrides(&args);
        assert!(c.admission.enabled);
        assert!(!c.admission.defer);
        assert!(c.admission.evict, "untouched trigger keeps its default");
        assert_eq!(c.admission.slack_margin, 0.25);
        assert_eq!(c.admission.offline_tbt_factor, 4.0);
        assert_eq!(c.admission.max_evictions, 8);

        // A typo'd boolean must not silently arm the subsystem.
        let args = Args::parse(
            ["--admission.enabled", "yep"].iter().map(|s| s.to_string()),
        );
        let mut c = SystemConfig::default();
        c.apply_overrides(&args);
        assert!(!c.admission.enabled);
    }

    #[test]
    fn admission_json_block_parses() {
        let j = Json::parse(
            r#"{"admission":{"enabled":true,"evict":false,"slack_margin":0.2}}"#,
        )
        .unwrap();
        let c = SystemConfig::from_json(&j);
        assert!(c.admission.enabled);
        assert!(!c.admission.evict);
        assert_eq!(c.admission.slack_margin, 0.2);
        // Untouched fields keep defaults.
        assert!(c.admission.defer);
        assert_eq!(c.admission.offline_tbt_factor, 8.0);
        assert_eq!(c.admission.max_evictions, 2);
    }

    #[test]
    fn chunk_defaults_off_and_overridable() {
        let c = SystemConfig::default();
        assert!(!c.chunk.enabled, "chunked prefill must be opt-in");
        assert!(c.chunk.slice_tokens >= 1);
        assert!(c.chunk.hybrid && c.chunk.interleave);

        let args = Args::parse(
            ["--chunk.enabled", "on", "--chunk.slice_tokens", "512",
             "--chunk.hybrid", "off", "--chunk.interleave", "false"]
                .iter()
                .map(|s| s.to_string()),
        );
        let mut c = SystemConfig::default();
        c.apply_overrides(&args);
        assert!(c.chunk.enabled);
        assert_eq!(c.chunk.slice_tokens, 512);
        assert!(!c.chunk.hybrid);
        assert!(!c.chunk.interleave);

        // A typo'd boolean must not silently arm the subsystem.
        let args = Args::parse(
            ["--chunk.enabled", "yep"].iter().map(|s| s.to_string()),
        );
        let mut c = SystemConfig::default();
        c.apply_overrides(&args);
        assert!(!c.chunk.enabled);
    }

    #[test]
    fn chunk_json_block_parses() {
        let j = Json::parse(
            r#"{"chunk":{"enabled":true,"slice_tokens":1024}}"#,
        )
        .unwrap();
        let c = SystemConfig::from_json(&j);
        assert!(c.chunk.enabled);
        assert_eq!(c.chunk.slice_tokens, 1024);
        // Untouched fields keep defaults.
        assert!(c.chunk.hybrid);
        assert!(c.chunk.interleave);
    }

    #[test]
    fn planner_defaults_bucket_and_overridable() {
        let c = SystemConfig::default();
        assert_eq!(
            c.planner.family,
            PlannerFamily::Bucket,
            "the paper's bucket planner stays the default"
        );
        assert!(c.planner.window >= 1);
        assert!(c.planner.offline_horizon_us > c.planner.commit_margin_us);

        let args = Args::parse(
            ["--planner.family", "lookahead", "--planner.window", "8",
             "--planner.commit_margin_us", "20000",
             "--planner.offline_horizon_us", "5000000"]
                .iter()
                .map(|s| s.to_string()),
        );
        let mut c = SystemConfig::default();
        c.apply_overrides(&args);
        assert_eq!(c.planner.family, PlannerFamily::Lookahead);
        assert_eq!(c.planner.window, 8);
        assert_eq!(c.planner.commit_margin_us, 20_000);
        assert_eq!(c.planner.offline_horizon_us, 5_000_000);

        // A typo'd family must not silently switch planners.
        let args = Args::parse(
            ["--planner.family", "lookahed"].iter().map(|s| s.to_string()),
        );
        let mut c = SystemConfig::default();
        c.apply_overrides(&args);
        assert_eq!(c.planner.family, PlannerFamily::Bucket);
    }

    #[test]
    fn planner_json_block_parses() {
        let j = Json::parse(
            r#"{"planner":{"family":"fcfs","window":16}}"#,
        )
        .unwrap();
        let c = SystemConfig::from_json(&j);
        assert_eq!(c.planner.family, PlannerFamily::Fcfs);
        assert_eq!(c.planner.window, 16);
        // Untouched fields keep defaults.
        assert_eq!(c.planner.commit_margin_us, 50_000);
        assert_eq!(c.planner.offline_horizon_us, 10_000_000);
    }

    #[test]
    fn planner_family_parse() {
        assert_eq!(PlannerFamily::parse("LOOKAHEAD"), PlannerFamily::Lookahead);
        assert_eq!(PlannerFamily::parse("deadline"), PlannerFamily::Lookahead);
        assert_eq!(PlannerFamily::parse("fcfs"), PlannerFamily::Fcfs);
        assert_eq!(PlannerFamily::parse("weird"), PlannerFamily::Bucket);
        for f in [
            PlannerFamily::Bucket,
            PlannerFamily::Fcfs,
            PlannerFamily::Lookahead,
        ] {
            assert_eq!(PlannerFamily::parse(f.name()), f, "name/parse round-trip");
        }
    }

    #[test]
    fn executor_resolution_clamps_to_shards() {
        // Note: no test asserts the *default* thread count — it is
        // deliberately env-sensitive (EXECUTOR_THREADS) so CI can run the
        // whole suite through the parallel executor.
        let seq = ExecutorSpec { threads: 1, plan_offload: true };
        assert_eq!(seq.resolve(1), 1);
        assert_eq!(seq.resolve(8), 1);
        let per_shard = ExecutorSpec { threads: 0, plan_offload: true };
        assert_eq!(per_shard.resolve(1), 1);
        assert_eq!(per_shard.resolve(4), 4);
        assert_eq!(per_shard.resolve(0), 1, "degenerate fleet still runs");
        let fixed = ExecutorSpec { threads: 3, plan_offload: true };
        assert_eq!(fixed.resolve(8), 3);
        assert_eq!(fixed.resolve(2), 2, "never more workers than shards");
    }

    #[test]
    fn executor_json_and_cli_overrides() {
        let j =
            Json::parse(r#"{"executor":{"threads":4,"plan_offload":false}}"#)
                .unwrap();
        let c = SystemConfig::from_json(&j);
        assert_eq!(c.executor.threads, 4);
        assert!(!c.executor.plan_offload);

        let args = Args::parse(
            ["--executor.threads", "0", "--executor.plan_offload", "false"]
                .iter()
                .map(|s| s.to_string()),
        );
        let mut c = SystemConfig::default();
        c.apply_overrides(&args);
        assert_eq!(c.executor.threads, 0, "0 = one worker per shard");
        assert!(!c.executor.plan_offload, "plan offload CLI-disableable");
    }

    #[test]
    fn realtime_defaults_and_overridable() {
        let c = SystemConfig::default();
        assert!(c.realtime.stream_buf >= 1);
        assert!((0.0..=1.0).contains(&c.realtime.ewma_alpha));
        assert!(c.realtime.pace >= 1.0);

        let j = Json::parse(
            r#"{"realtime":{"stream_buf":8,"ewma_alpha":0.5,"pace":200.0}}"#,
        )
        .unwrap();
        let c = SystemConfig::from_json(&j);
        assert_eq!(c.realtime.stream_buf, 8);
        assert_eq!(c.realtime.ewma_alpha, 0.5);
        assert_eq!(c.realtime.pace, 200.0);
        // Untouched fields keep defaults.
        assert_eq!(c.realtime.drain_timeout_ms, 5_000);

        let args = Args::parse(
            ["--realtime.stream_buf", "16", "--realtime.drain_timeout_ms",
             "100", "--realtime.ewma_alpha", "0.3", "--realtime.pace", "50"]
                .iter()
                .map(|s| s.to_string()),
        );
        let mut c = SystemConfig::default();
        c.apply_overrides(&args);
        assert_eq!(c.realtime.stream_buf, 16);
        assert_eq!(c.realtime.drain_timeout_ms, 100);
        assert_eq!(c.realtime.ewma_alpha, 0.3);
        assert_eq!(c.realtime.pace, 50.0);
    }

    #[test]
    fn priority_json_block_parses() {
        let j = Json::parse(
            r#"{"priority":{"enabled":false,"urgency_threshold":0.9},
                "scheduler":{"monitor_window_us":5000000}}"#,
        )
        .unwrap();
        let c = SystemConfig::from_json(&j);
        assert!(!c.priority.enabled);
        assert_eq!(c.priority.urgency_threshold, 0.9);
        // Untouched fields keep defaults.
        assert_eq!(c.priority.online_weight, 1.0);
        assert_eq!(c.scheduler.monitor_window_us, 5_000_000);
    }
}
