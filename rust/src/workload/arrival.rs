//! Arrival processes: when requests hit the gateway.
//!
//! Open-loop Poisson arrivals (the standard serving-evaluation model, and
//! what "client RPS" means in Fig. 5) plus a bursty variant (Poisson bursts
//! of gamma-ish size) for stress tests.

use crate::util::rng::Pcg;
use crate::Micros;

/// A source of inter-arrival gaps.
pub trait ArrivalProcess {
    /// Next arrival timestamp strictly after `now`.
    fn next_after(&mut self, now: Micros) -> Micros;
}

/// Open-loop Poisson arrivals at `rps` requests/second.
#[derive(Debug, Clone)]
pub struct Poisson {
    rps: f64,
    rng: Pcg,
}

impl Poisson {
    pub fn new(rps: f64, rng: Pcg) -> Poisson {
        assert!(rps > 0.0);
        Poisson { rps, rng }
    }
}

impl ArrivalProcess for Poisson {
    fn next_after(&mut self, now: Micros) -> Micros {
        let gap_s = self.rng.exponential(self.rps);
        now + (gap_s * 1e6).max(1.0) as Micros
    }
}

/// Bursty arrivals: Poisson burst epochs at `burst_rps` bursts/second, each
/// burst delivering 1..=`max_burst` requests back-to-back (1 µs apart).
#[derive(Debug, Clone)]
pub struct Bursty {
    burst_rps: f64,
    max_burst: u32,
    rng: Pcg,
    pending: u32,
}

impl Bursty {
    pub fn new(burst_rps: f64, max_burst: u32, rng: Pcg) -> Bursty {
        assert!(burst_rps > 0.0 && max_burst >= 1);
        Bursty { burst_rps, max_burst, rng, pending: 0 }
    }

    /// Effective mean request rate (requests/second).
    pub fn mean_rps(&self) -> f64 {
        self.burst_rps * (1.0 + self.max_burst as f64) / 2.0
    }
}

impl ArrivalProcess for Bursty {
    fn next_after(&mut self, now: Micros) -> Micros {
        if self.pending > 0 {
            self.pending -= 1;
            return now + 1;
        }
        self.pending = self.rng.range(1, self.max_burst as usize) as u32 - 1;
        let gap_s = self.rng.exponential(self.burst_rps);
        now + (gap_s * 1e6).max(1.0) as Micros
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_converges() {
        let mut p = Poisson::new(20.0, Pcg::seeded(1));
        let mut t = 0;
        let n = 20_000;
        for _ in 0..n {
            t = p.next_after(t);
        }
        let rate = n as f64 / (t as f64 / 1e6);
        assert!((rate - 20.0).abs() < 1.0, "rate {rate}");
    }

    #[test]
    fn poisson_strictly_increasing() {
        let mut p = Poisson::new(1000.0, Pcg::seeded(2));
        let mut t = 0;
        for _ in 0..1000 {
            let next = p.next_after(t);
            assert!(next > t);
            t = next;
        }
    }

    #[test]
    fn bursty_mean_rate() {
        let mut b = Bursty::new(5.0, 8, Pcg::seeded(3));
        let expect = b.mean_rps();
        let mut t = 0;
        let n = 30_000;
        for _ in 0..n {
            t = b.next_after(t);
        }
        let rate = n as f64 / (t as f64 / 1e6);
        assert!((rate - expect).abs() / expect < 0.1, "rate {rate} expect {expect}");
    }

    #[test]
    fn bursty_produces_clusters() {
        let mut b = Bursty::new(2.0, 10, Pcg::seeded(4));
        let mut t = 0;
        let mut tight_gaps = 0;
        for _ in 0..1000 {
            let next = b.next_after(t);
            if next - t <= 1 {
                tight_gaps += 1;
            }
            t = next;
        }
        assert!(tight_gaps > 200, "tight {tight_gaps}");
    }
}
