//! The request: the unit every layer of the system schedules.

use crate::config::SloSpec;
use crate::Micros;

/// Resolve the per-token TBT budget (µs) of a sequence — the single
/// definition shared by the request helpers and the coordinator's
/// TBT-aware admission layer. An explicit stamped override wins;
/// otherwise the class default applies: the SLO's `tbt_us` for the
/// online class, `offline_factor ×` that for offline throughput work
/// (no interactive reader, but a lax pacing bound keeps starvation
/// visible in the TBT metrics).
pub fn class_tbt_budget_us(
    class: RequestClass,
    override_us: u64,
    slo: &SloSpec,
    offline_factor: f64,
) -> u64 {
    if override_us > 0 {
        return override_us;
    }
    match class {
        RequestClass::Online => slo.tbt_us,
        RequestClass::Offline => {
            (slo.tbt_us as f64 * offline_factor.max(1.0)) as u64
        }
    }
}

/// Unique, monotonically assigned request id.
pub type RequestId = u64;

/// Online (latency-SLO-bound) vs. offline (throughput-oriented) class,
/// mirroring the paper's application-layer task split.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestClass {
    Online,
    Offline,
}

/// One inference request flowing through the system.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub class: RequestClass,
    /// Prompt length in tokens (the bucketing key).
    pub input_len: u32,
    /// Target generation length (simulator: known; real engine: cap).
    pub output_len: u32,
    /// Arrival time at the gateway.
    pub arrival: Micros,
    /// Optional prompt token ids (real-engine runs only; simulator leaves
    /// this empty to keep traces light).
    pub tokens: Vec<u32>,
    /// Per-token inter-token (TBT) budget override in µs; 0 = the class
    /// default resolved by [`class_tbt_budget_us`]. Stamped per class by
    /// [`crate::workload::Trace::stamp_tbt`] and consumed by the
    /// TBT-aware admission layer
    /// ([`crate::coordinator::admission::AdmissionEngine`]).
    pub tbt_deadline_us: u64,
    /// Prefix lineage: requests sharing a `prefix_id != 0` share their
    /// leading `prefix_len` prompt tokens (a system prompt plus, for
    /// multi-turn sessions, the conversation so far). Stamped by trace
    /// generators ([`crate::workload::Trace::multi_turn`]); 0 = no shared
    /// prefix. Consumed by the prefix-cache subsystem
    /// ([`crate::coordinator::prefix`]) — inert unless it is armed.
    pub prefix_id: u64,
    /// Length (tokens) of the shareable leading prefix; capped at
    /// `input_len` by consumers. Meaningless when `prefix_id == 0`.
    pub prefix_len: u32,
    /// Runtime-only routing hint: the resident prefix match the placement
    /// layer observed at arrival (never serialized; rewritten per run).
    pub prefix_cached_hint: u32,
}

impl Request {
    pub fn new(
        id: RequestId,
        class: RequestClass,
        input_len: u32,
        output_len: u32,
        arrival: Micros,
    ) -> Request {
        Request {
            id,
            class,
            input_len,
            output_len,
            arrival,
            tokens: Vec::new(),
            tbt_deadline_us: 0,
            prefix_id: 0,
            prefix_len: 0,
            prefix_cached_hint: 0,
        }
    }

    /// Builder-style TBT-budget override (see [`Request::tbt_deadline_us`]).
    pub fn with_tbt_deadline(mut self, us: u64) -> Request {
        self.tbt_deadline_us = us;
        self
    }

    /// Builder-style prefix-lineage stamp (see [`Request::prefix_id`]).
    /// The shareable length is capped at the prompt length.
    pub fn with_prefix(mut self, prefix_id: u64, prefix_len: u32) -> Request {
        self.prefix_id = prefix_id;
        self.prefix_len = prefix_len.min(self.input_len);
        self
    }

    /// This request's per-token TBT budget under `slo`, resolving the
    /// stamped override against the class default (offline class gets
    /// `offline_factor ×` the online budget).
    pub fn tbt_budget_us(&self, slo: &SloSpec, offline_factor: f64) -> u64 {
        class_tbt_budget_us(self.class, self.tbt_deadline_us, slo, offline_factor)
    }

    /// Total KV-cache tokens this request will eventually hold.
    pub fn total_len(&self) -> u32 {
        self.input_len + self.output_len
    }

    /// How long the request has been waiting at `now`.
    pub fn waiting(&self, now: Micros) -> Micros {
        now.saturating_sub(self.arrival)
    }

    /// Latest time the first token can land within the TTFT SLO.
    pub fn ttft_deadline(&self, slo: &SloSpec) -> Micros {
        self.arrival.saturating_add(slo.ttft_us)
    }

    /// Signed slack to the TTFT deadline at `now` (negative = overdue);
    /// what the priority scorer's online urgency is derived from.
    pub fn ttft_slack(&self, slo: &SloSpec, now: Micros) -> i64 {
        self.ttft_deadline(slo) as i64 - now as i64
    }
}

/// Completion record produced by the serving loop; the metrics layer
/// derives every figure from a vector of these.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: RequestId,
    pub class: RequestClass,
    pub input_len: u32,
    pub output_len: u32,
    pub arrival: Micros,
    /// When prefill produced the first token.
    pub first_token: Micros,
    /// When the last token was produced.
    pub finished: Micros,
    /// Padded sequence length the prefill batch used (for waste accounting).
    pub padded_len: u32,
}

impl Completion {
    pub fn ttft(&self) -> Micros {
        self.first_token.saturating_sub(self.arrival)
    }

    pub fn e2e(&self) -> Micros {
        self.finished.saturating_sub(self.arrival)
    }

    /// Mean time between output tokens (µs/token) after the first.
    pub fn tbt(&self) -> f64 {
        if self.output_len <= 1 {
            return 0.0;
        }
        self.finished.saturating_sub(self.first_token) as f64
            / (self.output_len - 1) as f64
    }

    /// Eq. 2 per-request view: wasted fraction of the padded prefill slot.
    pub fn waste_ratio(&self) -> f64 {
        if self.padded_len == 0 {
            return 0.0;
        }
        (self.padded_len - self.input_len.min(self.padded_len)) as f64
            / self.padded_len as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn waiting_saturates() {
        let r = Request::new(1, RequestClass::Online, 10, 5, 1000);
        assert_eq!(r.waiting(1500), 500);
        assert_eq!(r.waiting(500), 0);
    }

    #[test]
    fn ttft_deadline_and_slack() {
        let slo = SloSpec { ttft_us: 400_000, tbt_us: 100_000 };
        let r = Request::new(1, RequestClass::Online, 10, 5, 100_000);
        assert_eq!(r.ttft_deadline(&slo), 500_000);
        assert_eq!(r.ttft_slack(&slo, 100_000), 400_000);
        assert_eq!(r.ttft_slack(&slo, 500_000), 0);
        assert_eq!(r.ttft_slack(&slo, 600_000), -100_000);
    }

    #[test]
    fn tbt_budget_resolves_override_then_class_default() {
        let slo = SloSpec { ttft_us: 400_000, tbt_us: 100_000 };
        let online = Request::new(1, RequestClass::Online, 10, 5, 0);
        let offline = Request::new(2, RequestClass::Offline, 10, 5, 0);
        assert_eq!(online.tbt_budget_us(&slo, 8.0), 100_000);
        assert_eq!(offline.tbt_budget_us(&slo, 8.0), 800_000);
        // A stamped override wins for either class.
        let stamped = online.clone().with_tbt_deadline(30_000);
        assert_eq!(stamped.tbt_budget_us(&slo, 8.0), 30_000);
        assert_eq!(
            class_tbt_budget_us(RequestClass::Offline, 55_000, &slo, 8.0),
            55_000
        );
        // A sub-1 factor never shrinks offline below the online budget.
        assert_eq!(
            class_tbt_budget_us(RequestClass::Offline, 0, &slo, 0.5),
            100_000
        );
    }

    #[test]
    fn prefix_stamp_caps_at_prompt_length() {
        let r = Request::new(1, RequestClass::Online, 100, 5, 0);
        assert_eq!((r.prefix_id, r.prefix_len), (0, 0), "unstamped default");
        let s = r.clone().with_prefix(7, 80);
        assert_eq!((s.prefix_id, s.prefix_len), (7, 80));
        let over = r.with_prefix(7, 400);
        assert_eq!(over.prefix_len, 100, "shareable prefix caps at prompt");
    }

    #[test]
    fn completion_derived_metrics() {
        let c = Completion {
            id: 1,
            class: RequestClass::Online,
            input_len: 100,
            output_len: 11,
            arrival: 0,
            first_token: 250_000,
            finished: 1_250_000,
            padded_len: 128,
        };
        assert_eq!(c.ttft(), 250_000);
        assert_eq!(c.e2e(), 1_250_000);
        assert!((c.tbt() - 100_000.0).abs() < 1e-9);
        assert!((c.waste_ratio() - 28.0 / 128.0).abs() < 1e-12);
    }

    #[test]
    fn single_token_tbt_zero() {
        let c = Completion {
            id: 1,
            class: RequestClass::Offline,
            input_len: 8,
            output_len: 1,
            arrival: 0,
            first_token: 10,
            finished: 10,
            padded_len: 8,
        };
        assert_eq!(c.tbt(), 0.0);
        assert_eq!(c.waste_ratio(), 0.0);
    }
}
