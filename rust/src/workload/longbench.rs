//! Synthetic LongBench length distribution.
//!
//! The paper (Fig. 2b) describes LongBench as a **long-tail** distribution
//! of very long summarization prompts (median 41,417 tokens) which they
//! truncate to the model context. We reproduce that pipeline: draw from a
//! heavy-tailed log-normal whose median sits far above any realistic
//! context window, then truncate to `max_seq` — so, exactly as in the
//! paper, the bulk of LongBench requests arrive *at* the context limit and
//! the rest fill the upper range. Outputs are short summaries
//! (log-normal, mean ≈ 200).

use super::LengthSampler;
use crate::util::rng::Pcg;

#[derive(Debug, Clone)]
pub struct LongBench {
    max_seq: u32,
    mu_in: f64,
    sigma_in: f64,
    mu_out: f64,
    sigma_out: f64,
}

impl LongBench {
    pub fn new(max_seq: u32) -> LongBench {
        LongBench {
            max_seq,
            // Median exp(mu) = 41,417 (the paper's reported median);
            // sigma 1.4 gives the long tail in both directions.
            mu_in: 41_417f64.ln(),
            sigma_in: 1.4,
            mu_out: 200f64.ln() - 0.6f64 * 0.6 / 2.0,
            sigma_out: 0.6,
        }
    }
}

impl LengthSampler for LongBench {
    fn sample(&self, rng: &mut Pcg) -> (u32, u32) {
        let raw = rng.lognormal(self.mu_in, self.sigma_in).round().max(1.0);
        // Truncate to the context limit minus a generation reserve, as a
        // serving stack must (otherwise truncated prompts leave no room
        // for the summary).
        let reserve = (self.max_seq / 8).clamp(1, 512);
        let cap = self.max_seq.saturating_sub(reserve).max(1);
        let input = (raw.min(u32::MAX as f64) as u32).min(cap);
        let output = rng.lognormal(self.mu_out, self.sigma_out).round().max(1.0);
        let output = (output as u32).min(self.max_seq.saturating_sub(input)).max(1);
        (input, output)
    }

    fn name(&self) -> &'static str {
        "longbench"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominated_by_truncation() {
        // With a 4096 context, most raw draws exceed it → arrive truncated,
        // exactly the paper's "for ultra-long sequences, we truncate" path.
        let s = LongBench::new(4096);
        let mut rng = Pcg::seeded(1);
        let n = 20_000;
        // Cap = 4096 − reserve(512) = 3584.
        let at_cap = (0..n)
            .filter(|_| s.sample(&mut rng).0 == 3584)
            .count();
        assert!(at_cap as f64 / n as f64 > 0.8, "at_cap {at_cap}");
    }

    #[test]
    fn long_tail_below_cap() {
        // Raise the cap: the untruncated draws show the heavy tail.
        let s = LongBench::new(200_000);
        let mut rng = Pcg::seeded(2);
        let mut xs: Vec<f64> = (0..20_000)
            .map(|_| s.sample(&mut rng).0 as f64)
            .collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[xs.len() / 2];
        assert!((median - 41_417.0).abs() / 41_417.0 < 0.1, "median {median}");
        let p95 = xs[(xs.len() as f64 * 0.95) as usize];
        assert!(p95 > 3.0 * median, "p95 {p95} median {median}");
    }

    #[test]
    fn outputs_are_short_summaries() {
        let s = LongBench::new(8192);
        let mut rng = Pcg::seeded(3);
        let n = 10_000;
        let mean_out = (0..n)
            .map(|_| s.sample(&mut rng).1 as f64)
            .sum::<f64>()
            / n as f64;
        assert!(mean_out > 100.0 && mean_out < 300.0, "mean_out {mean_out}");
    }
}
