//! Synthetic Stanford-Alpaca length distribution.
//!
//! The paper (Fig. 2a) reports Alpaca prompts averaging **83 tokens** with a
//! short-tailed, right-skewed shape concentrated under ~256 tokens. A
//! log-normal with median ≈ 64 and σ ≈ 0.72 reproduces mean ≈ 83 and keeps
//! ~97% of mass below 256. Outputs follow the instruction-following profile:
//! generations a bit longer than prompts on average (mean ≈ 110), also
//! log-normal.

use super::LengthSampler;
use crate::util::rng::Pcg;

#[derive(Debug, Clone)]
pub struct Alpaca {
    max_seq: u32,
    mu_in: f64,
    sigma_in: f64,
    mu_out: f64,
    sigma_out: f64,
}

impl Alpaca {
    pub fn new(max_seq: u32) -> Alpaca {
        Alpaca {
            max_seq,
            // exp(mu + sigma^2/2) = 83  with sigma = 0.72 → mu ≈ ln(83) - 0.259
            mu_in: 83f64.ln() - 0.72f64 * 0.72 / 2.0,
            sigma_in: 0.72,
            mu_out: 110f64.ln() - 0.8f64 * 0.8 / 2.0,
            sigma_out: 0.8,
        }
    }
}

impl LengthSampler for Alpaca {
    fn sample(&self, rng: &mut Pcg) -> (u32, u32) {
        let input = rng.lognormal(self.mu_in, self.sigma_in).round().max(1.0);
        let output = rng.lognormal(self.mu_out, self.sigma_out).round().max(1.0);
        let input = (input as u32).min(self.max_seq);
        // Leave at least one token of generation room inside the context.
        let output = (output as u32).min(self.max_seq.saturating_sub(input)).max(1);
        (input, output)
    }

    fn name(&self) -> &'static str {
        "alpaca"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_matches_paper() {
        let s = Alpaca::new(4096);
        let mut rng = Pcg::seeded(1);
        let n = 50_000;
        let mean = (0..n)
            .map(|_| s.sample(&mut rng).0 as f64)
            .sum::<f64>()
            / n as f64;
        // Paper: Alpaca sequences averaging 83 tokens.
        assert!((mean - 83.0).abs() < 4.0, "mean {mean}");
    }

    #[test]
    fn mostly_short() {
        let s = Alpaca::new(4096);
        let mut rng = Pcg::seeded(2);
        let n = 20_000;
        let short = (0..n)
            .filter(|_| s.sample(&mut rng).0 < 256)
            .count();
        assert!(short as f64 / n as f64 > 0.93);
    }

    #[test]
    fn respects_context_limit() {
        let s = Alpaca::new(128);
        let mut rng = Pcg::seeded(3);
        for _ in 0..5_000 {
            let (i, o) = s.sample(&mut rng);
            assert!(i >= 1 && o >= 1);
            assert!(i <= 128);
            assert!(i + o <= 129, "i {i} o {o}"); // o clamped to room, min 1
        }
    }
}
