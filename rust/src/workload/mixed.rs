//! Mixed workload: the paper's hybrid of Alpaca and LongBench samples
//! "following a long-tail distribution pattern" (Fig. 3 caption). We draw
//! each request from Alpaca with probability `p_short` (default 0.7) and
//! LongBench otherwise — short requests dominate by count, long requests
//! dominate by tokens, which is exactly the heterogeneity that breaks
//! naive batching.
//!
//! This sampler mixes *lengths* within one request class. The two-sided
//! SLO experiments instead mix *classes* — an offline backlog under an
//! online stream — via [`crate::workload::Trace::mixed_classes`], whose
//! per-class TBT budgets can be stamped with
//! [`crate::workload::Trace::stamp_tbt`] for the TBT-aware admission
//! layer (the `tbt_slo` bench pairs exactly those two calls).

use super::{alpaca::Alpaca, longbench::LongBench, LengthSampler};
use crate::util::rng::Pcg;

#[derive(Debug, Clone)]
pub struct Mixed {
    short: Alpaca,
    long: LongBench,
    p_short: f64,
}

impl Mixed {
    pub fn new(max_seq: u32) -> Mixed {
        Mixed::with_ratio(max_seq, 0.7)
    }

    pub fn with_ratio(max_seq: u32, p_short: f64) -> Mixed {
        Mixed {
            short: Alpaca::new(max_seq),
            long: LongBench::new(max_seq),
            p_short,
        }
    }
}

impl LengthSampler for Mixed {
    fn sample(&self, rng: &mut Pcg) -> (u32, u32) {
        if rng.chance(self.p_short) {
            self.short.sample(rng)
        } else {
            self.long.sample(rng)
        }
    }

    fn name(&self) -> &'static str {
        "mixed"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bimodal_shape() {
        let s = Mixed::new(4096);
        let mut rng = Pcg::seeded(1);
        let n = 20_000;
        let mut short = 0usize;
        let mut long = 0usize;
        for _ in 0..n {
            let (i, _) = s.sample(&mut rng);
            if i < 256 {
                short += 1;
            } else if i >= 1024 {
                long += 1;
            }
        }
        let fs = short as f64 / n as f64;
        let fl = long as f64 / n as f64;
        assert!(fs > 0.55 && fs < 0.8, "short frac {fs}");
        assert!(fl > 0.2 && fl < 0.4, "long frac {fl}");
    }

    #[test]
    fn long_requests_dominate_tokens() {
        let s = Mixed::new(4096);
        let mut rng = Pcg::seeded(2);
        let mut short_toks = 0u64;
        let mut long_toks = 0u64;
        for _ in 0..20_000 {
            let (i, _) = s.sample(&mut rng);
            if i < 256 {
                short_toks += i as u64;
            } else {
                long_toks += i as u64;
            }
        }
        assert!(long_toks > 5 * short_toks);
    }

    #[test]
    fn ratio_parameter_respected() {
        let s = Mixed::with_ratio(4096, 0.95);
        let mut rng = Pcg::seeded(3);
        let n = 10_000;
        let short = (0..n).filter(|_| s.sample(&mut rng).0 < 512).count();
        assert!(short as f64 / n as f64 > 0.9);
    }
}
