//! Workload generation: requests, length distributions, arrival processes.
//!
//! The paper evaluates on Stanford Alpaca (short prompts, mean ≈ 83 tokens)
//! and LongBench (long-tail summarization prompts, truncated to the model
//! context), plus a Mixed hybrid. Neither dataset ships in this offline
//! image, so [`alpaca`], [`longbench`], and [`mixed`] generate synthetic
//! length distributions fitted to the statistics the paper reports
//! (DESIGN.md §2); all scheduling behaviour depends only on these lengths.

pub mod request;
pub mod alpaca;
pub mod longbench;
pub mod mixed;
pub mod arrival;
pub mod trace;

pub use request::{class_tbt_budget_us, Request, RequestClass, RequestId};
pub use arrival::ArrivalProcess;
pub use trace::Trace;

use crate::util::rng::Pcg;

/// A source of (input_len, output_len) pairs.
pub trait LengthSampler {
    /// Draw one request's prompt and generation lengths.
    fn sample(&self, rng: &mut Pcg) -> (u32, u32);

    /// Human-readable dataset name.
    fn name(&self) -> &'static str;
}

/// Which synthetic dataset to draw from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataset {
    Alpaca,
    LongBench,
    Mixed,
}

impl Dataset {
    pub fn parse(s: &str) -> Dataset {
        match s.to_ascii_lowercase().as_str() {
            "longbench" | "long" => Dataset::LongBench,
            "mixed" => Dataset::Mixed,
            _ => Dataset::Alpaca,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Dataset::Alpaca => "alpaca",
            Dataset::LongBench => "longbench",
            Dataset::Mixed => "mixed",
        }
    }

    /// Build the sampler, truncating to the model context `max_seq`.
    pub fn sampler(&self, max_seq: u32) -> Box<dyn LengthSampler + Send> {
        match self {
            Dataset::Alpaca => Box::new(alpaca::Alpaca::new(max_seq)),
            Dataset::LongBench => Box::new(longbench::LongBench::new(max_seq)),
            Dataset::Mixed => Box::new(mixed::Mixed::new(max_seq)),
        }
    }
}
