//! Replayable traces: a fully materialized list of requests with arrival
//! times. Every experiment generates its trace up front (seeded), so all
//! three systems replay *identical* arrivals — the comparisons in the
//! Fig. 5 benches are paired, not merely distributionally matched.

use super::{ArrivalProcess, Dataset, Request, RequestClass};
use crate::util::json::Json;
use crate::util::rng::Pcg;
use crate::workload::arrival::Poisson;
use crate::Micros;

/// A generated or loaded request trace, sorted by arrival time.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub requests: Vec<Request>,
}

impl Trace {
    /// Generate `n` requests from `dataset` with Poisson arrivals at `rps`.
    pub fn generate(
        dataset: Dataset,
        n: usize,
        rps: f64,
        class: RequestClass,
        max_seq: u32,
        seed: u64,
    ) -> Trace {
        let mut len_rng = Pcg::new(seed, 1);
        let mut arr = Poisson::new(rps, Pcg::new(seed, 2));
        let sampler = dataset.sampler(max_seq);
        let mut t: Micros = 0;
        let mut requests = Vec::with_capacity(n);
        for id in 0..n {
            t = arr.next_after(t);
            let (input, output) = sampler.sample(&mut len_rng);
            requests.push(Request::new(id as u64, class, input, output, t));
        }
        Trace { requests }
    }

    /// Generate a batch-arrival trace: all `n` requests arrive at t=0
    /// (the offline, Fig. 5a/5b setting).
    pub fn batch(
        dataset: Dataset,
        n: usize,
        class: RequestClass,
        max_seq: u32,
        seed: u64,
    ) -> Trace {
        let mut len_rng = Pcg::new(seed, 1);
        let sampler = dataset.sampler(max_seq);
        let requests = (0..n)
            .map(|id| {
                let (input, output) = sampler.sample(&mut len_rng);
                Request::new(id as u64, class, input, output, 0)
            })
            .collect();
        Trace { requests }
    }

    /// Mixed-class trace: an offline throughput backlog (all at t=0) plus
    /// an online Poisson stream — the priority subsystem's target
    /// workload. Ids are reassigned in arrival order so every system sees
    /// a well-formed trace.
    pub fn mixed_classes(
        online_dataset: Dataset,
        n_online: usize,
        rps: f64,
        offline_dataset: Dataset,
        n_offline: usize,
        max_seq: u32,
        seed: u64,
    ) -> Trace {
        let online = Trace::generate(
            online_dataset, n_online, rps, RequestClass::Online, max_seq, seed,
        );
        let offline = Trace::batch(
            offline_dataset,
            n_offline,
            RequestClass::Offline,
            max_seq,
            seed.wrapping_add(1),
        );
        let mut requests = offline.requests;
        requests.extend(online.requests);
        requests.sort_by_key(|r| r.arrival); // stable: offline first at t=0
        for (i, r) in requests.iter_mut().enumerate() {
            r.id = i as u64;
        }
        Trace { requests }
    }

    /// Multi-turn chat sessions — the prefix-cache subsystem's target
    /// workload. `n_sessions` concurrent sessions each run
    /// `turns_per_session` online turns; requests arrive as one global
    /// Poisson stream at `rps`, round-robined across sessions so turn
    /// order within a session follows arrival order. Session `s` carries
    /// lineage `prefix_id = s + 1`, its turns share a per-session system
    /// prompt, and every turn's prompt is the full conversation so far
    /// (context + the turn's fresh user text, capped at `max_seq`):
    /// `prefix_len` marks the shared context, so an armed prefix cache
    /// can serve each turn from the previous turn's resident KV. The
    /// stamps are inert unless the run arms
    /// [`crate::config::PrefixSpec`].
    pub fn multi_turn(
        dataset: Dataset,
        n_sessions: usize,
        turns_per_session: usize,
        rps: f64,
        max_seq: u32,
        seed: u64,
    ) -> Trace {
        assert!(n_sessions > 0 && turns_per_session > 0);
        let mut len_rng = Pcg::new(seed, 1);
        let mut arr = Poisson::new(rps, Pcg::new(seed, 2));
        let mut sys_rng = Pcg::new(seed, 3);
        let sampler = dataset.sampler(max_seq);
        // Per-session shared system prompt and running context length.
        let mut context: Vec<u32> = (0..n_sessions)
            .map(|_| (sys_rng.range_u64(64, 512) as u32).min(max_seq))
            .collect();
        let n = n_sessions * turns_per_session;
        let mut t: Micros = 0;
        let mut requests = Vec::with_capacity(n);
        for k in 0..n {
            t = arr.next_after(t);
            let s = k % n_sessions;
            let (fresh, output) = sampler.sample(&mut len_rng);
            let shared = context[s];
            let input = shared.saturating_add(fresh.max(1)).min(max_seq);
            requests.push(
                Request::new(k as u64, RequestClass::Online, input, output, t)
                    .with_prefix(s as u64 + 1, shared),
            );
            // Next turn replays this turn's full exchange as context.
            context[s] = input.saturating_add(output).min(max_seq);
        }
        Trace { requests }
    }

    /// Stamp per-class TBT budgets onto every request (builder-style):
    /// a nonzero value overrides that class's per-token budget, 0 leaves
    /// the class at the run-time default (`slo.tbt_us` for online,
    /// `admission.offline_tbt_factor ×` that for offline). Stamps never
    /// affect *scheduling* unless the run enables the TBT-aware
    /// admission layer; the per-token gap *measurement* in `RunReport`
    /// classifies violations against the stamped budget either way, so
    /// paired on/off comparisons must stamp both runs identically.
    pub fn stamp_tbt(mut self, online_us: u64, offline_us: u64) -> Trace {
        for r in &mut self.requests {
            let us = match r.class {
                RequestClass::Online => online_us,
                RequestClass::Offline => offline_us,
            };
            if us > 0 {
                r.tbt_deadline_us = us;
            }
        }
        self
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Duration between first and last arrival.
    pub fn span(&self) -> Micros {
        match (self.requests.first(), self.requests.last()) {
            (Some(f), Some(l)) => l.arrival - f.arrival,
            _ => 0,
        }
    }

    /// Total prompt + generation tokens.
    pub fn total_tokens(&self) -> u64 {
        self.requests.iter().map(|r| r.total_len() as u64).sum()
    }

    /// Serialize for replay / the TCP client.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.requests
                .iter()
                .map(|r| {
                    let mut fields = vec![
                        ("id", Json::from(r.id)),
                        ("class", Json::from(match r.class {
                            RequestClass::Online => "online",
                            RequestClass::Offline => "offline",
                        })),
                        ("input_len", Json::from(r.input_len as u64)),
                        ("output_len", Json::from(r.output_len as u64)),
                        ("arrival", Json::from(r.arrival)),
                    ];
                    // Emitted only when stamped, so unstamped traces keep
                    // their legacy byte-for-byte serialization.
                    if r.tbt_deadline_us > 0 {
                        fields.push((
                            "tbt_deadline_us",
                            Json::from(r.tbt_deadline_us),
                        ));
                    }
                    if r.prefix_id != 0 {
                        fields.push(("prefix_id", Json::from(r.prefix_id)));
                        fields.push((
                            "prefix_len",
                            Json::from(r.prefix_len as u64),
                        ));
                    }
                    Json::obj(fields)
                })
                .collect(),
        )
    }

    /// Parse a serialized trace.
    pub fn from_json(j: &Json) -> anyhow::Result<Trace> {
        let arr = j.as_arr().ok_or_else(|| anyhow::anyhow!("trace: not an array"))?;
        let mut requests = Vec::with_capacity(arr.len());
        for item in arr {
            let class = match item.get("class").as_str() {
                Some("offline") => RequestClass::Offline,
                _ => RequestClass::Online,
            };
            let mut req = Request::new(
                item.get("id").as_u64().unwrap_or(requests.len() as u64),
                class,
                item.get("input_len").as_u64().unwrap_or(1) as u32,
                item.get("output_len").as_u64().unwrap_or(1) as u32,
                item.get("arrival").as_u64().unwrap_or(0),
            );
            req.tbt_deadline_us =
                item.get("tbt_deadline_us").as_u64().unwrap_or(0);
            req.prefix_id = item.get("prefix_id").as_u64().unwrap_or(0);
            req.prefix_len = item
                .get("prefix_len")
                .as_u64()
                .unwrap_or(0)
                .min(req.input_len as u64) as u32;
            requests.push(req);
        }
        requests.sort_by_key(|r| r.arrival);
        Ok(Trace { requests })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_is_deterministic() {
        let a = Trace::generate(Dataset::Alpaca, 100, 8.0, RequestClass::Online, 4096, 7);
        let b = Trace::generate(Dataset::Alpaca, 100, 8.0, RequestClass::Online, 4096, 7);
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.input_len, y.input_len);
            assert_eq!(x.arrival, y.arrival);
        }
    }

    #[test]
    fn seeds_differ() {
        let a = Trace::generate(Dataset::Alpaca, 50, 8.0, RequestClass::Online, 4096, 1);
        let b = Trace::generate(Dataset::Alpaca, 50, 8.0, RequestClass::Online, 4096, 2);
        let same = a
            .requests
            .iter()
            .zip(&b.requests)
            .filter(|(x, y)| x.input_len == y.input_len)
            .count();
        assert!(same < 10);
    }

    #[test]
    fn arrivals_sorted_and_rate_close() {
        let t = Trace::generate(Dataset::Mixed, 2000, 16.0, RequestClass::Online, 4096, 3);
        assert!(t.requests.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        let rate = t.len() as f64 / (t.span() as f64 / 1e6);
        assert!((rate - 16.0).abs() < 2.0, "rate {rate}");
    }

    #[test]
    fn batch_trace_all_at_zero() {
        let t = Trace::batch(Dataset::Alpaca, 64, RequestClass::Offline, 4096, 5);
        assert!(t.requests.iter().all(|r| r.arrival == 0));
        assert_eq!(t.len(), 64);
    }

    #[test]
    fn mixed_classes_combines_both_streams() {
        let t = Trace::mixed_classes(
            Dataset::Alpaca, 20, 8.0, Dataset::LongBench, 30, 4096, 7,
        );
        assert_eq!(t.len(), 50);
        assert!(t.requests.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        let n_online = t
            .requests
            .iter()
            .filter(|r| r.class == RequestClass::Online)
            .count();
        assert_eq!(n_online, 20);
        let n_offline = t.len() - n_online;
        assert_eq!(n_offline, 30);
        // Offline backlog lands at t=0; ids are arrival-ordered and unique.
        assert!(t
            .requests
            .iter()
            .filter(|r| r.class == RequestClass::Offline)
            .all(|r| r.arrival == 0));
        let ids: Vec<u64> = t.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..50).collect::<Vec<u64>>());
    }

    #[test]
    fn multi_turn_sessions_share_growing_prefixes() {
        let t = Trace::multi_turn(Dataset::Alpaca, 4, 5, 8.0, 4096, 11);
        assert_eq!(t.len(), 20);
        assert!(t.requests.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        assert!(t.requests.iter().all(|r| r.class == RequestClass::Online));
        // Deterministic for a seed.
        let t2 = Trace::multi_turn(Dataset::Alpaca, 4, 5, 8.0, 4096, 11);
        for (a, b) in t.requests.iter().zip(&t2.requests) {
            assert_eq!((a.input_len, a.prefix_id, a.prefix_len),
                       (b.input_len, b.prefix_id, b.prefix_len));
        }
        for sid in 1..=4u64 {
            let turns: Vec<&Request> = t
                .requests
                .iter()
                .filter(|r| r.prefix_id == sid)
                .collect();
            assert_eq!(turns.len(), 5, "round-robin fills every session");
            // First turn shares only the system prompt; every later
            // turn's shared context is the previous turn's full exchange
            // (capped), so the prefix grows monotonically.
            assert!(turns[0].prefix_len >= 64);
            for w in turns.windows(2) {
                assert!(w[1].prefix_len >= w[0].prefix_len);
                assert_eq!(
                    w[1].prefix_len,
                    (w[0].input_len + w[0].output_len).min(4096),
                    "turn context replays the prior exchange"
                );
            }
            for r in &turns {
                assert!(r.prefix_len <= r.input_len);
                assert!(r.input_len <= 4096);
            }
        }
    }

    #[test]
    fn prefix_lineage_round_trips_and_unstamped_traces_omit_keys() {
        let plain = Trace::generate(
            Dataset::Alpaca, 10, 8.0, RequestClass::Online, 4096, 3,
        );
        assert!(!plain.to_json().to_string().contains("prefix_id"));
        let t = Trace::multi_turn(Dataset::Alpaca, 3, 4, 8.0, 4096, 7);
        let j = t.to_json().to_string();
        assert!(j.contains("prefix_id") && j.contains("prefix_len"));
        let t2 = Trace::from_json(&Json::parse(&j).unwrap()).unwrap();
        for (a, b) in t.requests.iter().zip(&t2.requests) {
            assert_eq!(a.prefix_id, b.prefix_id);
            assert_eq!(a.prefix_len, b.prefix_len);
            assert_eq!(b.prefix_cached_hint, 0, "runtime hint never persists");
        }
    }

    #[test]
    fn json_round_trip() {
        let t = Trace::generate(Dataset::LongBench, 20, 4.0, RequestClass::Offline, 4096, 9);
        let j = t.to_json().to_string();
        let t2 = Trace::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(t.len(), t2.len());
        for (a, b) in t.requests.iter().zip(&t2.requests) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.input_len, b.input_len);
            assert_eq!(a.output_len, b.output_len);
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.class, b.class);
        }
    }

    #[test]
    fn stamp_tbt_sets_budgets_per_class_and_round_trips() {
        let t = Trace::mixed_classes(
            Dataset::Alpaca, 10, 8.0, Dataset::LongBench, 10, 4096, 7,
        );
        // Unstamped serialization carries no TBT key at all.
        assert!(!t.to_json().to_string().contains("tbt_deadline_us"));
        let t = t.stamp_tbt(30_000, 0);
        for r in &t.requests {
            match r.class {
                RequestClass::Online => assert_eq!(r.tbt_deadline_us, 30_000),
                RequestClass::Offline => {
                    assert_eq!(r.tbt_deadline_us, 0, "0 leaves a class unset")
                }
            }
        }
        let j = t.to_json().to_string();
        let t2 = Trace::from_json(&Json::parse(&j).unwrap()).unwrap();
        for (a, b) in t.requests.iter().zip(&t2.requests) {
            assert_eq!(a.tbt_deadline_us, b.tbt_deadline_us);
        }
    }
}
