//! `bucketserve` CLI: the leader entrypoint.
//!
//! ```text
//! bucketserve run     --system bucketserve|distserve|uellm --dataset alpaca|longbench|mixed
//!                     [--n 200] [--rps 8] [--offline] [--engine sim|pjrt]
//!                     [--config cfg.json] [--scheduler.theta 0.5] [--json]
//! bucketserve serve   --addr 127.0.0.1:7777 [--system ...]      (TCP gateway;
//!                     [--realtime] = wall-clock streaming path)
//! bucketserve smoke   [--realtime.pace 20000]   (in-process realtime round trip)
//! bucketserve compare --dataset mixed --n 200 [--rps 8]          (3 systems, one trace)
//! bucketserve info                                               (config + artifact dump)
//! ```

use bucketserve::baselines::System;
use bucketserve::cluster::sim::SimEngine;
use bucketserve::cluster::Engine;
use bucketserve::config::SystemConfig;
use bucketserve::metrics::Summary;
use bucketserve::server::{RealtimeServer, Server, TcpClient};
use bucketserve::util::json::Json;
use bucketserve::util::bench::{f1, f2, Table};
use bucketserve::util::cli::Args;
use bucketserve::workload::{Dataset, RequestClass, Trace};
use bucketserve::{log_info, runtime};

fn main() {
    bucketserve::util::logging::init();
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "run" => cmd_run(&args),
        "serve" => cmd_serve(&args),
        "smoke" => cmd_smoke(&args),
        "compare" => cmd_compare(&args),
        "info" => cmd_info(&args),
        _ => {
            print_help();
            0
        }
    };
    std::process::exit(code);
}

fn load_config(args: &Args) -> SystemConfig {
    let mut cfg = match args.raw("config") {
        Some(path) => SystemConfig::load(path, args).unwrap_or_else(|e| {
            eprintln!("config error: {e}");
            std::process::exit(2);
        }),
        None => {
            let mut c = if args.raw("engine") == Some("pjrt") {
                SystemConfig::tiny_pjrt()
            } else {
                SystemConfig::default()
            };
            c.apply_overrides(args);
            c
        }
    };
    if let Some(seed) = args.get::<u64>("seed") {
        cfg.seed = seed;
    }
    cfg
}

fn make_trace(args: &Args, cfg: &SystemConfig) -> Trace {
    let dataset = Dataset::parse(args.raw("dataset").unwrap_or("alpaca"));
    let n = args.get_or("n", 100usize);
    let class = if args.flag("offline") {
        RequestClass::Offline
    } else {
        RequestClass::Online
    };
    if args.flag("offline") && args.get::<f64>("rps").is_none() {
        Trace::batch(dataset, n, class, cfg.model.max_seq, cfg.seed)
    } else {
        let rps = args.get_or("rps", 8.0f64);
        Trace::generate(dataset, n, rps, class, cfg.model.max_seq, cfg.seed)
    }
}

fn run_system(
    system: System,
    cfg: &SystemConfig,
    trace: &Trace,
    engine: &mut dyn Engine,
) -> bucketserve::coordinator::RunReport {
    match system {
        System::BucketServe => {
            bucketserve::BucketServe::new(cfg.clone()).run(trace, engine)
        }
        System::DistServe => {
            bucketserve::baselines::DistServe::new(cfg.clone()).run(trace, engine)
        }
        System::Uellm => {
            bucketserve::baselines::Uellm::new(cfg.clone()).run(trace, engine)
        }
    }
}

fn cmd_run(args: &Args) -> i32 {
    let cfg = load_config(args);
    let system = System::parse(args.raw("system").unwrap_or("bucketserve"));
    let trace = make_trace(args, &cfg);
    log_info!(
        "running {} on {} requests ({} engine)",
        system.name(),
        trace.len(),
        args.raw("engine").unwrap_or("sim")
    );

    let report = if args.raw("engine") == Some("pjrt") {
        let dir = args.raw("artifacts").unwrap_or(runtime::DEFAULT_ARTIFACTS_DIR);
        if !runtime::artifacts_available(dir) {
            eprintln!("artifacts not found in {dir}; run `make artifacts`");
            return 2;
        }
        let mut engine = match runtime::PjrtEngine::load(dir) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("pjrt engine: {e}");
                return 2;
            }
        };
        run_system(system, &cfg, &trace, &mut engine)
    } else {
        let mut engine = SimEngine::new(&cfg);
        run_system(system, &cfg, &trace, &mut engine)
    };

    let summary = Summary::from_report(system.name(), &report, &cfg.slo);
    if args.flag("json") {
        println!("{}", summary.to_json());
    } else {
        let mut t = Table::new(&["metric", "value"]);
        t.row(vec!["requests".into(), summary.n_requests.to_string()]);
        t.row(vec!["makespan (s)".into(), f2(summary.makespan_s)]);
        t.row(vec!["throughput (tok/s)".into(), f1(summary.throughput_tps)]);
        t.row(vec!["output tok/s".into(), f1(summary.output_tps)]);
        t.row(vec!["server RPS".into(), f2(summary.server_rps)]);
        t.row(vec!["GPU util".into(), f2(summary.gpu_util)]);
        t.row(vec!["SLO attainment".into(), f2(summary.slo_attainment)]);
        t.row(vec!["mean TTFT (ms)".into(), f1(summary.mean_ttft_ms)]);
        t.row(vec!["p99 TTFT (ms)".into(), f1(summary.p99_ttft_ms)]);
        t.row(vec!["mean E2E (ms)".into(), f1(summary.mean_e2e_ms)]);
        t.row(vec!["mean waste ratio".into(), f2(summary.mean_waste_ratio)]);
        t.row(vec!["peak batch".into(), summary.peak_batch.to_string()]);
        t.row(vec!["max buckets".into(), summary.max_buckets.to_string()]);
        t.row(vec![
            "bucketing overhead (ms)".into(),
            f2(summary.bucket_overhead_ms),
        ]);
        t.print(&format!("{} / {}", system.name(), args.raw("dataset").unwrap_or("alpaca")));
    }
    0
}

fn cmd_compare(args: &Args) -> i32 {
    let cfg = load_config(args);
    let trace = make_trace(args, &cfg);
    let mut t = Table::new(&[
        "system", "tok/s", "RPS", "util", "SLO", "TTFT ms", "E2E ms", "waste",
    ]);
    for system in System::ALL {
        let report = system.run_sim(&cfg, &trace);
        let s = Summary::from_report(system.name(), &report, &cfg.slo);
        t.row(vec![
            s.system.clone(),
            f1(s.throughput_tps),
            f2(s.server_rps),
            f2(s.gpu_util),
            f2(s.slo_attainment),
            f1(s.mean_ttft_ms),
            f1(s.mean_e2e_ms),
            f2(s.mean_waste_ratio),
        ]);
    }
    t.print(&format!(
        "compare — {} × {} requests",
        args.raw("dataset").unwrap_or("alpaca"),
        trace.len()
    ));
    0
}

fn cmd_serve(args: &Args) -> i32 {
    let cfg = load_config(args);
    let addr = args.raw("addr").unwrap_or("127.0.0.1:7777").to_string();
    if args.flag("realtime") {
        let server = RealtimeServer::new(cfg);
        log_info!("realtime gateway listening on {addr}");
        return match server.serve(&addr, |a| println!("listening on {a}")) {
            Ok(summary) => {
                println!("{}", summary.to_json());
                0
            }
            Err(e) => {
                eprintln!("serve: {e}");
                2
            }
        };
    }
    let system = System::parse(args.raw("system").unwrap_or("bucketserve"));
    let server = Server::new(cfg, system);
    log_info!("gateway listening on {addr} ({})", system.name());
    if let Err(e) = server.serve(&addr, |a| println!("listening on {a}")) {
        eprintln!("serve: {e}");
        return 2;
    }
    0
}

/// `bucketserve smoke` — spin up the realtime server in-process, run a
/// scripted client against it over a real socket, and verify streamed
/// delivery + introspection end to end. Exit code 0 only on full success
/// (CI's serve-smoke job wraps this in a timeout).
fn cmd_smoke(args: &Args) -> i32 {
    match run_smoke(args) {
        Ok(()) => {
            println!("smoke: ok");
            0
        }
        Err(e) => {
            eprintln!("smoke: FAILED: {e}");
            2
        }
    }
}

fn run_smoke(args: &Args) -> anyhow::Result<()> {
    let mut cfg = load_config(args);
    if args.raw("realtime.pace").is_none() {
        // Compress wall time so the smoke run finishes in well under a
        // second; the protocol exercised is identical to pace 1.0.
        cfg.realtime.pace = 20_000.0;
    }
    let (btx, brx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        RealtimeServer::new(cfg).serve("127.0.0.1:0", move |a| {
            let _ = btx.send(a);
        })
    });
    let addr = brx.recv()?;
    let mut c = TcpClient::connect(&addr)?;

    let pong = c.call(&Json::obj(vec![("op", Json::from("ping"))]))?;
    anyhow::ensure!(
        pong.get("realtime").as_bool() == Some(true),
        "not a realtime server: {pong}"
    );

    for (input, output, class) in
        [(64u64, 4u64, "online"), (96, 6, "online"), (128, 8, "offline")]
    {
        let ack = c.call(&Json::obj(vec![
            ("op", Json::from("submit")),
            ("input_len", Json::from(input)),
            ("output_len", Json::from(output)),
            ("class", Json::from(class)),
        ]))?;
        anyhow::ensure!(
            ack.get("ok").as_bool() == Some(true),
            "submit rejected: {ack}"
        );
        let id = ack
            .get("id")
            .as_u64()
            .ok_or_else(|| anyhow::anyhow!("no id in ack: {ack}"))?;
        let mut last_seq = 0u64;
        loop {
            let j = c.read_line()?;
            anyhow::ensure!(
                j.get("id").as_u64() == Some(id),
                "cross-stream line: {j}"
            );
            if j.get("done").as_bool() == Some(true) {
                anyhow::ensure!(
                    j.get("output_len").as_u64() == Some(output),
                    "bad summary line: {j}"
                );
                break;
            }
            anyhow::ensure!(j.get("aborted").is_null(), "unexpected abort: {j}");
            let seq = j
                .get("seq")
                .as_u64()
                .ok_or_else(|| anyhow::anyhow!("bad token line: {j}"))?;
            anyhow::ensure!(seq > last_seq, "non-monotone token seq: {j}");
            last_seq = seq;
        }
    }

    let health = c.call(&Json::obj(vec![("op", Json::from("health"))]))?;
    anyhow::ensure!(
        health.get("completions").as_u64() == Some(3),
        "bad health after 3 completions: {health}"
    );
    let loads = c.call(&Json::obj(vec![("op", Json::from("loads"))]))?;
    anyhow::ensure!(
        loads.get("kv_token_budget").as_u64().unwrap_or(0) > 0,
        "loads reports no KV budget: {loads}"
    );

    c.call(&Json::obj(vec![("op", Json::from("shutdown"))]))?;
    let summary = handle
        .join()
        .map_err(|_| anyhow::anyhow!("server thread panicked"))??;
    anyhow::ensure!(
        summary.n_requests == 3,
        "expected 3 completions in summary, got {}",
        summary.n_requests
    );
    Ok(())
}

fn cmd_info(args: &Args) -> i32 {
    let cfg = load_config(args);
    println!("{}", cfg.to_json());
    let dir = args.raw("artifacts").unwrap_or(runtime::DEFAULT_ARTIFACTS_DIR);
    if runtime::artifacts_available(dir) {
        match runtime::Manifest::load(dir) {
            Ok(m) => {
                println!(
                    "artifacts: {} compiled shapes, model {} params, buckets {:?}",
                    m.artifacts.len(),
                    m.model.param_count,
                    m.bucket_bounds()
                );
            }
            Err(e) => println!("artifacts: manifest error: {e}"),
        }
    } else {
        println!("artifacts: not built (run `make artifacts`)");
    }
    0
}

fn print_help() {
    println!(
        "bucketserve — bucket-based dynamic batching for LLM serving (paper reproduction)

USAGE:
  bucketserve run     --system bucketserve|distserve|uellm --dataset alpaca|longbench|mixed
                      [--n 200] [--rps 8] [--offline] [--engine sim|pjrt] [--json]
  bucketserve compare --dataset mixed --n 200 [--rps 8 | --offline]
  bucketserve serve   --addr 127.0.0.1:7777 [--system bucketserve] [--realtime]
  bucketserve smoke   [--realtime.pace 20000]   (realtime loopback self-test)
  bucketserve info    [--config cfg.json]

Config overrides: --scheduler.theta 0.5 --scheduler.policy sjf|ljf|fcfs
                  --fleet.n_prefill 2 --fleet.n_decode 2 --seed 42
                  --slo.ttft_us 400000 --slo.tbt_us 100000
                  --sharding.shards 0|N (0 = one per decode instance)
                  --sharding.placement least_loaded|kv|hash
                  --sharding.steal on|off
                  --priority.enabled on|off --priority.aging_rate 0.02
                  --preempt.enabled on|off --preempt.urgency_threshold 0.9
                  --admission.enabled on|off --admission.defer on|off
                  --admission.evict on|off --admission.slack_margin 0.1
                  --admission.offline_tbt_factor 8 --admission.max_evictions 2
                  --planner.family bucket|fcfs|lookahead (prefill planner)
                  --planner.window 32 --planner.commit_margin_us 50000
                  --planner.offline_horizon_us 10000000
                  --executor.threads 1|N|0 (0 = one worker per shard;
                      parallel output is byte-identical to sequential)
                  --realtime.stream_buf 64 --realtime.ewma_alpha 0.2
                  --realtime.drain_timeout_ms 5000
                  --realtime.pace 1.0 (wall-clock compression for tests/benches)
(full knob-by-knob table: docs/ARCHITECTURE.md)"
    );
}
