//! `bucketserve` CLI: the leader entrypoint.
//!
//! ```text
//! bucketserve run     --system bucketserve|distserve|uellm --dataset alpaca|longbench|mixed
//!                     [--n 200] [--rps 8] [--offline] [--engine sim|pjrt]
//!                     [--config cfg.json] [--scheduler.theta 0.5] [--json]
//! bucketserve serve   --addr 127.0.0.1:7777 [--system ...]      (TCP gateway)
//! bucketserve compare --dataset mixed --n 200 [--rps 8]          (3 systems, one trace)
//! bucketserve info                                               (config + artifact dump)
//! ```

use bucketserve::baselines::System;
use bucketserve::cluster::sim::SimEngine;
use bucketserve::cluster::Engine;
use bucketserve::config::SystemConfig;
use bucketserve::metrics::Summary;
use bucketserve::server::Server;
use bucketserve::util::bench::{f1, f2, Table};
use bucketserve::util::cli::Args;
use bucketserve::workload::{Dataset, RequestClass, Trace};
use bucketserve::{log_info, runtime};

fn main() {
    bucketserve::util::logging::init();
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "run" => cmd_run(&args),
        "serve" => cmd_serve(&args),
        "compare" => cmd_compare(&args),
        "info" => cmd_info(&args),
        _ => {
            print_help();
            0
        }
    };
    std::process::exit(code);
}

fn load_config(args: &Args) -> SystemConfig {
    let mut cfg = match args.raw("config") {
        Some(path) => SystemConfig::load(path, args).unwrap_or_else(|e| {
            eprintln!("config error: {e}");
            std::process::exit(2);
        }),
        None => {
            let mut c = if args.raw("engine") == Some("pjrt") {
                SystemConfig::tiny_pjrt()
            } else {
                SystemConfig::default()
            };
            c.apply_overrides(args);
            c
        }
    };
    if let Some(seed) = args.get::<u64>("seed") {
        cfg.seed = seed;
    }
    cfg
}

fn make_trace(args: &Args, cfg: &SystemConfig) -> Trace {
    let dataset = Dataset::parse(args.raw("dataset").unwrap_or("alpaca"));
    let n = args.get_or("n", 100usize);
    let class = if args.flag("offline") {
        RequestClass::Offline
    } else {
        RequestClass::Online
    };
    if args.flag("offline") && args.get::<f64>("rps").is_none() {
        Trace::batch(dataset, n, class, cfg.model.max_seq, cfg.seed)
    } else {
        let rps = args.get_or("rps", 8.0f64);
        Trace::generate(dataset, n, rps, class, cfg.model.max_seq, cfg.seed)
    }
}

fn run_system(
    system: System,
    cfg: &SystemConfig,
    trace: &Trace,
    engine: &mut dyn Engine,
) -> bucketserve::coordinator::RunReport {
    match system {
        System::BucketServe => {
            bucketserve::BucketServe::new(cfg.clone()).run(trace, engine)
        }
        System::DistServe => {
            bucketserve::baselines::DistServe::new(cfg.clone()).run(trace, engine)
        }
        System::Uellm => {
            bucketserve::baselines::Uellm::new(cfg.clone()).run(trace, engine)
        }
    }
}

fn cmd_run(args: &Args) -> i32 {
    let cfg = load_config(args);
    let system = System::parse(args.raw("system").unwrap_or("bucketserve"));
    let trace = make_trace(args, &cfg);
    log_info!(
        "running {} on {} requests ({} engine)",
        system.name(),
        trace.len(),
        args.raw("engine").unwrap_or("sim")
    );

    let report = if args.raw("engine") == Some("pjrt") {
        let dir = args.raw("artifacts").unwrap_or(runtime::DEFAULT_ARTIFACTS_DIR);
        if !runtime::artifacts_available(dir) {
            eprintln!("artifacts not found in {dir}; run `make artifacts`");
            return 2;
        }
        let mut engine = match runtime::PjrtEngine::load(dir) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("pjrt engine: {e}");
                return 2;
            }
        };
        run_system(system, &cfg, &trace, &mut engine)
    } else {
        let mut engine = SimEngine::new(&cfg);
        run_system(system, &cfg, &trace, &mut engine)
    };

    let summary = Summary::from_report(system.name(), &report, &cfg.slo);
    if args.flag("json") {
        println!("{}", summary.to_json());
    } else {
        let mut t = Table::new(&["metric", "value"]);
        t.row(vec!["requests".into(), summary.n_requests.to_string()]);
        t.row(vec!["makespan (s)".into(), f2(summary.makespan_s)]);
        t.row(vec!["throughput (tok/s)".into(), f1(summary.throughput_tps)]);
        t.row(vec!["output tok/s".into(), f1(summary.output_tps)]);
        t.row(vec!["server RPS".into(), f2(summary.server_rps)]);
        t.row(vec!["GPU util".into(), f2(summary.gpu_util)]);
        t.row(vec!["SLO attainment".into(), f2(summary.slo_attainment)]);
        t.row(vec!["mean TTFT (ms)".into(), f1(summary.mean_ttft_ms)]);
        t.row(vec!["p99 TTFT (ms)".into(), f1(summary.p99_ttft_ms)]);
        t.row(vec!["mean E2E (ms)".into(), f1(summary.mean_e2e_ms)]);
        t.row(vec!["mean waste ratio".into(), f2(summary.mean_waste_ratio)]);
        t.row(vec!["peak batch".into(), summary.peak_batch.to_string()]);
        t.row(vec!["max buckets".into(), summary.max_buckets.to_string()]);
        t.row(vec![
            "bucketing overhead (ms)".into(),
            f2(summary.bucket_overhead_ms),
        ]);
        t.print(&format!("{} / {}", system.name(), args.raw("dataset").unwrap_or("alpaca")));
    }
    0
}

fn cmd_compare(args: &Args) -> i32 {
    let cfg = load_config(args);
    let trace = make_trace(args, &cfg);
    let mut t = Table::new(&[
        "system", "tok/s", "RPS", "util", "SLO", "TTFT ms", "E2E ms", "waste",
    ]);
    for system in System::ALL {
        let report = system.run_sim(&cfg, &trace);
        let s = Summary::from_report(system.name(), &report, &cfg.slo);
        t.row(vec![
            s.system.clone(),
            f1(s.throughput_tps),
            f2(s.server_rps),
            f2(s.gpu_util),
            f2(s.slo_attainment),
            f1(s.mean_ttft_ms),
            f1(s.mean_e2e_ms),
            f2(s.mean_waste_ratio),
        ]);
    }
    t.print(&format!(
        "compare — {} × {} requests",
        args.raw("dataset").unwrap_or("alpaca"),
        trace.len()
    ));
    0
}

fn cmd_serve(args: &Args) -> i32 {
    let cfg = load_config(args);
    let system = System::parse(args.raw("system").unwrap_or("bucketserve"));
    let addr = args.raw("addr").unwrap_or("127.0.0.1:7777").to_string();
    let server = Server::new(cfg, system);
    log_info!("gateway listening on {addr} ({})", system.name());
    if let Err(e) = server.serve(&addr, |a| println!("listening on {a}")) {
        eprintln!("serve: {e}");
        return 2;
    }
    0
}

fn cmd_info(args: &Args) -> i32 {
    let cfg = load_config(args);
    println!("{}", cfg.to_json());
    let dir = args.raw("artifacts").unwrap_or(runtime::DEFAULT_ARTIFACTS_DIR);
    if runtime::artifacts_available(dir) {
        match runtime::Manifest::load(dir) {
            Ok(m) => {
                println!(
                    "artifacts: {} compiled shapes, model {} params, buckets {:?}",
                    m.artifacts.len(),
                    m.model.param_count,
                    m.bucket_bounds()
                );
            }
            Err(e) => println!("artifacts: manifest error: {e}"),
        }
    } else {
        println!("artifacts: not built (run `make artifacts`)");
    }
    0
}

fn print_help() {
    println!(
        "bucketserve — bucket-based dynamic batching for LLM serving (paper reproduction)

USAGE:
  bucketserve run     --system bucketserve|distserve|uellm --dataset alpaca|longbench|mixed
                      [--n 200] [--rps 8] [--offline] [--engine sim|pjrt] [--json]
  bucketserve compare --dataset mixed --n 200 [--rps 8 | --offline]
  bucketserve serve   --addr 127.0.0.1:7777 [--system bucketserve]
  bucketserve info    [--config cfg.json]

Config overrides: --scheduler.theta 0.5 --scheduler.policy sjf|ljf|fcfs
                  --fleet.n_prefill 2 --fleet.n_decode 2 --seed 42
                  --slo.ttft_us 400000 --slo.tbt_us 100000
                  --sharding.shards 0|N (0 = one per decode instance)
                  --sharding.placement least_loaded|kv|hash
                  --sharding.steal on|off
                  --priority.enabled on|off --priority.aging_rate 0.02
                  --preempt.enabled on|off --preempt.urgency_threshold 0.9
                  --admission.enabled on|off --admission.defer on|off
                  --admission.evict on|off --admission.slack_margin 0.1
                  --admission.offline_tbt_factor 8 --admission.max_evictions 2
                  --executor.threads 1|N|0 (0 = one worker per shard;
                      parallel output is byte-identical to sequential)
(full knob-by-knob table: docs/ARCHITECTURE.md)"
    );
}
