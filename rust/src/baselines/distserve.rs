//! DistServe-like baseline: disaggregated FCFS serving without bucketing.
//!
//! Reuses BucketServe's entire P/D pipeline ([`PdScheduler`]) with a plain
//! FIFO planner: requests batch strictly in arrival order under the same
//! Eq.-6 memory admission, padding to the batch's longest member. Under
//! heterogeneous traffic this is where the padding waste and head-of-line
//! blocking the paper measures (Fig. 3/5) come from — there is no bucket
//! homogenization and no skew-aware splitting.

use crate::cluster::{PrefillBatch, PrefillItem};
use crate::config::SystemConfig;
use crate::coordinator::batcher::FormedBatch;
use crate::coordinator::bucket::QueuedReq;
use crate::coordinator::scheduler::{
    kv_capped_take, oldest_online_in, OnlinePeek, PdScheduler, PrefillPlanner,
    RunReport,
};
use crate::cluster::Engine;
use crate::workload::{Request, Trace};
use crate::Micros;
use std::collections::VecDeque;
use std::time::Instant;

/// FCFS planner (no bucketing).
///
/// `Clone` is the snapshot stage of the executor's plan/commit protocol
/// ([`PrefillPlanner::clone_box`]): all fields are owned data, so the
/// derived clone is a complete deep copy.
#[derive(Clone)]
pub struct FcfsPlanner {
    queue: VecDeque<QueuedReq>,
    max_batch: usize,
    overhead_ns: u64,
    online_peek: OnlinePeek,
}

impl FcfsPlanner {
    pub fn new(cfg: &SystemConfig) -> FcfsPlanner {
        FcfsPlanner {
            queue: VecDeque::new(),
            max_batch: if cfg.scheduler.max_batch == 0 {
                usize::MAX
            } else {
                cfg.scheduler.max_batch as usize
            },
            overhead_ns: 0,
            online_peek: OnlinePeek::new(),
        }
    }
}

impl PrefillPlanner for FcfsPlanner {
    fn clone_box(&self) -> Box<dyn PrefillPlanner> {
        Box::new(self.clone())
    }

    fn admit(&mut self, req: &Request, _now: Micros) {
        let q = QueuedReq {
            id: req.id,
            len: req.input_len,
            output_len: req.output_len,
            arrival: req.arrival,
            class: req.class,
            tbt_us: req.tbt_deadline_us,
            // Lineage + the router's resident-match hint; `shared_len`
            // stays 0 until dispatch actually pins cache blocks. All-zero
            // when the prefix subsystem is off, so nothing downstream
            // changes.
            prefix: crate::coordinator::prefix::PrefixStamp {
                prefix_id: req.prefix_id,
                prefix_len: req.prefix_len.min(req.input_len),
                cached_len: req.prefix_cached_hint.min(req.input_len),
                shared_len: 0,
            },
        };
        self.online_peek.note_insert(&q);
        self.queue.push_back(q);
    }

    fn plan(&mut self, _now: Micros, headroom_tokens: u64) -> Option<FormedBatch> {
        let t0 = Instant::now();
        let mut take = 0usize;
        let mut acc = 0u64;
        for r in self.queue.iter() {
            if take >= self.max_batch {
                break;
            }
            let footprint = r.footprint();
            if acc + footprint > headroom_tokens {
                break;
            }
            acc += footprint;
            take += 1;
        }
        if take == 0 {
            self.overhead_ns += t0.elapsed().as_nanos() as u64;
            return None;
        }
        let reqs: Vec<QueuedReq> = self.queue.drain(..take).collect();
        self.online_peek.note_removed(reqs.iter());
        let padded_len = reqs.iter().map(|r| r.len).max().unwrap_or(1).max(1);
        let items = reqs
            .iter()
            .map(|r| PrefillItem { id: r.id, len: r.len, tokens: vec![] })
            .collect();
        self.overhead_ns += t0.elapsed().as_nanos() as u64;
        Some(FormedBatch {
            batch: PrefillBatch { items, padded_len },
            reqs,
            bucket_up: padded_len,
        })
    }

    fn force_pop(&mut self, _now: Micros) -> Option<QueuedReq> {
        let popped = self.queue.pop_front();
        if let Some(r) = &popped {
            self.online_peek.note_removed(std::iter::once(r));
        }
        popped
    }

    fn queued(&self) -> usize {
        self.queue.len()
    }

    fn queued_tokens(&self) -> u64 {
        self.queue.iter().map(QueuedReq::footprint).sum()
    }

    fn steal_tail(
        &mut self,
        max_n: usize,
        max_tokens: u64,
        _now: Micros,
    ) -> Vec<QueuedReq> {
        // The FIFO tail is the least-urgent end by construction; cap at
        // half the queue so the donor always keeps the head it would
        // dispatch next, and at `max_tokens` of full-context footprint so
        // the thief is never handed more than its KV headroom can admit.
        let cap = max_n.min(self.queue.len() / 2);
        let take = kv_capped_take(self.queue.iter().rev().take(cap), max_tokens);
        let stolen: Vec<QueuedReq> =
            self.queue.split_off(self.queue.len() - take).into_iter().collect();
        self.online_peek.note_removed(stolen.iter());
        stolen
    }

    fn absorb(&mut self, reqs: Vec<QueuedReq>, _now: Micros) {
        // Keep the queue FIFO: stolen requests slot in by arrival, after
        // any already-queued request that arrived at the same instant.
        for r in reqs {
            self.online_peek.note_insert(&r);
            let pos = self.queue.partition_point(|q| q.arrival <= r.arrival);
            self.queue.insert(pos, r);
        }
    }

    fn oldest_online(&mut self) -> Option<QueuedReq> {
        let queue = &self.queue;
        self.online_peek.get(|| oldest_online_in(queue.iter()))
    }

    fn drain_follows_urgency(&self) -> bool {
        // Strict FIFO: an aborted batch's earlier arrivals would re-form
        // ahead of the urgent candidate, so prefill abort buys nothing.
        false
    }

    fn overhead_ns(&self) -> u64 {
        self.overhead_ns
    }
}

/// The DistServe-like system façade.
pub struct DistServe {
    cfg: SystemConfig,
}

impl DistServe {
    pub fn new(cfg: SystemConfig) -> DistServe {
        DistServe { cfg }
    }

    pub fn run(&self, trace: &Trace, engine: &mut dyn Engine) -> RunReport {
        // One FIFO planner per scheduler shard (shards = 1 by default, so
        // this is the seed's single global queue unless sharding is on).
        let mut sched =
            PdScheduler::new(&self.cfg, || Box::new(FcfsPlanner::new(&self.cfg)));
        sched.run(trace, engine)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::sim::SimEngine;
    use crate::workload::{Dataset, RequestClass};

    #[test]
    fn completes_all_requests() {
        let cfg = SystemConfig::default();
        let trace = Trace::generate(
            Dataset::Mixed, 60, 8.0, RequestClass::Online, cfg.model.max_seq, 1,
        );
        let mut engine = SimEngine::new(&cfg);
        let report = DistServe::new(cfg).run(&trace, &mut engine);
        assert_eq!(report.completions.len(), 60);
    }

    #[test]
    fn fcfs_preserves_arrival_order_in_batches() {
        let cfg = SystemConfig::default();
        let mut planner = FcfsPlanner::new(&cfg);
        for i in 0..10u64 {
            let r = Request::new(
                i,
                crate::workload::RequestClass::Online,
                100,
                10,
                i * 100,
            );
            planner.admit(&r, i * 100);
        }
        let fb = planner.plan(1000, u64::MAX / 4).unwrap();
        let ids: Vec<u64> = fb.reqs.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<u64>>());
    }

    #[test]
    fn fcfs_steal_and_absorb_preserve_arrival_order() {
        let cfg = SystemConfig::default();
        let mut victim = FcfsPlanner::new(&cfg);
        let mut thief = FcfsPlanner::new(&cfg);
        for i in 0..8u64 {
            let r = Request::new(
                i, crate::workload::RequestClass::Online, 100, 10, i * 100,
            );
            victim.admit(&r, i * 100);
        }
        // Thief already holds a request that arrived mid-stream.
        let mid = Request::new(
            99, crate::workload::RequestClass::Online, 100, 10, 550,
        );
        thief.admit(&mid, 550);
        let stolen = victim.steal_tail(3, u64::MAX / 4, 800);
        assert_eq!(
            stolen.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![5, 6, 7],
            "tail of the FIFO queue"
        );
        assert_eq!(victim.queued(), 5);
        thief.absorb(stolen, 800);
        let fb = thief.plan(1000, u64::MAX / 4).unwrap();
        assert_eq!(
            fb.reqs.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![5, 99, 6, 7],
            "absorbed requests interleave by arrival time"
        );
        assert_eq!(victim.queued_tokens(), 5 * 110);
    }

    #[test]
    fn fcfs_steal_respects_token_cap_and_oldest_online_peeks() {
        let cfg = SystemConfig::default();
        let mut p = FcfsPlanner::new(&cfg);
        assert!(p.oldest_online().is_none());
        for i in 0..8u64 {
            let r = Request::new(
                i, crate::workload::RequestClass::Online, 100, 10, i * 100,
            );
            p.admit(&r, i * 100);
        }
        assert_eq!(p.oldest_online().unwrap().id, 0);
        // Footprint 110/request: a 250-token cap admits only 2 of the 4
        // requests the half-queue rule would otherwise surrender.
        let stolen = p.steal_tail(4, 250, 800);
        assert_eq!(
            stolen.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![6, 7]
        );
        assert_eq!(p.queued(), 6);
        assert_eq!(p.oldest_online().unwrap().id, 0, "head never stolen");
    }

    #[test]
    fn mixed_batches_pad_more_than_bucketed() {
        // The motivating delta: FCFS mixes short+long → higher waste ratio
        // than BucketServe's buckets on the same trace.
        let cfg = SystemConfig::default();
        let trace =
            Trace::batch(Dataset::Mixed, 120, RequestClass::Offline, 4096, 42);
        let rd = crate::baselines::System::DistServe.run_sim(&cfg, &trace);
        let rb = crate::baselines::System::BucketServe.run_sim(&cfg, &trace);
        // Padding-aware prefill efficiency: fraction of prefill GPU time
        // spent on real (non-padding) tokens. Bucketing's whole point.
        let eff = |r: &RunReport| {
            r.prefill_useful_us / r.prefill_busy_us.max(1) as f64
        };
        assert!(
            eff(&rb) > eff(&rd),
            "bucketserve prefill efficiency {} should exceed distserve {}",
            eff(&rb),
            eff(&rd)
        );
    }
}
