//! DistServe-like baseline: disaggregated FCFS serving without bucketing.
//!
//! Reuses BucketServe's entire P/D pipeline ([`PdScheduler`]) with a plain
//! FIFO planner: requests batch strictly in arrival order under the same
//! Eq.-6 memory admission, padding to the batch's longest member. Under
//! heterogeneous traffic this is where the padding waste and head-of-line
//! blocking the paper measures (Fig. 3/5) come from — there is no bucket
//! homogenization and no skew-aware splitting.

use crate::cluster::{PrefillBatch, PrefillItem};
use crate::config::SystemConfig;
use crate::coordinator::batcher::FormedBatch;
use crate::coordinator::bucket::QueuedReq;
use crate::coordinator::scheduler::{PdScheduler, PrefillPlanner, RunReport};
use crate::cluster::Engine;
use crate::workload::{Request, Trace};
use crate::Micros;
use std::collections::VecDeque;
use std::time::Instant;

/// FCFS planner (no bucketing).
pub struct FcfsPlanner {
    queue: VecDeque<QueuedReq>,
    max_batch: usize,
    overhead_ns: u64,
}

impl FcfsPlanner {
    pub fn new(cfg: &SystemConfig) -> FcfsPlanner {
        FcfsPlanner {
            queue: VecDeque::new(),
            max_batch: if cfg.scheduler.max_batch == 0 {
                usize::MAX
            } else {
                cfg.scheduler.max_batch as usize
            },
            overhead_ns: 0,
        }
    }
}

impl PrefillPlanner for FcfsPlanner {
    fn admit(&mut self, req: &Request, _now: Micros) {
        self.queue.push_back(QueuedReq {
            id: req.id,
            len: req.input_len,
            output_len: req.output_len,
            arrival: req.arrival,
            class: req.class,
        });
    }

    fn plan(&mut self, _now: Micros, headroom_tokens: u64) -> Option<FormedBatch> {
        let t0 = Instant::now();
        let mut take = 0usize;
        let mut acc = 0u64;
        for r in self.queue.iter() {
            if take >= self.max_batch {
                break;
            }
            let footprint = (r.len + r.output_len) as u64;
            if acc + footprint > headroom_tokens {
                break;
            }
            acc += footprint;
            take += 1;
        }
        if take == 0 {
            self.overhead_ns += t0.elapsed().as_nanos() as u64;
            return None;
        }
        let reqs: Vec<QueuedReq> = self.queue.drain(..take).collect();
        let padded_len = reqs.iter().map(|r| r.len).max().unwrap_or(1).max(1);
        let items = reqs
            .iter()
            .map(|r| PrefillItem { id: r.id, len: r.len, tokens: vec![] })
            .collect();
        self.overhead_ns += t0.elapsed().as_nanos() as u64;
        Some(FormedBatch {
            batch: PrefillBatch { items, padded_len },
            reqs,
            bucket_up: padded_len,
        })
    }

    fn force_pop(&mut self, _now: Micros) -> Option<QueuedReq> {
        self.queue.pop_front()
    }

    fn queued(&self) -> usize {
        self.queue.len()
    }

    fn overhead_ns(&self) -> u64 {
        self.overhead_ns
    }
}

/// The DistServe-like system façade.
pub struct DistServe {
    cfg: SystemConfig,
}

impl DistServe {
    pub fn new(cfg: SystemConfig) -> DistServe {
        DistServe { cfg }
    }

    pub fn run(&self, trace: &Trace, engine: &mut dyn Engine) -> RunReport {
        let planner = FcfsPlanner::new(&self.cfg);
        let mut sched = PdScheduler::new(&self.cfg, Box::new(planner));
        sched.run(trace, engine)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::sim::SimEngine;
    use crate::workload::{Dataset, RequestClass};

    #[test]
    fn completes_all_requests() {
        let cfg = SystemConfig::default();
        let trace = Trace::generate(
            Dataset::Mixed, 60, 8.0, RequestClass::Online, cfg.model.max_seq, 1,
        );
        let mut engine = SimEngine::new(&cfg);
        let report = DistServe::new(cfg).run(&trace, &mut engine);
        assert_eq!(report.completions.len(), 60);
    }

    #[test]
    fn fcfs_preserves_arrival_order_in_batches() {
        let cfg = SystemConfig::default();
        let mut planner = FcfsPlanner::new(&cfg);
        for i in 0..10u64 {
            let r = Request::new(
                i,
                crate::workload::RequestClass::Online,
                100,
                10,
                i * 100,
            );
            planner.admit(&r, i * 100);
        }
        let fb = planner.plan(1000, u64::MAX / 4).unwrap();
        let ids: Vec<u64> = fb.reqs.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<u64>>());
    }

    #[test]
    fn mixed_batches_pad_more_than_bucketed() {
        // The motivating delta: FCFS mixes short+long → higher waste ratio
        // than BucketServe's buckets on the same trace.
        let cfg = SystemConfig::default();
        let trace =
            Trace::batch(Dataset::Mixed, 120, RequestClass::Offline, 4096, 42);
        let rd = crate::baselines::System::DistServe.run_sim(&cfg, &trace);
        let rb = crate::baselines::System::BucketServe.run_sim(&cfg, &trace);
        // Padding-aware prefill efficiency: fraction of prefill GPU time
        // spent on real (non-padding) tokens. Bucketing's whole point.
        let eff = |r: &RunReport| {
            r.prefill_useful_us / r.prefill_busy_us.max(1) as f64
        };
        assert!(
            eff(&rb) > eff(&rd),
            "bucketserve prefill efficiency {} should exceed distserve {}",
            eff(&rb),
            eff(&rd)
        );
    }
}
