//! UELLM-like baseline: aggregated serving with profile-predicted static
//! batching.
//!
//! Models the properties the paper attributes to UELLM (§V Baselines):
//!
//! * **Coupled phases** — every GPU instance runs a request's prefill *and*
//!   its whole decode; there is no P/D specialization and no NVLink
//!   hand-off.
//! * **Profile-predicted batching** — the batch size is fixed up front
//!   from a resource-demand prediction (we emulate the "fine-tuned LLM
//!   predictor" with the trace's observable mean footprint), then never
//!   adapted to workload fluctuations.
//! * **Request-level batching** — a batch holds its instance until *every*
//!   member finishes decoding; early finishers leave dead slots (the
//!   classic pre-Orca inefficiency), which is where the low GPU
//!   utilization in Fig. 3b/5b comes from.

use crate::cluster::{DecodeBatch, DecodeSeq, Engine, PrefillBatch, PrefillItem};
use crate::config::SystemConfig;
use crate::coordinator::batcher::KvMemoryModel;
use crate::coordinator::scheduler::RunReport;
use crate::workload::request::Completion;
use crate::workload::Trace;
use crate::Micros;
use std::collections::VecDeque;

/// The UELLM-like system.
pub struct Uellm {
    cfg: SystemConfig,
}

/// One aggregated instance's in-flight request-level batch.
struct AggBatch {
    seqs: Vec<AggSeq>,
    /// When the current phase (prefill or the running decode iteration)
    /// completes.
    phase_end: Micros,
    in_prefill: bool,
    prefill_duration: Micros,
    padded_len: u32,
}

struct AggSeq {
    id: u64,
    class: crate::workload::RequestClass,
    arrival: Micros,
    input_len: u32,
    output_len: u32,
    generated: u32,
    first_token: Micros,
    done: bool,
}

impl Uellm {
    pub fn new(cfg: SystemConfig) -> Uellm {
        Uellm { cfg }
    }

    /// Static profile-predicted batch size: token budget over the mean
    /// footprint of the first profiling window (no runtime adaptation —
    /// the deficiency the paper highlights).
    fn predict_batch_size(&self, trace: &Trace, budget_tokens: u64) -> usize {
        let window = trace.requests.iter().take(32);
        let (mut sum, mut n) = (0u64, 0u64);
        for r in window {
            sum += (r.input_len + r.output_len) as u64;
            n += 1;
        }
        if n == 0 {
            return 1;
        }
        let mean = (sum / n).max(1);
        ((budget_tokens / mean) as usize).clamp(1, 64)
    }

    pub fn run(&self, trace: &Trace, engine: &mut dyn Engine) -> RunReport {
        let n_inst =
            (self.cfg.fleet.n_prefill + self.cfg.fleet.n_decode).max(1) as usize;
        let mem = KvMemoryModel::new(
            self.cfg.model.clone(),
            self.cfg.scheduler.mem_safety,
        );
        let budget = mem.token_budget(engine.decode_mem_budget());
        let static_batch = self.predict_batch_size(trace, budget);

        let mut queue: VecDeque<usize> = VecDeque::new();
        let mut next_arrival = 0usize;
        let mut clock: Micros = 0;
        let total = trace.len();
        let mut instances: Vec<Option<AggBatch>> =
            (0..n_inst).map(|_| None).collect();
        let mut report = RunReport {
            n_prefill: 0,
            n_decode: n_inst,
            ..Default::default()
        };
        let weight_bytes = engine.model().weight_bytes() as f64;
        let kv_per_token = engine.model().kv_bytes_per_token() as f64;

        while report.completions.len() < total {
            // Next event: arrival or any instance phase end.
            let mut next_event = Micros::MAX;
            if next_arrival < total {
                next_event = next_event.min(trace.requests[next_arrival].arrival);
            }
            for inst in instances.iter().flatten() {
                next_event = next_event.min(inst.phase_end);
            }
            assert!(
                next_event != Micros::MAX || !queue.is_empty(),
                "uellm: stalled with {} incomplete",
                total - report.completions.len()
            );
            if next_event != Micros::MAX {
                clock = clock.max(next_event);
            }

            // Admit arrivals.
            while next_arrival < total
                && trace.requests[next_arrival].arrival <= clock
            {
                queue.push_back(next_arrival);
                next_arrival += 1;
            }

            // Advance instances.
            for slot in instances.iter_mut() {
                let ready = matches!(slot, Some(b) if b.phase_end <= clock);
                if !ready {
                    continue;
                }
                let b = slot.as_mut().unwrap();
                if b.in_prefill {
                    // Prefill finished → first tokens; start decode.
                    report.prefill_batches += 1;
                    report.prefill_busy_us += b.prefill_duration;
                    let batch = PrefillBatch {
                        items: b
                            .seqs
                            .iter()
                            .map(|s| PrefillItem {
                                id: s.id,
                                len: s.input_len,
                                tokens: vec![],
                            })
                            .collect(),
                        padded_len: b.padded_len,
                    };
                    report.prefill_useful_us +=
                        b.prefill_duration as f64 * batch.efficiency();
                    report.prefill_exec_request_us +=
                        b.prefill_duration * b.seqs.len() as u64;
                    for s in &mut b.seqs {
                        s.first_token = clock;
                        s.generated = 1;
                        if s.generated >= s.output_len {
                            // Single-token request: completes at prefill.
                            s.done = true;
                            report.completions.push(Completion {
                                id: s.id,
                                class: s.class,
                                input_len: s.input_len,
                                output_len: s.output_len,
                                arrival: s.arrival,
                                first_token: clock,
                                finished: clock,
                                padded_len: b.padded_len,
                            });
                            engine.release(s.id);
                        }
                    }
                    b.in_prefill = false;
                } else {
                    // One decode iteration ended.
                    for s in b.seqs.iter_mut().filter(|s| !s.done) {
                        s.generated += 1;
                        if s.generated >= s.output_len {
                            s.done = true;
                            report.completions.push(Completion {
                                id: s.id,
                                class: s.class,
                                input_len: s.input_len,
                                output_len: s.output_len,
                                arrival: s.arrival,
                                first_token: s.first_token,
                                finished: clock,
                                padded_len: b.padded_len,
                            });
                            engine.release(s.id);
                        }
                    }
                }

                // Request-level batching: the batch holds the instance
                // until ALL members are done.
                if b.seqs.iter().all(|s| s.done) {
                    *slot = None;
                } else if !b.in_prefill {
                    // Launch the next decode iteration: finished sequences
                    // still occupy their slots (static batching), so the
                    // engine steps the full batch width with frozen ctx.
                    let batch = DecodeBatch {
                        seqs: b
                            .seqs
                            .iter()
                            .map(|s| DecodeSeq {
                                id: s.id,
                                ctx_len: s.input_len + s.generated.min(s.output_len),
                            })
                            .collect(),
                    };
                    let duration =
                        engine.decode_step(&batch).expect("uellm decode");
                    b.phase_end = clock + duration;
                    report.decode_iters += 1;
                    report.decode_busy_us += duration;
                    let active =
                        b.seqs.iter().filter(|s| !s.done).count() as f64;
                    let kv_bytes = batch.total_ctx() as f64 * kv_per_token;
                    let amort = kv_bytes / (kv_bytes + weight_bytes);
                    // Dead slots scale useful work down further.
                    let eff = amort * active / b.seqs.len().max(1) as f64;
                    report.decode_useful_us += duration as f64 * eff;
                }
            }

            // Form new static batches on idle instances.
            for slot in instances.iter_mut() {
                if slot.is_some() || queue.is_empty() {
                    continue;
                }
                let mut seqs = Vec::new();
                let mut acc = 0u64;
                while let Some(&idx) = queue.front() {
                    if seqs.len() >= static_batch {
                        break;
                    }
                    let r = &trace.requests[idx];
                    let footprint = (r.input_len + r.output_len) as u64;
                    if !seqs.is_empty() && acc + footprint > budget {
                        break;
                    }
                    acc += footprint;
                    queue.pop_front();
                    seqs.push(AggSeq {
                        id: r.id,
                        class: r.class,
                        arrival: r.arrival,
                        input_len: r.input_len,
                        output_len: r.output_len,
                        generated: 0,
                        first_token: 0,
                        done: false,
                    });
                }
                if seqs.is_empty() {
                    break;
                }
                let padded_len =
                    seqs.iter().map(|s| s.input_len).max().unwrap_or(1).max(1);
                let batch = PrefillBatch {
                    items: seqs
                        .iter()
                        .map(|s| PrefillItem {
                            id: s.id,
                            len: s.input_len,
                            tokens: vec![],
                        })
                        .collect(),
                    padded_len,
                };
                let duration = engine.prefill(&batch).expect("uellm prefill");
                report.peak_batch = report.peak_batch.max(seqs.len());
                for s in &seqs {
                    report.queue_wait_us +=
                        clock.saturating_sub(s.arrival);
                }
                *slot = Some(AggBatch {
                    seqs,
                    phase_end: clock + duration,
                    in_prefill: true,
                    prefill_duration: duration,
                    padded_len,
                });
            }

            report.makespan_us = report.makespan_us.max(clock);
        }

        if let Some(last) = report.completions.iter().map(|c| c.finished).max() {
            report.makespan_us = report.makespan_us.max(last);
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::System;
    use crate::cluster::sim::SimEngine;
    use crate::workload::{Dataset, RequestClass};

    #[test]
    fn completes_all_requests() {
        let cfg = SystemConfig::default();
        let trace = Trace::generate(
            Dataset::Alpaca, 50, 8.0, RequestClass::Online, cfg.model.max_seq, 1,
        );
        let mut engine = SimEngine::new(&cfg);
        let report = Uellm::new(cfg).run(&trace, &mut engine);
        assert_eq!(report.completions.len(), 50);
        let mut ids: Vec<_> = report.completions.iter().map(|c| c.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 50, "no duplicate completions");
    }

    #[test]
    fn timestamps_causal() {
        let cfg = SystemConfig::default();
        let trace = Trace::generate(
            Dataset::Mixed, 40, 4.0, RequestClass::Online, cfg.model.max_seq, 2,
        );
        let mut engine = SimEngine::new(&cfg);
        let report = Uellm::new(cfg).run(&trace, &mut engine);
        for c in &report.completions {
            assert!(c.first_token >= c.arrival);
            assert!(c.finished >= c.first_token);
        }
    }

    #[test]
    fn bucketserve_beats_uellm_on_heterogeneous_offline_load() {
        // The headline comparison (Fig. 5a direction).
        let cfg = SystemConfig::default();
        let trace =
            Trace::batch(Dataset::Mixed, 120, RequestClass::Offline, 4096, 42);
        let rb = System::BucketServe.run_sim(&cfg, &trace);
        let ru = System::Uellm.run_sim(&cfg, &trace);
        assert!(
            rb.throughput_tps() > ru.throughput_tps(),
            "bucketserve {} <= uellm {}",
            rb.throughput_tps(),
            ru.throughput_tps()
        );
    }

    #[test]
    fn uellm_gpu_util_lower_than_bucketserve() {
        let cfg = SystemConfig::default();
        let trace =
            Trace::batch(Dataset::Mixed, 120, RequestClass::Offline, 4096, 42);
        let rb = System::BucketServe.run_sim(&cfg, &trace);
        let ru = System::Uellm.run_sim(&cfg, &trace);
        assert!(rb.gpu_util() > ru.gpu_util());
    }
}
