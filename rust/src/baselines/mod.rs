//! Baseline systems the paper compares against.
//!
//! Both are implemented over the same [`crate::cluster::Engine`] and
//! metrics plumbing as BucketServe, so every figure bench is a paired
//! comparison on identical traces:
//!
//! * [`distserve`] — disaggregated FCFS serving (prefill/decode split,
//!   continuous decode batching) **without bucketing**: the planner is a
//!   plain FIFO queue, so heterogeneous batches pad to their longest
//!   member. Isolates exactly the delta the paper attributes to
//!   BucketServe.
//! * [`uellm`] — aggregated serving with profile-predicted **static**
//!   batching: prefill and decode run coupled on every GPU, batches are
//!   request-level (a batch occupies its instance until *all* members
//!   finish decoding), and the batch size is a fixed profile estimate
//!   with no runtime adaptation.

pub mod distserve;
pub mod uellm;

pub use distserve::DistServe;
pub use uellm::Uellm;

/// Which serving system to run (CLI/bench selector).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum System {
    BucketServe,
    DistServe,
    Uellm,
}

impl System {
    pub fn parse(s: &str) -> System {
        match s.to_ascii_lowercase().as_str() {
            "distserve" => System::DistServe,
            "uellm" => System::Uellm,
            _ => System::BucketServe,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            System::BucketServe => "BucketServe",
            System::DistServe => "DistServe",
            System::Uellm => "UELLM",
        }
    }

    pub const ALL: [System; 3] =
        [System::BucketServe, System::DistServe, System::Uellm];

    /// Run this system on a trace with a fresh simulated engine.
    pub fn run_sim(
        &self,
        cfg: &crate::config::SystemConfig,
        trace: &crate::workload::Trace,
    ) -> crate::coordinator::RunReport {
        use crate::cluster::sim::SimEngine;
        let mut engine = SimEngine::new(cfg);
        match self {
            System::BucketServe => {
                crate::coordinator::BucketServe::new(cfg.clone())
                    .run(trace, &mut engine)
            }
            System::DistServe => DistServe::new(cfg.clone()).run(trace, &mut engine),
            System::Uellm => Uellm::new(cfg.clone()).run(trace, &mut engine),
        }
    }
}
