//! Instance state machines for the disaggregated fleet.
//!
//! * [`PrefillFleet`] — one slot per prefill instance holding the
//!   [`InFlightPrefill`] batch it is executing (FCFS workers).
//! * [`DecodeFleet`] — one [`DecodeInstance`] per decode GPU: sequences
//!   pending NVLink hand-off, the continuous-batching active set, the KV
//!   token reservation, and the current iteration boundary.
//!
//! The scheduler owns *when* things happen (the event queue); the fleet
//! owns *what state* each instance is in. Both are engine-agnostic.

use super::batcher::FormedBatch;
use super::events::EventId;
use super::prefix::PrefixStamp;
use crate::workload::RequestClass;
use crate::Micros;

/// Progress of a chunked (sliced) prefill batch through its slices.
/// `None` on [`InFlightPrefill::slice`] means the batch runs
/// monolithically (chunking off, or it fits in one slice) and every
/// pre-chunking code path applies unchanged.
#[derive(Debug, Clone)]
pub struct SliceState {
    /// Token positions completed by *previous* slices (the current slice
    /// covers `[cursor, min(cursor + width, padded_len))`).
    pub cursor: u32,
    /// Positions each sequence advances per slice
    /// (`max(1, slice_tokens / n)`).
    pub width: u32,
    /// KV tokens reserved against the target decode instance so far —
    /// reservation is incremental per slice, so headroom accounting
    /// tracks KV actually being produced; sums to the batch's full
    /// footprint exactly by the final slice.
    pub reserved_so_far: u64,
    /// Execution time already charged for *completed* slices (busy/
    /// useful accounting happens per slice; the per-request
    /// `exec_request_us` charge needs the total at completion).
    pub exec_us: u64,
}

/// A sliced prefill batch parked at a slice boundary: the slot was
/// yielded to urgent online work and the batch waits on its owning shard
/// to resume from `cursor`. Parked batches hold their KV reservation
/// (`reserved_so_far`) but no prefill slot, and are not preemption
/// victims — there is nothing in flight to abort.
#[derive(Debug, Clone)]
pub struct ParkedPrefill {
    pub formed: FormedBatch,
    pub target_decode: usize,
    /// Original first-slice start (TTFT/queue-wait accounting anchors
    /// here across park/resume cycles).
    pub started_at: Micros,
    pub cursor: u32,
    pub width: u32,
    pub reserved_so_far: u64,
    pub exec_us: u64,
}

/// A prefill batch in flight on a prefill instance.
#[derive(Debug, Clone)]
pub struct InFlightPrefill {
    pub formed: FormedBatch,
    pub done_at: Micros,
    pub duration: Micros,
    /// Decode instance whose KV budget the batch was reserved against.
    pub target_decode: usize,
    /// When the batch started executing (progress/wasted-work accounting
    /// for the preemption subsystem). For a sliced batch this is the
    /// original first-slice start; `done_at`/`duration` describe the
    /// *current* slice.
    pub started_at: Micros,
    /// The scheduled `PrefillDone` completion event — tombstoned when the
    /// batch is aborted mid-flight. For a sliced batch this is the
    /// current slice's `PrefillSliceEnd` (or the final `PrefillDone`).
    pub done_event: EventId,
    /// Chunked-prefill progress; `None` = monolithic batch.
    pub slice: Option<SliceState>,
}

/// The prefill side: per-instance busy slots.
#[derive(Debug, Default)]
pub struct PrefillFleet {
    running: Vec<Option<InFlightPrefill>>,
}

impl PrefillFleet {
    pub fn new(n: usize) -> PrefillFleet {
        PrefillFleet { running: (0..n).map(|_| None).collect() }
    }

    pub fn n(&self) -> usize {
        self.running.len()
    }

    pub fn is_idle(&self, pi: usize) -> bool {
        self.running[pi].is_none()
    }

    /// Occupy instance `pi` with a dispatched batch.
    pub fn dispatch(&mut self, pi: usize, batch: InFlightPrefill) {
        debug_assert!(self.running[pi].is_none(), "instance {pi} already busy");
        self.running[pi] = Some(batch);
    }

    /// Take the finished batch off instance `pi` if it completed by `now`.
    pub fn take_done(&mut self, pi: usize, now: Micros) -> Option<InFlightPrefill> {
        let done = matches!(&self.running[pi], Some(p) if p.done_at <= now);
        if done {
            self.running[pi].take()
        } else {
            None
        }
    }

    /// The batch in flight on `pi`, if any (preemption victim scans).
    pub fn get(&self, pi: usize) -> Option<&InFlightPrefill> {
        self.running[pi].as_ref()
    }

    /// Abort the batch in flight on `pi`: the slot frees immediately.
    /// The caller owns the rest of the cancellation — tombstoning the
    /// batch's completion event, releasing its KV reservation, charging
    /// the wasted work, and requeueing its requests.
    pub fn abort(&mut self, pi: usize) -> Option<InFlightPrefill> {
        self.running[pi].take()
    }

    pub fn any_running(&self) -> bool {
        self.running.iter().any(|s| s.is_some())
    }

    /// Per-instance busy flags (stall diagnostics).
    pub fn running_mask(&self) -> Vec<bool> {
        self.running.iter().map(|s| s.is_some()).collect()
    }
}

/// A sequence active (or pending admission) on a decode instance.
#[derive(Debug, Clone)]
pub struct DecodeSeqState {
    pub id: u64,
    pub class: RequestClass,
    pub arrival: Micros,
    pub input_len: u32,
    pub padded_len: u32,
    pub output_len: u32,
    pub generated: u32,
    pub first_token: Micros,
    /// When the NVLink KV hand-off lands (earliest admission time).
    pub ready_at: Micros,
    /// Per-token TBT budget override carried from the request (0 = class
    /// default); consumed by the TBT-aware admission layer.
    pub tbt_us: u64,
    /// When this sequence's most recent *decode-iteration* token landed.
    /// Re-anchored to the admission instant by [`DecodeInstance::admit_due`],
    /// so the first observed inter-token gap is the first iteration's
    /// duration (hand-off/queueing latency is a TTFT-side effect, not a
    /// decode-pacing one). The TBT-aware admission layer measures every
    /// gap and slack from this anchor.
    pub last_token_at: Micros,
    /// Prefix-cache lineage carried through from the queued request, so
    /// completion/eviction can release the cache pins the dispatch
    /// acquired. All-zero when the prefix subsystem is off.
    pub prefix: PrefixStamp,
}

/// One decode instance running continuous (iteration-level) batching.
#[derive(Debug, Default)]
pub struct DecodeInstance {
    /// End of the most recent iteration.
    pub free_at: Micros,
    /// Sequences in the continuous batch.
    pub active: Vec<DecodeSeqState>,
    /// Sequences whose KV hand-off has not yet been admitted.
    pub pending: Vec<DecodeSeqState>,
    /// Full-context KV tokens reserved against this instance's budget.
    pub reserved_tokens: u64,
    /// Set while an iteration is executing; pending joins at the boundary.
    pub iter_end: Option<Micros>,
    /// Timestamp of an already-scheduled idle wake-up (dedupe guard).
    pub wake_at: Option<Micros>,
}

impl DecodeSeqState {
    /// KV token footprint this sequence reserved for itself — must
    /// mirror [`crate::coordinator::bucket::QueuedReq::footprint`] (the
    /// entry this sequence was reserved as), including the shared-prefix
    /// deduction, or release would not balance reserve.
    pub fn footprint(&self) -> u64 {
        ((self.input_len + self.output_len) as u64)
            .saturating_sub(self.prefix.shared_len as u64)
    }
}

impl DecodeInstance {
    /// Not mid-iteration (pending sequences may join immediately).
    pub fn at_boundary(&self) -> bool {
        self.iter_end.is_none()
    }

    /// Move every hand-off that has landed by `now` into the active set.
    /// Only legal at an iteration boundary. Admission anchors the
    /// sequence's inter-token clock: its next gap is measured from here.
    pub fn admit_due(&mut self, now: Micros) {
        debug_assert!(self.at_boundary());
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].ready_at <= now {
                let mut s = self.pending.remove(i);
                s.last_token_at = now;
                self.active.push(s);
            } else {
                i += 1;
            }
        }
    }

    /// Any sequence admitted or awaiting admission.
    pub fn in_flight(&self) -> bool {
        !self.active.is_empty() || !self.pending.is_empty()
    }
}

/// The decode side of the fleet.
#[derive(Debug, Default)]
pub struct DecodeFleet {
    insts: Vec<DecodeInstance>,
}

impl DecodeFleet {
    pub fn new(n: usize) -> DecodeFleet {
        DecodeFleet { insts: (0..n).map(|_| DecodeInstance::default()).collect() }
    }

    pub fn n(&self) -> usize {
        self.insts.len()
    }

    pub fn get(&self, di: usize) -> &DecodeInstance {
        &self.insts[di]
    }

    pub fn get_mut(&mut self, di: usize) -> &mut DecodeInstance {
        &mut self.insts[di]
    }

    pub fn iter(&self) -> std::slice::Iter<'_, DecodeInstance> {
        self.insts.iter()
    }

    pub fn iter_mut(&mut self) -> std::slice::IterMut<'_, DecodeInstance> {
        self.insts.iter_mut()
    }

    /// True when no sequence is active or awaiting admission anywhere
    /// (the memory-deadlock-breaker precondition).
    pub fn nothing_in_flight(&self) -> bool {
        self.insts.iter().all(|d| !d.in_flight())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{PrefillBatch, PrefillItem};
    use crate::coordinator::bucket::QueuedReq;

    fn in_flight(done_at: Micros, target: usize) -> InFlightPrefill {
        let req = QueuedReq {
            id: 1,
            len: 8,
            output_len: 4,
            arrival: 0,
            class: RequestClass::Online,
            tbt_us: 0,
            prefix: PrefixStamp::default(),
        };
        InFlightPrefill {
            formed: FormedBatch {
                batch: PrefillBatch {
                    items: vec![PrefillItem { id: 1, len: 8, tokens: vec![] }],
                    padded_len: 8,
                },
                reqs: vec![req],
                bucket_up: 8,
            },
            done_at,
            duration: done_at,
            target_decode: target,
            started_at: 0,
            done_event: EventId::NONE,
            slice: None,
        }
    }

    fn seq(id: u64, ready_at: Micros) -> DecodeSeqState {
        DecodeSeqState {
            id,
            class: RequestClass::Online,
            arrival: 0,
            input_len: 8,
            padded_len: 8,
            output_len: 4,
            generated: 1,
            first_token: 0,
            ready_at,
            tbt_us: 0,
            last_token_at: 0,
            prefix: PrefixStamp::default(),
        }
    }

    #[test]
    fn prefill_slots_track_occupancy() {
        let mut f = PrefillFleet::new(2);
        assert!(f.is_idle(0) && f.is_idle(1));
        assert!(!f.any_running());
        f.dispatch(0, in_flight(100, 0));
        assert!(!f.is_idle(0) && f.is_idle(1));
        assert!(f.any_running());
        assert_eq!(f.running_mask(), vec![true, false]);
        // Not done yet.
        assert!(f.take_done(0, 50).is_none());
        assert!(!f.is_idle(0));
        // Done.
        let p = f.take_done(0, 100).unwrap();
        assert_eq!(p.done_at, 100);
        assert!(f.is_idle(0));
        assert!(!f.any_running());
    }

    #[test]
    fn abort_frees_a_busy_slot_mid_flight() {
        let mut f = PrefillFleet::new(2);
        f.dispatch(1, in_flight(1000, 0));
        assert!(f.get(1).is_some());
        assert!(f.get(0).is_none());
        // Not done yet — but abort takes it anyway.
        assert!(f.take_done(1, 500).is_none());
        let p = f.abort(1).unwrap();
        assert_eq!(p.done_at, 1000);
        assert!(f.is_idle(1), "aborted slot frees immediately");
        assert!(f.abort(1).is_none(), "idle slot aborts to None");
    }

    #[test]
    fn decode_admits_only_due_handoffs() {
        let mut d = DecodeInstance::default();
        d.pending.push(seq(1, 10));
        d.pending.push(seq(2, 50));
        d.pending.push(seq(3, 20));
        d.admit_due(25);
        let mut active: Vec<u64> = d.active.iter().map(|s| s.id).collect();
        active.sort();
        assert_eq!(active, vec![1, 3]);
        assert_eq!(d.pending.len(), 1);
        assert!(d.in_flight());
        // Admission anchors the inter-token clock: the first gap the TBT
        // layer observes is measured from the admission instant, not from
        // the hand-off landing.
        assert!(d.active.iter().all(|s| s.last_token_at == 25));
        assert_eq!(d.pending[0].last_token_at, 0, "pending stays unanchored");
    }

    #[test]
    fn in_flight_tracking() {
        // Headroom targeting moved to coordinator::balance (see
        // best_decode_mirrors_seed_best_target there); the fleet keeps
        // only the in-flight bookkeeping.
        let mut f = DecodeFleet::new(3);
        f.get_mut(0).reserved_tokens = 800;
        assert!(f.nothing_in_flight());
        f.get_mut(2).pending.push(seq(9, 0));
        assert!(!f.nothing_in_flight());
    }
}
