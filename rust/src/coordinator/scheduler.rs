//! P/D Scheduler: the disaggregated serving loop (paper §III).
//!
//! Drives a fleet of prefill instances (FCFS workers over planner-formed
//! batches), the NVLink KV hand-off, and decode instances running
//! continuous (iteration-level) batching, against any [`Engine`]:
//!
//! ```text
//! arrivals ─▶ planner (buckets / FCFS) ─▶ prefill workers ─▶ NVLink ─▶
//!          decode instances (continuous batching) ─▶ completions
//! ```
//!
//! The loop is a discrete-event simulation in virtual time for
//! [`crate::cluster::sim::SimEngine`] and the *same* code path in wall time
//! for [`crate::runtime::PjrtEngine`] (blocking engine calls; sleeps until
//! arrivals). BucketServe and the DistServe-like baseline differ only in
//! the [`PrefillPlanner`] plugged in.

use super::batcher::{DynamicBatcher, FormedBatch, KvMemoryModel};
use super::bucket::{BucketManager, QueuedReq};
use super::monitor::GlobalMonitor;
use crate::cluster::{DecodeBatch, DecodeSeq, Engine};
use crate::config::SystemConfig;
use crate::workload::request::Completion;
use crate::workload::{Request, Trace};
use crate::Micros;
use std::time::Instant;

/// Planner plug-in: how arriving requests queue and batches form.
pub trait PrefillPlanner {
    /// A request arrived at the gateway.
    fn admit(&mut self, req: &Request, now: Micros);

    /// Form the next prefill batch given the target decode instance's KV
    /// headroom (in tokens). Returning None means "wait".
    fn plan(&mut self, now: Micros, headroom_tokens: u64) -> Option<FormedBatch>;

    /// Forced single-request pop to break memory deadlocks (a head request
    /// whose full context alone exceeds the headroom, with nothing else in
    /// flight).
    fn force_pop(&mut self) -> Option<QueuedReq>;

    /// Requests currently queued.
    fn queued(&self) -> usize;

    /// Cumulative planning overhead (ns) — bucketing cost for Fig. 6.
    fn overhead_ns(&self) -> u64;

    /// Current bucket count (1 for non-bucketing planners).
    fn n_buckets(&self) -> usize {
        1
    }
}

/// BucketServe's planner: Bucketing Manager + Dynamic Batching Controller.
pub struct BucketPlanner {
    mgr: BucketManager,
    batcher: DynamicBatcher,
    mem: KvMemoryModel,
    max_buckets_seen: usize,
}

impl BucketPlanner {
    pub fn new(cfg: &SystemConfig) -> BucketPlanner {
        BucketPlanner {
            mgr: BucketManager::new(
                cfg.scheduler.l_max,
                cfg.scheduler.theta,
                cfg.scheduler.min_bucket_width,
            ),
            batcher: DynamicBatcher::new(cfg.model.clone(), &cfg.scheduler),
            mem: KvMemoryModel::new(cfg.model.clone(), cfg.scheduler.mem_safety),
            max_buckets_seen: 1,
        }
    }

    pub fn manager(&self) -> &BucketManager {
        &self.mgr
    }

    pub fn max_buckets_seen(&self) -> usize {
        self.max_buckets_seen
    }
}

impl PrefillPlanner for BucketPlanner {
    fn admit(&mut self, req: &Request, _now: Micros) {
        self.mgr.assign(QueuedReq {
            id: req.id,
            len: req.input_len,
            output_len: req.output_len,
            arrival: req.arrival,
            class: req.class,
        });
    }

    fn plan(&mut self, _now: Micros, headroom_tokens: u64) -> Option<FormedBatch> {
        // Algorithm 1's AdjustBuckets with N_max from Eq. 6 (estimated via
        // the queue's mean full-context length — the Global Monitor view).
        let queued = self.mgr.total();
        if queued > 0 {
            let mean_len: f64 = self
                .mgr
                .buckets()
                .iter()
                .flat_map(|b| b.requests.iter())
                .map(|r| (r.len + r.output_len) as f64)
                .sum::<f64>()
                / queued as f64;
            let n_max = (headroom_tokens as f64 / mean_len.max(1.0))
                .floor()
                .max(1.0) as usize;
            self.mgr.adjust(n_max);
            self.max_buckets_seen = self.max_buckets_seen.max(self.mgr.n_buckets());
        }
        // The batcher already admits against headroom_tokens (Eq. 6).
        let _ = &self.mem;
        self.batcher.form_batch(&mut self.mgr, headroom_tokens)
    }

    fn force_pop(&mut self) -> Option<QueuedReq> {
        let bucket = self
            .mgr
            .buckets_mut()
            .iter_mut()
            .filter(|b| !b.is_empty())
            .min_by_key(|b| b.earliest_arrival().unwrap_or(Micros::MAX))?;
        let idx = bucket
            .requests
            .iter()
            .enumerate()
            .min_by_key(|(_, r)| r.arrival)
            .map(|(i, _)| i)?;
        Some(bucket.requests.remove(idx))
    }

    fn queued(&self) -> usize {
        self.mgr.total()
    }

    fn overhead_ns(&self) -> u64 {
        self.mgr.overhead_ns
    }

    fn n_buckets(&self) -> usize {
        self.mgr.n_buckets()
    }
}

// ---------------------------------------------------------------------------
// Run report
// ---------------------------------------------------------------------------

/// Everything a run produces; the metrics layer derives each figure from it.
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    pub completions: Vec<Completion>,
    pub makespan_us: Micros,
    pub n_prefill: usize,
    pub n_decode: usize,
    pub prefill_busy_us: u64,
    pub decode_busy_us: u64,
    /// Busy time weighted by useful-work fraction (padding-aware).
    pub prefill_useful_us: f64,
    /// Busy time weighted by the bandwidth-amortization factor.
    pub decode_useful_us: f64,
    pub bucket_overhead_ns: u64,
    pub max_buckets: usize,
    pub peak_batch: usize,
    pub prefill_batches: u64,
    pub decode_iters: u64,
    /// Σ per-request prefill execution time (batch duration × members).
    pub prefill_exec_request_us: u64,
    /// Σ per-request queueing delay before prefill dispatch.
    pub queue_wait_us: u64,
}

impl RunReport {
    /// Offline throughput: total (prompt + generated) tokens per second.
    pub fn throughput_tps(&self) -> f64 {
        let tokens: u64 = self
            .completions
            .iter()
            .map(|c| (c.input_len + c.output_len) as u64)
            .sum();
        tokens as f64 / (self.makespan_us as f64 / 1e6).max(1e-9)
    }

    /// Generated tokens per second.
    pub fn output_tps(&self) -> f64 {
        let tokens: u64 =
            self.completions.iter().map(|c| c.output_len as u64).sum();
        tokens as f64 / (self.makespan_us as f64 / 1e6).max(1e-9)
    }

    /// Completed requests per second ("server RPS" in Fig. 5).
    pub fn server_rps(&self) -> f64 {
        self.completions.len() as f64 / (self.makespan_us as f64 / 1e6).max(1e-9)
    }

    /// SLO attainment: fraction of completions meeting both TTFT and TBT.
    pub fn slo_attainment(&self, ttft_us: u64, tbt_us: u64) -> f64 {
        if self.completions.is_empty() {
            return 1.0;
        }
        let ok = self
            .completions
            .iter()
            .filter(|c| c.ttft() <= ttft_us && c.tbt() <= tbt_us as f64)
            .count();
        ok as f64 / self.completions.len() as f64
    }

    /// Mean padding-aware GPU utilization across the fleet (Fig. 3b / 5b).
    pub fn gpu_util(&self) -> f64 {
        let cap = (self.n_prefill + self.n_decode) as f64
            * self.makespan_us as f64;
        if cap <= 0.0 {
            return 0.0;
        }
        (self.prefill_useful_us + self.decode_useful_us) / cap
    }

    /// Mean end-to-end latency (µs).
    pub fn mean_e2e_us(&self) -> f64 {
        if self.completions.is_empty() {
            return 0.0;
        }
        self.completions.iter().map(|c| c.e2e() as f64).sum::<f64>()
            / self.completions.len() as f64
    }

    /// Mean TTFT (µs).
    pub fn mean_ttft_us(&self) -> f64 {
        if self.completions.is_empty() {
            return 0.0;
        }
        self.completions.iter().map(|c| c.ttft() as f64).sum::<f64>()
            / self.completions.len() as f64
    }

    /// Fig. 6a phase breakdown, all in µs per request:
    /// (queue wait, prefill exec, decode exec, bucketing overhead).
    pub fn breakdown_us(&self) -> (f64, f64, f64, f64) {
        let n = self.completions.len().max(1) as f64;
        let decode: f64 = self
            .completions
            .iter()
            .map(|c| c.finished.saturating_sub(c.first_token) as f64)
            .sum::<f64>()
            / n;
        (
            self.queue_wait_us as f64 / n,
            self.prefill_exec_request_us as f64 / n,
            decode,
            self.bucket_overhead_ns as f64 / 1e3 / n,
        )
    }
}

// ---------------------------------------------------------------------------
// The serving loop
// ---------------------------------------------------------------------------

/// A prefill batch in flight on a prefill instance.
struct InFlightPrefill {
    formed: FormedBatch,
    done_at: Micros,
    duration: Micros,
    target_decode: usize,
}

/// A sequence active (or pending admission) on a decode instance.
#[derive(Debug, Clone)]
struct ActiveSeq {
    id: u64,
    class: crate::workload::RequestClass,
    arrival: Micros,
    input_len: u32,
    padded_len: u32,
    output_len: u32,
    generated: u32,
    first_token: Micros,
    ready_at: Micros,
}

struct DecodeInst {
    free_at: Micros,
    active: Vec<ActiveSeq>,
    pending: Vec<ActiveSeq>,
    reserved_tokens: u64,
    iter_end: Option<Micros>,
}

/// The P/D scheduler: owns instance timelines and queues; engine-agnostic.
pub struct PdScheduler {
    cfg: SystemConfig,
    planner: Box<dyn PrefillPlanner>,
    monitor: GlobalMonitor,
}

impl PdScheduler {
    pub fn new(cfg: &SystemConfig, planner: Box<dyn PrefillPlanner>) -> PdScheduler {
        PdScheduler {
            cfg: cfg.clone(),
            planner,
            monitor: GlobalMonitor::new(10_000_000, 0),
        }
    }

    /// Serve the whole trace; returns the run report.
    pub fn run(&mut self, trace: &Trace, engine: &mut dyn Engine) -> RunReport {
        let mem = KvMemoryModel::new(
            self.cfg.model.clone(),
            self.cfg.scheduler.mem_safety,
        );
        let per_decode_budget = mem.token_budget(engine.decode_mem_budget());
        self.monitor = GlobalMonitor::new(
            10_000_000,
            per_decode_budget * self.cfg.fleet.n_decode as u64,
        );

        let realtime = engine.realtime();
        let wall_start = Instant::now();
        let n_prefill = self.cfg.fleet.n_prefill.max(1) as usize;
        let n_decode = self.cfg.fleet.n_decode.max(1) as usize;

        let mut prefill_free: Vec<Micros> = vec![0; n_prefill];
        let mut prefill_running: Vec<Option<InFlightPrefill>> =
            (0..n_prefill).map(|_| None).collect();
        let mut decode: Vec<DecodeInst> = (0..n_decode)
            .map(|_| DecodeInst {
                free_at: 0,
                active: Vec::new(),
                pending: Vec::new(),
                reserved_tokens: 0,
                iter_end: None,
            })
            .collect();

        let mut report = RunReport {
            n_prefill,
            n_decode,
            ..Default::default()
        };
        let mut next_arrival = 0usize;
        let mut clock: Micros = 0;
        let total = trace.len();
        let weight_bytes = engine.model().weight_bytes() as f64;
        let kv_per_token = engine.model().kv_bytes_per_token() as f64;

        let mut spin_guard: u64 = 0;
        while report.completions.len() < total {
            spin_guard += 1;
            if spin_guard > 50_000_000 {
                panic!(
                    "scheduler livelock: clock={clock} done={}/{} queued={} \
                     arrivals={next_arrival} prefill_busy={:?} \
                     decode=[{}]",
                    report.completions.len(),
                    total,
                    self.planner.queued(),
                    prefill_running.iter().map(|s| s.is_some()).collect::<Vec<_>>(),
                    decode
                        .iter()
                        .map(|d| format!(
                            "(act={} pend={} resv={} iter_end={:?})",
                            d.active.len(), d.pending.len(), d.reserved_tokens, d.iter_end
                        ))
                        .collect::<Vec<_>>()
                        .join(",")
                );
            }
            // ---- 1. Next event time --------------------------------------
            let mut next_event = Micros::MAX;
            if next_arrival < total {
                next_event = next_event.min(trace.requests[next_arrival].arrival);
            }
            for p in prefill_running.iter().flatten() {
                next_event = next_event.min(p.done_at);
            }
            for d in &decode {
                if let Some(t) = d.iter_end {
                    // Mid-iteration: the boundary is the next actionable
                    // moment for this instance; pending hand-offs with
                    // earlier ready_at join at that boundary, so they must
                    // NOT pin next_event in the past (livelock otherwise).
                    next_event = next_event.min(t);
                } else {
                    for s in &d.pending {
                        next_event = next_event.min(s.ready_at.max(clock));
                    }
                }
            }
            if next_event == Micros::MAX {
                // Nothing scheduled: should not happen unless deadlocked.
                debug_assert!(
                    self.planner.queued() > 0,
                    "idle with no work and {} incomplete",
                    total - report.completions.len()
                );
                next_event = clock;
            }
            if realtime {
                let wall = wall_start.elapsed().as_micros() as Micros;
                if next_event > wall {
                    std::thread::sleep(std::time::Duration::from_micros(
                        next_event - wall,
                    ));
                }
                clock = wall_start.elapsed().as_micros() as Micros;
            } else {
                clock = clock.max(next_event);
            }

            // ---- 2. Admit arrivals ---------------------------------------
            while next_arrival < total
                && trace.requests[next_arrival].arrival <= clock
            {
                let r = &trace.requests[next_arrival];
                self.planner.admit(r, clock);
                self.monitor.on_arrival(clock, r.input_len);
                next_arrival += 1;
            }

            // ---- 3. Prefill completions → NVLink → decode pending --------
            for slot in prefill_running.iter_mut() {
                let finished = matches!(slot, Some(p) if p.done_at <= clock);
                if !finished {
                    continue;
                }
                let p = slot.take().unwrap();
                report.prefill_batches += 1;
                report.peak_batch = report.peak_batch.max(p.formed.batch.n());
                report.prefill_busy_us += p.duration;
                report.prefill_useful_us +=
                    p.duration as f64 * p.formed.batch.efficiency();
                report.prefill_exec_request_us +=
                    p.duration * p.formed.batch.n() as u64;
                self.monitor.on_batch_done(p.duration);
                let transfer =
                    engine.kv_transfer(p.formed.batch.useful_tokens());
                let d = &mut decode[p.target_decode];
                for r in &p.formed.reqs {
                    report.queue_wait_us += p
                        .done_at
                        .saturating_sub(p.duration)
                        .saturating_sub(r.arrival);
                    d.pending.push(ActiveSeq {
                        id: r.id,
                        class: r.class,
                        arrival: r.arrival,
                        input_len: r.len,
                        padded_len: p.formed.batch.padded_len,
                        output_len: r.output_len,
                        generated: 1, // prefill produced the first token
                        first_token: p.done_at,
                        ready_at: p.done_at + transfer,
                    });
                }
                self.monitor.on_decode_enter(p.formed.reqs.len());
            }

            // ---- 4. Decode iteration completions -------------------------
            for d in decode.iter_mut() {
                let ended = matches!(d.iter_end, Some(t) if t <= clock);
                if !ended {
                    continue;
                }
                let iter_end = d.iter_end.take().unwrap();
                let mut still_active = Vec::with_capacity(d.active.len());
                for mut s in d.active.drain(..) {
                    s.generated += 1;
                    if s.generated >= s.output_len {
                        let footprint = (s.input_len + s.output_len) as u64;
                        d.reserved_tokens =
                            d.reserved_tokens.saturating_sub(footprint);
                        self.monitor.kv_release(footprint);
                        self.monitor.on_decode_exit(1);
                        engine.release(s.id);
                        report.completions.push(Completion {
                            id: s.id,
                            class: s.class,
                            input_len: s.input_len,
                            output_len: s.output_len,
                            arrival: s.arrival,
                            first_token: s.first_token,
                            finished: iter_end,
                            padded_len: s.padded_len,
                        });
                    } else {
                        still_active.push(s);
                    }
                }
                d.active = still_active;
            }

            // ---- 5. Continuous-batching admission at iteration boundary --
            for d in decode.iter_mut() {
                if d.iter_end.is_some() {
                    continue; // mid-iteration; join at the next boundary
                }
                let mut i = 0;
                while i < d.pending.len() {
                    if d.pending[i].ready_at <= clock {
                        let s = d.pending.remove(i);
                        d.active.push(s);
                    } else {
                        i += 1;
                    }
                }
            }

            // ---- 6. Dispatch prefill batches ------------------------------
            for pi in 0..n_prefill {
                if prefill_running[pi].is_some() || prefill_free[pi] > clock {
                    continue;
                }
                // Target: the decode instance with the most KV headroom.
                let (ti, headroom) = decode
                    .iter()
                    .enumerate()
                    .map(|(i, d)| {
                        (i, per_decode_budget.saturating_sub(d.reserved_tokens))
                    })
                    .max_by_key(|&(_, h)| h)
                    .unwrap();
                let formed = match self.planner.plan(clock, headroom) {
                    Some(f) => Some(f),
                    None => {
                        // Deadlock breaker: nothing anywhere in flight and a
                        // head request alone exceeds even an idle budget.
                        let nothing_in_flight = prefill_running
                            .iter()
                            .all(|s| s.is_none())
                            && decode.iter().all(|d| {
                                d.active.is_empty() && d.pending.is_empty()
                            });
                        if nothing_in_flight && self.planner.queued() > 0 {
                            self.planner.force_pop().map(|r| {
                                let padded = r.len.max(1);
                                FormedBatch {
                                    batch: crate::cluster::PrefillBatch {
                                        items: vec![crate::cluster::PrefillItem {
                                            id: r.id,
                                            len: r.len,
                                            tokens: vec![],
                                        }],
                                        padded_len: padded,
                                    },
                                    reqs: vec![r],
                                    bucket_up: padded,
                                }
                            })
                        } else {
                            None
                        }
                    }
                };
                let Some(formed) = formed else { break };
                let footprint: u64 = formed
                    .reqs
                    .iter()
                    .map(|r| (r.len + r.output_len) as u64)
                    .sum();
                decode[ti].reserved_tokens += footprint;
                self.monitor.kv_reserve(footprint);
                self.monitor.on_prefill_dispatch(formed.reqs.len());
                let duration = engine
                    .prefill(&formed.batch)
                    .expect("prefill execution failed");
                // Realtime engines block inside prefill(): completion is
                // "now" on the wall clock. Virtual engines schedule ahead.
                let done_at = if realtime {
                    wall_start.elapsed().as_micros() as Micros
                } else {
                    clock + duration
                };
                prefill_free[pi] = done_at;
                prefill_running[pi] = Some(InFlightPrefill {
                    formed,
                    done_at,
                    duration,
                    target_decode: ti,
                });
            }

            // ---- 7. Launch decode iterations ------------------------------
            for d in decode.iter_mut() {
                if d.iter_end.is_some() || d.active.is_empty() {
                    continue;
                }
                let batch = DecodeBatch {
                    seqs: d
                        .active
                        .iter()
                        .map(|s| DecodeSeq {
                            id: s.id,
                            ctx_len: s.input_len + s.generated,
                        })
                        .collect(),
                };
                let duration = engine
                    .decode_step(&batch)
                    .expect("decode execution failed");
                let end = if realtime {
                    wall_start.elapsed().as_micros() as Micros
                } else {
                    clock.max(d.free_at) + duration
                };
                d.free_at = end;
                d.iter_end = Some(end);
                report.decode_iters += 1;
                report.decode_busy_us += duration;
                // Bandwidth-amortization efficiency: fraction of streamed
                // bytes that are per-sequence KV rather than the weight
                // read shared by the batch.
                let kv_bytes = batch.total_ctx() as f64 * kv_per_token;
                let eff = kv_bytes / (kv_bytes + weight_bytes);
                report.decode_useful_us += duration as f64 * eff;
            }

            report.makespan_us = report.makespan_us.max(clock);
        }

        report.bucket_overhead_ns = self.planner.overhead_ns();
        report.max_buckets = report.max_buckets.max(self.planner.n_buckets());
        if let Some(last) = report.completions.iter().map(|c| c.finished).max() {
            report.makespan_us = report.makespan_us.max(last);
        }
        report
    }

    pub fn monitor(&mut self) -> &mut GlobalMonitor {
        &mut self.monitor
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::sim::SimEngine;
    use crate::workload::{Dataset, RequestClass};

    fn small_cfg() -> SystemConfig {
        let mut cfg = SystemConfig::default();
        cfg.fleet.n_prefill = 1;
        cfg.fleet.n_decode = 1;
        cfg
    }

    fn run_bucketserve(cfg: &SystemConfig, trace: &Trace) -> RunReport {
        let planner = BucketPlanner::new(cfg);
        let mut sched = PdScheduler::new(cfg, Box::new(planner));
        let mut engine = SimEngine::new(cfg);
        sched.run(trace, &mut engine)
    }

    #[test]
    fn completes_every_request() {
        let cfg = small_cfg();
        let trace = Trace::generate(
            Dataset::Alpaca, 50, 4.0, RequestClass::Online, cfg.model.max_seq, 1,
        );
        let report = run_bucketserve(&cfg, &trace);
        assert_eq!(report.completions.len(), 50);
        let mut ids: Vec<_> = report.completions.iter().map(|c| c.id).collect();
        ids.sort();
        assert_eq!(ids, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn timestamps_are_causal() {
        let cfg = small_cfg();
        let trace = Trace::generate(
            Dataset::Mixed, 40, 8.0, RequestClass::Online, cfg.model.max_seq, 2,
        );
        let report = run_bucketserve(&cfg, &trace);
        for c in &report.completions {
            assert!(c.first_token >= c.arrival, "ttft causal for {}", c.id);
            assert!(c.finished >= c.first_token, "decode causal for {}", c.id);
        }
    }

    #[test]
    fn offline_batch_trace_completes() {
        let cfg = small_cfg();
        let trace =
            Trace::batch(Dataset::Alpaca, 64, RequestClass::Offline, 4096, 3);
        let report = run_bucketserve(&cfg, &trace);
        assert_eq!(report.completions.len(), 64);
        assert!(report.throughput_tps() > 0.0);
        assert!(report.gpu_util() > 0.0 && report.gpu_util() <= 1.0);
    }

    #[test]
    fn multi_instance_fleet_is_faster() {
        let mut cfg = small_cfg();
        let trace =
            Trace::batch(Dataset::Mixed, 96, RequestClass::Offline, 4096, 4);
        let r1 = run_bucketserve(&cfg, &trace);
        cfg.fleet.n_prefill = 2;
        cfg.fleet.n_decode = 2;
        let r2 = run_bucketserve(&cfg, &trace);
        assert!(
            r2.makespan_us < r1.makespan_us,
            "2+2 fleet {} vs 1+1 {}",
            r2.makespan_us,
            r1.makespan_us
        );
    }

    #[test]
    fn oversized_request_does_not_deadlock() {
        let mut cfg = small_cfg();
        // Tiny GPU: budget smaller than one max request.
        cfg.gpu.mem_bytes = 27 * (1u64 << 30); // 26 GB weights + ~1 GB
        let trace =
            Trace::batch(Dataset::LongBench, 3, RequestClass::Offline, 4096, 5);
        let report = run_bucketserve(&cfg, &trace);
        assert_eq!(report.completions.len(), 3);
    }

    #[test]
    fn decode_dominates_e2e() {
        // Paper Fig. 6a: decode ≈ 90% of execution time.
        let cfg = small_cfg();
        let trace = Trace::generate(
            Dataset::Alpaca, 40, 2.0, RequestClass::Online, cfg.model.max_seq, 6,
        );
        let report = run_bucketserve(&cfg, &trace);
        let (_q, pre, dec, _b) = report.breakdown_us();
        assert!(
            dec > 4.0 * pre,
            "decode {dec} should dominate prefill {pre}"
        );
    }

    #[test]
    fn bucketing_overhead_negligible() {
        // Paper: bucketing + dynamic batching < 1% of execution time.
        let cfg = small_cfg();
        let trace = Trace::generate(
            Dataset::Mixed, 100, 16.0, RequestClass::Online, cfg.model.max_seq, 7,
        );
        let report = run_bucketserve(&cfg, &trace);
        let overhead_us = report.bucket_overhead_ns as f64 / 1e3;
        assert!(
            overhead_us < 0.01 * report.makespan_us as f64,
            "overhead {overhead_us}µs vs makespan {}µs",
            report.makespan_us
        );
    }

    #[test]
    fn kv_reservation_never_exceeds_budget() {
        // Indirect check: a run against a small budget still respects
        // completion integrity and never admits unbounded batches.
        let mut cfg = small_cfg();
        cfg.gpu.mem_bytes = 30 * (1u64 << 30);
        let trace =
            Trace::batch(Dataset::Mixed, 60, RequestClass::Offline, 4096, 8);
        let report = run_bucketserve(&cfg, &trace);
        assert_eq!(report.completions.len(), 60);
        // ~1.8 GB of KV headroom ≈ 2.4k tokens: Eq. 6 keeps batches far
        // below the unconstrained case (which would admit all 60 at once).
        assert!(report.peak_batch <= 32, "peak {}", report.peak_batch);
    }

    #[test]
    fn slo_attainment_degrades_with_load() {
        let cfg = SystemConfig::default();
        let low = Trace::generate(
            Dataset::Alpaca, 150, 2.0, RequestClass::Online, cfg.model.max_seq, 9,
        );
        let high = Trace::generate(
            Dataset::Alpaca, 150, 60.0, RequestClass::Online, cfg.model.max_seq, 9,
        );
        let rl = run_bucketserve(&cfg, &low);
        let rh = run_bucketserve(&cfg, &high);
        let al = rl.slo_attainment(cfg.slo.ttft_us, cfg.slo.tbt_us);
        let ah = rh.slo_attainment(cfg.slo.ttft_us, cfg.slo.tbt_us);
        assert!(al >= ah, "low-load {al} >= high-load {ah}");
    }
}
