//! P/D Scheduler: the disaggregated serving loop (paper §III).
//!
//! Drives a fleet of prefill instances (FCFS workers over planner-formed
//! batches), the NVLink KV hand-off, and decode instances running
//! continuous (iteration-level) batching, against any [`Engine`]:
//!
//! ```text
//! arrivals ─▶ placement ─▶ shard planners (buckets / priority / FCFS) ─▶
//!     prefill workers ─▶ NVLink ─▶ decode instances (continuous
//!     batching, one owner shard each) ─▶ completions
//! ```
//!
//! The loop is event-driven: [`PdScheduler::run`] pops typed events off a
//! [`EventQueue`] (arrivals, prefill completions, hand-off landings,
//! decode iteration boundaries), advances the clock, and dispatches to the
//! fleet state machines in [`super::fleet`]. Scheduling state is sharded
//! per decode instance ([`super::shard`]): arrivals route to a shard via
//! the [`super::balance`] placement policy, each shard plans against its
//! own decode instances' KV budgets, and work-stealing rebalances queues
//! at decode-iteration boundaries. In virtual time this is a
//! discrete-event simulation ([`crate::cluster::sim::SimEngine`]); the
//! *same* code path runs in wall time for [`crate::runtime::PjrtEngine`]
//! (blocking engine calls; sleeps until arrivals). BucketServe and the
//! DistServe-like baseline differ only in the [`PrefillPlanner`] plugged
//! in; priority-aware SLO scheduling rides inside the bucket planner.

use super::admission::AdmissionEngine;
use super::balance;
use super::batcher::{DynamicBatcher, FormedBatch, KvMemoryModel};
use super::bucket::{BucketManager, QueuedReq};
use super::events::{Event, EventId, EventKind, EventQueue};
use super::executor::{
    self, BoundaryJob, BoundaryOutcome, ExecutorPool, PlanJob, PlanProposal,
    SyncKey,
};
use super::fleet::{
    DecodeFleet, DecodeSeqState, InFlightPrefill, ParkedPrefill, PrefillFleet,
    SliceState,
};
use super::live::{HealthInfo, InstanceLoad, LiveCmd, LiveState, LoadsInfo};
use super::monitor::GlobalMonitor;
use super::preempt::PreemptionEngine;
use super::prefix::{PrefixCache, PrefixStamp};
use super::priority::PriorityScorer;
use super::shard::ShardSet;
use crate::cluster::{DecodeBatch, DecodeSeq, Engine, PrefillBatch, PrefillItem};
use crate::config::{ChunkSpec, Placement, SystemConfig};
use crate::workload::request::Completion;
use crate::workload::{Request, RequestClass, Trace};
use crate::workload::RequestId;
use crate::Micros;
use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::{Duration, Instant};

/// Iteration ceiling standing in for the old 50M-spin livelock guard;
/// exceeding it ends the run with [`RunReport::error`] set instead of a
/// panic.
const MAX_SCHED_EVENTS: u64 = 50_000_000;

/// Planner plug-in: how arriving requests queue and batches form.
///
/// `Send` because the plan/commit protocol ships planner *snapshots*
/// (see [`clone_box`](Self::clone_box)) to executor worker threads for
/// speculation; the live planner itself never leaves the merge loop.
pub trait PrefillPlanner: Send {
    /// A request arrived at the gateway.
    fn admit(&mut self, req: &Request, now: Micros);

    /// Deep copy of the full planner state — the snapshot stage of the
    /// executor's plan/commit protocol. Speculation runs [`plan`](Self::plan)
    /// against the copy on a worker thread; committing the proposal
    /// *installs* the copy as the shard's planner, so the copy must be
    /// complete enough that installing it is indistinguishable from
    /// having planned inline.
    fn clone_box(&self) -> Box<dyn PrefillPlanner>;

    /// Form the next prefill batch given the target decode instance's KV
    /// headroom (in tokens). Returning None means "wait".
    fn plan(&mut self, now: Micros, headroom_tokens: u64) -> Option<FormedBatch>;

    /// Forced single-request pop to break memory deadlocks (a head request
    /// whose full context alone exceeds the headroom, with nothing else in
    /// flight).
    fn force_pop(&mut self, now: Micros) -> Option<QueuedReq>;

    /// Requests currently queued.
    fn queued(&self) -> usize;

    /// Full-context (prompt + expected generation) token footprint of the
    /// queued requests — what KV-aware placement weighs a shard by.
    fn queued_tokens(&self) -> u64;

    /// Work-stealing donor side: give up to `max_n` queued requests from
    /// the *tail* of the drain order (the least-urgent end of the queue
    /// segment the next `plan` would serve), whose cumulative
    /// full-context footprint stays within `max_tokens` (the thief's KV
    /// admission headroom — stealing more than the thief can admit just
    /// parks backlog behind a different fence), preserving their relative
    /// order. Implementations must never surrender the head half of that
    /// segment — the donor keeps what it was about to dispatch, so a
    /// steal can move backlog but never the most urgent work.
    fn steal_tail(
        &mut self,
        max_n: usize,
        max_tokens: u64,
        now: Micros,
    ) -> Vec<QueuedReq>;

    /// Work-stealing thief side: absorb requests stolen from another
    /// shard's planner, as if they had been admitted here originally.
    /// Preemption reuses this for its requeues (aborted prefill batches,
    /// checkpoint-restored evictees).
    fn absorb(&mut self, reqs: Vec<QueuedReq>, now: Micros);

    /// The queued online request with the earliest arrival — online TTFT
    /// urgency is monotone in waiting time, so this is the request whose
    /// slack the preemption triggers weigh. Ties break on id so the peek
    /// is deterministic. None when no online request is queued.
    ///
    /// Takes `&mut self` so implementations can serve it from a cached
    /// [`OnlinePeek`] (maintained on admit/absorb, lazily recomputed
    /// after a drain removes the cached head) — the preemption trigger
    /// scan is then O(shards) amortized per event instead of the
    /// O(queued) full walk the ROADMAP flagged.
    fn oldest_online(&mut self) -> Option<QueuedReq>;

    /// True when this planner's drain order serves by SLO urgency, i.e.
    /// an urgent requeued request is dispatched ahead of the work it
    /// preempted. The whole preemption subsystem arms only when this
    /// holds: under a pure-FIFO drain the aborted batch's members — or
    /// the earlier-arrival queue head, for an eviction — would simply
    /// re-take the freed slot/KV, making every preemption pure wasted
    /// FLOP-time (the scheduler warns and stays inert instead).
    fn drain_follows_urgency(&self) -> bool;

    /// Cumulative planning overhead (ns) — bucketing cost for Fig. 6.
    fn overhead_ns(&self) -> u64;

    /// Current bucket count (1 for non-bucketing planners).
    fn n_buckets(&self) -> usize {
        1
    }

    /// Distinct prefix lineages queued here, as `(prefix_id, max
    /// shareable length)` pairs — what the cache-affinity steal scorer
    /// weighs a shard's stolen tail by. The default (no lineage
    /// tracking) keeps victim selection on pure queue depth, so planners
    /// that predate the prefix subsystem need no changes.
    fn lineage_summary(&self) -> Vec<(u64, u32)> {
        Vec::new()
    }
}

/// Number of entries from `tail` (iterated least-urgent-first, i.e. the
/// donor queue's back-to-front) whose cumulative full-context footprint
/// stays within `max_tokens` — the KV-aware steal-sizing rule shared by
/// both planner families so their donor behavior cannot silently
/// diverge.
pub(crate) fn kv_capped_take<'a>(
    tail: impl Iterator<Item = &'a QueuedReq>,
    max_tokens: u64,
) -> usize {
    let mut take = 0usize;
    let mut tokens = 0u64;
    for r in tail {
        let footprint = r.footprint();
        if tokens + footprint > max_tokens {
            break;
        }
        tokens += footprint;
        take += 1;
    }
    take
}

/// The queued online request with the earliest arrival, ties on id —
/// the shared full-scan fallback behind [`PrefillPlanner::oldest_online`]
/// (the [`OnlinePeek`] cache recomputes through this when stale, and the
/// cache-consistency property test pins the two against each other).
pub(crate) fn oldest_online_in<'a>(
    reqs: impl Iterator<Item = &'a QueuedReq>,
) -> Option<QueuedReq> {
    reqs.filter(|r| r.class == RequestClass::Online)
        .min_by_key(|r| (r.arrival, r.id))
        .copied()
}

/// Cached min-arrival online peek shared by both planner families — the
/// ROADMAP's "O(queued) preemption candidate scan" fix. The cache is a
/// three-state cell: `Some(Some(r))` = the oldest online request is `r`,
/// `Some(None)` = provably no online request queued, `None` = stale
/// (the cached head was drained; the next [`OnlinePeek::get`] pays one
/// full scan to refresh). Inserts keep a fresh cache fresh in O(1)
/// (min under insertion is a comparison); only removing the cached
/// minimum itself forces a rescan, so `oldest_online` is O(1) amortized
/// across the event loop.
#[derive(Debug, Default, Clone)]
pub struct OnlinePeek {
    cached: Option<Option<QueuedReq>>,
}

impl OnlinePeek {
    /// An empty planner provably has no online request queued.
    pub fn new() -> OnlinePeek {
        OnlinePeek { cached: Some(None) }
    }

    /// A request entered the queue (admit/absorb/requeue).
    pub fn note_insert(&mut self, r: &QueuedReq) {
        if r.class != RequestClass::Online {
            return;
        }
        if let Some(cur) = &mut self.cached {
            match cur {
                Some(c) if (r.arrival, r.id) < (c.arrival, c.id) => *c = *r,
                None => *cur = Some(*r),
                _ => {}
            }
        }
    }

    /// Requests left the queue (plan/force-pop/steal). Invalidates only
    /// when the cached head itself was among them — draining anything
    /// else leaves the minimum untouched.
    pub fn note_removed<'a>(
        &mut self,
        removed: impl IntoIterator<Item = &'a QueuedReq>,
    ) {
        if let Some(Some(c)) = &self.cached {
            let cid = c.id;
            if removed.into_iter().any(|r| r.id == cid) {
                self.cached = None;
            }
        }
    }

    /// The cached peek, refreshing via `recompute` (a full scan) when
    /// stale.
    pub fn get(
        &mut self,
        recompute: impl FnOnce() -> Option<QueuedReq>,
    ) -> Option<QueuedReq> {
        if self.cached.is_none() {
            self.cached = Some(recompute());
        }
        self.cached.unwrap()
    }
}

/// Σ context tokens (prompt + generated so far) across decode sequences —
/// the `total_ctx` the admission layer's iteration-time projections feed
/// to [`Engine::projected_decode_us`], matching what `launch_decode`
/// would hand the engine for the same set. The single definition every
/// projection site shares (full active set, online-only floor, incoming
/// batches), so context accounting cannot silently diverge between them.
fn active_ctx<'a>(seqs: impl IntoIterator<Item = &'a DecodeSeqState>) -> u64 {
    seqs.into_iter()
        .map(|s| (s.input_len + s.generated) as u64)
        .sum()
}

/// Record one observed inter-token gap against its sequence's per-token
/// TBT budget — shared by the per-iteration accounting and the
/// eviction-stall accounting so the two can never classify differently.
/// Free-standing (report + admission passed in) because the iteration
/// site calls it while holding a decode-instance borrow. Always on
/// (cheap), so disabled baselines stay comparable; only the Summary JSON
/// block is gated on `admission.enabled`.
fn record_tbt_gap(
    report: &mut RunReport,
    admission: &AdmissionEngine,
    class: RequestClass,
    tbt_override_us: u64,
    gap: Micros,
) {
    let budget = admission.budget_us(class, tbt_override_us);
    match class {
        RequestClass::Online => {
            report.tbt_gaps_online_us.push(gap);
            if gap > budget {
                report.tbt_violations_online += 1;
            }
        }
        RequestClass::Offline => {
            report.tbt_gaps_offline_us.push(gap);
            if gap > budget {
                report.tbt_violations_offline += 1;
            }
        }
    }
}

/// BucketServe's planner: Bucketing Manager + Dynamic Batching Controller
/// (+ the priority scorer when `cfg.priority.enabled`).
///
/// `Clone` is the snapshot stage of the executor's plan/commit protocol
/// (see [`PrefillPlanner::clone_box`]): every field is plain owned data,
/// so the derived clone is a complete deep copy.
#[derive(Clone)]
pub struct BucketPlanner {
    mgr: BucketManager,
    batcher: DynamicBatcher,
    mem: KvMemoryModel,
    max_buckets_seen: usize,
    online_peek: OnlinePeek,
}

impl BucketPlanner {
    pub fn new(cfg: &SystemConfig) -> BucketPlanner {
        let mut batcher = DynamicBatcher::new(cfg.model.clone(), &cfg.scheduler);
        if cfg.priority.enabled {
            batcher = batcher.with_priority(PriorityScorer::new(
                cfg.priority.clone(),
                cfg.slo.clone(),
            ));
        }
        BucketPlanner {
            mgr: BucketManager::new(
                cfg.scheduler.l_max,
                cfg.scheduler.theta,
                cfg.scheduler.min_bucket_width,
            ),
            batcher,
            mem: KvMemoryModel::new(cfg.model.clone(), cfg.scheduler.mem_safety),
            max_buckets_seen: 1,
            online_peek: OnlinePeek::new(),
        }
    }

    pub fn manager(&self) -> &BucketManager {
        &self.mgr
    }

    pub fn max_buckets_seen(&self) -> usize {
        self.max_buckets_seen
    }
}

impl PrefillPlanner for BucketPlanner {
    fn clone_box(&self) -> Box<dyn PrefillPlanner> {
        Box::new(self.clone())
    }

    fn admit(&mut self, req: &Request, _now: Micros) {
        let q = QueuedReq {
            id: req.id,
            len: req.input_len,
            output_len: req.output_len,
            arrival: req.arrival,
            class: req.class,
            tbt_us: req.tbt_deadline_us,
            // Lineage + the router's resident-match hint; `shared_len`
            // stays 0 until dispatch actually pins cache blocks. All-zero
            // when the prefix subsystem is off, so bucket keying and
            // footprints are untouched.
            prefix: PrefixStamp {
                prefix_id: req.prefix_id,
                prefix_len: req.prefix_len.min(req.input_len),
                cached_len: req.prefix_cached_hint.min(req.input_len),
                shared_len: 0,
            },
        };
        self.online_peek.note_insert(&q);
        self.mgr.assign(q);
    }

    fn plan(&mut self, now: Micros, headroom_tokens: u64) -> Option<FormedBatch> {
        // Algorithm 1's AdjustBuckets with N_max from Eq. 6 (estimated via
        // the queue's mean full-context length — the Global Monitor view).
        let queued = self.mgr.total();
        if queued > 0 {
            // Integer-exact total (one u64 sum in the manager) instead
            // of a per-request f64 accumulation, so the mean — and the
            // N_max it derives — cannot drift with summation order when
            // a planner snapshot replans on a worker thread.
            let mean_len = self.mgr.total_footprint() as f64 / queued as f64;
            let n_max = (headroom_tokens as f64 / mean_len.max(1.0))
                .floor()
                .max(1.0) as usize;
            self.mgr.adjust(n_max);
            self.max_buckets_seen = self.max_buckets_seen.max(self.mgr.n_buckets());
        }
        // The batcher already admits against headroom_tokens (Eq. 6).
        let _ = &self.mem;
        let formed = self.batcher.form_batch(&mut self.mgr, now, headroom_tokens);
        if let Some(fb) = &formed {
            self.online_peek.note_removed(fb.reqs.iter());
        }
        formed
    }

    fn force_pop(&mut self, now: Micros) -> Option<QueuedReq> {
        // Priority mode: pop the globally highest-ranked request under the
        // scorer's canonical order, through the batcher's own policy gate
        // so the pop can never contradict the configured drain order.
        let pos = self
            .batcher
            .scorer()
            .map(|sc| sc.best_position(self.mgr.buckets(), now));
        let popped = if let Some(pos) = pos {
            let (bi, ri) = pos?;
            Some(self.mgr.buckets_mut()[bi].requests.remove(ri))
        } else {
            let bucket = self
                .mgr
                .buckets_mut()
                .iter_mut()
                .filter(|b| !b.is_empty())
                .min_by_key(|b| b.earliest_arrival().unwrap_or(Micros::MAX))?;
            let idx = bucket
                .requests
                .iter()
                .enumerate()
                .min_by_key(|(_, r)| r.arrival)
                .map(|(i, _)| i)?;
            Some(bucket.requests.remove(idx))
        };
        if let Some(r) = &popped {
            self.online_peek.note_removed(std::iter::once(r));
        }
        popped
    }

    fn queued(&self) -> usize {
        self.mgr.total()
    }

    fn queued_tokens(&self) -> u64 {
        self.mgr.total_footprint()
    }

    fn steal_tail(
        &mut self,
        max_n: usize,
        max_tokens: u64,
        now: Micros,
    ) -> Vec<QueuedReq> {
        if max_n == 0 {
            return Vec::new();
        }
        // Same bucket the next drain would serve (highest-urgency bucket
        // under the scorer, policy order otherwise), same drain sort —
        // so the stolen tail is exactly the work the donor would have
        // served last. Capped at half the bucket so the urgent head
        // always stays with the donor (a one-request bucket yields
        // nothing; rebalance just skips the move), and KV-capped so the
        // donor never surrenders more full-context tokens than the
        // thief's decode headroom (`max_tokens`) can admit.
        let Some(idx) = self.batcher.pick_bucket(&self.mgr, now) else {
            return Vec::new();
        };
        let b = &mut self.mgr.buckets_mut()[idx];
        self.batcher.sort_for_drain(b, now);
        let cap = max_n.min(b.requests.len() / 2);
        let take = kv_capped_take(b.requests.iter().rev().take(cap), max_tokens);
        let stolen = b.requests.split_off(b.requests.len() - take);
        self.online_peek.note_removed(stolen.iter());
        stolen
    }

    fn absorb(&mut self, reqs: Vec<QueuedReq>, _now: Micros) {
        for r in reqs {
            self.online_peek.note_insert(&r);
            self.mgr.assign(r);
        }
    }

    fn oldest_online(&mut self) -> Option<QueuedReq> {
        let mgr = &self.mgr;
        self.online_peek.get(|| {
            oldest_online_in(mgr.buckets().iter().flat_map(|b| b.requests.iter()))
        })
    }

    fn drain_follows_urgency(&self) -> bool {
        // Exactly when the priority scorer governs the drain (priority
        // enabled + FCFS policy) — the same gate the batcher applies.
        self.batcher.scorer().is_some()
    }

    fn overhead_ns(&self) -> u64 {
        self.mgr.overhead_ns
    }

    fn n_buckets(&self) -> usize {
        self.mgr.n_buckets()
    }

    fn lineage_summary(&self) -> Vec<(u64, u32)> {
        // O(queued) walk, paid only when the prefix subsystem is armed
        // (the scheduler never calls this otherwise) and only at steal
        // cadence. Dedupe by lineage keeping the longest shareable run.
        let mut out: Vec<(u64, u32)> = Vec::new();
        for r in self.mgr.buckets().iter().flat_map(|b| b.requests.iter()) {
            if r.prefix.prefix_id == 0 {
                continue;
            }
            let shareable = r.prefix.prefix_len.min(r.len);
            match out.iter_mut().find(|(id, _)| *id == r.prefix.prefix_id) {
                Some((_, len)) => *len = (*len).max(shareable),
                None => out.push((r.prefix.prefix_id, shareable)),
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Run report
// ---------------------------------------------------------------------------

/// Everything a run produces; the metrics layer derives each figure from it.
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    pub completions: Vec<Completion>,
    pub makespan_us: Micros,
    pub n_prefill: usize,
    pub n_decode: usize,
    pub prefill_busy_us: u64,
    pub decode_busy_us: u64,
    /// Busy time weighted by useful-work fraction (padding-aware).
    pub prefill_useful_us: f64,
    /// Busy time weighted by the bandwidth-amortization factor.
    pub decode_useful_us: f64,
    pub bucket_overhead_ns: u64,
    pub max_buckets: usize,
    pub peak_batch: usize,
    pub prefill_batches: u64,
    pub decode_iters: u64,
    /// Σ per-request prefill execution time (batch duration × members).
    pub prefill_exec_request_us: u64,
    /// Σ per-request queueing delay before prefill dispatch.
    pub queue_wait_us: u64,
    /// Scheduler shards the run used (1 = the unsharded global queue).
    pub n_shards: usize,
    /// Requests migrated between shards by work-stealing.
    pub steals: u64,
    /// Per-shard arrivals routed by the placement policy.
    pub shard_routed: Vec<u64>,
    /// Per-shard prefill batches dispatched.
    pub shard_batches: Vec<u64>,
    /// Whether the preemption subsystem was armed for this run (gates the
    /// Summary JSON block so disabled runs stay byte-identical).
    pub preempt_enabled: bool,
    /// Prefill batches aborted mid-flight by preemption.
    pub prefill_aborts: u64,
    /// Decode sequences evicted (checkpoint-and-restore) by preemption.
    pub decode_evictions: u64,
    /// GPU time burned by aborted prefill batches (busy, zero useful).
    pub wasted_prefill_us: u64,
    /// Padded prefill tokens whose FLOPs were discarded by aborts.
    pub wasted_prefill_tokens: u64,
    /// Full-context KV tokens released by preemption-triggered decode
    /// evictions (the admission layer's TBT evictions keep their own
    /// books below, so neither subsystem's JSON block double-reports).
    pub evicted_kv_tokens: u64,
    /// Context tokens preemption-evicted sequences must replay at
    /// re-prefill.
    pub recompute_tokens: u64,
    /// Whether the TBT-aware admission subsystem was armed for this run
    /// (gates the Summary JSON block so disabled output stays
    /// byte-identical).
    pub admission_enabled: bool,
    /// Deferral decisions: dispatch rounds in which a shard's formed
    /// batch was returned to its queue because every owned decode
    /// instance's projected iteration would have blown a resident online
    /// sequence's TBT budget (at most one per shard per round; a batch
    /// blocked across many events counts once per retrying round).
    pub admission_deferrals: u64,
    /// Offline decode sequences shed by the TBT eviction trigger
    /// (checkpoint-and-restore; disjoint from `decode_evictions`, which
    /// counts only preemption-triggered evictions).
    pub tbt_evictions: u64,
    /// Full-context KV tokens released by TBT evictions.
    pub tbt_evicted_kv_tokens: u64,
    /// Context tokens TBT-evicted sequences must replay at re-prefill —
    /// the recompute debt the attainment win is paid for with.
    pub tbt_recompute_tokens: u64,
    /// Observed inter-token gaps (µs) of online tokens, one per
    /// decode-iteration token. Recorded for every run (cheap), reported
    /// only when admission is enabled.
    pub tbt_gaps_online_us: Vec<u64>,
    /// Observed inter-token gaps (µs) of offline tokens.
    pub tbt_gaps_offline_us: Vec<u64>,
    /// Online gaps exceeding their sequence's per-token TBT budget.
    pub tbt_violations_online: u64,
    /// Offline gaps exceeding their (lax) per-token TBT budget.
    pub tbt_violations_offline: u64,
    /// Whether the prefix-cache subsystem was armed for this run (gates
    /// the Summary JSON block so disabled output stays byte-identical).
    pub prefix_enabled: bool,
    /// Dispatch-time cache acquisitions that found at least one resident
    /// block, summed across every instance's cache.
    pub prefix_hits: u64,
    /// Acquisitions that found nothing resident (lineage-less requests
    /// included).
    pub prefix_misses: u64,
    /// Prompt tokens served from cache — prefill compute the hits saved.
    pub prefix_hit_tokens: u64,
    /// Blocks peeled by LRU eviction across every instance's cache.
    pub prefix_evictions: u64,
    /// KV tokens those evictions released back to the instance books.
    pub prefix_evicted_tokens: u64,
    /// Cache-resident KV tokens still held at run end (cache-charged, so
    /// the deduplicated per-request books balance against them).
    pub prefix_resident_tokens: u64,
    /// Whether the chunked-prefill subsystem was armed for this run
    /// (gates the Summary JSON block so disabled output stays
    /// byte-identical).
    pub chunk_enabled: bool,
    /// Prefill batches that executed as a sequence of slices (padded
    /// length spanned at least two slice widths).
    pub chunk_sliced_batches: u64,
    /// Prefill slices executed, final slices included — each is one
    /// kernel launch paying one step overhead.
    pub chunk_slices: u64,
    /// Slice boundaries at which an in-flight sliced batch parked its
    /// remainder (freeing the prefill slot) because urgent online work
    /// was queued — the interleaving the subsystem exists for.
    pub chunk_yields: u64,
    /// Decode iterations priced as hybrid batches: the weight read was
    /// shared with a co-resident prefill slice targeting the same
    /// instance, so only the KV-stream term was charged.
    pub chunk_hybrid_iters: u64,
    /// Largest token volume (batch width × slice span) any single
    /// executed slice carried — the bound `chunk.slice_tokens` is
    /// meant to enforce, surfaced so tests can check it.
    pub chunk_max_slice_tokens: u64,
    /// Resolved executor worker count (1 = the sequential serving loop).
    /// Executor counters live on the `RunReport` only — they are
    /// deliberately kept *out* of Summary JSON so the determinism
    /// contract (parallel output byte-identical to sequential) holds
    /// exactly; the `shard_scaling` bench surfaces them per row.
    pub executor_threads: usize,
    /// Synchronization points the parallel executor processed: maximal
    /// same-instant runs of decode-iteration boundaries, plus (with
    /// `executor.plan_offload`) dispatch rounds whose plan speculations
    /// were fanned out to workers. Deterministic: a function of the
    /// virtual-time schedule, not of thread timing. 0 on the sequential
    /// path.
    pub executor_sync_points: u64,
    /// Boundary events that crossed a worker channel. 0 when sequential.
    pub executor_parallel_events: u64,
    /// Prefill dispatch rounds in which at least one shard planned
    /// (speculatively or inline). Deterministic — a function of the
    /// schedule — and counted identically in both modes, so it is the
    /// denominator for the per-round planning wall-clock columns.
    pub executor_plan_rounds: u64,
    /// Plan speculations that crossed a worker channel (the plan/commit
    /// protocol's fan-out volume). 0 when sequential or when
    /// `executor.plan_offload` is off. Deterministic.
    pub executor_parallel_plans: u64,
    /// Proposals rejected by commit-time validation (stale headroom →
    /// inline re-plan). Deterministic.
    pub executor_plan_invalidations: u64,
    /// Wall-clock the merge loop itself spent on planning, ns: the eager
    /// speculation block (snapshot + blocking on the worker fan-out) plus
    /// every inline plan/re-plan. Host-dependent — RunReport/bench tables
    /// only, never Summary JSON (same rule as `bucket_overhead_ns`,
    /// which Summary normalizes away).
    pub plan_merge_ns: u64,
    /// Wall-clock workers spent inside plan speculations, ns (Σ over
    /// proposals; off-merge-loop time). Host-dependent, RunReport only.
    pub plan_worker_ns: u64,
    /// Whether the run was driven by the realtime serving path
    /// ([`PdScheduler::run_realtime`]); gates the Summary JSON block so
    /// virtual-time replay output stays byte-identical.
    pub realtime_enabled: bool,
    /// Requests aborted mid-flight because their client disconnected
    /// (realtime path only; KV/prefix reservations are released at the
    /// drop point).
    pub client_aborts: u64,
    /// Streamed token lines shed because a client's bounded stream
    /// buffer was full (final summary lines are never shed).
    pub stream_drops: u64,
    /// Set when the run ended abnormally (scheduler stall / livelock
    /// guard); carries the diagnostics the old panic printed. Completions
    /// gathered before the stall are still reported.
    pub error: Option<String>,
}

impl RunReport {
    /// Offline throughput: total (prompt + generated) tokens per second.
    pub fn throughput_tps(&self) -> f64 {
        let tokens: u64 = self
            .completions
            .iter()
            .map(|c| (c.input_len + c.output_len) as u64)
            .sum();
        tokens as f64 / (self.makespan_us as f64 / 1e6).max(1e-9)
    }

    /// Generated tokens per second.
    pub fn output_tps(&self) -> f64 {
        let tokens: u64 =
            self.completions.iter().map(|c| c.output_len as u64).sum();
        tokens as f64 / (self.makespan_us as f64 / 1e6).max(1e-9)
    }

    /// Completed requests per second ("server RPS" in Fig. 5).
    pub fn server_rps(&self) -> f64 {
        self.completions.len() as f64 / (self.makespan_us as f64 / 1e6).max(1e-9)
    }

    /// SLO attainment: fraction of completions meeting both TTFT and TBT.
    pub fn slo_attainment(&self, ttft_us: u64, tbt_us: u64) -> f64 {
        if self.completions.is_empty() {
            return 1.0;
        }
        let ok = self
            .completions
            .iter()
            .filter(|c| c.ttft() <= ttft_us && c.tbt() <= tbt_us as f64)
            .count();
        ok as f64 / self.completions.len() as f64
    }

    /// Completions of one request class.
    pub fn n_class(&self, class: RequestClass) -> usize {
        self.completions.iter().filter(|c| c.class == class).count()
    }

    /// Per-class SLO attainment (1.0 when the class is absent) — the
    /// priority subsystem's target metric.
    pub fn slo_attainment_class(
        &self,
        class: RequestClass,
        ttft_us: u64,
        tbt_us: u64,
    ) -> f64 {
        let mut n = 0usize;
        let mut ok = 0usize;
        for c in self.completions.iter().filter(|c| c.class == class) {
            n += 1;
            if c.ttft() <= ttft_us && c.tbt() <= tbt_us as f64 {
                ok += 1;
            }
        }
        if n == 0 {
            1.0
        } else {
            ok as f64 / n as f64
        }
    }

    /// Observed inter-token gaps of one class (µs), as recorded at
    /// decode-iteration boundaries.
    pub fn tbt_gaps_class(&self, class: RequestClass) -> &[u64] {
        match class {
            RequestClass::Online => &self.tbt_gaps_online_us,
            RequestClass::Offline => &self.tbt_gaps_offline_us,
        }
    }

    /// Per-class TBT attainment: fraction of observed inter-token gaps
    /// within the per-token budget (1.0 when the class produced no
    /// gaps) — the admission subsystem's target metric, the TBT-side
    /// mirror of [`RunReport::slo_attainment_class`].
    pub fn tbt_attainment_class(&self, class: RequestClass) -> f64 {
        let gaps = self.tbt_gaps_class(class).len();
        let violations = match class {
            RequestClass::Online => self.tbt_violations_online,
            RequestClass::Offline => self.tbt_violations_offline,
        };
        if gaps == 0 {
            1.0
        } else {
            1.0 - violations as f64 / gaps as f64
        }
    }

    /// Per-class inter-token gap percentile (µs); 0 when the class
    /// produced no gaps.
    pub fn tbt_gap_percentile_us(&self, class: RequestClass, q: f64) -> f64 {
        let gaps = self.tbt_gaps_class(class);
        if gaps.is_empty() {
            return 0.0;
        }
        let mut s = crate::util::stats::Samples::new();
        for &g in gaps {
            s.push(g as f64);
        }
        s.percentile(q)
    }

    /// Per-class mean TTFT (µs); 0 when the class is absent.
    pub fn mean_ttft_class_us(&self, class: RequestClass) -> f64 {
        let mut n = 0usize;
        let mut sum = 0.0;
        for c in self.completions.iter().filter(|c| c.class == class) {
            n += 1;
            sum += c.ttft() as f64;
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    /// Mean padding-aware GPU utilization across the fleet (Fig. 3b / 5b).
    pub fn gpu_util(&self) -> f64 {
        let cap = (self.n_prefill + self.n_decode) as f64
            * self.makespan_us as f64;
        if cap <= 0.0 {
            return 0.0;
        }
        (self.prefill_useful_us + self.decode_useful_us) / cap
    }

    /// Mean end-to-end latency (µs).
    pub fn mean_e2e_us(&self) -> f64 {
        if self.completions.is_empty() {
            return 0.0;
        }
        self.completions.iter().map(|c| c.e2e() as f64).sum::<f64>()
            / self.completions.len() as f64
    }

    /// Mean TTFT (µs).
    pub fn mean_ttft_us(&self) -> f64 {
        if self.completions.is_empty() {
            return 0.0;
        }
        self.completions.iter().map(|c| c.ttft() as f64).sum::<f64>()
            / self.completions.len() as f64
    }

    /// Fig. 6a phase breakdown, all in µs per request:
    /// (queue wait, prefill exec, decode exec, bucketing overhead).
    pub fn breakdown_us(&self) -> (f64, f64, f64, f64) {
        let n = self.completions.len().max(1) as f64;
        let decode: f64 = self
            .completions
            .iter()
            .map(|c| c.finished.saturating_sub(c.first_token) as f64)
            .sum::<f64>()
            / n;
        (
            self.queue_wait_us as f64 / n,
            self.prefill_exec_request_us as f64 / n,
            decode,
            self.bucket_overhead_ns as f64 / 1e3 / n,
        )
    }
}

// ---------------------------------------------------------------------------
// The serving loop
// ---------------------------------------------------------------------------

/// The P/D scheduler: a thin orchestrator that pops events and dispatches
/// to the fleet state machines; engine-agnostic. Scheduling state lives
/// in per-decode-instance shards ([`ShardSet`]); the planner `factory` is
/// invoked once per shard so every shard owns independent queue state.
pub struct PdScheduler {
    cfg: SystemConfig,
    shards: ShardSet,
    monitor: GlobalMonitor,
    preempt: PreemptionEngine,
    admission: AdmissionEngine,
}

impl PdScheduler {
    pub fn new(
        cfg: &SystemConfig,
        factory: impl FnMut() -> Box<dyn PrefillPlanner>,
    ) -> PdScheduler {
        let n_decode = cfg.fleet.n_decode.max(1) as usize;
        PdScheduler {
            shards: ShardSet::new(&cfg.sharding, n_decode, factory),
            monitor: GlobalMonitor::new(cfg.scheduler.monitor_window_us, 0),
            preempt: Self::make_preempt(cfg),
            admission: Self::make_admission(cfg),
            cfg: cfg.clone(),
        }
    }

    /// The one place the config turns into a [`PreemptionEngine`] —
    /// built at construction and rebuilt fresh for every run (checkpoints
    /// and the anti-thrash guard must not leak across traces).
    fn make_preempt(cfg: &SystemConfig) -> PreemptionEngine {
        PreemptionEngine::new(
            cfg.preempt.clone(),
            cfg.priority.clone(),
            cfg.slo.clone(),
        )
    }

    /// The one place the config turns into an [`AdmissionEngine`] — pure
    /// policy (budget resolution, risk predicates, victim ordering), so
    /// rebuilding per run only guards against future statefulness.
    fn make_admission(cfg: &SystemConfig) -> AdmissionEngine {
        AdmissionEngine::new(
            cfg.admission.clone(),
            cfg.priority.clone(),
            cfg.slo.clone(),
        )
    }

    /// Serve the whole trace; returns the run report.
    ///
    /// Pure event dispatch: pop the earliest event, advance the clock,
    /// apply its handler plus any events due at the same instant, then run
    /// the state-driven phases (hand-off admission → prefill dispatch →
    /// decode launch). All instance state lives in the fleet modules.
    pub fn run(&mut self, trace: &Trace, engine: &mut dyn Engine) -> RunReport {
        let mem = KvMemoryModel::new(
            self.cfg.model.clone(),
            self.cfg.scheduler.mem_safety,
        );
        let per_decode_budget = mem.token_budget(engine.decode_mem_budget());
        let n_shards = self.shards.n();
        // Each shard monitors KV against the budget of the decode
        // instances it fronts; the aggregate view sums to the fleet total.
        let shard_budgets: Vec<u64> = (0..n_shards)
            .map(|si| {
                per_decode_budget * self.shards.get(si).owned.len() as u64
            })
            .collect();
        self.monitor = GlobalMonitor::sharded(
            self.cfg.scheduler.monitor_window_us,
            &shard_budgets,
        );
        self.preempt = Self::make_preempt(&self.cfg);
        self.admission = Self::make_admission(&self.cfg);
        let admission_active = self.cfg.admission.enabled;
        // The deferral/eviction triggers lean on the engine's pure decode
        // cost projection; an engine without one (the trait default
        // returns 0) can only catch sequences that are already overdue.
        // Surface that instead of silently under-delivering.
        if admission_active && engine.projected_decode_us(1, 1) == 0 {
            crate::log_warn!(
                "admission.enabled: engine provides no decode-cost \
                 projection; TBT triggers only react to already-overdue \
                 sequences"
            );
        }
        // Preemption only converts freed capacity into TTFT wins when
        // the drain order serves by urgency; surface the dead
        // combination (e.g. `--preempt.enabled on --priority.enabled
        // false`, an SJF/LJF policy, or the FIFO baseline) instead of
        // silently reporting all-zero counters. Shards share one
        // planner factory, so shard 0 speaks for all of them.
        let preempt_active = self.cfg.preempt.enabled
            && self.shards.get(0).planner.drain_follows_urgency();
        if self.cfg.preempt.enabled && !preempt_active {
            crate::log_warn!(
                "preempt.enabled is inert: the drain order is not \
                 urgency-ordered (requires priority.enabled with the \
                 fcfs policy); no trigger will ever fire"
            );
        }
        let n_prefill = self.cfg.fleet.n_prefill.max(1) as usize;
        let n_decode = self.cfg.fleet.n_decode.max(1) as usize;
        let weight_bytes = engine.model().weight_bytes() as f64;
        let kv_per_token = engine.model().kv_bytes_per_token() as f64;
        let realtime = engine.realtime();
        // Parallel executor: thread-per-shard fan-out of decode-iteration
        // boundaries, virtual time only (a realtime engine's blocking
        // calls serialize the loop anyway, and its wall-clock sleeps must
        // stay on the merge thread). Whatever resolves here, the schedule
        // is byte-identical to sequential — see `coordinator::executor`.
        let n_workers = self.cfg.executor.resolve(n_shards);
        if n_workers > 1 && realtime {
            crate::log_warn!(
                "executor.threads: realtime engines run sequentially; \
                 parallel boundary execution is virtual-time only"
            );
        }
        let parallel = n_workers > 1 && !realtime;
        // One radix cache per decode instance, sized as a fraction of
        // that instance's KV token budget — resident blocks are charged
        // to the same per-shard books the requests reserve against, so
        // the cache can never oversubscribe an instance.
        let prefix_caches: Option<Vec<PrefixCache>> = if self.cfg.prefix.enabled
        {
            let budget = (per_decode_budget as f64
                * self.cfg.prefix.cache_frac.clamp(0.0, 1.0))
                as u64;
            Some(
                (0..n_decode)
                    .map(|_| PrefixCache::new(self.cfg.prefix.block, budget))
                    .collect(),
            )
        } else {
            None
        };

        let mut core = RunCore {
            shards: &mut self.shards,
            monitor: &mut self.monitor,
            preempt: &mut self.preempt,
            preempt_active,
            admission: &self.admission,
            admission_active,
            engine,
            events: EventQueue::with_partitions(n_shards),
            prefill: PrefillFleet::new(n_prefill),
            decode: DecodeFleet::new(n_decode),
            pool: if parallel {
                Some(ExecutorPool::new(n_workers))
            } else {
                None
            },
            report: RunReport {
                n_prefill,
                n_decode,
                n_shards,
                preempt_enabled: self.cfg.preempt.enabled,
                admission_enabled: admission_active,
                prefix_enabled: self.cfg.prefix.enabled,
                chunk_enabled: self.cfg.chunk.enabled,
                executor_threads: if parallel { n_workers } else { 1 },
                ..Default::default()
            },
            clock: 0,
            next_arrival: 0,
            total: trace.len(),
            per_decode_budget,
            realtime,
            wall_start: Instant::now(),
            weight_bytes,
            kv_per_token,
            boost_shard: None,
            preempt_wake: None,
            recheck_preempt: false,
            restore_buf: Vec::new(),
            deferred_mask: Vec::new(),
            boundary_scratch: Vec::new(),
            plan_offload: parallel && self.cfg.executor.plan_offload,
            prefix: prefix_caches,
            prefix_affinity: self.cfg.sharding.placement
                == Placement::PrefixAffinity,
            live: None,
            chunk: self.cfg.chunk.clone(),
        };
        if core.total > 0 {
            core.events.push(trace.requests[0].arrival, EventKind::Arrival);
        }

        let mut processed: u64 = 0;
        while core.report.completions.len() < core.total {
            processed += 1;
            if processed > MAX_SCHED_EVENTS {
                core.fail("livelock guard tripped");
                break;
            }
            let Some(ev) = core.events.pop() else {
                core.fail("no scheduled events but requests incomplete");
                break;
            };
            core.advance_to(ev.at);
            core.handle_event(ev, trace);
            // Drain same-instant events and run the preemption check; a
            // trigger schedules its own same-instant events (the
            // `PreemptPrefill` abort, a zero-latency `RestoreReady`), so
            // loop until the instant is quiescent. The anti-thrash guard
            // in the engine bounds this to one extra pass per candidate,
            // and with preemption disabled the check is a constant-time
            // `false` — one pass, exactly the pre-preemption behavior.
            loop {
                while let Some(due) = core.events.pop_due(core.clock) {
                    core.handle_event(due, trace);
                }
                core.admit_handoffs();
                if !core.check_preemption() {
                    break;
                }
            }
            core.dispatch_prefill();
            if std::mem::take(&mut core.recheck_preempt) {
                // Dispatch just resolved the outstanding preemption; run
                // the check once more so the next candidate acts (its
                // events pop at this same instant) or plants its wake,
                // instead of waiting for the next — possibly distant —
                // event.
                core.check_preemption();
            }
            core.launch_decode();
            core.schedule_idle_wakes();
            core.report.makespan_us = core.report.makespan_us.max(core.clock);
        }

        // Fold per-instance cache counters before the report is taken —
        // the caches die with the core.
        if let Some(caches) = &core.prefix {
            for c in caches {
                let st = c.stats();
                core.report.prefix_hits += st.hits;
                core.report.prefix_misses += st.misses;
                core.report.prefix_hit_tokens += st.hit_tokens;
                core.report.prefix_evictions += st.evictions;
                core.report.prefix_evicted_tokens += st.evicted_tokens;
                core.report.prefix_resident_tokens += c.resident_tokens();
            }
        }
        // Take the report out and drop the core explicitly: dropping the
        // core joins the executor workers (clean shutdown, even when a
        // shard's event partition drained early) before final assembly.
        let mut report = std::mem::take(&mut core.report);
        drop(core);
        for shard in self.shards.iter() {
            report.bucket_overhead_ns += shard.planner.overhead_ns();
            report.max_buckets =
                report.max_buckets.max(shard.planner.n_buckets());
            report.shard_routed.push(shard.stats.routed);
            report.shard_batches.push(shard.stats.batches);
        }
        if let Some(last) = report.completions.iter().map(|c| c.finished).max() {
            report.makespan_us = report.makespan_us.max(last);
        }
        report
    }

    /// Drive the scheduler from live wall-clock submissions instead of a
    /// trace — the serving loop behind the realtime TCP path
    /// ([`crate::server::realtime`]).
    ///
    /// Commands arrive on `cmds` (see [`LiveCmd`]); tokens and final
    /// summaries stream back through each submission's
    /// [`super::live::StreamSink`]. The loop runs until a `Shutdown`
    /// command (or the channel closing) *and* the system drains —
    /// bounded by `realtime.drain_timeout_ms`, after which any still-open
    /// stream is closed with an aborted line so no client hangs.
    ///
    /// Requires a wall-clock engine ([`Engine::realtime`]): event due
    /// times are compared against the wall, so a virtual-time engine's
    /// future-dated events would starve live arrivals forever.
    pub fn run_realtime(
        &mut self,
        engine: &mut dyn Engine,
        cmds: Receiver<LiveCmd>,
    ) -> RunReport {
        assert!(
            engine.realtime(),
            "run_realtime requires a realtime engine (Engine::realtime())"
        );
        // Setup mirrors `run`, sequential only: a realtime engine's
        // blocking calls serialize the loop anyway, so no worker pool and
        // no plan offload.
        let mem = KvMemoryModel::new(
            self.cfg.model.clone(),
            self.cfg.scheduler.mem_safety,
        );
        let per_decode_budget = mem.token_budget(engine.decode_mem_budget());
        let n_shards = self.shards.n();
        let shard_budgets: Vec<u64> = (0..n_shards)
            .map(|si| {
                per_decode_budget * self.shards.get(si).owned.len() as u64
            })
            .collect();
        self.monitor = GlobalMonitor::sharded(
            self.cfg.scheduler.monitor_window_us,
            &shard_budgets,
        );
        self.preempt = Self::make_preempt(&self.cfg);
        self.admission = Self::make_admission(&self.cfg);
        let admission_active = self.cfg.admission.enabled;
        if admission_active && engine.projected_decode_us(1, 1) == 0 {
            // Expected at startup with the observed-latency estimator:
            // it has nothing to project from until iterations land.
            crate::log_warn!(
                "admission.enabled: no decode-cost projection yet; TBT \
                 triggers react only to overdue sequences until observed \
                 iterations seed the estimator"
            );
        }
        let preempt_active = self.cfg.preempt.enabled
            && self.shards.get(0).planner.drain_follows_urgency();
        if self.cfg.preempt.enabled && !preempt_active {
            crate::log_warn!(
                "preempt.enabled is inert: the drain order is not \
                 urgency-ordered (requires priority.enabled with the \
                 fcfs policy); no trigger will ever fire"
            );
        }
        let n_prefill = self.cfg.fleet.n_prefill.max(1) as usize;
        let n_decode = self.cfg.fleet.n_decode.max(1) as usize;
        let weight_bytes = engine.model().weight_bytes() as f64;
        let kv_per_token = engine.model().kv_bytes_per_token() as f64;
        let prefix_caches: Option<Vec<PrefixCache>> = if self.cfg.prefix.enabled
        {
            let budget = (per_decode_budget as f64
                * self.cfg.prefix.cache_frac.clamp(0.0, 1.0))
                as u64;
            Some(
                (0..n_decode)
                    .map(|_| PrefixCache::new(self.cfg.prefix.block, budget))
                    .collect(),
            )
        } else {
            None
        };

        let mut core = RunCore {
            shards: &mut self.shards,
            monitor: &mut self.monitor,
            preempt: &mut self.preempt,
            preempt_active,
            admission: &self.admission,
            admission_active,
            engine,
            events: EventQueue::with_partitions(n_shards),
            prefill: PrefillFleet::new(n_prefill),
            decode: DecodeFleet::new(n_decode),
            pool: None,
            report: RunReport {
                n_prefill,
                n_decode,
                n_shards,
                preempt_enabled: self.cfg.preempt.enabled,
                admission_enabled: admission_active,
                prefix_enabled: self.cfg.prefix.enabled,
                chunk_enabled: self.cfg.chunk.enabled,
                executor_threads: 1,
                realtime_enabled: true,
                ..Default::default()
            },
            clock: 0,
            next_arrival: 0,
            // Arrivals come from the command channel, not a trace, so the
            // trace cursor stays pinned at "exhausted".
            total: 0,
            per_decode_budget,
            realtime: true,
            wall_start: Instant::now(),
            weight_bytes,
            kv_per_token,
            boost_shard: None,
            preempt_wake: None,
            recheck_preempt: false,
            restore_buf: Vec::new(),
            deferred_mask: Vec::new(),
            boundary_scratch: Vec::new(),
            plan_offload: false,
            prefix: prefix_caches,
            prefix_affinity: self.cfg.sharding.placement
                == Placement::PrefixAffinity,
            live: Some(LiveState::new(self.cfg.slo.clone())),
            chunk: self.cfg.chunk.clone(),
        };

        let empty = Trace { requests: Vec::new() };
        let drain_timeout =
            Duration::from_millis(self.cfg.realtime.drain_timeout_ms);
        // Idle poll cap: the longest the loop sits blocked before
        // re-checking drain state; an arriving command wakes it
        // immediately regardless.
        let poll = Duration::from_millis(5);
        let mut open = true; // command channel still connected
        let mut drain_deadline: Option<Instant> = None;
        loop {
            core.clock = core.clock.max(core.wall_now());
            // Ingest every queued command without blocking.
            let mut activity = false;
            while open {
                match cmds.try_recv() {
                    Ok(cmd) => {
                        if core.apply_cmd(cmd) && drain_deadline.is_none() {
                            drain_deadline =
                                Some(Instant::now() + drain_timeout);
                        }
                        activity = true;
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        open = false;
                        if drain_deadline.is_none() {
                            drain_deadline =
                                Some(Instant::now() + drain_timeout);
                        }
                    }
                }
            }
            // Process every event due on the wall clock.
            while let Some(at) = core.events.peek_at() {
                if at > core.wall_now() {
                    break;
                }
                let Some(ev) = core.events.pop() else { break };
                core.advance_to(ev.at);
                core.handle_event(ev, &empty);
                // Same-instant drain + preemption loop, as in `run`.
                loop {
                    while let Some(due) = core.events.pop_due(core.clock) {
                        core.handle_event(due, &empty);
                    }
                    core.admit_handoffs();
                    if !core.check_preemption() {
                        break;
                    }
                }
                activity = true;
            }
            if activity {
                // State-driven phases, as in `run`, plus the client-abort
                // sweep (boundary-safe removal of disconnected requests).
                core.sweep_aborts();
                core.dispatch_prefill();
                if std::mem::take(&mut core.recheck_preempt) {
                    core.check_preemption();
                }
                core.launch_decode();
                core.schedule_idle_wakes();
                core.report.makespan_us =
                    core.report.makespan_us.max(core.clock);
                continue; // commands may have queued while we worked
            }
            // Quiescent instant: exit when draining and done (or out of
            // patience), otherwise wait for the next event or command.
            if let Some(deadline) = drain_deadline {
                if core.quiescent() || Instant::now() >= deadline {
                    break;
                }
            }
            let wait = match core.events.peek_at() {
                Some(at) => {
                    Duration::from_micros(at.saturating_sub(core.wall_now()))
                        .min(poll)
                }
                None => poll,
            };
            if open {
                match cmds.recv_timeout(wait) {
                    Ok(cmd) => {
                        if core.apply_cmd(cmd) && drain_deadline.is_none() {
                            drain_deadline =
                                Some(Instant::now() + drain_timeout);
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => {
                        open = false;
                        if drain_deadline.is_none() {
                            drain_deadline =
                                Some(Instant::now() + drain_timeout);
                        }
                    }
                }
            } else {
                std::thread::sleep(wait);
            }
        }
        // Anything still in flight past the drain deadline: close its
        // stream so no client hangs (not charged as a client abort — the
        // server left, not the client).
        if let Some(live) = &mut core.live {
            live.close_all();
        }
        if let Some(caches) = &core.prefix {
            for c in caches {
                let st = c.stats();
                core.report.prefix_hits += st.hits;
                core.report.prefix_misses += st.misses;
                core.report.prefix_hit_tokens += st.hit_tokens;
                core.report.prefix_evictions += st.evictions;
                core.report.prefix_evicted_tokens += st.evicted_tokens;
                core.report.prefix_resident_tokens += c.resident_tokens();
            }
        }
        let mut report = std::mem::take(&mut core.report);
        drop(core);
        for shard in self.shards.iter() {
            report.bucket_overhead_ns += shard.planner.overhead_ns();
            report.max_buckets =
                report.max_buckets.max(shard.planner.n_buckets());
            report.shard_routed.push(shard.stats.routed);
            report.shard_batches.push(shard.stats.batches);
        }
        if let Some(last) = report.completions.iter().map(|c| c.finished).max() {
            report.makespan_us = report.makespan_us.max(last);
        }
        report
    }

    pub fn monitor(&mut self) -> &mut GlobalMonitor {
        &mut self.monitor
    }

    /// The shard layer (inspection/tests).
    pub fn shards(&self) -> &ShardSet {
        &self.shards
    }
}

/// Mutable run state threaded through the event handlers; split out of
/// [`PdScheduler`] so `run` stays a thin pop-and-dispatch loop.
struct RunCore<'a> {
    shards: &'a mut ShardSet,
    monitor: &'a mut GlobalMonitor,
    preempt: &'a mut PreemptionEngine,
    /// Preemption armed *and* able to pay off: `preempt.enabled` with an
    /// urgency-ordered drain (uniform across shards — one factory).
    /// False short-circuits every preemption path to a single branch.
    preempt_active: bool,
    /// TBT-aware admission policy (budget resolution, deadline-risk
    /// predicates, eviction-victim order); pure, so shared.
    admission: &'a AdmissionEngine,
    /// `admission.enabled`: false short-circuits the deferral gate and
    /// the TBT evict pass to one branch each. Gap/violation accounting
    /// runs either way (a push and a compare per token) so disabled
    /// baselines stay comparable; only the Summary JSON block is gated.
    admission_active: bool,
    engine: &'a mut dyn Engine,
    events: EventQueue,
    prefill: PrefillFleet,
    decode: DecodeFleet,
    /// The thread-per-shard worker pool, present only when
    /// `executor.threads` resolves above one on a virtual-time run.
    /// `None` = the sequential path, which runs the identical
    /// capture → [`executor::boundary_outcome`] → apply pipeline inline.
    pool: Option<ExecutorPool>,
    report: RunReport,
    clock: Micros,
    next_arrival: usize,
    total: usize,
    per_decode_budget: u64,
    realtime: bool,
    wall_start: Instant,
    weight_bytes: f64,
    kv_per_token: f64,
    /// One-shot dispatch preference set by a prefill abort: the next
    /// dispatch tries the preempting candidate's shard first, so the slot
    /// freed for it cannot be consumed by another shard's backlog.
    boost_shard: Option<usize>,
    /// The outstanding `PreemptCheck` wake, if any: its timestamp (for
    /// dedupe) and its event id (so a superseded wake is tombstoned
    /// instead of left to fire stale).
    preempt_wake: Option<(Micros, EventId)>,
    /// Set when this round's dispatch resolved the outstanding
    /// preemption: the check already ran this round, so it must run once
    /// more or the next candidate's trigger/wake waits for the next
    /// event, which may be arbitrarily far away.
    recheck_preempt: bool,
    /// Checkpoint-restored requests awaiting their `RestoreReady` event:
    /// (due time, decode instance whose owner shard requeues them, entry).
    restore_buf: Vec<(Micros, usize, QueuedReq)>,
    /// One simulated radix prefix cache per decode instance, present only
    /// when `prefix.enabled`. `None` short-circuits every prefix path to
    /// a single branch — the disabled byte-identity contract.
    prefix: Option<Vec<PrefixCache>>,
    /// `sharding.placement == PrefixAffinity`: arrivals with a resident
    /// prefix match bypass the load-based router for the owning shard.
    prefix_affinity: bool,
    /// Per-shard "already deferred this round" mask for
    /// [`RunCore::dispatch_prefill`] — reused across rounds (cleared, not
    /// reallocated) so the membership test the old `Vec<usize>` +
    /// `contains` scan paid is one indexed load.
    deferred_mask: Vec<bool>,
    /// Recycled `(gaps, done)` output buffers for boundary jobs: popped
    /// in [`RunCore::take_boundary_job`], refilled by the worker, drained
    /// and returned in [`RunCore::apply_boundary`]. Together with the
    /// in-place `active` compaction this makes steady-state sync points
    /// allocation-free.
    boundary_scratch: Vec<(Vec<executor::GapSample>, Vec<executor::FinishedSeq>)>,
    /// `executor.plan_offload` resolved against the run mode: true only
    /// when the pool exists. Gates the eager speculation fan-out in
    /// [`RunCore::dispatch_prefill`]; planning falls back inline (same
    /// pipeline, lazy) when false.
    plan_offload: bool,
    /// Realtime serving state (per-request stream sinks + pending client
    /// aborts), present only under [`PdScheduler::run_realtime`]. `None`
    /// short-circuits every live path to a single branch — trace runs
    /// stay byte-identical.
    live: Option<LiveState>,
    /// Chunked-prefill knobs (`chunk.enabled` is the master switch;
    /// false short-circuits every slicing path to a single branch — the
    /// disabled byte-identity contract).
    chunk: ChunkSpec,
}

impl<'a> RunCore<'a> {
    /// Advance the clock to an event's timestamp; realtime engines sleep
    /// until then on the wall clock (arrivals pace the run).
    fn advance_to(&mut self, at: Micros) {
        if self.realtime {
            let wall = self.wall_start.elapsed().as_micros() as Micros;
            if at > wall {
                std::thread::sleep(std::time::Duration::from_micros(at - wall));
            }
            let now = self.wall_start.elapsed().as_micros() as Micros;
            self.clock = self.clock.max(now);
        } else {
            self.clock = self.clock.max(at);
        }
    }

    /// Microseconds elapsed on this run's wall epoch — the realtime
    /// loop's notion of "now".
    fn wall_now(&self) -> Micros {
        self.wall_start.elapsed().as_micros() as Micros
    }

    /// Event dispatch seam between the sequential and parallel paths:
    /// with a worker pool, a due decode-iteration boundary opens a
    /// synchronization point ([`RunCore::boundary_group`]); every other
    /// event — and the whole sequential mode — goes through
    /// [`RunCore::handle`] unchanged.
    fn handle_event(&mut self, ev: Event, trace: &Trace) {
        if self.pool.is_some()
            && matches!(ev.kind, EventKind::DecodeIterEnd { .. })
        {
            self.boundary_group(ev);
        } else {
            self.handle(ev, trace);
        }
    }

    fn handle(&mut self, ev: Event, trace: &Trace) {
        match ev.kind {
            EventKind::Arrival => self.on_arrival(trace),
            EventKind::PrefillDone { instance } => self.on_prefill_done(instance),
            EventKind::PrefillSliceEnd { instance } => {
                self.on_prefill_slice_end(instance)
            }
            EventKind::DecodeIterEnd { decode } => {
                // Sequential boundary: the same pure computation the
                // executor's workers run, called inline — one pipeline,
                // so parallel ≡ sequential by construction.
                let key = SyncKey {
                    at: ev.at,
                    event: ev.seq_id(),
                    shard: self.shards.owner_of(decode),
                };
                let outcome = self
                    .take_boundary_job(decode, key)
                    .map(executor::boundary_outcome);
                self.finish_boundary(decode, outcome);
            }
            EventKind::HandoffReady { decode } => {
                // Pure wake-up: admission happens in admit_handoffs.
                self.decode.get_mut(decode).wake_at = None;
            }
            EventKind::PreemptPrefill { instance } => {
                self.on_preempt_prefill(instance)
            }
            EventKind::RestoreReady { decode } => self.on_restore_ready(decode),
            EventKind::PreemptCheck => {
                // Pure wake-up: the preemption check itself runs in the
                // state-driven phases after every event.
                self.preempt_wake = None;
            }
        }
    }

    /// Admit every trace arrival due by now (each routed to a shard by
    /// the placement policy), then schedule the next one.
    fn on_arrival(&mut self, trace: &Trace) {
        while self.next_arrival < self.total
            && trace.requests[self.next_arrival].arrival <= self.clock
        {
            self.admit_one(&trace.requests[self.next_arrival]);
            self.next_arrival += 1;
        }
        if self.next_arrival < self.total {
            self.events.push(
                trace.requests[self.next_arrival].arrival,
                EventKind::Arrival,
            );
        }
    }

    /// Route and admit one request — the shared admission seam of the
    /// trace path above and the realtime `Submit` command.
    fn admit_one(&mut self, r: &Request) {
        // Cache-affinity intercept: under `prefix_affinity`, an
        // arrival whose lineage has resident blocks somewhere routes
        // to the shard fronting the instance with the longest match
        // (ties → lowest instance). Everything else — and every
        // other placement policy — takes the load-based router.
        let (si, hint) = match self.resident_match(r) {
            Some((di, m)) => (self.shards.route_to(self.shards.owner_of(di)), m),
            None => (
                self.shards.route(r.id, &self.decode, self.per_decode_budget),
                0,
            ),
        };
        if hint > 0 {
            // The hint rides the queue as `cached_len` so bucket
            // keying and batch formation see the uncached suffix;
            // dispatch re-stamps it with the actual hit.
            let mut hinted = r.clone();
            hinted.prefix_cached_hint = hint.min(hinted.input_len);
            self.shards.get_mut(si).planner.admit(&hinted, self.clock);
        } else {
            self.shards.get_mut(si).planner.admit(r, self.clock);
        }
        self.monitor.on_arrival(si, self.clock, r.input_len);
    }

    /// The decode instance holding the longest resident prefix of `r`,
    /// with the match length in tokens — the cache-affinity placement
    /// signal. `None` unless `prefix_affinity` is on, the caches are
    /// armed, and some instance actually has resident blocks for this
    /// lineage (a zero-token match must fall back to load-based routing,
    /// not pile every lineage-mate onto shard 0). Ties keep the lowest
    /// instance index so routing is deterministic.
    fn resident_match(&self, r: &Request) -> Option<(usize, u32)> {
        if !self.prefix_affinity {
            return None;
        }
        let caches = self.prefix.as_ref()?;
        let shareable = r.prefix_len.min(r.input_len);
        let mut best: Option<(usize, u32)> = None;
        for (di, c) in caches.iter().enumerate() {
            let m = c.match_len(r.prefix_id, shareable);
            if m > 0 && best.is_none_or(|(_, bm)| m > bm) {
                best = Some((di, m));
            }
        }
        best
    }

    /// Run a work-stealing pass and mirror any moves into the monitor's
    /// per-shard queue depths and the run report.
    ///
    /// With the prefix caches armed, victim selection is locality-aware:
    /// each potential victim's queued lineages (deduped, longest
    /// shareable run) are scored by their best resident match on the
    /// thief's instances minus their best match on the victim's own —
    /// see [`balance::steal_victim_with_affinity`]. Queues with no
    /// lineage anywhere skip the scoring entirely and fall back to the
    /// legacy queue-depth policy.
    fn rebalance_shards(&mut self) {
        let gain_inputs: Option<(Vec<Vec<(u64, u32)>>, Vec<Vec<usize>>)> =
            match &self.prefix {
                Some(_) if self.shards.n() > 1 => {
                    let lineages: Vec<Vec<(u64, u32)>> = (0..self.shards.n())
                        .map(|si| self.shards.get(si).planner.lineage_summary())
                        .collect();
                    if lineages.iter().all(|l| l.is_empty()) {
                        None
                    } else {
                        let owned: Vec<Vec<usize>> = (0..self.shards.n())
                            .map(|si| self.shards.get(si).owned.clone())
                            .collect();
                        Some((lineages, owned))
                    }
                }
                _ => None,
            };
        let moves = match (&self.prefix, &gain_inputs) {
            (Some(caches), Some((lineages, owned))) => {
                let best_match = |si: usize, pid: u64, len: u32| -> i64 {
                    owned[si]
                        .iter()
                        .map(|&di| caches[di].match_len(pid, len) as i64)
                        .max()
                        .unwrap_or(0)
                };
                let gain = |victim: usize, thief: usize| -> i64 {
                    lineages[victim]
                        .iter()
                        .map(|&(pid, len)| {
                            best_match(thief, pid, len)
                                - best_match(victim, pid, len)
                        })
                        .sum()
                };
                self.shards.rebalance_with_affinity(
                    self.clock,
                    &self.decode,
                    self.per_decode_budget,
                    Some(&gain),
                )
            }
            _ => self.shards.rebalance(
                self.clock,
                &self.decode,
                self.per_decode_budget,
            ),
        };
        for (from, to, n) in moves {
            self.monitor.on_steal(from, to, n);
            self.report.steals += n as u64;
        }
    }

    /// Prefill completion → metrics → NVLink hand-off to the target decode
    /// instance's pending set.
    fn on_prefill_done(&mut self, pi: usize) {
        let Some(p) = self.prefill.take_done(pi, self.clock) else {
            return;
        };
        self.report.prefill_batches += 1;
        self.report.peak_batch = self.report.peak_batch.max(p.formed.batch.n());
        // For a sliced batch, `duration` is the *final* slice only —
        // earlier slices charged busy/useful at their own boundaries
        // ([`RunCore::on_prefill_slice_end`]); only the per-request
        // execution charge spans the whole slice sequence.
        self.report.prefill_busy_us += p.duration;
        self.report.prefill_useful_us +=
            p.duration as f64 * p.formed.batch.efficiency();
        let exec_us = match &p.slice {
            Some(s) => s.exec_us + p.duration,
            None => p.duration,
        };
        self.report.prefill_exec_request_us +=
            exec_us * p.formed.batch.n() as u64;
        self.monitor.on_batch_done(p.duration);
        // When this batch left the queue: a sliced batch's final
        // `done_at − duration` is mid-execution (and excludes parked
        // time), so it uses the recorded first-slice start instead.
        let dispatched_at = match &p.slice {
            Some(_) => p.started_at,
            None => p.done_at.saturating_sub(p.duration),
        };
        let transfer = self.engine.kv_transfer(p.formed.batch.useful_tokens());
        let mut entered = 0usize;
        for r in &p.formed.reqs {
            // A checkpoint-restored sequence resumes where eviction cut
            // it off: the recompute prefill replayed `input + generated`
            // context and produced token `generated + 1`; the original
            // prompt/output split and the already-paid first token come
            // back from the checkpoint so completion records (and TTFT)
            // are indistinguishable from an uninterrupted run. Its queue
            // wait was charged at the original prefill — counting
            // dispatch-to-dispatch again would book decode time and the
            // first prefill as "queueing" in the Fig. 6a breakdown.
            let seq = match self.preempt.take_restore(r.id) {
                Some(ri) => {
                    // The stall between the last pre-eviction token and
                    // the recompute prefill's completion (which produces
                    // the next token) is a real inter-token gap the
                    // client experienced — record it, or evictions would
                    // erase exactly the gaps they cause and flatter the
                    // TBT metrics they are judged by.
                    record_tbt_gap(
                        &mut self.report,
                        self.admission,
                        r.class,
                        r.tbt_us,
                        p.done_at.saturating_sub(ri.last_token_at),
                    );
                    DecodeSeqState {
                        id: r.id,
                        class: r.class,
                        arrival: r.arrival,
                        input_len: ri.input_len,
                        padded_len: ri.padded_len,
                        output_len: ri.output_len,
                        generated: ri.generated + 1,
                        first_token: ri.first_token,
                        ready_at: p.done_at + transfer,
                        tbt_us: r.tbt_us,
                        // Provisional: decode admission re-anchors the
                        // inter-token clock (`admit_due`), so hand-off
                        // and boundary-wait latency stay TTFT-side
                        // effects.
                        last_token_at: p.done_at + transfer,
                        // Dispatch's re-stamp rides along so completion
                        // and eviction release exactly the pins this
                        // sequence holds.
                        prefix: r.prefix,
                    }
                }
                None => {
                    self.report.queue_wait_us +=
                        dispatched_at.saturating_sub(r.arrival);
                    DecodeSeqState {
                        id: r.id,
                        class: r.class,
                        arrival: r.arrival,
                        input_len: r.len,
                        padded_len: p.formed.batch.padded_len,
                        output_len: r.output_len,
                        generated: 1, // prefill produced the first token
                        first_token: p.done_at,
                        ready_at: p.done_at + transfer,
                        tbt_us: r.tbt_us,
                        last_token_at: p.done_at + transfer,
                        prefix: r.prefix,
                    }
                }
            };
            // Realtime path: a request whose client disconnected while it
            // was queued or prefilling drops at the hand-off — the
            // prefill compute is sunk, but its KV reservation, prefix
            // pins, and engine state release right here instead of
            // riding a dead sequence through decode.
            let gone = self
                .live
                .as_ref()
                .is_some_and(|l| l.aborted.contains(&seq.id));
            if gone {
                let footprint = seq.footprint();
                let si = self.shards.owner_of(p.target_decode);
                let d = self.decode.get_mut(p.target_decode);
                d.reserved_tokens = d.reserved_tokens.saturating_sub(footprint);
                self.monitor.kv_release(si, footprint);
                self.release_prefix_pins(p.target_decode, &seq.prefix);
                self.engine.release(seq.id);
                if let Some(live) = &mut self.live {
                    live.finish_aborted(seq.id, &mut self.report);
                }
                continue;
            }
            if let Some(live) = &mut self.live {
                // Stream the token this prefill just produced (token 1,
                // or `generated` for a checkpoint-restored sequence) as
                // soon as it exists.
                live.stream_token(
                    seq.id,
                    seq.generated,
                    p.done_at,
                    &mut self.report,
                );
            }
            self.decode.get_mut(p.target_decode).pending.push(seq);
            entered += 1;
        }
        self.monitor.on_decode_enter(entered);
    }

    /// Slice width (positions per sequence per slice) for a formed
    /// batch, or `None` when the batch executes monolithically:
    /// chunking off, or the padded length already fits in one slice.
    /// Width is `max(1, slice_tokens / n)` so a slice's token volume
    /// (width × n) stays within `chunk.slice_tokens` whenever the
    /// batch itself is narrower than the slice budget.
    fn slice_width(&self, formed: &FormedBatch) -> Option<u32> {
        if !self.chunk.enabled {
            return None;
        }
        let n = formed.batch.n().max(1) as u32;
        let width = (self.chunk.slice_tokens / n).max(1);
        (formed.batch.padded_len > width).then_some(width)
    }

    /// Launch one slice of a sliced prefill batch on instance `pi`:
    /// reserve the slice's incremental KV share, price the `[from, to)`
    /// position range through the engine, schedule its boundary event
    /// (`PrefillSliceEnd`, or the final `PrefillDone` when the slice
    /// reaches the padded length), and occupy the slot. Shared by the
    /// initial sliced dispatch, the slice-to-slice continuation, and
    /// the parked-batch resume, so the three paths cannot drift.
    #[allow(clippy::too_many_arguments)]
    fn launch_slice(
        &mut self,
        pi: usize,
        formed: FormedBatch,
        target_decode: usize,
        started_at: Micros,
        cursor: u32,
        width: u32,
        reserved_so_far: u64,
        exec_us: u64,
    ) {
        let padded = formed.batch.padded_len.max(1);
        let from = cursor;
        let to = (cursor + width).min(padded);
        // Incremental KV reservation: the progress-proportional share
        // of the batch's full footprint covered by [0, to), minus what
        // previous slices already hold. The shares telescope to the
        // exact footprint at the final slice (to == padded), so
        // headroom accounting tracks the KV the slices have actually
        // produced instead of charging the whole batch up front.
        let total: u64 = formed.reqs.iter().map(QueuedReq::footprint).sum();
        let covered =
            (total as u128 * to as u128 / padded as u128) as u64;
        let inc = covered.saturating_sub(reserved_so_far);
        let si = self.shards.owner_of(target_decode);
        self.decode.get_mut(target_decode).reserved_tokens += inc;
        self.monitor.kv_reserve(si, inc);
        let duration = self
            .engine
            .prefill_slice(&formed.batch, from, to)
            .expect("prefill slice execution failed");
        let done_at = if self.realtime {
            self.wall_start.elapsed().as_micros() as Micros
        } else {
            self.clock + duration
        };
        let kind = if to >= padded {
            EventKind::PrefillDone { instance: pi }
        } else {
            EventKind::PrefillSliceEnd { instance: pi }
        };
        let done_event = self.events.push_owned(done_at, kind, si);
        self.report.chunk_slices += 1;
        self.report.chunk_max_slice_tokens = self
            .report
            .chunk_max_slice_tokens
            .max((to - from) as u64 * formed.batch.n() as u64);
        self.prefill.dispatch(
            pi,
            InFlightPrefill {
                formed,
                done_at,
                duration,
                target_decode,
                started_at,
                done_event,
                slice: Some(SliceState {
                    cursor,
                    width,
                    reserved_so_far: reserved_so_far + inc,
                    exec_us,
                }),
            },
        );
    }

    /// A sliced prefill finished one non-final slice: charge the
    /// completed slice's execution (at the same rates the monolithic
    /// path charges at completion, so an abort after N slices wastes
    /// only the partial slice it interrupts), advance the resume
    /// cursor, then either continue with the next slice immediately
    /// or — when urgent online work is queued and `chunk.interleave`
    /// is on — park the remainder on the owning shard and free the
    /// slot so that work can prefill first.
    fn on_prefill_slice_end(&mut self, pi: usize) {
        let Some(mut p) = self.prefill.take_done(pi, self.clock) else {
            return; // stale: the batch was aborted in this same instant
        };
        let Some(mut slice) = p.slice.take() else {
            // Unreachable by construction (only launch_slice schedules
            // this event, and aborts tombstone it); reinstall rather
            // than corrupt the slot if it ever fires anyway.
            self.prefill.dispatch(pi, p);
            return;
        };
        self.report.prefill_busy_us += p.duration;
        self.report.prefill_useful_us +=
            p.duration as f64 * p.formed.batch.efficiency();
        self.monitor.on_batch_done(p.duration);
        slice.exec_us += p.duration;
        slice.cursor =
            (slice.cursor + slice.width).min(p.formed.batch.padded_len);
        // Interleave gate: park (freeing the slot) only when some shard
        // actually has online work queued — the urgency this subsystem
        // protects. Otherwise continue immediately; the slot has
        // nothing better to do. The peek is guarded by `chunk.enabled`
        // (we are inside a slice), so disabled runs never touch it.
        let urgent = self.chunk.interleave
            && (0..self.shards.n()).any(|si| {
                self.shards.get_mut(si).planner.oldest_online().is_some()
            });
        if urgent {
            self.report.chunk_yields += 1;
            let si = self.shards.owner_of(p.target_decode);
            self.shards.get_mut(si).parked.push_back(ParkedPrefill {
                formed: p.formed,
                target_decode: p.target_decode,
                started_at: p.started_at,
                cursor: slice.cursor,
                width: slice.width,
                reserved_so_far: slice.reserved_so_far,
                exec_us: slice.exec_us,
            });
            return;
        }
        self.launch_slice(
            pi,
            p.formed,
            p.target_decode,
            p.started_at,
            slice.cursor,
            slice.width,
            slice.reserved_so_far,
            slice.exec_us,
        );
    }

    /// Resume the oldest parked sliced batch of shard `si` on idle
    /// prefill instance `pi`. Deliberately bypasses admission, prefix
    /// acquisition, preemption bookkeeping, and the dispatch counters —
    /// all of those were charged at the batch's original dispatch; a
    /// resume is the continuation of that same batch, not a new one.
    fn resume_parked(&mut self, pi: usize, si: usize) {
        let pk = self
            .shards
            .get_mut(si)
            .parked
            .pop_front()
            .expect("resume_parked on shard with empty parked queue");
        self.launch_slice(
            pi,
            pk.formed,
            pk.target_decode,
            pk.started_at,
            pk.cursor,
            pk.width,
            pk.reserved_so_far,
            pk.exec_us,
        );
    }

    /// Capture stage of a decode-iteration boundary: snapshot instance
    /// `di`'s live boundary (iteration end + drained active set) into a
    /// self-contained [`BoundaryJob`]. `None` for a stale event (the
    /// instance is not at a due boundary), which still gets its
    /// evict/rebalance side passes at the call site — exactly the old
    /// early-return semantics.
    fn take_boundary_job(
        &mut self,
        di: usize,
        key: SyncKey,
    ) -> Option<BoundaryJob> {
        let d = self.decode.get_mut(di);
        let ended = matches!(d.iter_end, Some(t) if t <= self.clock);
        if !ended {
            return None;
        }
        let iter_end = d.iter_end.take().unwrap();
        let active = std::mem::take(&mut d.active);
        // Recycled output buffers: returned (cleared, capacity kept) by
        // `apply_boundary`, so steady-state boundaries allocate nothing.
        let (gaps, done) = self.boundary_scratch.pop().unwrap_or_default();
        Some(BoundaryJob { key, di, iter_end, active, gaps, done, stall_us: 0 })
    }

    /// Apply stage of a decode-iteration boundary: fold one
    /// [`BoundaryOutcome`] — wherever it was computed — into the report,
    /// monitor, engine, and fleet, in the exact mutation order the
    /// pre-executor handler used (gap records in active-set order, then
    /// completions in active-set order).
    fn apply_boundary(&mut self, o: BoundaryOutcome) {
        let BoundaryOutcome { key: _, di, still_active, mut gaps, mut done } = o;
        let shard = self.shards.owner_of(di);
        for g in &gaps {
            record_tbt_gap(
                &mut self.report,
                self.admission,
                g.class,
                g.tbt_us,
                g.gap,
            );
        }
        // Survivors travel back in the buffer the capture stage moved
        // out (compacted in place on the worker) — no allocation.
        self.decode.get_mut(di).active = still_active;
        if self.live.is_some() {
            // Realtime path: one streamed token line per surviving member
            // of the completed iteration (finished members get their
            // final summary line below instead).
            let lines: Vec<(RequestId, u32, Micros)> = self
                .decode
                .get(di)
                .active
                .iter()
                .map(|s| (s.id, s.generated, s.last_token_at))
                .collect();
            if let Some(live) = &mut self.live {
                for (id, seq, at) in lines {
                    live.stream_token(id, seq, at, &mut self.report);
                }
            }
        }
        for f in done.drain(..) {
            let d = self.decode.get_mut(di);
            d.reserved_tokens = d.reserved_tokens.saturating_sub(f.footprint);
            self.monitor.kv_release(shard, f.footprint);
            self.monitor.on_decode_exit(1);
            // A completed sequence's shared-prefix pins unpin; the blocks
            // stay resident (cache-charged) until LRU eviction reclaims
            // them, which is the whole point of cross-request reuse.
            self.release_prefix_pins(di, &f.prefix);
            self.engine.release(f.completion.id);
            if let Some(live) = &mut self.live {
                live.finish_ok(&f.completion);
            }
            self.report.completions.push(f.completion);
        }
        // Return the output buffers to the scratch pool, capacity kept.
        gaps.clear();
        self.boundary_scratch.push((gaps, done));
    }

    /// Drop one departing sequence's refcounts on its pinned prefix
    /// blocks. A single branch when the subsystem is off or the sequence
    /// never pinned anything.
    fn release_prefix_pins(&mut self, di: usize, stamp: &PrefixStamp) {
        if stamp.shared_len == 0 {
            return;
        }
        if let Some(caches) = &mut self.prefix {
            caches[di].release(stamp.prefix_id, stamp.shared_len);
        }
    }

    /// Shared tail of one boundary member: apply the outcome (when the
    /// boundary was live), then the member's side passes. The single
    /// definition both the sequential handler and the parallel merge
    /// call, so the per-member sequence cannot drift between modes.
    fn finish_boundary(&mut self, di: usize, outcome: Option<BoundaryOutcome>) {
        if let Some(o) = outcome {
            debug_assert_eq!(o.di, di, "outcome applied to the wrong instance");
            self.apply_boundary(o);
        }
        // Iteration boundaries are also the TBT-eviction cadence: the
        // only instant an instance's KV is unpinned. No-op unless
        // `admission.enabled` + `admission.evict`.
        self.tbt_evict_pass(di);
        // Decode-iteration boundaries are the work-stealing cadence:
        // freed KV is when an idle shard can absorb a loaded shard's
        // backlog. No-op unless sharded + enabled.
        self.rebalance_shards();
    }

    /// One synchronization point of the parallel executor: the maximal
    /// consecutive run of decode-iteration boundaries due at this
    /// instant, fanned out to the per-shard workers and merged back in
    /// [`SyncKey`] order — which *is* the sequential pop order (event
    /// ids are global), so the schedule cannot depend on worker
    /// interleaving. Each member's TBT-evict and work-stealing side
    /// passes run at its ordinal position in that order, exactly where
    /// the sequential loop runs them. Members' boundary computations are
    /// mutually independent by construction: a boundary job reads only
    /// its own instance's drained active set, and the side passes touch
    /// planner/queue state, never another instance's actives.
    fn boundary_group(&mut self, head: Event) {
        let mut members = vec![head];
        while let Some(ev) = self.events.pop_due_if(self.clock, |e| {
            matches!(e.kind, EventKind::DecodeIterEnd { .. })
        }) {
            members.push(ev);
        }
        let mut jobs = Vec::with_capacity(members.len());
        let mut plan = Vec::with_capacity(members.len());
        for ev in members {
            let EventKind::DecodeIterEnd { decode: di } = ev.kind else {
                continue;
            };
            let key = SyncKey {
                at: ev.at,
                event: ev.seq_id(),
                shard: self.shards.owner_of(di),
            };
            let job = self.take_boundary_job(di, key);
            plan.push((di, job.is_some()));
            if let Some(j) = job {
                jobs.push(j);
            }
        }
        let n_jobs = jobs.len();
        let outcomes = self
            .pool
            .as_ref()
            .expect("boundary_group without a worker pool")
            .process(jobs);
        self.report.executor_sync_points += 1;
        self.report.executor_parallel_events += n_jobs as u64;
        let mut next = outcomes.into_iter();
        for (di, has_job) in plan {
            let outcome = if has_job {
                Some(next.next().expect("executor outcome lost"))
            } else {
                None
            };
            self.finish_boundary(di, outcome);
        }
    }

    /// Continuous-batching admission: landed hand-offs join instances at
    /// their iteration boundary.
    fn admit_handoffs(&mut self) {
        let clock = self.clock;
        for d in self.decode.iter_mut() {
            if d.at_boundary() {
                d.admit_due(clock);
            }
        }
    }

    /// Preemption pass (constant-time false unless `preempt.enabled`):
    /// find the most urgent queued online request across shards; if one
    /// has burned past the urgency threshold, (a) schedule a
    /// `PreemptPrefill` abort of the least-urgent in-flight batch when
    /// every prefill slot is busy with work the candidate outranks, and
    /// (b) evict least-urgent offline decode sequences when the
    /// candidate's KV admission would fail on its shard's best instance.
    /// Returns true when it acted, so the caller re-drains same-instant
    /// events before dispatching.
    ///
    /// Cost note: the candidate scan peeks every shard's oldest online
    /// request through the planner's cached [`OnlinePeek`], O(shards)
    /// amortized per event — a full O(queued) rescan happens only on the
    /// first peek after a drain removed the cached head (the default-off
    /// path still pays one branch).
    fn check_preemption(&mut self) -> bool {
        if !self.preempt_active || self.preempt.pending().is_some() {
            // Disabled (or armed but inert under a non-urgency drain —
            // warned at run start), or an outstanding preemption blocks
            // new candidates anyway — skip the queue walk entirely.
            return false;
        }
        let oldest: Vec<Option<QueuedReq>> = (0..self.shards.n())
            .map(|si| self.shards.get_mut(si).planner.oldest_online())
            .collect();
        let Some((csi, cand)) = self.preempt.candidate(&oldest, self.clock)
        else {
            // Nobody is ripe yet: plant a wake at the earliest
            // threshold crossing, or an urgency trigger landing in an
            // otherwise event-free window (e.g. the trace tail, one
            // long offline wave in flight, decode idle) would only be
            // noticed when that wave completes — too late to abort it.
            self.schedule_preempt_wake(&oldest);
            return false;
        };
        // Decide first, commit only if the plan actually leaves the
        // candidate dispatchable — an abort or eviction whose freed
        // capacity the candidate still could not use would be pure
        // wasted work that also ties up the pending guard.
        //
        // Trigger (a) selection: abort candidate when every prefill slot
        // is busy with work the candidate outranks. What the abort frees
        // (its target instance's KV reservation) counts toward the
        // candidate's projected headroom below, so trigger (b) never
        // evicts to cover a deficit the abort already covers.
        let abort: Option<(usize, usize, u64)> = if (0..self.prefill.n())
            .all(|pi| !self.prefill.is_idle(pi))
        {
            let n = self.prefill.n();
            let running: Vec<(usize, &InFlightPrefill)> = (0..n)
                .filter_map(|pi| self.prefill.get(pi).map(|p| (pi, p)))
                .collect();
            self.preempt
                .pick_prefill_victim(&cand, &running, self.clock)
                .map(|pi| {
                    let p = running.iter().find(|(i, _)| *i == pi).unwrap().1;
                    // A sliced victim only holds its incremental
                    // reservation so far, not the full footprint.
                    let freed: u64 = match &p.slice {
                        Some(s) => s.reserved_so_far,
                        None => p
                            .formed
                            .reqs
                            .iter()
                            .map(QueuedReq::footprint)
                            .sum(),
                    };
                    (pi, p.target_decode, freed)
                })
        } else {
            None
        };
        // Projected KV headroom on the candidate shard's best owned
        // instance (admission is per-instance, so that is where freed
        // capacity becomes usable). The abort's released reservation
        // counts wherever it lands: if the victim's target instance
        // belongs to the candidate shard and ends up with more projected
        // headroom than the current best, admission (and any eviction)
        // retargets there — evicting elsewhere to cover a deficit the
        // abort already covers would be pure recompute waste.
        let (mut ti, mut headroom) = balance::best_decode_in(
            &self.shards.get(csi).owned,
            &self.decode,
            self.per_decode_budget,
        );
        if let Some((_, di, freed)) = abort {
            if self.shards.owner_of(di) == csi {
                let projected = self
                    .per_decode_budget
                    .saturating_sub(self.decode.get(di).reserved_tokens)
                    + freed;
                if projected >= headroom {
                    ti = di;
                    headroom = projected;
                }
            }
        }
        let need = cand.footprint();
        // Trigger (b) selection: evict for any remaining deficit, but
        // only at an iteration boundary (mid-iteration KV is pinned by
        // the running kernel) and only when the candidate has a path to
        // a prefill slot this round (one idle, or the abort frees one).
        let slot_reachable = abort.is_some()
            || (0..self.prefill.n()).any(|pi| self.prefill.is_idle(pi));
        let victims = if slot_reachable
            && need > headroom
            && self.decode.get(ti).at_boundary()
        {
            self.preempt.pick_decode_victims(
                &self.decode.get(ti).active,
                need - headroom,
                self.clock,
            )
        } else {
            Vec::new()
        };
        // Commit gate: the plan must end with the candidate admissible
        // (pick_decode_victims is all-or-nothing, so non-empty victims
        // cover the whole deficit). Otherwise do nothing — the blocking
        // condition (a boundary, a completion, more headroom) arrives as
        // a later event and the check re-fires then.
        let dispatchable = need <= headroom || !victims.is_empty();
        let acted = dispatchable && (abort.is_some() || !victims.is_empty());
        if !acted {
            return false;
        }
        if let Some((pi, adi, _)) = abort {
            self.events.push_owned(
                self.clock,
                EventKind::PreemptPrefill { instance: pi },
                self.shards.owner_of(adi),
            );
        }
        for id in victims {
            self.evict_decode_seq(ti, id, false);
        }
        // Whichever trigger fired, the freed capacity (slot or KV) was
        // bought for this candidate: the next dispatch must try its
        // shard first or another shard's backlog can consume it.
        self.preempt.note_preempt(cand.id);
        self.boost_shard = Some(csi);
        true
    }

    /// No candidate has crossed the urgency threshold yet: schedule a
    /// `PreemptCheck` wake at the earliest crossing among the queued
    /// online peeks (deduped via `preempt_wake_at`). Conditions other
    /// than the clock (slots freeing, boundaries, arrivals) already
    /// arrive as events, so the crossing is the only trigger edge that
    /// needs its own wake-up.
    fn schedule_preempt_wake(&mut self, oldest: &[Option<QueuedReq>]) {
        let Some(crossing) = oldest
            .iter()
            .flatten()
            .map(|r| self.preempt.crossing_at(r))
            .min()
        else {
            // No online work queued anywhere: retire any planted wake
            // instead of letting it fire stale and burn a scan.
            if let Some((_, id)) = self.preempt_wake.take() {
                self.events.cancel(id);
            }
            return;
        };
        if crossing <= self.clock {
            return; // float-rounding edge: the next real event re-checks
        }
        if let Some((at, _)) = self.preempt_wake {
            if at == crossing {
                return; // already planted
            }
        }
        // A superseded wake (its request dispatched or stolen away) is
        // tombstoned rather than left to fire stale and burn a scan.
        if let Some((_, id)) = self.preempt_wake.take() {
            self.events.cancel(id);
        }
        let id = self.events.push(crossing, EventKind::PreemptCheck);
        self.preempt_wake = Some((crossing, id));
    }

    /// Trigger (a) mechanism: abort the batch in flight on `pi`,
    /// tombstone its completion event, charge the burned GPU time (and
    /// the FLOP-proportional share of its padded tokens) as waste,
    /// release its KV reservation, and return its requests to the owning
    /// shard's queue. The drain sort restores arrival order among them.
    fn on_preempt_prefill(&mut self, pi: usize) {
        let Some(p) = self.prefill.abort(pi) else {
            return; // the batch completed in this same instant
        };
        self.events.cancel(p.done_event);
        // Elapsed GPU time being discarded: for a sliced batch the
        // current slice began at `done_at − duration` (earlier slices
        // charged busy at their own boundaries, and `started_at` is the
        // original first-slice start, which spans parked time); for a
        // monolithic batch it is time since dispatch.
        let elapsed = match &p.slice {
            Some(_) => self
                .clock
                .saturating_sub(p.done_at.saturating_sub(p.duration))
                .min(p.duration),
            None => self.clock.saturating_sub(p.started_at).min(p.duration),
        };
        self.report.prefill_busy_us += elapsed;
        // Waste: a monolithic abort discards the FLOP-proportional share
        // of its padded tokens; a sliced abort additionally discards
        // every *completed* slice (their busy time was already charged,
        // but their output dies with the batch).
        let (wasted_us, wasted_tokens) = match &p.slice {
            Some(s) => {
                let span = (s.cursor + s.width).min(p.formed.batch.padded_len)
                    - s.cursor;
                let n = p.formed.batch.n() as u128;
                (
                    s.exec_us + elapsed,
                    (n * s.cursor as u128
                        + n * span as u128 * elapsed as u128
                            / p.duration.max(1) as u128)
                        as u64,
                )
            }
            None => (
                elapsed,
                (p.formed.batch.padded_tokens() as u128 * elapsed as u128
                    / p.duration.max(1) as u128) as u64,
            ),
        };
        self.report.wasted_prefill_us += wasted_us;
        self.report.wasted_prefill_tokens += wasted_tokens;
        self.report.prefill_aborts += 1;
        // Release the deduplicated reservations dispatch charged; the
        // blocks the dispatch *inserted* stay resident on the cache's own
        // books (still useful to whoever re-dispatches). A sliced victim
        // releases only what its slices reserved so far.
        let footprint: u64 = match &p.slice {
            Some(s) => s.reserved_so_far,
            None => p.formed.reqs.iter().map(QueuedReq::footprint).sum(),
        };
        let si = self.shards.owner_of(p.target_decode);
        let d = self.decode.get_mut(p.target_decode);
        d.reserved_tokens = d.reserved_tokens.saturating_sub(footprint);
        self.monitor.kv_release(si, footprint);
        let mut reqs = p.formed.reqs;
        if self.prefix.is_some() {
            // Unpin and strip acquisition state: a requeued request
            // reserves its full context again and re-acquires (possibly
            // re-hitting) at its next dispatch. Lineage survives.
            for r in reqs.iter_mut() {
                self.release_prefix_pins(p.target_decode, &r.prefix);
                r.prefix.cached_len = 0;
                r.prefix.shared_len = 0;
            }
        }
        self.monitor.on_requeue(si, reqs.len());
        self.shards.get_mut(si).planner.absorb(reqs, self.clock);
    }

    /// Eviction mechanism shared by preemption trigger (b) and the
    /// admission layer's TBT trigger, per victim: drop the sequence from
    /// the active set, release its full-context KV reservation,
    /// checkpoint its generated-token progress, and schedule the
    /// `RestoreReady` requeue once the (tiny) checkpoint transfer lands.
    /// `tbt` selects which trigger's books the eviction is charged to —
    /// counts, freed KV, and recompute debt each stay with the subsystem
    /// that caused them, so neither JSON block double-reports.
    fn evict_decode_seq(&mut self, di: usize, id: RequestId, tbt: bool) {
        let si = self.shards.owner_of(di);
        let (s, footprint) = {
            let d = self.decode.get_mut(di);
            let Some(pos) = d.active.iter().position(|s| s.id == id) else {
                return;
            };
            let s = d.active.remove(pos);
            let footprint = s.footprint();
            d.reserved_tokens = d.reserved_tokens.saturating_sub(footprint);
            (s, footprint)
        };
        self.monitor.kv_release(si, footprint);
        self.monitor.on_decode_exit(1);
        // The evicted sequence's prefix pins drop with it; the
        // checkpoint entry keeps lineage but zeroes acquisition state
        // (`checkpoint_seq`), so the restore reserves full context and
        // re-acquires at its recompute dispatch.
        self.release_prefix_pins(di, &s.prefix);
        self.engine.release(s.id);
        let ckpt = self.engine.checkpoint(s.generated);
        let entry = self.preempt.checkpoint_seq(&s);
        if tbt {
            self.report.tbt_evictions += 1;
            self.report.tbt_evicted_kv_tokens += footprint;
            self.report.tbt_recompute_tokens += entry.len as u64;
        } else {
            self.report.decode_evictions += 1;
            self.report.evicted_kv_tokens += footprint;
            self.report.recompute_tokens += entry.len as u64;
        }
        let due = self.clock + ckpt;
        self.restore_buf.push((due, di, entry));
        self.events.push_owned(due, EventKind::RestoreReady { decode: di }, si);
    }

    /// Apply one live command; returns true for `Shutdown` (the caller
    /// starts the drain clock). Realtime drive mode only.
    fn apply_cmd(&mut self, cmd: LiveCmd) -> bool {
        match cmd {
            LiveCmd::Submit { mut req, sink } => {
                // Re-stamp arrival on this run's wall epoch so TTFT and
                // queue-wait accounting stay on one clock regardless of
                // when the submitter's process started.
                req.arrival = self.clock;
                if let Some(live) = &mut self.live {
                    live.sinks.insert(req.id, sink);
                }
                self.admit_one(&req);
            }
            LiveCmd::Abort(id) => {
                if let Some(live) = &mut self.live {
                    live.abort(id);
                }
            }
            LiveCmd::Health { reply } => {
                // The submitter may have hung up; a dead reply channel is
                // its problem, not the serving loop's.
                let _ = reply.send(HealthInfo {
                    in_flight: self.live.as_ref().map_or(0, |l| l.sinks.len()),
                    queued: (0..self.shards.n())
                        .map(|si| self.shards.get(si).planner.queued())
                        .sum(),
                    completions: self.report.completions.len() as u64,
                    client_aborts: self.report.client_aborts,
                });
            }
            LiveCmd::Loads { reply } => {
                let view = self.monitor.view(self.clock);
                let instances = (0..self.decode.n())
                    .map(|di| {
                        let d = self.decode.get(di);
                        InstanceLoad {
                            instance: di,
                            active: d.active.len(),
                            pending: d.pending.len(),
                            reserved_tokens: d.reserved_tokens,
                        }
                    })
                    .collect();
                let (ttft, tbt) = match &self.live {
                    Some(l) => (
                        self.report.slo_attainment_class(
                            RequestClass::Online,
                            l.slo.ttft_us,
                            u64::MAX,
                        ),
                        self.report.tbt_attainment_class(RequestClass::Online),
                    ),
                    None => (1.0, 1.0),
                };
                let _ = reply.send(LoadsInfo {
                    view,
                    instances,
                    ttft_attainment_online: ttft,
                    tbt_attainment_online: tbt,
                });
            }
            LiveCmd::Shutdown => return true,
        }
        false
    }

    /// Client-abort sweep (realtime only): remove every abort-flagged
    /// sequence from decode instances sitting at an iteration boundary —
    /// mid-iteration KV is pinned by the running kernel, so in-flight
    /// instances are swept at their next boundary instead. Requests
    /// still queued or prefilling drop at the prefill hand-off
    /// (`on_prefill_done`).
    fn sweep_aborts(&mut self) {
        let ids: Vec<RequestId> = match &self.live {
            Some(l) if !l.aborted.is_empty() => {
                l.aborted.iter().copied().collect()
            }
            _ => return,
        };
        for di in 0..self.decode.n() {
            if !self.decode.get(di).at_boundary() {
                continue;
            }
            for &id in &ids {
                self.abort_decode_seq(di, id);
            }
        }
    }

    /// Mirror of [`RunCore::evict_decode_seq`] minus
    /// checkpoint-and-restore: the client is gone, so the sequence's
    /// work is dropped, not requeued — its KV reservation, prefix pins,
    /// and engine state release here and its stream closes with an
    /// aborted line. A no-op when `id` is not on instance `di`.
    fn abort_decode_seq(&mut self, di: usize, id: RequestId) {
        let si = self.shards.owner_of(di);
        let (s, footprint) = {
            let d = self.decode.get_mut(di);
            let s = match d.active.iter().position(|s| s.id == id) {
                Some(pos) => d.active.remove(pos),
                None => match d.pending.iter().position(|s| s.id == id) {
                    Some(pos) => d.pending.remove(pos),
                    None => return,
                },
            };
            let footprint = s.footprint();
            d.reserved_tokens = d.reserved_tokens.saturating_sub(footprint);
            (s, footprint)
        };
        self.monitor.kv_release(si, footprint);
        self.monitor.on_decode_exit(1);
        self.release_prefix_pins(di, &s.prefix);
        self.engine.release(s.id);
        if let Some(live) = &mut self.live {
            live.finish_aborted(s.id, &mut self.report);
        }
    }

    /// Nothing queued, prefilling, handing off, decoding, or awaiting a
    /// checkpoint restore — the realtime drain-exit condition.
    fn quiescent(&self) -> bool {
        if self.prefill.any_running() || !self.restore_buf.is_empty() {
            return false;
        }
        for di in 0..self.decode.n() {
            let d = self.decode.get(di);
            if !d.active.is_empty()
                || !d.pending.is_empty()
                || d.iter_end.is_some()
            {
                return false;
            }
        }
        (0..self.shards.n()).all(|si| {
            let sh = self.shards.get(si);
            sh.planner.queued() == 0 && sh.parked.is_empty()
        })
    }

    /// The admission layer's trigger (b), run at `di`'s iteration
    /// boundary: when the *next* projected iteration would land a
    /// resident online sequence past its effective inter-token deadline,
    /// shed least-urgent offline actives (checkpoint-and-restore) until
    /// the projection fits, the reclaimable pool runs dry, or the
    /// per-trigger cap is hit. Shedding is useless when even an
    /// online-only batch blows the budget (the budget is below the
    /// weight-read floor), so that case evicts nothing.
    fn tbt_evict_pass(&mut self, di: usize) {
        if !self.admission_active || !self.admission.evict_enabled() {
            return;
        }
        if !self.decode.get(di).at_boundary() {
            return; // stale event; KV is pinned mid-iteration anyway
        }
        if !self.tbt_instance_at_risk(di) {
            return;
        }
        // Floor check: would the resident online members alone still blow
        // the budget? Then shedding offline buys nothing — evicting would
        // be pure recompute waste.
        if self.tbt_online_floor_at_risk(di) {
            return;
        }
        let order = self.admission.victim_order(
            &self.decode.get(di).active,
            self.clock,
        );
        let mut shed = 0u32;
        for id in order {
            if shed >= self.admission.max_evictions() {
                break;
            }
            self.evict_decode_seq(di, id, true);
            shed += 1;
            if !self.tbt_instance_at_risk(di) {
                break;
            }
        }
    }

    /// Would `di`'s *next* iteration blow a resident online sequence's
    /// effective inter-token deadline? Projects over the active set
    /// *plus the pending hand-offs already due* — `admit_handoffs` joins
    /// those at this same boundary, so an active-only projection would
    /// systematically undershoot the iteration that actually launches
    /// (trigger (a)'s `tbt_target` counts them for the same reason).
    ///
    /// Predicate split (the boundary-to-boundary accounting fix,
    /// mirrored from the dispatch gate): *actives* have a live
    /// inter-token clock, so their risk is anchor-charged
    /// (`deadline_at_risk` — projected iteration plus time already
    /// burned since their last token). A *due-pending* member's anchor
    /// is its hand-off landing, which predates the boundary it joins
    /// at — its gap clock re-anchors on admission, so charging the
    /// pre-boundary wait against it double-counts and trips the trigger
    /// spuriously. Pending members are therefore scored
    /// boundary-to-boundary (`iteration_at_risk`): the projected
    /// iteration alone against their budgets.
    fn tbt_instance_at_risk(&self, di: usize) -> bool {
        let d = self.decode.get(di);
        let clock = self.clock;
        let due = move |s: &&DecodeSeqState| s.ready_at <= clock;
        let n = d.active.len() + d.pending.iter().filter(due).count();
        if n == 0 {
            return false;
        }
        let ctx =
            active_ctx(d.active.iter().chain(d.pending.iter().filter(due)));
        let projected = self.engine.projected_decode_us(n, ctx);
        self.admission.deadline_at_risk(d.active.iter(), projected, clock)
            || self
                .admission
                .iteration_at_risk(d.pending.iter().filter(due), projected)
    }

    /// The evict pass's floor: the projected iteration over only the
    /// resident online members (active + due pending — none of which the
    /// pass may evict) against their own deadlines, with the same
    /// active/pending predicate split as [`RunCore::tbt_instance_at_risk`]
    /// so the floor can never be *easier* to trip than the trigger.
    fn tbt_online_floor_at_risk(&self, di: usize) -> bool {
        let d = self.decode.get(di);
        let clock = self.clock;
        let online = |s: &&DecodeSeqState| s.class == RequestClass::Online;
        let active: Vec<&DecodeSeqState> =
            d.active.iter().filter(online).collect();
        let pending: Vec<&DecodeSeqState> = d
            .pending
            .iter()
            .filter(|s| s.ready_at <= clock)
            .filter(online)
            .collect();
        let ctx = active_ctx(active.iter().copied())
            + active_ctx(pending.iter().copied());
        let floor = self
            .engine
            .projected_decode_us(active.len() + pending.len(), ctx);
        self.admission
            .deadline_at_risk(active.into_iter(), floor, clock)
            || self.admission.iteration_at_risk(pending.into_iter(), floor)
    }

    /// The admission layer's trigger (a) decision for a formed batch: the
    /// decode instance among shard `si`'s owned set that can absorb `f`
    /// without pushing any resident online sequence (active or pending —
    /// a landed hand-off joins at the next boundary regardless) past its
    /// effective inter-token deadline. Tries the planned target `ti`
    /// first (the shard's max-headroom instance the batch was admitted
    /// against), then the remaining owned instances in descending
    /// headroom order, skipping any whose KV headroom no longer fits the
    /// batch. `None` means defer: the batch returns to the shard queue.
    fn tbt_target(&self, si: usize, ti: usize, f: &FormedBatch) -> Option<usize> {
        let need: u64 = f.reqs.iter().map(QueuedReq::footprint).sum();
        let n_new = f.reqs.len();
        // An incoming sequence enters the continuous batch holding its
        // prompt plus the prefill-produced first token.
        let ctx_new: u64 = f.reqs.iter().map(|r| r.len as u64 + 1).sum();
        let mut cands: Vec<(usize, u64)> = self
            .shards
            .get(si)
            .owned
            .iter()
            .map(|&di| {
                let headroom = self
                    .per_decode_budget
                    .saturating_sub(self.decode.get(di).reserved_tokens);
                (di, headroom)
            })
            .collect();
        cands.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        for (di, headroom) in cands {
            if di != ti && headroom < need {
                continue; // the batch was sized against ti's headroom
            }
            let d = self.decode.get(di);
            let n = d.active.len() + d.pending.len() + n_new;
            let ctx = active_ctx(&d.active) + active_ctx(&d.pending) + ctx_new;
            let projected = self.engine.projected_decode_us(n, ctx);
            let members = d.active.iter().chain(d.pending.iter());
            // Boundary-to-boundary accounting: the batch joins at an
            // iteration boundary, where every resident's gap clock
            // re-anchors — so the gap it induces is the projected
            // iteration itself, not `projected` plus whatever already
            // elapsed since a resident's last token (the old
            // mid-iteration predicate double-charged that and deferred
            // spuriously).
            if !self.admission.iteration_at_risk(members, projected) {
                return Some(di);
            }
        }
        None
    }

    /// A checkpoint landed: requeue every restore-buffer entry that is
    /// due for this decode instance's owner shard, as
    /// recompute-from-checkpoint work.
    fn on_restore_ready(&mut self, di: usize) {
        let si = self.shards.owner_of(di);
        let clock = self.clock;
        let mut ready = Vec::new();
        self.restore_buf.retain(|&(due, d, entry)| {
            if d == di && due <= clock {
                ready.push(entry);
                false
            } else {
                true
            }
        });
        if ready.is_empty() {
            return;
        }
        self.monitor.on_requeue(si, ready.len());
        self.shards.get_mut(si).planner.absorb(ready, clock);
    }

    /// Consume stage of the plan/commit protocol — the ONLY way a shard
    /// plans during a dispatch round, in both executor modes. Commit a
    /// speculative [`PlanProposal`] when one is waiting and its captured
    /// inputs still hold (install the speculated planner state, take the
    /// formed batch); otherwise run the identical snapshot → speculate →
    /// install pipeline inline (the sequential mode and the re-plan path
    /// after an invalidation). Installing the clone an inline
    /// speculation just mutated is indistinguishable from having planned
    /// on the live planner, so parallel ≡ sequential ≡ the pre-protocol
    /// `planner.plan()` call, instruction for instruction.
    fn consume_plan(
        &mut self,
        si: usize,
        headroom: u64,
        proposals: &mut [Option<PlanProposal>],
        planned: &mut bool,
    ) -> Option<FormedBatch> {
        if self.shards.get(si).planner.queued() == 0 {
            // Nothing to plan over — and provably no proposal either
            // (speculation is only fanned out for non-empty queues, and
            // a queue cannot empty mid-round without its proposal being
            // consumed by the commit that drained it).
            return None;
        }
        if let Some(p) = proposals[si].take() {
            if executor::proposal_valid(&p, self.clock, headroom) {
                *planned = true;
                self.shards.get_mut(si).planner = p.speculated;
                return p.formed;
            }
            // Stale: an earlier commit this round changed the shard's
            // target headroom. Discard (the live planner was never
            // touched by the speculation) and re-plan inline below.
            self.report.executor_plan_invalidations += 1;
        }
        *planned = true;
        let t0 = Instant::now();
        let p = executor::speculate_plan(PlanJob {
            // Never crosses a channel — no merge key to allocate.
            key: SyncKey { at: self.clock, event: 0, shard: si },
            now: self.clock,
            headroom,
            snapshot: self.shards.get(si).planner.clone_box(),
        });
        self.shards.get_mut(si).planner = p.speculated;
        self.report.plan_merge_ns += t0.elapsed().as_nanos() as u64;
        p.formed
    }

    /// Form and dispatch prefill batches onto idle instances. The shard
    /// layer supplies the candidates: shards in descending order of their
    /// best owned decode instance's KV headroom (Eq. 6 admission), each
    /// paired with that target instance. The first shard whose planner
    /// yields a batch wins; with one shard this is exactly the seed's
    /// global max-headroom `best_target` scan.
    ///
    /// Planning runs behind the executor's plan/commit protocol: with
    /// `plan_offload`, every candidate shard's planner is snapshotted up
    /// front and speculated on the worker pool while the merge loop
    /// waits, then each shard's proposal is committed (or invalidated
    /// and re-planned inline) at the moment the headroom scan reaches it
    /// — see [`RunCore::consume_plan`]. The dispatch order is computed
    /// once per round and repaired entry-by-entry as commits change
    /// shards' target headroom ([`ShardSet::repair_dispatch_order`]),
    /// instead of the old from-scratch recompute per idle instance.
    fn dispatch_prefill(&mut self) {
        if (0..self.prefill.n()).all(|pi| !self.prefill.is_idle(pi)) {
            return;
        }
        // Shards whose head batch the admission gate deferred this round:
        // nothing about the decision's inputs changes within one dispatch
        // pass, so re-planning the same batch for the next idle prefill
        // instance would just repeat the plan/sort/absorb churn (and
        // double-count the deferral). Cleared every round — the *next*
        // event re-evaluates against fresh decode state. (A reused
        // boolean mask: the old `Vec<usize>` + `contains` scan was
        // O(deferred) per candidate.)
        self.deferred_mask.clear();
        self.deferred_mask.resize(self.shards.n(), false);
        let mut order = self
            .shards
            .dispatch_order(&self.decode, self.per_decode_budget);
        // Eager speculation fan-out: snapshot every candidate shard with
        // queued work and let the workers plan them all concurrently.
        // Proposals land indexed by shard, awaiting their commit/discard
        // at the scan below. The elapsed time of this whole block —
        // snapshots plus blocking on the slowest worker — is what the
        // merge loop actually pays for planning (`plan_merge_ns`); the
        // Σ of per-proposal worker time (`plan_worker_ns`) is what it
        // would have paid inline.
        let mut proposals: Vec<Option<PlanProposal>> =
            (0..self.shards.n()).map(|_| None).collect();
        if self.plan_offload {
            let t0 = Instant::now();
            let mut jobs: Vec<PlanJob> = Vec::new();
            for &(si, _, headroom) in &order {
                if self.shards.get(si).planner.queued() == 0 {
                    continue;
                }
                jobs.push(PlanJob {
                    key: SyncKey {
                        at: self.clock,
                        event: self.events.stamp(),
                        shard: si,
                    },
                    now: self.clock,
                    headroom,
                    snapshot: self.shards.get(si).planner.clone_box(),
                });
            }
            if !jobs.is_empty() {
                let props = self
                    .pool
                    .as_ref()
                    .expect("plan offload without a worker pool")
                    .plan(jobs);
                self.report.executor_sync_points += 1;
                self.report.executor_parallel_plans += props.len() as u64;
                for p in props {
                    self.report.plan_worker_ns += p.spec_ns;
                    proposals[p.key.shard] = Some(p);
                }
            }
            self.report.plan_merge_ns += t0.elapsed().as_nanos() as u64;
        }
        let mut planned = false;
        for pi in 0..self.prefill.n() {
            if !self.prefill.is_idle(pi) {
                continue;
            }
            // Chunked prefill: a parked sliced batch resumes ahead of
            // new planning once no shard has online work queued (the
            // symmetric condition of the yield that parked it) — it is
            // older than anything still waiting. The *globally oldest*
            // parked batch resumes first (minimum original-dispatch
            // `started_at` across shard fronts), not the first parked
            // shard in headroom order: a resume targets the batch's own
            // original decode instance, so headroom preference buys
            // nothing and would let a younger batch on a high-headroom
            // shard jump an older one elsewhere. Both peeks are guarded
            // by `chunk.enabled`, so disabled runs pay one branch.
            if self.chunk.enabled {
                let oldest_parked = self.shards.oldest_parked_shard();
                let online_somewhere = oldest_parked.is_some()
                    && (0..self.shards.n()).any(|si| {
                        self.shards.get_mut(si).planner.oldest_online().is_some()
                    });
                if let (Some(si), false) = (oldest_parked, online_somewhere) {
                    self.resume_parked(pi, si);
                    self.shards.repair_dispatch_order(
                        &mut order,
                        si,
                        &self.decode,
                        self.per_decode_budget,
                    );
                    continue;
                }
            }
            // A prefill abort promised its slot to the preempting
            // candidate's shard; honor that before the headroom order —
            // as an iteration adapter (boosted entry first, then the
            // rest in order), leaving the cached order itself intact.
            let boost_pos = self.boost_shard.take().and_then(|bs| {
                order.iter().position(|&(si, _, _)| si == bs)
            });
            let scan: Vec<usize> = match boost_pos {
                Some(bp) => std::iter::once(bp)
                    .chain((0..order.len()).filter(|&i| i != bp))
                    .collect(),
                None => (0..order.len()).collect(),
            };
            let mut chosen: Option<(usize, usize, FormedBatch)> = None;
            for &oi in &scan {
                let (si, ti, headroom) = order[oi];
                if self.deferred_mask[si] {
                    continue;
                }
                let Some(f) = self.consume_plan(
                    si,
                    headroom,
                    &mut proposals,
                    &mut planned,
                ) else {
                    continue;
                };
                if self.admission_active && self.admission.defer_enabled() {
                    // Admission trigger (a): commit the batch only onto
                    // an instance whose projected iteration keeps every
                    // resident online sequence inside its TBT budget.
                    match self.tbt_target(si, ti, &f) {
                        Some(target) => {
                            chosen = Some((si, target, f));
                            break;
                        }
                        None => {
                            // Defer: the batch returns to its shard's
                            // queue (requeue, not a new arrival — the
                            // monitor's queue depth was never
                            // decremented) and the next shard in
                            // headroom order gets its turn. The blocked
                            // instance keeps producing DecodeIterEnd
                            // events, so the retry cadence is its online
                            // actives draining — no lost wake-up.
                            self.report.admission_deferrals += 1;
                            self.deferred_mask[si] = true;
                            self.shards
                                .get_mut(si)
                                .planner
                                .absorb(f.reqs, self.clock);
                            continue;
                        }
                    }
                }
                chosen = Some((si, ti, f));
                break;
            }
            if chosen.is_none() && self.chunk.enabled {
                // Nothing new formed (empty queues, exhausted headroom,
                // or every shard deferred): resume a parked sliced
                // batch even with online work still queued — a parked
                // batch must never be able to stall the run, and the
                // work it yielded to provably cannot dispatch right
                // now anyway. Same oldest-first selection as the eager
                // path above.
                if let Some(si) = self.shards.oldest_parked_shard() {
                    self.resume_parked(pi, si);
                    self.shards.repair_dispatch_order(
                        &mut order,
                        si,
                        &self.decode,
                        self.per_decode_budget,
                    );
                    continue;
                }
            }
            if chosen.is_none() {
                // Deadlock breaker: nothing anywhere in flight and a head
                // request alone exceeds even an idle budget — pop one
                // solo from the first candidate shard with queued work.
                let nothing_in_flight = !self.prefill.any_running()
                    && self.decode.nothing_in_flight();
                if nothing_in_flight && self.shards.queued_total() > 0 {
                    for &(si, ti, _) in &order {
                        let popped =
                            self.shards.get_mut(si).planner.force_pop(self.clock);
                        let Some(r) = popped else { continue };
                        let padded = r.len.max(1);
                        chosen = Some((
                            si,
                            ti,
                            FormedBatch {
                                batch: PrefillBatch {
                                    items: vec![PrefillItem {
                                        id: r.id,
                                        len: r.len,
                                        tokens: vec![],
                                    }],
                                    padded_len: padded,
                                },
                                reqs: vec![r],
                                bucket_up: padded,
                            },
                        ));
                        break;
                    }
                }
            }
            let Some((si, ti, mut formed)) = chosen else { break };
            let had_pending = self.preempt.pending().is_some();
            self.preempt.on_dispatch(&formed.reqs);
            if had_pending && self.preempt.pending().is_none() {
                self.recheck_preempt = true;
            }
            // Prefix-cache acquisition, now that the target instance is
            // known: each request's stamp is rewritten with the *actual*
            // hit (`cached_len` — compute it saves) and the pinned run
            // (`shared_len` — KV it need not reserve). Insertions charge
            // the instance's books (the cache owns resident blocks);
            // LRU evictions release theirs.
            if let Some(caches) = &mut self.prefix {
                let cache = &mut caches[ti];
                let mut inserted = 0u64;
                let mut evicted = 0u64;
                for r in formed.reqs.iter_mut() {
                    let shareable = r.prefix.prefix_len.min(r.len);
                    let a = cache.acquire(r.prefix.prefix_id, shareable);
                    r.prefix.cached_len = a.hit_tokens;
                    r.prefix.shared_len = a.pinned_len;
                    inserted += a.inserted_tokens;
                    evicted += a.evicted_tokens;
                }
                let d = self.decode.get_mut(ti);
                d.reserved_tokens =
                    (d.reserved_tokens + inserted).saturating_sub(evicted);
                self.monitor.kv_reserve(si, inserted);
                self.monitor.kv_release(si, evicted);
                // Price prefill on the uncached suffixes only: the batch
                // the engine executes shrinks to what actually needs
                // computing (padded among the suffixes). Hit-free
                // batches keep their original padding so a cold cache
                // prices exactly like the baseline.
                if formed.reqs.iter().any(|r| r.prefix.cached_len > 0) {
                    let items: Vec<PrefillItem> = formed
                        .reqs
                        .iter()
                        .map(|r| PrefillItem {
                            id: r.id,
                            len: r.len.saturating_sub(r.prefix.cached_len).max(1),
                            tokens: vec![],
                        })
                        .collect();
                    let padded_len = items
                        .iter()
                        .map(|i| i.len)
                        .max()
                        .unwrap_or(1)
                        .min(formed.batch.padded_len)
                        .max(1);
                    formed.batch = PrefillBatch { items, padded_len };
                }
            }
            if let Some(width) = self.slice_width(&formed) {
                // Chunked path: no up-front footprint reservation —
                // `launch_slice` reserves each slice's progress share
                // as it executes, so headroom reflects KV actually
                // produced. Dispatch-time bookkeeping still happens
                // exactly once, here.
                self.monitor.on_prefill_dispatch(si, formed.reqs.len());
                self.shards.get_mut(si).stats.batches += 1;
                self.report.chunk_sliced_batches += 1;
                self.launch_slice(pi, formed, ti, self.clock, 0, width, 0, 0);
            } else {
                let footprint: u64 = formed
                    .reqs
                    .iter()
                    .map(QueuedReq::footprint)
                    .sum();
                self.decode.get_mut(ti).reserved_tokens += footprint;
                self.monitor.kv_reserve(si, footprint);
                self.monitor.on_prefill_dispatch(si, formed.reqs.len());
                self.shards.get_mut(si).stats.batches += 1;
                let duration = self
                    .engine
                    .prefill(&formed.batch)
                    .expect("prefill execution failed");
                // Realtime engines block inside prefill(): completion is
                // "now" on the wall clock. Virtual engines schedule ahead.
                let done_at = if self.realtime {
                    self.wall_start.elapsed().as_micros() as Micros
                } else {
                    self.clock + duration
                };
                let done_event = self.events.push_owned(
                    done_at,
                    EventKind::PrefillDone { instance: pi },
                    si,
                );
                self.prefill.dispatch(
                    pi,
                    InFlightPrefill {
                        formed,
                        done_at,
                        duration,
                        target_decode: ti,
                        started_at: self.clock,
                        done_event,
                        slice: None,
                    },
                );
            }
            // Commit bookkeeping. Any proposal still held for this shard
            // speculated over a queue that just changed — drop it
            // outright (commit-time validation alone could miss a
            // zero-footprint commit, which leaves headroom untouched
            // while the queue shrank). Then repair the shard's entry in
            // the cached dispatch order: this commit's reservations only
            // moved *this* shard's target headroom — shards own disjoint
            // decode instances — so one entry repair keeps the cache
            // byte-identical to a full recompute.
            proposals[si] = None;
            self.shards.repair_dispatch_order(
                &mut order,
                si,
                &self.decode,
                self.per_decode_budget,
            );
        }
        if planned {
            self.report.executor_plan_rounds += 1;
        }
    }

    /// Launch the next decode iteration on every instance with an active
    /// continuous batch.
    fn launch_decode(&mut self) {
        for di in 0..self.decode.n() {
            let d = self.decode.get_mut(di);
            if !d.at_boundary() || d.active.is_empty() {
                continue;
            }
            let batch = DecodeBatch {
                seqs: d
                    .active
                    .iter()
                    .map(|s| DecodeSeq {
                        id: s.id,
                        ctx_len: s.input_len + s.generated,
                    })
                    .collect(),
            };
            // Hybrid-batch pricing: while a prefill *slice* targeting
            // this instance is in flight, the decode iteration
            // piggybacks on its weight read — the engine charges only
            // the KV-stream term. Monolithic prefills never qualify:
            // without slice boundaries there is no co-scheduling seam.
            let hybrid = self.chunk.enabled
                && self.chunk.hybrid
                && (0..self.prefill.n()).any(|pi| {
                    self.prefill.get(pi).is_some_and(|p| {
                        p.slice.is_some() && p.target_decode == di
                    })
                });
            if hybrid {
                self.report.chunk_hybrid_iters += 1;
            }
            let duration = if hybrid {
                self.engine.hybrid_decode_step(&batch)
            } else {
                self.engine.decode_step(&batch)
            }
            .expect("decode execution failed");
            let end = if self.realtime {
                self.wall_start.elapsed().as_micros() as Micros
            } else {
                self.clock.max(d.free_at) + duration
            };
            let d = self.decode.get_mut(di);
            d.free_at = end;
            d.iter_end = Some(end);
            self.report.decode_iters += 1;
            self.report.decode_busy_us += duration;
            // Bandwidth-amortization efficiency: fraction of streamed
            // bytes that are per-sequence KV rather than the weight
            // read shared by the batch.
            let kv_bytes = batch.total_ctx() as f64 * self.kv_per_token;
            let eff = kv_bytes / (kv_bytes + self.weight_bytes);
            self.report.decode_useful_us += duration as f64 * eff;
            self.events.push_owned(
                end,
                EventKind::DecodeIterEnd { decode: di },
                self.shards.owner_of(di),
            );
        }
    }

    /// Idle instances with only future hand-offs need a wake-up event at
    /// the earliest landing (deduped via `wake_at`), or the queue would
    /// drain with work still pending.
    fn schedule_idle_wakes(&mut self) {
        let clock = self.clock;
        for di in 0..self.decode.n() {
            let d = self.decode.get_mut(di);
            if !d.at_boundary() || !d.active.is_empty() || d.pending.is_empty() {
                continue;
            }
            let earliest = d
                .pending
                .iter()
                .map(|s| s.ready_at)
                .min()
                .unwrap()
                .max(clock);
            if d.wake_at != Some(earliest) {
                d.wake_at = Some(earliest);
                self.events.push_owned(
                    earliest,
                    EventKind::HandoffReady { decode: di },
                    self.shards.owner_of(di),
                );
            }
        }
    }

    /// End the run abnormally: record the diagnostics on the report (the
    /// old livelock panic's payload) and shout on the log so a truncated
    /// run can't masquerade as a clean one.
    fn fail(&mut self, why: &str) {
        let msg = self.diagnostics(why);
        crate::log_warn!("{msg}");
        self.report.error = Some(msg);
    }

    /// Stall diagnostics (the payload of the old livelock panic).
    fn diagnostics(&self, why: &str) -> String {
        format!(
            "scheduler stall ({why}): clock={} done={}/{} queued={} \
             arrivals={} prefill_busy={:?} decode=[{}]",
            self.clock,
            self.report.completions.len(),
            self.total,
            self.shards.queued_total(),
            self.next_arrival,
            self.prefill.running_mask(),
            self.decode
                .iter()
                .map(|d| format!(
                    "(act={} pend={} resv={} iter_end={:?})",
                    d.active.len(),
                    d.pending.len(),
                    d.reserved_tokens,
                    d.iter_end
                ))
                .collect::<Vec<_>>()
                .join(","),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::sim::SimEngine;
    use crate::config::Policy;
    use crate::util::prop;
    use crate::workload::{Dataset, RequestClass};

    fn small_cfg() -> SystemConfig {
        let mut cfg = SystemConfig::default();
        cfg.fleet.n_prefill = 1;
        cfg.fleet.n_decode = 1;
        cfg
    }

    fn run_bucketserve(cfg: &SystemConfig, trace: &Trace) -> RunReport {
        let mut sched = PdScheduler::new(cfg, || Box::new(BucketPlanner::new(cfg)));
        let mut engine = SimEngine::new(cfg);
        sched.run(trace, &mut engine)
    }

    #[test]
    fn completes_every_request() {
        let cfg = small_cfg();
        let trace = Trace::generate(
            Dataset::Alpaca, 50, 4.0, RequestClass::Online, cfg.model.max_seq, 1,
        );
        let report = run_bucketserve(&cfg, &trace);
        assert_eq!(report.completions.len(), 50);
        assert!(report.error.is_none(), "{:?}", report.error);
        let mut ids: Vec<_> = report.completions.iter().map(|c| c.id).collect();
        ids.sort();
        assert_eq!(ids, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn timestamps_are_causal() {
        let cfg = small_cfg();
        let trace = Trace::generate(
            Dataset::Mixed, 40, 8.0, RequestClass::Online, cfg.model.max_seq, 2,
        );
        let report = run_bucketserve(&cfg, &trace);
        for c in &report.completions {
            assert!(c.first_token >= c.arrival, "ttft causal for {}", c.id);
            assert!(c.finished >= c.first_token, "decode causal for {}", c.id);
        }
    }

    #[test]
    fn offline_batch_trace_completes() {
        let cfg = small_cfg();
        let trace =
            Trace::batch(Dataset::Alpaca, 64, RequestClass::Offline, 4096, 3);
        let report = run_bucketserve(&cfg, &trace);
        assert_eq!(report.completions.len(), 64);
        assert!(report.throughput_tps() > 0.0);
        assert!(report.gpu_util() > 0.0 && report.gpu_util() <= 1.0);
    }

    #[test]
    fn multi_instance_fleet_is_faster() {
        let mut cfg = small_cfg();
        let trace =
            Trace::batch(Dataset::Mixed, 96, RequestClass::Offline, 4096, 4);
        let r1 = run_bucketserve(&cfg, &trace);
        cfg.fleet.n_prefill = 2;
        cfg.fleet.n_decode = 2;
        let r2 = run_bucketserve(&cfg, &trace);
        assert!(
            r2.makespan_us < r1.makespan_us,
            "2+2 fleet {} vs 1+1 {}",
            r2.makespan_us,
            r1.makespan_us
        );
    }

    #[test]
    fn oversized_request_does_not_deadlock() {
        let mut cfg = small_cfg();
        // Tiny GPU: budget smaller than one max request.
        cfg.gpu.mem_bytes = 27 * (1u64 << 30); // 26 GB weights + ~1 GB
        let trace =
            Trace::batch(Dataset::LongBench, 3, RequestClass::Offline, 4096, 5);
        let report = run_bucketserve(&cfg, &trace);
        assert_eq!(report.completions.len(), 3);
        assert!(report.error.is_none(), "{:?}", report.error);
    }

    #[test]
    fn decode_dominates_e2e() {
        // Paper Fig. 6a: decode ≈ 90% of execution time.
        let cfg = small_cfg();
        let trace = Trace::generate(
            Dataset::Alpaca, 40, 2.0, RequestClass::Online, cfg.model.max_seq, 6,
        );
        let report = run_bucketserve(&cfg, &trace);
        let (_q, pre, dec, _b) = report.breakdown_us();
        assert!(
            dec > 4.0 * pre,
            "decode {dec} should dominate prefill {pre}"
        );
    }

    #[test]
    fn bucketing_overhead_negligible() {
        // Paper: bucketing + dynamic batching < 1% of execution time.
        let cfg = small_cfg();
        let trace = Trace::generate(
            Dataset::Mixed, 100, 16.0, RequestClass::Online, cfg.model.max_seq, 7,
        );
        let report = run_bucketserve(&cfg, &trace);
        let overhead_us = report.bucket_overhead_ns as f64 / 1e3;
        assert!(
            overhead_us < 0.01 * report.makespan_us as f64,
            "overhead {overhead_us}µs vs makespan {}µs",
            report.makespan_us
        );
    }

    #[test]
    fn kv_reservation_never_exceeds_budget() {
        // Indirect check: a run against a small budget still respects
        // completion integrity and never admits unbounded batches.
        let mut cfg = small_cfg();
        cfg.gpu.mem_bytes = 30 * (1u64 << 30);
        let trace =
            Trace::batch(Dataset::Mixed, 60, RequestClass::Offline, 4096, 8);
        let report = run_bucketserve(&cfg, &trace);
        assert_eq!(report.completions.len(), 60);
        // ~1.8 GB of KV headroom ≈ 2.4k tokens: Eq. 6 keeps batches far
        // below the unconstrained case (which would admit all 60 at once).
        assert!(report.peak_batch <= 32, "peak {}", report.peak_batch);
    }

    #[test]
    fn slo_attainment_degrades_with_load() {
        let cfg = SystemConfig::default();
        let low = Trace::generate(
            Dataset::Alpaca, 150, 2.0, RequestClass::Online, cfg.model.max_seq, 9,
        );
        let high = Trace::generate(
            Dataset::Alpaca, 150, 60.0, RequestClass::Online, cfg.model.max_seq, 9,
        );
        let rl = run_bucketserve(&cfg, &low);
        let rh = run_bucketserve(&cfg, &high);
        let al = rl.slo_attainment(cfg.slo.ttft_us, cfg.slo.tbt_us);
        let ah = rh.slo_attainment(cfg.slo.ttft_us, cfg.slo.tbt_us);
        assert!(al >= ah, "low-load {al} >= high-load {ah}");
    }

    #[test]
    fn priority_improves_online_slo_on_mixed_overload() {
        // The priority subsystem's acceptance scenario: a big offline
        // backlog at t=0 plus an online Poisson stream. FCFS drain
        // head-of-line-blocks the online class behind ~10 KV-bound offline
        // waves (tens of virtual seconds); priority-aware drain jumps
        // online requests into freed headroom within a wave or two. The
        // TTFT budget is set to the scale of one offline wave (20 s) so
        // attainment separates the two schedules instead of rounding both
        // to zero under this deliberate overload.
        let mut cfg = small_cfg();
        cfg.slo.ttft_us = 20_000_000;
        let trace = Trace::mixed_classes(
            Dataset::Alpaca, 30, 4.0, Dataset::LongBench, 40,
            cfg.model.max_seq, 21,
        );
        cfg.priority.enabled = false;
        let fcfs = run_bucketserve(&cfg, &trace);
        cfg.priority.enabled = true;
        let prio = run_bucketserve(&cfg, &trace);
        assert_eq!(fcfs.completions.len(), trace.len());
        assert_eq!(prio.completions.len(), trace.len());

        let attain = |r: &RunReport| {
            r.slo_attainment_class(
                RequestClass::Online, cfg.slo.ttft_us, cfg.slo.tbt_us,
            )
        };
        let (af, ap) = (attain(&fcfs), attain(&prio));
        assert!(
            ap >= af,
            "priority online attainment {ap} < fcfs {af}"
        );
        let tf = fcfs.mean_ttft_class_us(RequestClass::Online);
        let tp = prio.mean_ttft_class_us(RequestClass::Online);
        assert!(
            tp <= tf,
            "priority mean online TTFT {tp}µs worse than fcfs {tf}µs"
        );
        // The scenario must actually stress FCFS (otherwise the test is
        // vacuous) and priority must rescue real attainment.
        assert!(
            ap > af,
            "expected a strict online-SLO win: priority {ap} vs fcfs {af}"
        );
    }

    #[test]
    fn priority_off_matches_legacy_fcfs_on_single_class() {
        // Flipping the priority switch must not perturb single-class runs
        // (scores degenerate to arrival order).
        let mut cfg = small_cfg();
        let trace = Trace::generate(
            Dataset::Mixed, 60, 8.0, RequestClass::Online, cfg.model.max_seq, 22,
        );
        cfg.priority.enabled = true;
        let on = run_bucketserve(&cfg, &trace);
        cfg.priority.enabled = false;
        let off = run_bucketserve(&cfg, &trace);
        assert_eq!(on.completions.len(), off.completions.len());
        assert_eq!(on.makespan_us, off.makespan_us);
        assert_eq!(on.prefill_batches, off.prefill_batches);
        assert_eq!(on.decode_iters, off.decode_iters);
    }

    #[test]
    fn sharded_run_completes_and_conserves() {
        // One shard per decode instance, hash placement (deliberately
        // load-blind) and stealing on: every request still completes
        // exactly once and the shard accounting adds up.
        use crate::config::Placement;
        let mut cfg = SystemConfig::default();
        cfg.fleet.n_prefill = 4;
        cfg.fleet.n_decode = 4;
        cfg.sharding.shards = 0; // one per decode instance
        cfg.sharding.placement = Placement::Hash;
        cfg.sharding.steal = true;
        let trace = Trace::mixed_classes(
            Dataset::Alpaca, 60, 16.0, Dataset::LongBench, 40,
            cfg.model.max_seq, 31,
        );
        let report = run_bucketserve(&cfg, &trace);
        assert_eq!(report.completions.len(), trace.len());
        assert!(report.error.is_none(), "{:?}", report.error);
        let mut ids: Vec<_> = report.completions.iter().map(|c| c.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), trace.len(), "duplicated completions");
        assert_eq!(report.n_shards, 4);
        assert_eq!(
            report.shard_routed.iter().sum::<u64>(),
            trace.len() as u64,
            "every arrival routed to exactly one shard"
        );
        assert_eq!(
            report.shard_batches.len(),
            4,
            "per-shard batch counters reported"
        );
        // Hash placement spreads a 100-request trace across 4 shards.
        assert!(
            report.shard_routed.iter().filter(|&&n| n > 0).count() >= 2,
            "hash placement landed everything on one shard: {:?}",
            report.shard_routed
        );
    }

    #[test]
    fn sharded_runs_match_for_each_placement_policy() {
        // All placement policies must conserve requests and finish clean;
        // they may schedule differently, but totals agree.
        use crate::config::Placement;
        for placement in
            [Placement::LeastLoaded, Placement::JoinShortestKv, Placement::Hash]
        {
            let mut cfg = SystemConfig::default();
            cfg.fleet.n_prefill = 2;
            cfg.fleet.n_decode = 2;
            cfg.sharding.shards = 0;
            cfg.sharding.placement = placement;
            let trace = Trace::generate(
                Dataset::Mixed, 50, 12.0, RequestClass::Online,
                cfg.model.max_seq, 19,
            );
            let report = run_bucketserve(&cfg, &trace);
            assert_eq!(
                report.completions.len(),
                50,
                "{} lost requests",
                placement.name()
            );
            assert!(report.error.is_none(), "{:?}", report.error);
        }
    }

    #[test]
    fn work_stealing_rebalances_skewed_queues() {
        // Hash placement on a mixed trace leaves shards with uneven work;
        // with stealing enabled some requests must migrate, and the run
        // must stay lossless.
        use crate::config::Placement;
        let mut cfg = SystemConfig::default();
        cfg.fleet.n_prefill = 2;
        cfg.fleet.n_decode = 4;
        cfg.sharding.shards = 0;
        cfg.sharding.placement = Placement::Hash;
        cfg.sharding.steal = true;
        let trace = Trace::mixed_classes(
            Dataset::Alpaca, 40, 8.0, Dataset::LongBench, 60,
            cfg.model.max_seq, 77,
        );
        let stolen = run_bucketserve(&cfg, &trace);
        assert_eq!(stolen.completions.len(), trace.len());
        assert!(
            stolen.steals > 0,
            "skewed offline backlog should trigger stealing"
        );
        cfg.sharding.steal = false;
        let fixed = run_bucketserve(&cfg, &trace);
        assert_eq!(fixed.completions.len(), trace.len());
        assert_eq!(fixed.steals, 0, "steal=false must never migrate work");
        // Whether stealing helps end-to-end is workload-dependent (the
        // shard_scaling bench quantifies it); correctness-wise both runs
        // must finish clean.
        assert!(fixed.error.is_none() && stolen.error.is_none());
    }

    #[test]
    fn oldest_online_peeks_min_arrival_online() {
        let cfg = small_cfg();
        let mut planner = BucketPlanner::new(&cfg);
        assert!(planner.oldest_online().is_none());
        // Offline requests never surface, whatever their age.
        planner.admit(&Request::new(0, RequestClass::Offline, 50, 10, 0), 0);
        assert!(planner.oldest_online().is_none());
        // Spread online requests across both ends of the length range so
        // a bucket split cannot hide the oldest one.
        planner.admit(&Request::new(1, RequestClass::Online, 3000, 10, 500), 500);
        planner.admit(&Request::new(2, RequestClass::Online, 20, 10, 100), 500);
        for i in 3..20u64 {
            planner.admit(
                &Request::new(i, RequestClass::Online, 10, 10, 1000 + i),
                1000 + i,
            );
        }
        let _ = planner.plan(2000, 0); // adjust() may split; peek must work
        assert_eq!(planner.oldest_online().unwrap().id, 2);
        // Draining the oldest promotes the next-oldest.
        let r = planner.force_pop(2000).unwrap();
        assert_eq!(r.id, 2);
        assert_eq!(planner.oldest_online().unwrap().id, 1);
    }

    #[test]
    fn bucket_steal_tail_respects_token_cap() {
        let cfg = small_cfg();
        let mut planner = BucketPlanner::new(&cfg);
        for i in 0..10u64 {
            planner.admit(&Request::new(i, RequestClass::Online, 100, 10, i), i);
        }
        // Footprint 110/request; the half-queue rule alone would give 4.
        let stolen = planner.steal_tail(4, 230, 100);
        assert_eq!(
            stolen.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![8, 9],
            "token cap trims the steal to what the thief can admit"
        );
        assert_eq!(planner.queued(), 8);
        // A cap below a single footprint steals nothing.
        assert!(planner.steal_tail(4, 50, 100).is_empty());
        assert_eq!(planner.queued(), 8);
    }

    #[test]
    fn preemption_disabled_is_inert() {
        // The default config must take zero preemption paths: counters
        // stay at zero, the report flag is off, and the schedule is
        // identical whether the spec's knobs are default or aggressive
        // (the master switch gates everything).
        let mut cfg = small_cfg();
        let trace = Trace::mixed_classes(
            Dataset::Alpaca, 30, 8.0, Dataset::LongBench, 20,
            cfg.model.max_seq, 41,
        );
        let off = run_bucketserve(&cfg, &trace);
        assert!(!off.preempt_enabled);
        assert_eq!(off.prefill_aborts, 0);
        assert_eq!(off.decode_evictions, 0);
        assert_eq!(off.wasted_prefill_us, 0);
        assert_eq!(off.evicted_kv_tokens, 0);
        cfg.preempt.urgency_threshold = 0.01;
        cfg.preempt.max_abort_progress = 1.0;
        cfg.preempt.max_evictions = 64;
        let knobs = run_bucketserve(&cfg, &trace);
        assert_eq!(off.makespan_us, knobs.makespan_us);
        assert_eq!(off.prefill_batches, knobs.prefill_batches);
        assert_eq!(off.decode_iters, knobs.decode_iters);
        assert_eq!(knobs.prefill_aborts, 0);
    }

    #[test]
    fn admission_disabled_is_inert() {
        // The default config must take zero TBT-admission paths: counters
        // stay at zero, the report flag is off, and the schedule is
        // identical whether the spec's knobs are default or aggressive
        // (the master switch gates everything). Gap accounting itself
        // runs either way so disabled baselines stay comparable.
        let mut cfg = small_cfg();
        let trace = Trace::mixed_classes(
            Dataset::Alpaca, 30, 8.0, Dataset::LongBench, 20,
            cfg.model.max_seq, 43,
        );
        let off = run_bucketserve(&cfg, &trace);
        assert!(!off.admission_enabled);
        assert_eq!(off.admission_deferrals, 0);
        assert_eq!(off.tbt_evictions, 0);
        assert!(
            !off.tbt_gaps_online_us.is_empty(),
            "gap accounting runs even when admission is disabled"
        );
        cfg.admission.slack_margin = 0.9;
        cfg.admission.offline_tbt_factor = 1.0;
        cfg.admission.max_evictions = 64;
        let knobs = run_bucketserve(&cfg, &trace);
        assert_eq!(off.makespan_us, knobs.makespan_us);
        assert_eq!(off.prefill_batches, knobs.prefill_batches);
        assert_eq!(off.decode_iters, knobs.decode_iters);
        assert_eq!(off.tbt_gaps_online_us, knobs.tbt_gaps_online_us);
        assert_eq!(knobs.admission_deferrals, 0);
        assert_eq!(knobs.tbt_evictions, 0);
    }

    #[test]
    fn prop_planner_contract_all_families() {
        // The full PrefillPlanner contract, pinned once across all three
        // families (bucket / fcfs / lookahead) instead of per-family:
        // under any interleaving of admits, drains, force-pops,
        // steal-then-absorb round trips, and clone_box replacements,
        //   * the cached min-arrival online peek (the ROADMAP's
        //     O(queued)-scan fix) agrees with a full scan of the queue,
        //   * queued() matches the live request count,
        //   * queued_tokens() matches the recomputed footprint sum,
        //   * and every admitted request is drained exactly once (token
        //     conservation) by a final far-future drain — far-future so
        //     the lookahead family's hold gate has no slack left and
        //     must commit.
        use crate::baselines::distserve::FcfsPlanner;
        use crate::coordinator::lookahead::LookaheadPlanner;
        prop::check("planner contract holds for all families", 50, |g| {
            let mut cfg = SystemConfig::default();
            cfg.priority.enabled = g.bool();
            let mut planner: Box<dyn PrefillPlanner> = match g.usize(0, 2) {
                0 => Box::new(BucketPlanner::new(&cfg)),
                1 => Box::new(FcfsPlanner::new(&cfg)),
                _ => Box::new(LookaheadPlanner::new(&cfg)),
            };
            let mut alive: Vec<QueuedReq> = Vec::new();
            let mut drained: Vec<u64> = Vec::new();
            let mut now: Micros = 0;
            let mut next_id = 0u64;
            let remove_ids =
                |alive: &mut Vec<QueuedReq>, drained: &mut Vec<u64>, ids: &[u64]| {
                    alive.retain(|r| !ids.contains(&r.id));
                    drained.extend_from_slice(ids);
                };
            for _ in 0..g.usize(1, 70) {
                now += g.u64(0, 50_000);
                match g.usize(0, 10) {
                    0..=4 => {
                        let class = if g.bool() {
                            RequestClass::Online
                        } else {
                            RequestClass::Offline
                        };
                        let req = Request::new(
                            next_id,
                            class,
                            g.u64(1, 4000) as u32,
                            g.u64(1, 400) as u32,
                            g.u64(0, now + 1),
                        );
                        planner.admit(&req, now);
                        alive.push(QueuedReq {
                            id: req.id,
                            len: req.input_len,
                            output_len: req.output_len,
                            arrival: req.arrival,
                            class: req.class,
                            tbt_us: 0,
                            prefix: PrefixStamp::default(),
                        });
                        next_id += 1;
                    }
                    5..=6 => {
                        if let Some(fb) = planner.plan(now, g.u64(0, 20_000)) {
                            let ids: Vec<u64> =
                                fb.reqs.iter().map(|r| r.id).collect();
                            remove_ids(&mut alive, &mut drained, &ids);
                        }
                    }
                    7 => {
                        if let Some(r) = planner.force_pop(now) {
                            remove_ids(&mut alive, &mut drained, &[r.id]);
                        }
                    }
                    8 => {
                        // The executor snapshots planners with clone_box;
                        // a replacement must carry the whole contract
                        // (queue, caches, cost state) with it.
                        planner = planner.clone_box();
                    }
                    _ => {
                        // Steal then absorb right back: net queue content
                        // unchanged, but both cache paths (removal
                        // invalidation, insert maintenance) exercised.
                        let stolen = planner.steal_tail(
                            g.usize(0, 8),
                            g.u64(0, 20_000),
                            now,
                        );
                        planner.absorb(stolen, now);
                    }
                }
                assert_eq!(
                    planner.oldest_online(),
                    oldest_online_in(alive.iter()),
                    "cached peek diverged from full scan"
                );
                assert_eq!(planner.queued(), alive.len(), "queued() drifted");
                assert_eq!(
                    planner.queued_tokens(),
                    alive.iter().map(QueuedReq::footprint).sum::<u64>(),
                    "queued_tokens() diverged from recomputed sum"
                );
            }
            // Conservation: drain the remainder well past every deadline
            // and aging horizon, then account for every admitted id.
            now += 30_000_000;
            while let Some(fb) = planner.plan(now, u64::MAX / 4) {
                let ids: Vec<u64> = fb.reqs.iter().map(|r| r.id).collect();
                remove_ids(&mut alive, &mut drained, &ids);
                now += 1;
            }
            while let Some(r) = planner.force_pop(now) {
                remove_ids(&mut alive, &mut drained, &[r.id]);
            }
            assert_eq!(planner.queued(), 0);
            assert_eq!(planner.queued_tokens(), 0);
            assert!(alive.is_empty());
            drained.sort();
            assert_eq!(
                drained,
                (0..next_id).collect::<Vec<_>>(),
                "requests lost or duplicated"
            );
        });
    }

    #[test]
    fn prop_plan_commit_speculate_matches_inline() {
        // The plan/commit protocol's core equivalence, for all three
        // planner families: running `plan` on a worker-thread *snapshot*
        // and committing the result (installing the speculated state) is
        // indistinguishable from planning inline on the live planner —
        // whatever traffic preceded the plan and however many rival
        // speculations from the same snapshot state were produced and
        // discarded in between (speculation is pure, so discards leave
        // zero trace and any rival commits identically). For lookahead
        // this also covers held plans: a hold (`plan` → None) must hold
        // identically on the snapshot and inline paths.
        use crate::baselines::distserve::FcfsPlanner;
        use crate::coordinator::lookahead::LookaheadPlanner;
        prop::check("speculate-over-snapshot ≡ inline planning", 40, |g| {
            let mut cfg = SystemConfig::default();
            cfg.priority.enabled = g.bool();
            let family = g.usize(0, 2);
            let mk = |cfg: &SystemConfig| -> Box<dyn PrefillPlanner> {
                match family {
                    0 => Box::new(BucketPlanner::new(cfg)),
                    1 => Box::new(FcfsPlanner::new(cfg)),
                    _ => Box::new(LookaheadPlanner::new(cfg)),
                }
            };
            // `live` runs the sequential (inline) consume path; `spec`
            // the speculative one with random rival/discard
            // interleavings. Identical traffic feeds both.
            let mut live = mk(&cfg);
            let mut spec = mk(&cfg);
            let mut now: Micros = 0;
            let mut next_id = 0u64;
            for _ in 0..g.usize(1, 30) {
                now += g.u64(0, 50_000);
                for _ in 0..g.usize(0, 4) {
                    let class = if g.bool() {
                        RequestClass::Online
                    } else {
                        RequestClass::Offline
                    };
                    let req = Request::new(
                        next_id,
                        class,
                        g.u64(1, 4000) as u32,
                        g.u64(1, 400) as u32,
                        g.u64(0, now + 1),
                    );
                    live.admit(&req, now);
                    spec.admit(&req, now);
                    next_id += 1;
                }
                let headroom = g.u64(0, 30_000);
                // Inline pipeline (what consume_plan does sequentially).
                let pa = executor::speculate_plan(PlanJob {
                    key: SyncKey { at: now, event: 0, shard: 0 },
                    now,
                    headroom,
                    snapshot: live.clone_box(),
                });
                live = pa.speculated;
                // Speculative pipeline: several rival proposals off the
                // same snapshot state, commit a random one, drop the
                // rest on the floor.
                let n_props = g.usize(1, 3);
                let mut props: Vec<PlanProposal> = (0..n_props)
                    .map(|i| {
                        executor::speculate_plan(PlanJob {
                            key: SyncKey {
                                at: now,
                                event: i as u64,
                                shard: 0,
                            },
                            now,
                            headroom,
                            snapshot: spec.clone_box(),
                        })
                    })
                    .collect();
                let pb = props.swap_remove(g.usize(0, n_props - 1));
                assert!(executor::proposal_valid(&pb, now, headroom));
                assert!(!executor::proposal_valid(&pb, now, headroom + 1));
                spec = pb.speculated;
                match (&pa.formed, &pb.formed) {
                    (Some(fa), Some(fb)) => {
                        assert_eq!(
                            fa.signature(),
                            fb.signature(),
                            "speculated batch diverged from inline"
                        );
                    }
                    (None, None) => {}
                    _ => panic!("one pipeline formed a batch, the other not"),
                }
                assert_eq!(live.queued(), spec.queued());
                assert_eq!(live.queued_tokens(), spec.queued_tokens());
                assert_eq!(live.oldest_online(), spec.oldest_online());
            }
        });
    }

    #[test]
    fn plan_commit_stale_proposal_replans_not_dispatches() {
        // A proposal speculated against headroom an earlier commit then
        // consumed must FAIL commit-time validation and be replaced by
        // an inline re-plan against the real headroom — never dispatch
        // the stale (over-sized) batch, and never lose a request.
        let cfg = SystemConfig::default();
        let mut live: Box<dyn PrefillPlanner> =
            Box::new(BucketPlanner::new(&cfg));
        for i in 0..2u64 {
            // Footprint 110 each (len 100 + output 10).
            live.admit(&Request::new(i, RequestClass::Online, 100, 10, i), i);
        }
        let now: Micros = 1_000;
        // Speculate against generous headroom: both requests fit.
        let p = executor::speculate_plan(PlanJob {
            key: SyncKey { at: now, event: 0, shard: 0 },
            now,
            headroom: 10_000,
            snapshot: live.clone_box(),
        });
        assert_eq!(p.formed.as_ref().unwrap().reqs.len(), 2);
        // By commit time, an earlier commit shrank the target to one
        // request's worth of headroom. Validation rejects the proposal…
        let headroom_now = 115;
        assert!(!executor::proposal_valid(&p, now, headroom_now));
        drop(p); // …the speculated clone drops with zero trace…
        // …and the shard re-plans inline against the real headroom:
        // consume_plan's invalidation path, verbatim.
        let rp = executor::speculate_plan(PlanJob {
            key: SyncKey { at: now, event: 0, shard: 0 },
            now,
            headroom: headroom_now,
            snapshot: live.clone_box(),
        });
        live = rp.speculated;
        let f = rp.formed.expect("one request fits the shrunk headroom");
        assert_eq!(f.reqs.len(), 1, "stale two-request batch must not ship");
        // Conservation: dispatched + still-queued covers both requests.
        assert_eq!(live.queued(), 1);
        let mut ids: Vec<u64> = f.reqs.iter().map(|r| r.id).collect();
        ids.push(live.oldest_online().expect("survivor still queued").id);
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1]);
    }

    #[test]
    fn preemption_rescues_urgent_online_under_offline_overload() {
        // The subsystem's acceptance scenario: a large offline LongBench
        // backlog at t=0 holds both the single prefill instance (batches
        // run for seconds) and the decode KV; an online Alpaca stream
        // arrives on top. Priority-only scheduling reorders the queue but
        // cannot touch dispatched work, so online requests still stall
        // behind multi-second offline waves. Timing: KV-bound LongBench
        // waves run ~3 s, so with a 2 s TTFT budget and a 0.6 trigger the
        // escalation fires 1.2 s after arrival — inside the abortable
        // half of a wave (max_abort_progress 0.5) for requests landing
        // early in it, and with ~0.8 s of budget left to re-prefill,
        // which is what converts aborts into met deadlines.
        let mut cfg = small_cfg();
        cfg.slo.ttft_us = 2_000_000;
        cfg.preempt.urgency_threshold = 0.6;
        let trace = Trace::mixed_classes(
            Dataset::Alpaca, 40, 4.0, Dataset::LongBench, 40,
            cfg.model.max_seq, 51,
        );
        let base = run_bucketserve(&cfg, &trace);
        cfg.preempt.enabled = true;
        let pre = run_bucketserve(&cfg, &trace);

        // Conservation first: preemption must never lose or duplicate a
        // request, aborted/evicted ones included.
        assert_eq!(base.completions.len(), trace.len());
        assert_eq!(pre.completions.len(), trace.len());
        assert!(pre.error.is_none(), "{:?}", pre.error);
        let mut ids: Vec<_> = pre.completions.iter().map(|c| c.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), trace.len(), "exactly-once completion");

        // The scenario must actually exercise the subsystem...
        assert!(
            pre.prefill_aborts + pre.decode_evictions > 0,
            "overload this deliberate must trigger preemption"
        );
        // ...whose whole point is the online class: mean TTFT must drop
        // against the priority-only baseline, and attainment not regress.
        let tb = base.mean_ttft_class_us(RequestClass::Online);
        let tp = pre.mean_ttft_class_us(RequestClass::Online);
        assert!(
            tp < tb,
            "preemption online mean TTFT {tp}µs not better than {tb}µs"
        );
        let ab = base.slo_attainment_class(
            RequestClass::Online, cfg.slo.ttft_us, cfg.slo.tbt_us,
        );
        let ap = pre.slo_attainment_class(
            RequestClass::Online, cfg.slo.ttft_us, cfg.slo.tbt_us,
        );
        assert!(ap >= ab, "online attainment regressed: {ap} < {ab}");
        // Waste accounting is only ever nonzero alongside its trigger.
        if pre.prefill_aborts == 0 {
            assert_eq!(pre.wasted_prefill_us, 0);
            assert_eq!(pre.wasted_prefill_tokens, 0);
        }
        assert_eq!(pre.evicted_kv_tokens > 0, pre.decode_evictions > 0);
        assert_eq!(pre.recompute_tokens > 0, pre.decode_evictions > 0);
    }

    #[test]
    fn prefix_disabled_is_inert_and_enabled_cuts_prefill_cost() {
        // Off by default: zero counters, flag off, and aggressive knobs
        // behind the master switch change nothing. Armed on a multi-turn
        // trace: later turns hit the cache, prefill prices only uncached
        // suffixes, and the run still conserves every request.
        let mut cfg = small_cfg();
        let trace = Trace::multi_turn(
            Dataset::Alpaca, 6, 5, 4.0, cfg.model.max_seq, 61,
        );
        let off = run_bucketserve(&cfg, &trace);
        assert!(!off.prefix_enabled);
        assert_eq!(off.prefix_hits, 0);
        assert_eq!(off.prefix_hit_tokens, 0);
        assert_eq!(off.prefix_resident_tokens, 0);
        cfg.prefix.block = 16;
        cfg.prefix.cache_frac = 0.9;
        let knobs = run_bucketserve(&cfg, &trace);
        assert_eq!(off.makespan_us, knobs.makespan_us);
        assert_eq!(off.prefill_busy_us, knobs.prefill_busy_us);
        assert_eq!(off.decode_iters, knobs.decode_iters);
        assert_eq!(knobs.prefix_hits, 0);

        cfg.prefix.enabled = true;
        let on = run_bucketserve(&cfg, &trace);
        assert_eq!(on.completions.len(), trace.len());
        assert!(on.error.is_none(), "{:?}", on.error);
        let mut ids: Vec<_> = on.completions.iter().map(|c| c.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), trace.len(), "exactly-once completion");
        assert!(on.prefix_enabled);
        assert!(
            on.prefix_hits > 0 && on.prefix_hit_tokens > 0,
            "session turns share prefixes; the cache must hit: {:?}",
            (on.prefix_hits, on.prefix_misses)
        );
        assert!(
            on.prefill_busy_us < off.prefill_busy_us,
            "suffix-only prefill {} must undercut full prefill {}",
            on.prefill_busy_us,
            off.prefill_busy_us
        );
    }

    #[test]
    fn chunk_disabled_is_inert_and_enabled_bounds_slice_length() {
        // Off by default: zero counters, flag off, and aggressive knobs
        // behind the master switch change nothing. Armed: every request
        // still completes exactly once, long prompts actually slice,
        // and no executed slice ever exceeds the configured token
        // budget.
        let mut cfg = small_cfg();
        let trace = Trace::mixed_classes(
            Dataset::Alpaca, 30, 8.0, Dataset::LongBench, 20,
            cfg.model.max_seq, 45,
        );
        let off = run_bucketserve(&cfg, &trace);
        assert!(!off.chunk_enabled);
        assert_eq!(off.chunk_sliced_batches, 0);
        assert_eq!(off.chunk_slices, 0);
        assert_eq!(off.chunk_yields, 0);
        assert_eq!(off.chunk_hybrid_iters, 0);
        assert_eq!(off.chunk_max_slice_tokens, 0);
        cfg.chunk.slice_tokens = 64;
        cfg.chunk.hybrid = false;
        cfg.chunk.interleave = false;
        let knobs = run_bucketserve(&cfg, &trace);
        assert_eq!(off.makespan_us, knobs.makespan_us);
        assert_eq!(off.prefill_batches, knobs.prefill_batches);
        assert_eq!(off.decode_iters, knobs.decode_iters);
        assert_eq!(off.prefill_busy_us, knobs.prefill_busy_us);
        assert_eq!(knobs.chunk_slices, 0);

        cfg.chunk = ChunkSpec { enabled: true, ..ChunkSpec::default() };
        cfg.chunk.slice_tokens = 512;
        let on = run_bucketserve(&cfg, &trace);
        assert_eq!(on.completions.len(), trace.len());
        assert!(on.error.is_none(), "{:?}", on.error);
        let mut ids: Vec<_> = on.completions.iter().map(|c| c.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), trace.len(), "exactly-once completion");
        assert!(on.chunk_enabled);
        assert!(
            on.chunk_sliced_batches > 0,
            "LongBench prompts must span multiple 512-token slices"
        );
        // A sliced batch has ≥ 2 slices by definition.
        assert!(on.chunk_slices >= 2 * on.chunk_sliced_batches);
        assert!(
            on.chunk_max_slice_tokens <= 512,
            "slice bound violated: {} > 512 tokens",
            on.chunk_max_slice_tokens
        );
    }

    #[test]
    fn chunking_protects_ttft_without_abort_waste() {
        // The subsystem's acceptance scenario, sharing the preemption
        // test's overload (same trace, seed, and TTFT budget): a
        // LongBench offline backlog holds the single prefill instance
        // for seconds while an online Alpaca stream arrives on top.
        // Preemption rescues online TTFT by aborting offline waves —
        // paying their burned FLOPs as waste. Chunking slices the waves
        // instead: online work interleaves at slice boundaries, so the
        // same protection costs zero discarded prefill work.
        let mut cfg = small_cfg();
        cfg.slo.ttft_us = 2_000_000;
        let trace = Trace::mixed_classes(
            Dataset::Alpaca, 40, 4.0, Dataset::LongBench, 40,
            cfg.model.max_seq, 51,
        );
        let base = run_bucketserve(&cfg, &trace);
        cfg.preempt.enabled = true;
        cfg.preempt.urgency_threshold = 0.6;
        let pre = run_bucketserve(&cfg, &trace);
        cfg.preempt.enabled = false;
        cfg.chunk.enabled = true;
        cfg.chunk.slice_tokens = 512;
        let chunk = run_bucketserve(&cfg, &trace);

        // Conservation in all three schedules, aborted/parked work
        // included.
        for r in [&base, &pre, &chunk] {
            assert_eq!(r.completions.len(), trace.len());
            assert!(r.error.is_none(), "{:?}", r.error);
            let mut ids: Vec<_> = r.completions.iter().map(|c| c.id).collect();
            ids.sort();
            ids.dedup();
            assert_eq!(ids.len(), trace.len(), "exactly-once completion");
        }
        // The scenario must actually exercise both mechanisms.
        assert!(chunk.chunk_sliced_batches > 0, "waves must slice");
        assert!(
            chunk.chunk_yields > 0,
            "online arrivals must interleave at slice boundaries"
        );
        assert!(
            pre.prefill_aborts + pre.decode_evictions > 0,
            "the preemption arm must fire under this overload"
        );

        let attain = |r: &RunReport| {
            r.slo_attainment_class(
                RequestClass::Online, cfg.slo.ttft_us, cfg.slo.tbt_us,
            )
        };
        // Chunking must protect online TTFT at least as well as
        // abort-and-requeue…
        let (ac, ap, ab) = (attain(&chunk), attain(&pre), attain(&base));
        assert!(
            ac >= ap,
            "chunk online attainment {ac} < preemption's {ap}"
        );
        assert!(
            ac > ab,
            "chunking must strictly rescue attainment: {ac} vs base {ab}"
        );
        let tb = base.mean_ttft_class_us(RequestClass::Online);
        let tc = chunk.mean_ttft_class_us(RequestClass::Online);
        assert!(
            tc < tb,
            "chunk mean online TTFT {tc}µs not better than base {tb}µs"
        );
        // …at zero wasted prefill work, where preemption pays real
        // waste for the same protection.
        assert_eq!(chunk.prefill_aborts, 0);
        assert_eq!(chunk.wasted_prefill_us, 0);
        assert_eq!(chunk.wasted_prefill_tokens, 0);
        assert!(
            pre.wasted_prefill_tokens + pre.recompute_tokens > 0,
            "preemption's protection is paid in discarded or replayed \
             FLOPs here (aborts={}, evictions={})",
            pre.prefill_aborts,
            pre.decode_evictions
        );
    }

    #[test]
    fn prop_planner_never_drops_requests() {
        // Conservation through the full planner path: everything admitted
        // is eventually drained exactly once by plan()/force_pop(), and
        // the bucket partition invariant holds throughout.
        prop::check("planner conserves requests", 60, |g| {
            let mut cfg = SystemConfig::default();
            cfg.priority.enabled = g.bool();
            cfg.scheduler.policy =
                *g.pick(&[Policy::Fcfs, Policy::Sjf, Policy::Ljf]);
            let mut planner = BucketPlanner::new(&cfg);
            let n_ops = g.usize(1, 80);
            let mut admitted = 0u64;
            let mut drained: Vec<u64> = Vec::new();
            let mut now: Micros = 0;
            for _ in 0..n_ops {
                now += g.u64(0, 50_000);
                if g.chance(0.7) {
                    let class = if g.bool() {
                        RequestClass::Online
                    } else {
                        RequestClass::Offline
                    };
                    let req = Request::new(
                        admitted,
                        class,
                        g.u64(1, 4000) as u32,
                        g.u64(1, 400) as u32,
                        now,
                    );
                    planner.admit(&req, now);
                    admitted += 1;
                } else if let Some(fb) = planner.plan(now, g.u64(0, 20_000)) {
                    drained.extend(fb.reqs.iter().map(|r| r.id));
                }
                planner.manager().check_invariants().unwrap();
            }
            while let Some(fb) = planner.plan(now, u64::MAX / 4) {
                drained.extend(fb.reqs.iter().map(|r| r.id));
                now += 1;
            }
            while let Some(r) = planner.force_pop(now) {
                drained.push(r.id);
            }
            assert_eq!(planner.queued(), 0);
            drained.sort();
            assert_eq!(drained, (0..admitted).collect::<Vec<_>>());
            planner.manager().check_invariants().unwrap();
        });
    }

    #[test]
    fn per_class_attainment_splits_by_class() {
        let report = RunReport {
            completions: vec![
                Completion {
                    id: 0,
                    class: RequestClass::Online,
                    input_len: 10,
                    output_len: 5,
                    arrival: 0,
                    first_token: 100,     // meets any sane TTFT
                    finished: 500,
                    padded_len: 10,
                },
                Completion {
                    id: 1,
                    class: RequestClass::Offline,
                    input_len: 10,
                    output_len: 5,
                    arrival: 0,
                    first_token: 10_000_000, // blows TTFT
                    finished: 10_000_400,
                    padded_len: 10,
                },
            ],
            ..Default::default()
        };
        let (ttft, tbt) = (400_000, 100_000);
        assert_eq!(
            report.slo_attainment_class(RequestClass::Online, ttft, tbt),
            1.0
        );
        assert_eq!(
            report.slo_attainment_class(RequestClass::Offline, ttft, tbt),
            0.0
        );
        assert_eq!(report.n_class(RequestClass::Online), 1);
        assert_eq!(report.n_class(RequestClass::Offline), 1);
        // Overall attainment is the blend.
        assert!((report.slo_attainment(ttft, tbt) - 0.5).abs() < 1e-12);
        // Absent class defaults to perfect attainment.
        let empty = RunReport::default();
        assert_eq!(
            empty.slo_attainment_class(RequestClass::Online, ttft, tbt),
            1.0
        );
        assert_eq!(empty.mean_ttft_class_us(RequestClass::Online), 0.0);
    }

    // -- realtime drive mode ------------------------------------------------

    use crate::cluster::realtime::RealtimeEngine;
    use crate::coordinator::live::{StreamMsg, StreamSink};

    fn realtime_cfg() -> SystemConfig {
        let mut cfg = small_cfg();
        // Heavy compression: ~tens-of-ms simulated steps run as ~µs
        // sleeps, so these tests finish in milliseconds of wall time.
        cfg.realtime.pace = 50_000.0;
        cfg
    }

    #[test]
    fn realtime_drive_streams_tokens_and_answers_introspection() {
        let cfg = realtime_cfg();
        let mut engine = RealtimeEngine::new(&cfg);
        let mut sched =
            PdScheduler::new(&cfg, || Box::new(BucketPlanner::new(&cfg)));
        let (tx, rx) = std::sync::mpsc::channel();
        let report = std::thread::scope(|s| {
            let serving = s.spawn(|| sched.run_realtime(&mut engine, rx));
            let sink = StreamSink::new(64);
            tx.send(LiveCmd::Submit {
                req: Request::new(0, RequestClass::Online, 64, 6, 0),
                sink: sink.clone(),
            })
            .unwrap();
            let mut tokens: Vec<(u32, Micros)> = Vec::new();
            let mut done = None;
            for _ in 0..10_000 {
                match sink.recv_timeout(Duration::from_millis(20)) {
                    Some(StreamMsg::Token { id, seq, at_us }) => {
                        assert_eq!(id, 0);
                        tokens.push((seq, at_us));
                    }
                    Some(StreamMsg::Done { completion }) => {
                        done = Some(completion);
                        break;
                    }
                    Some(StreamMsg::Aborted { id }) => {
                        panic!("unexpected abort of {id}")
                    }
                    None => {}
                }
            }
            let done = done.expect("request should stream to completion");
            assert_eq!(done.id, 0);
            assert_eq!(done.output_len, 6);
            assert!(
                !tokens.is_empty(),
                "at least the first token must stream before the summary"
            );
            for w in tokens.windows(2) {
                assert!(w[1].0 > w[0].0, "token ordinals strictly increase");
                assert!(w[1].1 >= w[0].1, "token timestamps are monotone");
            }
            let (htx, hrx) = std::sync::mpsc::channel();
            tx.send(LiveCmd::Health { reply: htx }).unwrap();
            let health = hrx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(health.completions, 1);
            assert_eq!(health.client_aborts, 0);
            assert_eq!(health.in_flight, 0);
            let (ltx, lrx) = std::sync::mpsc::channel();
            tx.send(LiveCmd::Loads { reply: ltx }).unwrap();
            let loads = lrx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(loads.instances.len(), 1);
            assert_eq!(loads.view.shards.len(), 1);
            tx.send(LiveCmd::Shutdown).unwrap();
            serving.join().unwrap()
        });
        assert!(report.realtime_enabled);
        assert_eq!(report.completions.len(), 1);
        assert_eq!(report.client_aborts, 0);
        assert!(report.error.is_none(), "{:?}", report.error);
        // The streamed timeline is causal on the wall clock.
        let c = &report.completions[0];
        assert!(c.first_token >= c.arrival && c.finished >= c.first_token);
    }

    #[test]
    fn realtime_client_abort_releases_every_reservation() {
        let cfg = realtime_cfg();
        let mut engine = RealtimeEngine::new(&cfg);
        let mut sched =
            PdScheduler::new(&cfg, || Box::new(BucketPlanner::new(&cfg)));
        let (tx, rx) = std::sync::mpsc::channel();
        let report = std::thread::scope(|s| {
            let serving = s.spawn(|| sched.run_realtime(&mut engine, rx));
            let sink = StreamSink::new(8);
            // Generation long enough that the abort lands mid-decode.
            tx.send(LiveCmd::Submit {
                req: Request::new(9, RequestClass::Online, 64, 512, 0),
                sink: sink.clone(),
            })
            .unwrap();
            let mut saw_token = false;
            for _ in 0..10_000 {
                if let Some(StreamMsg::Token { .. }) =
                    sink.recv_timeout(Duration::from_millis(20))
                {
                    saw_token = true;
                    break;
                }
            }
            assert!(saw_token, "request must be live before the disconnect");
            sink.mark_disconnected();
            tx.send(LiveCmd::Abort(9)).unwrap();
            // Conservation: poll `loads` until the abort has released
            // every reservation (bounded; each poll also pumps the loop).
            let mut clean = false;
            for _ in 0..10_000 {
                let (ltx, lrx) = std::sync::mpsc::channel();
                tx.send(LiveCmd::Loads { reply: ltx }).unwrap();
                let l = lrx.recv_timeout(Duration::from_secs(5)).unwrap();
                if l.view.kv_tokens_in_use == 0
                    && l.instances.iter().all(|i| {
                        i.active == 0 && i.pending == 0 && i.reserved_tokens == 0
                    })
                {
                    clean = true;
                    break;
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            assert!(clean, "client abort must release every KV reservation");
            // The final aborted line is still delivered (disconnect sheds
            // token lines, never the summary).
            let mut got_abort = false;
            for _ in 0..1_000 {
                match sink.recv_timeout(Duration::from_millis(10)) {
                    Some(StreamMsg::Aborted { id }) => {
                        assert_eq!(id, 9);
                        got_abort = true;
                        break;
                    }
                    Some(_) => {}
                    None if sink.finished() => break,
                    None => {}
                }
            }
            assert!(got_abort, "aborted summary line must be delivered");
            tx.send(LiveCmd::Shutdown).unwrap();
            serving.join().unwrap()
        });
        assert!(report.realtime_enabled);
        assert_eq!(report.client_aborts, 1);
        assert_eq!(report.completions.len(), 0, "the aborted request never completes");
        assert!(report.error.is_none(), "{:?}", report.error);
    }
}
