//! P/D Scheduler: the disaggregated serving loop (paper §III).
//!
//! Drives a fleet of prefill instances (FCFS workers over planner-formed
//! batches), the NVLink KV hand-off, and decode instances running
//! continuous (iteration-level) batching, against any [`Engine`]:
//!
//! ```text
//! arrivals ─▶ placement ─▶ shard planners (buckets / priority / FCFS) ─▶
//!     prefill workers ─▶ NVLink ─▶ decode instances (continuous
//!     batching, one owner shard each) ─▶ completions
//! ```
//!
//! The loop is event-driven: [`PdScheduler::run`] pops typed events off a
//! [`EventQueue`] (arrivals, prefill completions, hand-off landings,
//! decode iteration boundaries), advances the clock, and dispatches to the
//! fleet state machines in [`super::fleet`]. Scheduling state is sharded
//! per decode instance ([`super::shard`]): arrivals route to a shard via
//! the [`super::balance`] placement policy, each shard plans against its
//! own decode instances' KV budgets, and work-stealing rebalances queues
//! at decode-iteration boundaries. In virtual time this is a
//! discrete-event simulation ([`crate::cluster::sim::SimEngine`]); the
//! *same* code path runs in wall time for [`crate::runtime::PjrtEngine`]
//! (blocking engine calls; sleeps until arrivals). BucketServe and the
//! DistServe-like baseline differ only in the [`PrefillPlanner`] plugged
//! in; priority-aware SLO scheduling rides inside the bucket planner.

use super::batcher::{DynamicBatcher, FormedBatch, KvMemoryModel};
use super::bucket::{BucketManager, QueuedReq};
use super::events::{Event, EventKind, EventQueue};
use super::fleet::{DecodeFleet, DecodeSeqState, InFlightPrefill, PrefillFleet};
use super::monitor::GlobalMonitor;
use super::priority::PriorityScorer;
use super::shard::ShardSet;
use crate::cluster::{DecodeBatch, DecodeSeq, Engine, PrefillBatch, PrefillItem};
use crate::config::SystemConfig;
use crate::workload::request::Completion;
use crate::workload::{Request, RequestClass, Trace};
use crate::Micros;
use std::time::Instant;

/// Iteration ceiling standing in for the old 50M-spin livelock guard;
/// exceeding it ends the run with [`RunReport::error`] set instead of a
/// panic.
const MAX_SCHED_EVENTS: u64 = 50_000_000;

/// Planner plug-in: how arriving requests queue and batches form.
pub trait PrefillPlanner {
    /// A request arrived at the gateway.
    fn admit(&mut self, req: &Request, now: Micros);

    /// Form the next prefill batch given the target decode instance's KV
    /// headroom (in tokens). Returning None means "wait".
    fn plan(&mut self, now: Micros, headroom_tokens: u64) -> Option<FormedBatch>;

    /// Forced single-request pop to break memory deadlocks (a head request
    /// whose full context alone exceeds the headroom, with nothing else in
    /// flight).
    fn force_pop(&mut self, now: Micros) -> Option<QueuedReq>;

    /// Requests currently queued.
    fn queued(&self) -> usize;

    /// Full-context (prompt + expected generation) token footprint of the
    /// queued requests — what KV-aware placement weighs a shard by.
    fn queued_tokens(&self) -> u64;

    /// Work-stealing donor side: give up to `max_n` queued requests from
    /// the *tail* of the drain order (the least-urgent end of the queue
    /// segment the next `plan` would serve), preserving their relative
    /// order. Implementations must never surrender the head half of that
    /// segment — the donor keeps what it was about to dispatch, so a
    /// steal can move backlog but never the most urgent work.
    fn steal_tail(&mut self, max_n: usize, now: Micros) -> Vec<QueuedReq>;

    /// Work-stealing thief side: absorb requests stolen from another
    /// shard's planner, as if they had been admitted here originally.
    fn absorb(&mut self, reqs: Vec<QueuedReq>, now: Micros);

    /// Cumulative planning overhead (ns) — bucketing cost for Fig. 6.
    fn overhead_ns(&self) -> u64;

    /// Current bucket count (1 for non-bucketing planners).
    fn n_buckets(&self) -> usize {
        1
    }
}

/// BucketServe's planner: Bucketing Manager + Dynamic Batching Controller
/// (+ the priority scorer when `cfg.priority.enabled`).
pub struct BucketPlanner {
    mgr: BucketManager,
    batcher: DynamicBatcher,
    mem: KvMemoryModel,
    max_buckets_seen: usize,
}

impl BucketPlanner {
    pub fn new(cfg: &SystemConfig) -> BucketPlanner {
        let mut batcher = DynamicBatcher::new(cfg.model.clone(), &cfg.scheduler);
        if cfg.priority.enabled {
            batcher = batcher.with_priority(PriorityScorer::new(
                cfg.priority.clone(),
                cfg.slo.clone(),
            ));
        }
        BucketPlanner {
            mgr: BucketManager::new(
                cfg.scheduler.l_max,
                cfg.scheduler.theta,
                cfg.scheduler.min_bucket_width,
            ),
            batcher,
            mem: KvMemoryModel::new(cfg.model.clone(), cfg.scheduler.mem_safety),
            max_buckets_seen: 1,
        }
    }

    pub fn manager(&self) -> &BucketManager {
        &self.mgr
    }

    pub fn max_buckets_seen(&self) -> usize {
        self.max_buckets_seen
    }
}

impl PrefillPlanner for BucketPlanner {
    fn admit(&mut self, req: &Request, _now: Micros) {
        self.mgr.assign(QueuedReq {
            id: req.id,
            len: req.input_len,
            output_len: req.output_len,
            arrival: req.arrival,
            class: req.class,
        });
    }

    fn plan(&mut self, now: Micros, headroom_tokens: u64) -> Option<FormedBatch> {
        // Algorithm 1's AdjustBuckets with N_max from Eq. 6 (estimated via
        // the queue's mean full-context length — the Global Monitor view).
        let queued = self.mgr.total();
        if queued > 0 {
            let mean_len: f64 = self
                .mgr
                .buckets()
                .iter()
                .flat_map(|b| b.requests.iter())
                .map(|r| (r.len + r.output_len) as f64)
                .sum::<f64>()
                / queued as f64;
            let n_max = (headroom_tokens as f64 / mean_len.max(1.0))
                .floor()
                .max(1.0) as usize;
            self.mgr.adjust(n_max);
            self.max_buckets_seen = self.max_buckets_seen.max(self.mgr.n_buckets());
        }
        // The batcher already admits against headroom_tokens (Eq. 6).
        let _ = &self.mem;
        self.batcher.form_batch(&mut self.mgr, now, headroom_tokens)
    }

    fn force_pop(&mut self, now: Micros) -> Option<QueuedReq> {
        // Priority mode: pop the globally highest-ranked request under the
        // scorer's canonical order, through the batcher's own policy gate
        // so the pop can never contradict the configured drain order.
        let pos = self
            .batcher
            .scorer()
            .map(|sc| sc.best_position(self.mgr.buckets(), now));
        if let Some(pos) = pos {
            let (bi, ri) = pos?;
            return Some(self.mgr.buckets_mut()[bi].requests.remove(ri));
        }
        let bucket = self
            .mgr
            .buckets_mut()
            .iter_mut()
            .filter(|b| !b.is_empty())
            .min_by_key(|b| b.earliest_arrival().unwrap_or(Micros::MAX))?;
        let idx = bucket
            .requests
            .iter()
            .enumerate()
            .min_by_key(|(_, r)| r.arrival)
            .map(|(i, _)| i)?;
        Some(bucket.requests.remove(idx))
    }

    fn queued(&self) -> usize {
        self.mgr.total()
    }

    fn queued_tokens(&self) -> u64 {
        self.mgr
            .buckets()
            .iter()
            .flat_map(|b| b.requests.iter())
            .map(|r| (r.len + r.output_len) as u64)
            .sum()
    }

    fn steal_tail(&mut self, max_n: usize, now: Micros) -> Vec<QueuedReq> {
        if max_n == 0 {
            return Vec::new();
        }
        // Same bucket the next drain would serve (highest-urgency bucket
        // under the scorer, policy order otherwise), same drain sort —
        // so the stolen tail is exactly the work the donor would have
        // served last. Capped at half the bucket so the urgent head
        // always stays with the donor (a one-request bucket yields
        // nothing; rebalance just skips the move).
        let Some(idx) = self.batcher.pick_bucket(&self.mgr, now) else {
            return Vec::new();
        };
        let b = &mut self.mgr.buckets_mut()[idx];
        self.batcher.sort_for_drain(b, now);
        let take = max_n.min(b.requests.len() / 2);
        b.requests.split_off(b.requests.len() - take)
    }

    fn absorb(&mut self, reqs: Vec<QueuedReq>, _now: Micros) {
        for r in reqs {
            self.mgr.assign(r);
        }
    }

    fn overhead_ns(&self) -> u64 {
        self.mgr.overhead_ns
    }

    fn n_buckets(&self) -> usize {
        self.mgr.n_buckets()
    }
}

// ---------------------------------------------------------------------------
// Run report
// ---------------------------------------------------------------------------

/// Everything a run produces; the metrics layer derives each figure from it.
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    pub completions: Vec<Completion>,
    pub makespan_us: Micros,
    pub n_prefill: usize,
    pub n_decode: usize,
    pub prefill_busy_us: u64,
    pub decode_busy_us: u64,
    /// Busy time weighted by useful-work fraction (padding-aware).
    pub prefill_useful_us: f64,
    /// Busy time weighted by the bandwidth-amortization factor.
    pub decode_useful_us: f64,
    pub bucket_overhead_ns: u64,
    pub max_buckets: usize,
    pub peak_batch: usize,
    pub prefill_batches: u64,
    pub decode_iters: u64,
    /// Σ per-request prefill execution time (batch duration × members).
    pub prefill_exec_request_us: u64,
    /// Σ per-request queueing delay before prefill dispatch.
    pub queue_wait_us: u64,
    /// Scheduler shards the run used (1 = the unsharded global queue).
    pub n_shards: usize,
    /// Requests migrated between shards by work-stealing.
    pub steals: u64,
    /// Per-shard arrivals routed by the placement policy.
    pub shard_routed: Vec<u64>,
    /// Per-shard prefill batches dispatched.
    pub shard_batches: Vec<u64>,
    /// Set when the run ended abnormally (scheduler stall / livelock
    /// guard); carries the diagnostics the old panic printed. Completions
    /// gathered before the stall are still reported.
    pub error: Option<String>,
}

impl RunReport {
    /// Offline throughput: total (prompt + generated) tokens per second.
    pub fn throughput_tps(&self) -> f64 {
        let tokens: u64 = self
            .completions
            .iter()
            .map(|c| (c.input_len + c.output_len) as u64)
            .sum();
        tokens as f64 / (self.makespan_us as f64 / 1e6).max(1e-9)
    }

    /// Generated tokens per second.
    pub fn output_tps(&self) -> f64 {
        let tokens: u64 =
            self.completions.iter().map(|c| c.output_len as u64).sum();
        tokens as f64 / (self.makespan_us as f64 / 1e6).max(1e-9)
    }

    /// Completed requests per second ("server RPS" in Fig. 5).
    pub fn server_rps(&self) -> f64 {
        self.completions.len() as f64 / (self.makespan_us as f64 / 1e6).max(1e-9)
    }

    /// SLO attainment: fraction of completions meeting both TTFT and TBT.
    pub fn slo_attainment(&self, ttft_us: u64, tbt_us: u64) -> f64 {
        if self.completions.is_empty() {
            return 1.0;
        }
        let ok = self
            .completions
            .iter()
            .filter(|c| c.ttft() <= ttft_us && c.tbt() <= tbt_us as f64)
            .count();
        ok as f64 / self.completions.len() as f64
    }

    /// Completions of one request class.
    pub fn n_class(&self, class: RequestClass) -> usize {
        self.completions.iter().filter(|c| c.class == class).count()
    }

    /// Per-class SLO attainment (1.0 when the class is absent) — the
    /// priority subsystem's target metric.
    pub fn slo_attainment_class(
        &self,
        class: RequestClass,
        ttft_us: u64,
        tbt_us: u64,
    ) -> f64 {
        let mut n = 0usize;
        let mut ok = 0usize;
        for c in self.completions.iter().filter(|c| c.class == class) {
            n += 1;
            if c.ttft() <= ttft_us && c.tbt() <= tbt_us as f64 {
                ok += 1;
            }
        }
        if n == 0 {
            1.0
        } else {
            ok as f64 / n as f64
        }
    }

    /// Per-class mean TTFT (µs); 0 when the class is absent.
    pub fn mean_ttft_class_us(&self, class: RequestClass) -> f64 {
        let mut n = 0usize;
        let mut sum = 0.0;
        for c in self.completions.iter().filter(|c| c.class == class) {
            n += 1;
            sum += c.ttft() as f64;
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    /// Mean padding-aware GPU utilization across the fleet (Fig. 3b / 5b).
    pub fn gpu_util(&self) -> f64 {
        let cap = (self.n_prefill + self.n_decode) as f64
            * self.makespan_us as f64;
        if cap <= 0.0 {
            return 0.0;
        }
        (self.prefill_useful_us + self.decode_useful_us) / cap
    }

    /// Mean end-to-end latency (µs).
    pub fn mean_e2e_us(&self) -> f64 {
        if self.completions.is_empty() {
            return 0.0;
        }
        self.completions.iter().map(|c| c.e2e() as f64).sum::<f64>()
            / self.completions.len() as f64
    }

    /// Mean TTFT (µs).
    pub fn mean_ttft_us(&self) -> f64 {
        if self.completions.is_empty() {
            return 0.0;
        }
        self.completions.iter().map(|c| c.ttft() as f64).sum::<f64>()
            / self.completions.len() as f64
    }

    /// Fig. 6a phase breakdown, all in µs per request:
    /// (queue wait, prefill exec, decode exec, bucketing overhead).
    pub fn breakdown_us(&self) -> (f64, f64, f64, f64) {
        let n = self.completions.len().max(1) as f64;
        let decode: f64 = self
            .completions
            .iter()
            .map(|c| c.finished.saturating_sub(c.first_token) as f64)
            .sum::<f64>()
            / n;
        (
            self.queue_wait_us as f64 / n,
            self.prefill_exec_request_us as f64 / n,
            decode,
            self.bucket_overhead_ns as f64 / 1e3 / n,
        )
    }
}

// ---------------------------------------------------------------------------
// The serving loop
// ---------------------------------------------------------------------------

/// The P/D scheduler: a thin orchestrator that pops events and dispatches
/// to the fleet state machines; engine-agnostic. Scheduling state lives
/// in per-decode-instance shards ([`ShardSet`]); the planner `factory` is
/// invoked once per shard so every shard owns independent queue state.
pub struct PdScheduler {
    cfg: SystemConfig,
    shards: ShardSet,
    monitor: GlobalMonitor,
}

impl PdScheduler {
    pub fn new(
        cfg: &SystemConfig,
        factory: impl FnMut() -> Box<dyn PrefillPlanner>,
    ) -> PdScheduler {
        let n_decode = cfg.fleet.n_decode.max(1) as usize;
        PdScheduler {
            cfg: cfg.clone(),
            shards: ShardSet::new(&cfg.sharding, n_decode, factory),
            monitor: GlobalMonitor::new(cfg.scheduler.monitor_window_us, 0),
        }
    }

    /// Serve the whole trace; returns the run report.
    ///
    /// Pure event dispatch: pop the earliest event, advance the clock,
    /// apply its handler plus any events due at the same instant, then run
    /// the state-driven phases (hand-off admission → prefill dispatch →
    /// decode launch). All instance state lives in the fleet modules.
    pub fn run(&mut self, trace: &Trace, engine: &mut dyn Engine) -> RunReport {
        let mem = KvMemoryModel::new(
            self.cfg.model.clone(),
            self.cfg.scheduler.mem_safety,
        );
        let per_decode_budget = mem.token_budget(engine.decode_mem_budget());
        let n_shards = self.shards.n();
        // Each shard monitors KV against the budget of the decode
        // instances it fronts; the aggregate view sums to the fleet total.
        let shard_budgets: Vec<u64> = (0..n_shards)
            .map(|si| {
                per_decode_budget * self.shards.get(si).owned.len() as u64
            })
            .collect();
        self.monitor = GlobalMonitor::sharded(
            self.cfg.scheduler.monitor_window_us,
            &shard_budgets,
        );
        let n_prefill = self.cfg.fleet.n_prefill.max(1) as usize;
        let n_decode = self.cfg.fleet.n_decode.max(1) as usize;
        let weight_bytes = engine.model().weight_bytes() as f64;
        let kv_per_token = engine.model().kv_bytes_per_token() as f64;
        let realtime = engine.realtime();

        let mut core = RunCore {
            shards: &mut self.shards,
            monitor: &mut self.monitor,
            engine,
            events: EventQueue::new(),
            prefill: PrefillFleet::new(n_prefill),
            decode: DecodeFleet::new(n_decode),
            report: RunReport {
                n_prefill,
                n_decode,
                n_shards,
                ..Default::default()
            },
            clock: 0,
            next_arrival: 0,
            total: trace.len(),
            per_decode_budget,
            realtime,
            wall_start: Instant::now(),
            weight_bytes,
            kv_per_token,
        };
        if core.total > 0 {
            core.events.push(trace.requests[0].arrival, EventKind::Arrival);
        }

        let mut processed: u64 = 0;
        while core.report.completions.len() < core.total {
            processed += 1;
            if processed > MAX_SCHED_EVENTS {
                core.fail("livelock guard tripped");
                break;
            }
            let Some(ev) = core.events.pop() else {
                core.fail("no scheduled events but requests incomplete");
                break;
            };
            core.advance_to(ev.at);
            core.handle(ev, trace);
            while let Some(due) = core.events.pop_due(core.clock) {
                core.handle(due, trace);
            }
            core.admit_handoffs();
            core.dispatch_prefill();
            core.launch_decode();
            core.schedule_idle_wakes();
            core.report.makespan_us = core.report.makespan_us.max(core.clock);
        }

        let mut report = core.report;
        for shard in self.shards.iter() {
            report.bucket_overhead_ns += shard.planner.overhead_ns();
            report.max_buckets =
                report.max_buckets.max(shard.planner.n_buckets());
            report.shard_routed.push(shard.stats.routed);
            report.shard_batches.push(shard.stats.batches);
        }
        if let Some(last) = report.completions.iter().map(|c| c.finished).max() {
            report.makespan_us = report.makespan_us.max(last);
        }
        report
    }

    pub fn monitor(&mut self) -> &mut GlobalMonitor {
        &mut self.monitor
    }

    /// The shard layer (inspection/tests).
    pub fn shards(&self) -> &ShardSet {
        &self.shards
    }
}

/// Mutable run state threaded through the event handlers; split out of
/// [`PdScheduler`] so `run` stays a thin pop-and-dispatch loop.
struct RunCore<'a> {
    shards: &'a mut ShardSet,
    monitor: &'a mut GlobalMonitor,
    engine: &'a mut dyn Engine,
    events: EventQueue,
    prefill: PrefillFleet,
    decode: DecodeFleet,
    report: RunReport,
    clock: Micros,
    next_arrival: usize,
    total: usize,
    per_decode_budget: u64,
    realtime: bool,
    wall_start: Instant,
    weight_bytes: f64,
    kv_per_token: f64,
}

impl<'a> RunCore<'a> {
    /// Advance the clock to an event's timestamp; realtime engines sleep
    /// until then on the wall clock (arrivals pace the run).
    fn advance_to(&mut self, at: Micros) {
        if self.realtime {
            let wall = self.wall_start.elapsed().as_micros() as Micros;
            if at > wall {
                std::thread::sleep(std::time::Duration::from_micros(at - wall));
            }
            let now = self.wall_start.elapsed().as_micros() as Micros;
            self.clock = self.clock.max(now);
        } else {
            self.clock = self.clock.max(at);
        }
    }

    fn handle(&mut self, ev: Event, trace: &Trace) {
        match ev.kind {
            EventKind::Arrival => self.on_arrival(trace),
            EventKind::PrefillDone { instance } => self.on_prefill_done(instance),
            EventKind::DecodeIterEnd { decode } => {
                self.on_decode_iter_end(decode);
                // Decode-iteration boundaries are the work-stealing
                // cadence: freed KV is when an idle shard can absorb a
                // loaded shard's backlog. No-op unless sharded + enabled.
                self.rebalance_shards();
            }
            EventKind::HandoffReady { decode } => {
                // Pure wake-up: admission happens in admit_handoffs.
                self.decode.get_mut(decode).wake_at = None;
            }
        }
    }

    /// Admit every trace arrival due by now (each routed to a shard by
    /// the placement policy), then schedule the next one.
    fn on_arrival(&mut self, trace: &Trace) {
        while self.next_arrival < self.total
            && trace.requests[self.next_arrival].arrival <= self.clock
        {
            let r = &trace.requests[self.next_arrival];
            let si = self.shards.route(r.id, &self.decode, self.per_decode_budget);
            self.shards.get_mut(si).planner.admit(r, self.clock);
            self.monitor.on_arrival(si, self.clock, r.input_len);
            self.next_arrival += 1;
        }
        if self.next_arrival < self.total {
            self.events.push(
                trace.requests[self.next_arrival].arrival,
                EventKind::Arrival,
            );
        }
    }

    /// Run a work-stealing pass and mirror any moves into the monitor's
    /// per-shard queue depths and the run report.
    fn rebalance_shards(&mut self) {
        let moves = self.shards.rebalance(
            self.clock,
            &self.decode,
            self.per_decode_budget,
        );
        for (from, to, n) in moves {
            self.monitor.on_steal(from, to, n);
            self.report.steals += n as u64;
        }
    }

    /// Prefill completion → metrics → NVLink hand-off to the target decode
    /// instance's pending set.
    fn on_prefill_done(&mut self, pi: usize) {
        let Some(p) = self.prefill.take_done(pi, self.clock) else {
            return;
        };
        self.report.prefill_batches += 1;
        self.report.peak_batch = self.report.peak_batch.max(p.formed.batch.n());
        self.report.prefill_busy_us += p.duration;
        self.report.prefill_useful_us +=
            p.duration as f64 * p.formed.batch.efficiency();
        self.report.prefill_exec_request_us +=
            p.duration * p.formed.batch.n() as u64;
        self.monitor.on_batch_done(p.duration);
        let transfer = self.engine.kv_transfer(p.formed.batch.useful_tokens());
        let d = self.decode.get_mut(p.target_decode);
        for r in &p.formed.reqs {
            self.report.queue_wait_us += p
                .done_at
                .saturating_sub(p.duration)
                .saturating_sub(r.arrival);
            d.pending.push(DecodeSeqState {
                id: r.id,
                class: r.class,
                arrival: r.arrival,
                input_len: r.len,
                padded_len: p.formed.batch.padded_len,
                output_len: r.output_len,
                generated: 1, // prefill produced the first token
                first_token: p.done_at,
                ready_at: p.done_at + transfer,
            });
        }
        self.monitor.on_decode_enter(p.formed.reqs.len());
    }

    /// Decode iteration boundary: count the generated token, complete
    /// finished sequences, release their KV reservations.
    fn on_decode_iter_end(&mut self, di: usize) {
        let shard = self.shards.owner_of(di);
        let d = self.decode.get_mut(di);
        let ended = matches!(d.iter_end, Some(t) if t <= self.clock);
        if !ended {
            return;
        }
        let iter_end = d.iter_end.take().unwrap();
        let mut still_active = Vec::with_capacity(d.active.len());
        for mut s in d.active.drain(..) {
            s.generated += 1;
            if s.generated >= s.output_len {
                let footprint = (s.input_len + s.output_len) as u64;
                d.reserved_tokens = d.reserved_tokens.saturating_sub(footprint);
                self.monitor.kv_release(shard, footprint);
                self.monitor.on_decode_exit(1);
                self.engine.release(s.id);
                self.report.completions.push(Completion {
                    id: s.id,
                    class: s.class,
                    input_len: s.input_len,
                    output_len: s.output_len,
                    arrival: s.arrival,
                    first_token: s.first_token,
                    finished: iter_end,
                    padded_len: s.padded_len,
                });
            } else {
                still_active.push(s);
            }
        }
        d.active = still_active;
    }

    /// Continuous-batching admission: landed hand-offs join instances at
    /// their iteration boundary.
    fn admit_handoffs(&mut self) {
        let clock = self.clock;
        for d in self.decode.iter_mut() {
            if d.at_boundary() {
                d.admit_due(clock);
            }
        }
    }

    /// Form and dispatch prefill batches onto idle instances. The shard
    /// layer supplies the candidates: shards in descending order of their
    /// best owned decode instance's KV headroom (Eq. 6 admission), each
    /// paired with that target instance. The first shard whose planner
    /// yields a batch wins; with one shard this is exactly the seed's
    /// global max-headroom `best_target` scan.
    fn dispatch_prefill(&mut self) {
        for pi in 0..self.prefill.n() {
            if !self.prefill.is_idle(pi) {
                continue;
            }
            let order = self
                .shards
                .dispatch_order(&self.decode, self.per_decode_budget);
            let mut chosen: Option<(usize, usize, FormedBatch)> = None;
            for &(si, ti, headroom) in &order {
                if let Some(f) =
                    self.shards.get_mut(si).planner.plan(self.clock, headroom)
                {
                    chosen = Some((si, ti, f));
                    break;
                }
            }
            if chosen.is_none() {
                // Deadlock breaker: nothing anywhere in flight and a head
                // request alone exceeds even an idle budget — pop one
                // solo from the first candidate shard with queued work.
                let nothing_in_flight = !self.prefill.any_running()
                    && self.decode.nothing_in_flight();
                if nothing_in_flight && self.shards.queued_total() > 0 {
                    for &(si, ti, _) in &order {
                        let popped =
                            self.shards.get_mut(si).planner.force_pop(self.clock);
                        let Some(r) = popped else { continue };
                        let padded = r.len.max(1);
                        chosen = Some((
                            si,
                            ti,
                            FormedBatch {
                                batch: PrefillBatch {
                                    items: vec![PrefillItem {
                                        id: r.id,
                                        len: r.len,
                                        tokens: vec![],
                                    }],
                                    padded_len: padded,
                                },
                                reqs: vec![r],
                                bucket_up: padded,
                            },
                        ));
                        break;
                    }
                }
            }
            let Some((si, ti, formed)) = chosen else { break };
            let footprint: u64 = formed
                .reqs
                .iter()
                .map(|r| (r.len + r.output_len) as u64)
                .sum();
            self.decode.get_mut(ti).reserved_tokens += footprint;
            self.monitor.kv_reserve(si, footprint);
            self.monitor.on_prefill_dispatch(si, formed.reqs.len());
            self.shards.get_mut(si).stats.batches += 1;
            let duration = self
                .engine
                .prefill(&formed.batch)
                .expect("prefill execution failed");
            // Realtime engines block inside prefill(): completion is
            // "now" on the wall clock. Virtual engines schedule ahead.
            let done_at = if self.realtime {
                self.wall_start.elapsed().as_micros() as Micros
            } else {
                self.clock + duration
            };
            self.prefill.dispatch(
                pi,
                InFlightPrefill { formed, done_at, duration, target_decode: ti },
            );
            self.events.push(done_at, EventKind::PrefillDone { instance: pi });
        }
    }

    /// Launch the next decode iteration on every instance with an active
    /// continuous batch.
    fn launch_decode(&mut self) {
        for di in 0..self.decode.n() {
            let d = self.decode.get_mut(di);
            if !d.at_boundary() || d.active.is_empty() {
                continue;
            }
            let batch = DecodeBatch {
                seqs: d
                    .active
                    .iter()
                    .map(|s| DecodeSeq {
                        id: s.id,
                        ctx_len: s.input_len + s.generated,
                    })
                    .collect(),
            };
            let duration = self
                .engine
                .decode_step(&batch)
                .expect("decode execution failed");
            let end = if self.realtime {
                self.wall_start.elapsed().as_micros() as Micros
            } else {
                self.clock.max(d.free_at) + duration
            };
            let d = self.decode.get_mut(di);
            d.free_at = end;
            d.iter_end = Some(end);
            self.report.decode_iters += 1;
            self.report.decode_busy_us += duration;
            // Bandwidth-amortization efficiency: fraction of streamed
            // bytes that are per-sequence KV rather than the weight
            // read shared by the batch.
            let kv_bytes = batch.total_ctx() as f64 * self.kv_per_token;
            let eff = kv_bytes / (kv_bytes + self.weight_bytes);
            self.report.decode_useful_us += duration as f64 * eff;
            self.events.push(end, EventKind::DecodeIterEnd { decode: di });
        }
    }

    /// Idle instances with only future hand-offs need a wake-up event at
    /// the earliest landing (deduped via `wake_at`), or the queue would
    /// drain with work still pending.
    fn schedule_idle_wakes(&mut self) {
        let clock = self.clock;
        for di in 0..self.decode.n() {
            let d = self.decode.get_mut(di);
            if !d.at_boundary() || !d.active.is_empty() || d.pending.is_empty() {
                continue;
            }
            let earliest = d
                .pending
                .iter()
                .map(|s| s.ready_at)
                .min()
                .unwrap()
                .max(clock);
            if d.wake_at != Some(earliest) {
                d.wake_at = Some(earliest);
                self.events
                    .push(earliest, EventKind::HandoffReady { decode: di });
            }
        }
    }

    /// End the run abnormally: record the diagnostics on the report (the
    /// old livelock panic's payload) and shout on the log so a truncated
    /// run can't masquerade as a clean one.
    fn fail(&mut self, why: &str) {
        let msg = self.diagnostics(why);
        crate::log_warn!("{msg}");
        self.report.error = Some(msg);
    }

    /// Stall diagnostics (the payload of the old livelock panic).
    fn diagnostics(&self, why: &str) -> String {
        format!(
            "scheduler stall ({why}): clock={} done={}/{} queued={} \
             arrivals={} prefill_busy={:?} decode=[{}]",
            self.clock,
            self.report.completions.len(),
            self.total,
            self.shards.queued_total(),
            self.next_arrival,
            self.prefill.running_mask(),
            self.decode
                .iter()
                .map(|d| format!(
                    "(act={} pend={} resv={} iter_end={:?})",
                    d.active.len(),
                    d.pending.len(),
                    d.reserved_tokens,
                    d.iter_end
                ))
                .collect::<Vec<_>>()
                .join(","),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::sim::SimEngine;
    use crate::config::Policy;
    use crate::util::prop;
    use crate::workload::{Dataset, RequestClass};

    fn small_cfg() -> SystemConfig {
        let mut cfg = SystemConfig::default();
        cfg.fleet.n_prefill = 1;
        cfg.fleet.n_decode = 1;
        cfg
    }

    fn run_bucketserve(cfg: &SystemConfig, trace: &Trace) -> RunReport {
        let mut sched = PdScheduler::new(cfg, || Box::new(BucketPlanner::new(cfg)));
        let mut engine = SimEngine::new(cfg);
        sched.run(trace, &mut engine)
    }

    #[test]
    fn completes_every_request() {
        let cfg = small_cfg();
        let trace = Trace::generate(
            Dataset::Alpaca, 50, 4.0, RequestClass::Online, cfg.model.max_seq, 1,
        );
        let report = run_bucketserve(&cfg, &trace);
        assert_eq!(report.completions.len(), 50);
        assert!(report.error.is_none(), "{:?}", report.error);
        let mut ids: Vec<_> = report.completions.iter().map(|c| c.id).collect();
        ids.sort();
        assert_eq!(ids, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn timestamps_are_causal() {
        let cfg = small_cfg();
        let trace = Trace::generate(
            Dataset::Mixed, 40, 8.0, RequestClass::Online, cfg.model.max_seq, 2,
        );
        let report = run_bucketserve(&cfg, &trace);
        for c in &report.completions {
            assert!(c.first_token >= c.arrival, "ttft causal for {}", c.id);
            assert!(c.finished >= c.first_token, "decode causal for {}", c.id);
        }
    }

    #[test]
    fn offline_batch_trace_completes() {
        let cfg = small_cfg();
        let trace =
            Trace::batch(Dataset::Alpaca, 64, RequestClass::Offline, 4096, 3);
        let report = run_bucketserve(&cfg, &trace);
        assert_eq!(report.completions.len(), 64);
        assert!(report.throughput_tps() > 0.0);
        assert!(report.gpu_util() > 0.0 && report.gpu_util() <= 1.0);
    }

    #[test]
    fn multi_instance_fleet_is_faster() {
        let mut cfg = small_cfg();
        let trace =
            Trace::batch(Dataset::Mixed, 96, RequestClass::Offline, 4096, 4);
        let r1 = run_bucketserve(&cfg, &trace);
        cfg.fleet.n_prefill = 2;
        cfg.fleet.n_decode = 2;
        let r2 = run_bucketserve(&cfg, &trace);
        assert!(
            r2.makespan_us < r1.makespan_us,
            "2+2 fleet {} vs 1+1 {}",
            r2.makespan_us,
            r1.makespan_us
        );
    }

    #[test]
    fn oversized_request_does_not_deadlock() {
        let mut cfg = small_cfg();
        // Tiny GPU: budget smaller than one max request.
        cfg.gpu.mem_bytes = 27 * (1u64 << 30); // 26 GB weights + ~1 GB
        let trace =
            Trace::batch(Dataset::LongBench, 3, RequestClass::Offline, 4096, 5);
        let report = run_bucketserve(&cfg, &trace);
        assert_eq!(report.completions.len(), 3);
        assert!(report.error.is_none(), "{:?}", report.error);
    }

    #[test]
    fn decode_dominates_e2e() {
        // Paper Fig. 6a: decode ≈ 90% of execution time.
        let cfg = small_cfg();
        let trace = Trace::generate(
            Dataset::Alpaca, 40, 2.0, RequestClass::Online, cfg.model.max_seq, 6,
        );
        let report = run_bucketserve(&cfg, &trace);
        let (_q, pre, dec, _b) = report.breakdown_us();
        assert!(
            dec > 4.0 * pre,
            "decode {dec} should dominate prefill {pre}"
        );
    }

    #[test]
    fn bucketing_overhead_negligible() {
        // Paper: bucketing + dynamic batching < 1% of execution time.
        let cfg = small_cfg();
        let trace = Trace::generate(
            Dataset::Mixed, 100, 16.0, RequestClass::Online, cfg.model.max_seq, 7,
        );
        let report = run_bucketserve(&cfg, &trace);
        let overhead_us = report.bucket_overhead_ns as f64 / 1e3;
        assert!(
            overhead_us < 0.01 * report.makespan_us as f64,
            "overhead {overhead_us}µs vs makespan {}µs",
            report.makespan_us
        );
    }

    #[test]
    fn kv_reservation_never_exceeds_budget() {
        // Indirect check: a run against a small budget still respects
        // completion integrity and never admits unbounded batches.
        let mut cfg = small_cfg();
        cfg.gpu.mem_bytes = 30 * (1u64 << 30);
        let trace =
            Trace::batch(Dataset::Mixed, 60, RequestClass::Offline, 4096, 8);
        let report = run_bucketserve(&cfg, &trace);
        assert_eq!(report.completions.len(), 60);
        // ~1.8 GB of KV headroom ≈ 2.4k tokens: Eq. 6 keeps batches far
        // below the unconstrained case (which would admit all 60 at once).
        assert!(report.peak_batch <= 32, "peak {}", report.peak_batch);
    }

    #[test]
    fn slo_attainment_degrades_with_load() {
        let cfg = SystemConfig::default();
        let low = Trace::generate(
            Dataset::Alpaca, 150, 2.0, RequestClass::Online, cfg.model.max_seq, 9,
        );
        let high = Trace::generate(
            Dataset::Alpaca, 150, 60.0, RequestClass::Online, cfg.model.max_seq, 9,
        );
        let rl = run_bucketserve(&cfg, &low);
        let rh = run_bucketserve(&cfg, &high);
        let al = rl.slo_attainment(cfg.slo.ttft_us, cfg.slo.tbt_us);
        let ah = rh.slo_attainment(cfg.slo.ttft_us, cfg.slo.tbt_us);
        assert!(al >= ah, "low-load {al} >= high-load {ah}");
    }

    #[test]
    fn priority_improves_online_slo_on_mixed_overload() {
        // The priority subsystem's acceptance scenario: a big offline
        // backlog at t=0 plus an online Poisson stream. FCFS drain
        // head-of-line-blocks the online class behind ~10 KV-bound offline
        // waves (tens of virtual seconds); priority-aware drain jumps
        // online requests into freed headroom within a wave or two. The
        // TTFT budget is set to the scale of one offline wave (20 s) so
        // attainment separates the two schedules instead of rounding both
        // to zero under this deliberate overload.
        let mut cfg = small_cfg();
        cfg.slo.ttft_us = 20_000_000;
        let trace = Trace::mixed_classes(
            Dataset::Alpaca, 30, 4.0, Dataset::LongBench, 40,
            cfg.model.max_seq, 21,
        );
        cfg.priority.enabled = false;
        let fcfs = run_bucketserve(&cfg, &trace);
        cfg.priority.enabled = true;
        let prio = run_bucketserve(&cfg, &trace);
        assert_eq!(fcfs.completions.len(), trace.len());
        assert_eq!(prio.completions.len(), trace.len());

        let attain = |r: &RunReport| {
            r.slo_attainment_class(
                RequestClass::Online, cfg.slo.ttft_us, cfg.slo.tbt_us,
            )
        };
        let (af, ap) = (attain(&fcfs), attain(&prio));
        assert!(
            ap >= af,
            "priority online attainment {ap} < fcfs {af}"
        );
        let tf = fcfs.mean_ttft_class_us(RequestClass::Online);
        let tp = prio.mean_ttft_class_us(RequestClass::Online);
        assert!(
            tp <= tf,
            "priority mean online TTFT {tp}µs worse than fcfs {tf}µs"
        );
        // The scenario must actually stress FCFS (otherwise the test is
        // vacuous) and priority must rescue real attainment.
        assert!(
            ap > af,
            "expected a strict online-SLO win: priority {ap} vs fcfs {af}"
        );
    }

    #[test]
    fn priority_off_matches_legacy_fcfs_on_single_class() {
        // Flipping the priority switch must not perturb single-class runs
        // (scores degenerate to arrival order).
        let mut cfg = small_cfg();
        let trace = Trace::generate(
            Dataset::Mixed, 60, 8.0, RequestClass::Online, cfg.model.max_seq, 22,
        );
        cfg.priority.enabled = true;
        let on = run_bucketserve(&cfg, &trace);
        cfg.priority.enabled = false;
        let off = run_bucketserve(&cfg, &trace);
        assert_eq!(on.completions.len(), off.completions.len());
        assert_eq!(on.makespan_us, off.makespan_us);
        assert_eq!(on.prefill_batches, off.prefill_batches);
        assert_eq!(on.decode_iters, off.decode_iters);
    }

    #[test]
    fn sharded_run_completes_and_conserves() {
        // One shard per decode instance, hash placement (deliberately
        // load-blind) and stealing on: every request still completes
        // exactly once and the shard accounting adds up.
        use crate::config::Placement;
        let mut cfg = SystemConfig::default();
        cfg.fleet.n_prefill = 4;
        cfg.fleet.n_decode = 4;
        cfg.sharding.shards = 0; // one per decode instance
        cfg.sharding.placement = Placement::Hash;
        cfg.sharding.steal = true;
        let trace = Trace::mixed_classes(
            Dataset::Alpaca, 60, 16.0, Dataset::LongBench, 40,
            cfg.model.max_seq, 31,
        );
        let report = run_bucketserve(&cfg, &trace);
        assert_eq!(report.completions.len(), trace.len());
        assert!(report.error.is_none(), "{:?}", report.error);
        let mut ids: Vec<_> = report.completions.iter().map(|c| c.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), trace.len(), "duplicated completions");
        assert_eq!(report.n_shards, 4);
        assert_eq!(
            report.shard_routed.iter().sum::<u64>(),
            trace.len() as u64,
            "every arrival routed to exactly one shard"
        );
        assert_eq!(
            report.shard_batches.len(),
            4,
            "per-shard batch counters reported"
        );
        // Hash placement spreads a 100-request trace across 4 shards.
        assert!(
            report.shard_routed.iter().filter(|&&n| n > 0).count() >= 2,
            "hash placement landed everything on one shard: {:?}",
            report.shard_routed
        );
    }

    #[test]
    fn sharded_runs_match_for_each_placement_policy() {
        // All placement policies must conserve requests and finish clean;
        // they may schedule differently, but totals agree.
        use crate::config::Placement;
        for placement in
            [Placement::LeastLoaded, Placement::JoinShortestKv, Placement::Hash]
        {
            let mut cfg = SystemConfig::default();
            cfg.fleet.n_prefill = 2;
            cfg.fleet.n_decode = 2;
            cfg.sharding.shards = 0;
            cfg.sharding.placement = placement;
            let trace = Trace::generate(
                Dataset::Mixed, 50, 12.0, RequestClass::Online,
                cfg.model.max_seq, 19,
            );
            let report = run_bucketserve(&cfg, &trace);
            assert_eq!(
                report.completions.len(),
                50,
                "{} lost requests",
                placement.name()
            );
            assert!(report.error.is_none(), "{:?}", report.error);
        }
    }

    #[test]
    fn work_stealing_rebalances_skewed_queues() {
        // Hash placement on a mixed trace leaves shards with uneven work;
        // with stealing enabled some requests must migrate, and the run
        // must stay lossless.
        use crate::config::Placement;
        let mut cfg = SystemConfig::default();
        cfg.fleet.n_prefill = 2;
        cfg.fleet.n_decode = 4;
        cfg.sharding.shards = 0;
        cfg.sharding.placement = Placement::Hash;
        cfg.sharding.steal = true;
        let trace = Trace::mixed_classes(
            Dataset::Alpaca, 40, 8.0, Dataset::LongBench, 60,
            cfg.model.max_seq, 77,
        );
        let stolen = run_bucketserve(&cfg, &trace);
        assert_eq!(stolen.completions.len(), trace.len());
        assert!(
            stolen.steals > 0,
            "skewed offline backlog should trigger stealing"
        );
        cfg.sharding.steal = false;
        let fixed = run_bucketserve(&cfg, &trace);
        assert_eq!(fixed.completions.len(), trace.len());
        assert_eq!(fixed.steals, 0, "steal=false must never migrate work");
        // Whether stealing helps end-to-end is workload-dependent (the
        // shard_scaling bench quantifies it); correctness-wise both runs
        // must finish clean.
        assert!(fixed.error.is_none() && stolen.error.is_none());
    }

    #[test]
    fn prop_planner_never_drops_requests() {
        // Conservation through the full planner path: everything admitted
        // is eventually drained exactly once by plan()/force_pop(), and
        // the bucket partition invariant holds throughout.
        prop::check("planner conserves requests", 60, |g| {
            let mut cfg = SystemConfig::default();
            cfg.priority.enabled = g.bool();
            cfg.scheduler.policy =
                *g.pick(&[Policy::Fcfs, Policy::Sjf, Policy::Ljf]);
            let mut planner = BucketPlanner::new(&cfg);
            let n_ops = g.usize(1, 80);
            let mut admitted = 0u64;
            let mut drained: Vec<u64> = Vec::new();
            let mut now: Micros = 0;
            for _ in 0..n_ops {
                now += g.u64(0, 50_000);
                if g.chance(0.7) {
                    let class = if g.bool() {
                        RequestClass::Online
                    } else {
                        RequestClass::Offline
                    };
                    let req = Request::new(
                        admitted,
                        class,
                        g.u64(1, 4000) as u32,
                        g.u64(1, 400) as u32,
                        now,
                    );
                    planner.admit(&req, now);
                    admitted += 1;
                } else if let Some(fb) = planner.plan(now, g.u64(0, 20_000)) {
                    drained.extend(fb.reqs.iter().map(|r| r.id));
                }
                planner.manager().check_invariants().unwrap();
            }
            while let Some(fb) = planner.plan(now, u64::MAX / 4) {
                drained.extend(fb.reqs.iter().map(|r| r.id));
                now += 1;
            }
            while let Some(r) = planner.force_pop(now) {
                drained.push(r.id);
            }
            assert_eq!(planner.queued(), 0);
            drained.sort();
            assert_eq!(drained, (0..admitted).collect::<Vec<_>>());
            planner.manager().check_invariants().unwrap();
        });
    }

    #[test]
    fn per_class_attainment_splits_by_class() {
        let report = RunReport {
            completions: vec![
                Completion {
                    id: 0,
                    class: RequestClass::Online,
                    input_len: 10,
                    output_len: 5,
                    arrival: 0,
                    first_token: 100,     // meets any sane TTFT
                    finished: 500,
                    padded_len: 10,
                },
                Completion {
                    id: 1,
                    class: RequestClass::Offline,
                    input_len: 10,
                    output_len: 5,
                    arrival: 0,
                    first_token: 10_000_000, // blows TTFT
                    finished: 10_000_400,
                    padded_len: 10,
                },
            ],
            ..Default::default()
        };
        let (ttft, tbt) = (400_000, 100_000);
        assert_eq!(
            report.slo_attainment_class(RequestClass::Online, ttft, tbt),
            1.0
        );
        assert_eq!(
            report.slo_attainment_class(RequestClass::Offline, ttft, tbt),
            0.0
        );
        assert_eq!(report.n_class(RequestClass::Online), 1);
        assert_eq!(report.n_class(RequestClass::Offline), 1);
        // Overall attainment is the blend.
        assert!((report.slo_attainment(ttft, tbt) - 0.5).abs() < 1e-12);
        // Absent class defaults to perfect attainment.
        let empty = RunReport::default();
        assert_eq!(
            empty.slo_attainment_class(RequestClass::Online, ttft, tbt),
            1.0
        );
        assert_eq!(empty.mean_ttft_class_us(RequestClass::Online), 0.0);
    }
}
