//! Live-serving plumbing between a front end and the scheduler's
//! realtime drive mode ([`super::PdScheduler::run_realtime`]).
//!
//! The coordinator cannot depend on the server layer, so this module
//! defines the protocol both sides meet at:
//!
//! * [`LiveCmd`] — the command channel into the serving loop: submit a
//!   request with its delivery sink, abort on client disconnect, answer
//!   `health`/`loads` introspection, request shutdown.
//! * [`StreamSink`] — a bounded per-request delivery buffer. The
//!   scheduler *never blocks* on a slow client: token lines drop-oldest
//!   when the buffer is full (counted as `stream_drops` — the
//!   backpressure signal), while the final summary line is always
//!   delivered. The consumer side marks the sink disconnected when its
//!   socket dies, which the scheduler converts into a client abort.
//! * [`LiveState`] — the scheduler-side registry (sink per in-flight
//!   request, pending abort set) carried by the run core only in
//!   realtime mode; trace runs carry `None` and pay a single branch.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use super::monitor::MonitorView;
use super::scheduler::RunReport;
use crate::config::SloSpec;
use crate::workload::request::Completion;
use crate::workload::{Request, RequestId};
use crate::Micros;

/// One command into the realtime serving loop.
pub enum LiveCmd {
    /// Admit a request. `req.arrival` is re-stamped by the scheduler at
    /// ingest (its wall epoch, not the submitter's), so TTFT/queue-wait
    /// accounting stays on one clock.
    Submit { req: Request, sink: StreamSink },
    /// The client went away: abort the request wherever it is in flight.
    Abort(RequestId),
    /// Liveness + request-lifecycle counters.
    Health { reply: Sender<HealthInfo> },
    /// Per-shard/per-instance load introspection from the Global Monitor.
    Loads { reply: Sender<LoadsInfo> },
    /// Stop accepting and drain (bounded by `realtime.drain_timeout_ms`).
    Shutdown,
}

/// `health` payload.
#[derive(Debug, Clone)]
pub struct HealthInfo {
    /// Requests with a live stream (queued, prefilling, or decoding).
    pub in_flight: usize,
    /// Requests queued in the shard planners.
    pub queued: usize,
    pub completions: u64,
    pub client_aborts: u64,
}

/// One decode instance's occupancy in the `loads` payload.
#[derive(Debug, Clone)]
pub struct InstanceLoad {
    pub instance: usize,
    pub active: usize,
    pub pending: usize,
    pub reserved_tokens: u64,
}

/// `loads` payload: the Global Monitor's system/per-shard view plus
/// per-instance occupancy and running SLO attainment.
#[derive(Debug, Clone)]
pub struct LoadsInfo {
    pub view: MonitorView,
    pub instances: Vec<InstanceLoad>,
    pub ttft_attainment_online: f64,
    pub tbt_attainment_online: f64,
}

/// One line of a request's delivery stream.
#[derive(Debug, Clone)]
pub enum StreamMsg {
    /// One generated token: `seq` is the running token ordinal (1 =
    /// prefill's first token), `at_us` its production time on the run's
    /// wall clock.
    Token { id: RequestId, seq: u32, at_us: Micros },
    /// Final summary line of a completed request.
    Done { completion: Completion },
    /// Final line of a request dropped before completion (client abort
    /// or server shutdown).
    Aborted { id: RequestId },
}

#[derive(Default)]
struct SinkState {
    buf: VecDeque<StreamMsg>,
    /// Producer closed: the final line is in (or already consumed).
    closed: bool,
    /// Consumer gone: its socket died; stop buffering for it.
    disconnected: bool,
}

struct SinkInner {
    cap: usize,
    state: Mutex<SinkState>,
    cond: Condvar,
}

/// Bounded per-request delivery buffer (see module docs). Clone shares
/// the buffer: the scheduler holds the producer clone, the connection
/// thread the consumer clone.
#[derive(Clone)]
pub struct StreamSink {
    inner: Arc<SinkInner>,
}

impl StreamSink {
    /// `cap`: maximum buffered token lines (`realtime.stream_buf`).
    pub fn new(cap: usize) -> StreamSink {
        StreamSink {
            inner: Arc::new(SinkInner {
                cap: cap.max(1),
                state: Mutex::new(SinkState::default()),
                cond: Condvar::new(),
            }),
        }
    }

    /// Producer: buffer one token line. When the buffer is full the
    /// oldest buffered *token* line is dropped to make room (final lines
    /// are never displaced). Returns the number of lines dropped (0|1) —
    /// the caller's `stream_drops` charge.
    pub fn push_token(&self, msg: StreamMsg) -> u64 {
        let mut st = self.inner.state.lock().unwrap();
        if st.closed || st.disconnected {
            return 0;
        }
        let mut dropped = 0;
        if st.buf.len() >= self.inner.cap {
            if let Some(pos) =
                st.buf.iter().position(|m| matches!(m, StreamMsg::Token { .. }))
            {
                st.buf.remove(pos);
                dropped = 1;
            }
        }
        st.buf.push_back(msg);
        drop(st);
        self.inner.cond.notify_all();
        dropped
    }

    /// Producer: deliver the final line and close the stream. Always
    /// buffered, even past `cap` — a client may lose token lines under
    /// backpressure but never the summary.
    pub fn finish(&self, msg: StreamMsg) {
        let mut st = self.inner.state.lock().unwrap();
        if !st.closed {
            st.buf.push_back(msg);
            st.closed = true;
        }
        drop(st);
        self.inner.cond.notify_all();
    }

    /// Consumer: the socket died; stop buffering on its behalf.
    pub fn mark_disconnected(&self) {
        self.inner.state.lock().unwrap().disconnected = true;
        self.inner.cond.notify_all();
    }

    pub fn is_disconnected(&self) -> bool {
        self.inner.state.lock().unwrap().disconnected
    }

    /// Consumer: true once the final line has been consumed — the
    /// stream's end-of-life, distinguishing a timed-out
    /// [`StreamSink::recv_timeout`] from a finished one.
    pub fn finished(&self) -> bool {
        let st = self.inner.state.lock().unwrap();
        st.closed && st.buf.is_empty()
    }

    /// Consumer: next buffered line, blocking up to `timeout`. `None`
    /// means timeout or finished — check [`StreamSink::finished`].
    pub fn recv_timeout(&self, timeout: Duration) -> Option<StreamMsg> {
        let mut st = self.inner.state.lock().unwrap();
        loop {
            if let Some(m) = st.buf.pop_front() {
                return Some(m);
            }
            if st.closed {
                return None;
            }
            let (guard, to) =
                self.inner.cond.wait_timeout(st, timeout).unwrap();
            st = guard;
            if to.timed_out() {
                return st.buf.pop_front();
            }
        }
    }
}

/// Scheduler-side live-run registry, present on the run core only in
/// realtime drive mode.
pub struct LiveState {
    /// SLO budgets for the `loads` attainment columns.
    pub slo: SloSpec,
    /// Delivery sink per in-flight request; removal is the request's
    /// lifecycle end (completion or abort).
    pub sinks: HashMap<RequestId, StreamSink>,
    /// Abort-requested ids awaiting their removal touchpoint (hand-off
    /// drop for queued work, boundary sweep for decoding work).
    pub aborted: HashSet<RequestId>,
}

impl LiveState {
    pub fn new(slo: SloSpec) -> LiveState {
        LiveState { slo, sinks: HashMap::new(), aborted: HashSet::new() }
    }

    /// Register an abort request. A no-op for ids with no live sink
    /// (already completed, never submitted), so the pending set cannot
    /// grow without bound.
    pub fn abort(&mut self, id: RequestId) {
        if self.sinks.contains_key(&id) {
            self.aborted.insert(id);
        }
    }

    /// Stream one token line; converts a consumer-side disconnect into a
    /// pending abort and charges buffer-overflow drops to the report.
    pub fn stream_token(
        &mut self,
        id: RequestId,
        seq: u32,
        at_us: Micros,
        report: &mut RunReport,
    ) {
        let Some(sink) = self.sinks.get(&id) else { return };
        if sink.is_disconnected() {
            self.aborted.insert(id);
            return;
        }
        report.stream_drops += sink.push_token(StreamMsg::Token { id, seq, at_us });
    }

    /// Lifecycle end, success: deliver the summary line, retire the sink.
    pub fn finish_ok(&mut self, c: &Completion) {
        if let Some(sink) = self.sinks.remove(&c.id) {
            sink.finish(StreamMsg::Done { completion: c.clone() });
        }
        self.aborted.remove(&c.id);
    }

    /// Lifecycle end, client abort: deliver the aborted line, retire the
    /// sink, charge the counter.
    pub fn finish_aborted(&mut self, id: RequestId, report: &mut RunReport) {
        if let Some(sink) = self.sinks.remove(&id) {
            sink.finish(StreamMsg::Aborted { id });
        }
        self.aborted.remove(&id);
        report.client_aborts += 1;
    }

    /// Server shutdown with work still in flight: close every remaining
    /// stream (not charged as client aborts — the server left, not the
    /// clients).
    pub fn close_all(&mut self) {
        for (id, sink) in self.sinks.drain() {
            sink.finish(StreamMsg::Aborted { id });
        }
        self.aborted.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sink_delivers_in_order() {
        let s = StreamSink::new(8);
        for seq in 1..=3 {
            assert_eq!(s.push_token(StreamMsg::Token { id: 7, seq, at_us: seq as u64 }), 0);
        }
        for want in 1..=3u32 {
            match s.recv_timeout(Duration::from_millis(10)) {
                Some(StreamMsg::Token { id: 7, seq, .. }) => assert_eq!(seq, want),
                other => panic!("expected token {want}, got {other:?}"),
            }
        }
        assert!(!s.finished(), "still open: no final line yet");
        assert!(s.recv_timeout(Duration::from_millis(1)).is_none());
    }

    #[test]
    fn sink_overflow_drops_oldest_token_keeps_final() {
        let s = StreamSink::new(2);
        assert_eq!(s.push_token(StreamMsg::Token { id: 1, seq: 1, at_us: 1 }), 0);
        assert_eq!(s.push_token(StreamMsg::Token { id: 1, seq: 2, at_us: 2 }), 0);
        assert_eq!(s.push_token(StreamMsg::Token { id: 1, seq: 3, at_us: 3 }), 1);
        s.finish(StreamMsg::Aborted { id: 1 });
        // Oldest token (seq 1) was shed; the rest arrive in order, final
        // line last.
        match s.recv_timeout(Duration::from_millis(10)) {
            Some(StreamMsg::Token { seq: 2, .. }) => {}
            other => panic!("expected token 2, got {other:?}"),
        }
        match s.recv_timeout(Duration::from_millis(10)) {
            Some(StreamMsg::Token { seq: 3, .. }) => {}
            other => panic!("expected token 3, got {other:?}"),
        }
        assert!(matches!(
            s.recv_timeout(Duration::from_millis(10)),
            Some(StreamMsg::Aborted { id: 1 })
        ));
        assert!(s.finished());
    }

    #[test]
    fn disconnected_sink_stops_buffering() {
        let s = StreamSink::new(4);
        s.mark_disconnected();
        assert!(s.is_disconnected());
        assert_eq!(s.push_token(StreamMsg::Token { id: 1, seq: 1, at_us: 1 }), 0);
        assert!(s.recv_timeout(Duration::from_millis(1)).is_none());
    }

    #[test]
    fn live_state_abort_only_tracks_live_sinks() {
        let mut l = LiveState::new(SloSpec::default());
        l.abort(42);
        assert!(l.aborted.is_empty(), "no sink -> nothing to abort");
        l.sinks.insert(42, StreamSink::new(2));
        l.abort(42);
        assert!(l.aborted.contains(&42));
        let mut report = RunReport::default();
        l.finish_aborted(42, &mut report);
        assert_eq!(report.client_aborts, 1);
        assert!(l.sinks.is_empty() && l.aborted.is_empty());
    }
}
