//! Deadline-lookahead prefill planning: push every request toward its
//! *latest feasible start* and form batches backwards from the earliest
//! deadline (ROADMAP open item #2; the memory-aware SLA-constrained
//! batching line of work, arxiv 2503.05248).
//!
//! The bucket planner drains eagerly: whenever a prefill slot and KV
//! headroom exist, it forms the best batch it can from whatever is
//! queued *right now*. Under bursty traffic that fragments buckets and
//! serves requests seconds ahead of their deadlines while the requests
//! arriving just behind them form thin, padding-heavy batches.
//! [`LookaheadPlanner`] inverts the decision:
//!
//! 1. Every queued request carries a **deadline** — online requests
//!    their TTFT deadline (`arrival + slo.ttft_us`), offline requests a
//!    synthetic aging anchor (`arrival + planner.offline_horizon_us`)
//!    so throughput work can wait but never starve.
//! 2. One plan round examines only the `planner.window` earliest
//!    deadlines (the queue is kept deadline-sorted, so this is the
//!    front; O(window) per dispatch round) and greedily admits them in
//!    deadline order under the KV headroom and `scheduler.max_batch` —
//!    the batch forms *backwards from the earliest deadline*, urgent
//!    work first, fillers after.
//! 3. The formed batch's **latest feasible start** is
//!    `earliest member deadline − projected prefill time` (the analytic
//!    [`CostModel`], same one the engine prices the batch with). While
//!    `now + planner.commit_margin_us` is still earlier than that — and
//!    the batch has absorbed the whole queue without saturating — the
//!    planner *holds* (returns `None`): committing now would waste the
//!    slack that lets later arrivals join and form a fuller, more
//!    length-homogeneous batch. A batch that is saturated (headroom- or
//!    `max_batch`-limited, or with work queued beyond the window)
//!    commits immediately — holding could not make it better.
//!
//! Liveness needs no planner-side timer: the serving loop re-plans at
//! every event, so the clock a held batch waits on is carried by
//! whatever is in flight, and the scheduler's memory-deadlock breaker
//! (`force_pop`, which here pops the earliest deadline) already covers
//! the nothing-in-flight corner.
//!
//! Every decision is a pure function of `(queue, now, headroom)` over
//! integer microseconds, so plan/commit speculation on executor worker
//! threads stays byte-identical to inline planning; wall-clock
//! (`Instant`) is read only to meter [`PrefillPlanner::overhead_ns`].
//!
//! Composition: sharding/work-stealing ([`PrefillPlanner::steal_tail`]
//! surrenders the farthest-deadline tail, KV-capped), preemption
//! ([`PrefillPlanner::drain_follows_urgency`] is `true` — the drain *is*
//! deadline order), TBT admission (deferred batches
//! [`PrefillPlanner::absorb`] back in deadline position), prefix caching
//! ([`PrefillPlanner::lineage_summary`] walks the queue), and chunking
//! (slices operate on formed batches, downstream of planning) all ride
//! the trait surface unchanged.

use super::batcher::FormedBatch;
use super::bucket::QueuedReq;
use super::prefix::PrefixStamp;
use super::scheduler::{kv_capped_take, oldest_online_in, OnlinePeek, PrefillPlanner};
use crate::cluster::gpu::CostModel;
use crate::cluster::{PrefillBatch, PrefillItem};
use crate::config::{PlannerSpec, SloSpec, SystemConfig};
use crate::workload::{Request, RequestClass};
use crate::Micros;
use std::time::Instant;

/// Deadline-lookahead planner (latest-feasible-start batch formation).
///
/// `Clone` is the snapshot stage of the executor's plan/commit protocol
/// ([`PrefillPlanner::clone_box`]): all fields are owned data, so the
/// derived clone is a complete deep copy.
#[derive(Clone)]
pub struct LookaheadPlanner {
    /// Kept sorted ascending by `(deadline, arrival, id)` — the front is
    /// always the most-due request, so one plan round's window is a
    /// prefix slice and `force_pop` is the front.
    queue: Vec<(Micros, QueuedReq)>,
    cost: CostModel,
    slo: SloSpec,
    spec: PlannerSpec,
    max_batch: usize,
    overhead_ns: u64,
    online_peek: OnlinePeek,
}

impl LookaheadPlanner {
    pub fn new(cfg: &SystemConfig) -> LookaheadPlanner {
        LookaheadPlanner {
            queue: Vec::new(),
            cost: CostModel::new(cfg.model.clone(), cfg.gpu.clone(), cfg.fleet.tp),
            slo: cfg.slo.clone(),
            spec: cfg.planner.clone(),
            max_batch: if cfg.scheduler.max_batch == 0 {
                usize::MAX
            } else {
                cfg.scheduler.max_batch as usize
            },
            overhead_ns: 0,
            online_peek: OnlinePeek::new(),
        }
    }

    /// The request's deadline: TTFT for online, the aging anchor for
    /// offline — the single key the queue orders and batches anchor on.
    fn deadline(&self, r: &QueuedReq) -> Micros {
        match r.class {
            RequestClass::Online => r.arrival.saturating_add(self.slo.ttft_us),
            RequestClass::Offline => {
                r.arrival.saturating_add(self.spec.offline_horizon_us)
            }
        }
    }

    /// Insert preserving the `(deadline, arrival, id)` sort.
    fn insert(&mut self, r: QueuedReq) {
        self.online_peek.note_insert(&r);
        let dl = self.deadline(&r);
        let key = (dl, r.arrival, r.id);
        let pos = self
            .queue
            .partition_point(|(d, q)| (*d, q.arrival, q.id) <= key);
        self.queue.insert(pos, (dl, r));
    }
}

impl PrefillPlanner for LookaheadPlanner {
    fn clone_box(&self) -> Box<dyn PrefillPlanner> {
        Box::new(self.clone())
    }

    fn admit(&mut self, req: &Request, _now: Micros) {
        let q = QueuedReq {
            id: req.id,
            len: req.input_len,
            output_len: req.output_len,
            arrival: req.arrival,
            class: req.class,
            tbt_us: req.tbt_deadline_us,
            // Lineage + the router's resident-match hint; `shared_len`
            // stays 0 until dispatch actually pins cache blocks. All-zero
            // when the prefix subsystem is off, so nothing downstream
            // changes.
            prefix: PrefixStamp {
                prefix_id: req.prefix_id,
                prefix_len: req.prefix_len.min(req.input_len),
                cached_len: req.prefix_cached_hint.min(req.input_len),
                shared_len: 0,
            },
        };
        self.insert(q);
    }

    fn plan(&mut self, now: Micros, headroom_tokens: u64) -> Option<FormedBatch> {
        let t0 = Instant::now();
        if self.queue.is_empty() {
            self.overhead_ns += t0.elapsed().as_nanos() as u64;
            return None;
        }
        // Backwards from the earliest deadline: admit window members in
        // deadline order while they fit. A member whose footprint
        // overflows the remaining headroom is *skipped*, not a barrier —
        // the window exists so one oversized request cannot block the
        // due work queued just behind it.
        let window = (self.spec.window.max(1) as usize).min(self.queue.len());
        let mut take_idx: Vec<usize> = Vec::new();
        let mut acc = 0u64;
        for i in 0..window {
            if take_idx.len() >= self.max_batch {
                break;
            }
            let footprint = self.queue[i].1.footprint();
            if acc + footprint > headroom_tokens {
                continue;
            }
            acc += footprint;
            take_idx.push(i);
        }
        if take_idx.is_empty() {
            self.overhead_ns += t0.elapsed().as_nanos() as u64;
            return None;
        }
        // Hold-for-accumulation gate: only an *unsaturated* batch — one
        // that absorbed the whole queue with batch-size room to spare —
        // can get fuller by waiting, and it waits only while its whole
        // window keeps `commit_margin_us` of slack before the latest
        // feasible start. Saturated batches commit now.
        let n = take_idx.len();
        if n == self.queue.len() && n < self.max_batch {
            let padded =
                take_idx.iter().map(|&i| self.queue[i].1.len).max().unwrap_or(1);
            let dur = self.cost.prefill_time(n, padded.max(1));
            let latest_start = self.queue[take_idx[0]].0.saturating_sub(dur);
            if now.saturating_add(self.spec.commit_margin_us) < latest_start {
                self.overhead_ns += t0.elapsed().as_nanos() as u64;
                return None;
            }
        }
        // Drain the members (descending index so positions stay valid),
        // then restore deadline order — the dispatch order downstream
        // bookkeeping sees.
        let mut reqs: Vec<QueuedReq> = Vec::with_capacity(n);
        for &i in take_idx.iter().rev() {
            reqs.push(self.queue.remove(i).1);
        }
        reqs.reverse();
        self.online_peek.note_removed(reqs.iter());
        let padded_len = reqs.iter().map(|r| r.len).max().unwrap_or(1).max(1);
        let items = reqs
            .iter()
            .map(|r| PrefillItem { id: r.id, len: r.len, tokens: vec![] })
            .collect();
        self.overhead_ns += t0.elapsed().as_nanos() as u64;
        Some(FormedBatch {
            batch: PrefillBatch { items, padded_len },
            reqs,
            bucket_up: padded_len,
        })
    }

    fn force_pop(&mut self, _now: Micros) -> Option<QueuedReq> {
        if self.queue.is_empty() {
            return None;
        }
        let (_, r) = self.queue.remove(0);
        self.online_peek.note_removed(std::iter::once(&r));
        Some(r)
    }

    fn queued(&self) -> usize {
        self.queue.len()
    }

    fn queued_tokens(&self) -> u64 {
        self.queue.iter().map(|(_, r)| r.footprint()).sum()
    }

    fn steal_tail(
        &mut self,
        max_n: usize,
        max_tokens: u64,
        _now: Micros,
    ) -> Vec<QueuedReq> {
        // The farthest-deadline tail is the least-urgent end by
        // construction; cap at half the queue so the donor keeps the due
        // head it would dispatch next, and at `max_tokens` of
        // full-context footprint so the thief is never handed more than
        // its KV headroom can admit.
        let cap = max_n.min(self.queue.len() / 2);
        let take = kv_capped_take(
            self.queue.iter().rev().take(cap).map(|(_, r)| r),
            max_tokens,
        );
        let stolen: Vec<QueuedReq> = self
            .queue
            .split_off(self.queue.len() - take)
            .into_iter()
            .map(|(_, r)| r)
            .collect();
        self.online_peek.note_removed(stolen.iter());
        stolen
    }

    fn absorb(&mut self, reqs: Vec<QueuedReq>, _now: Micros) {
        // Stolen/requeued work slots back in by deadline, as if admitted
        // here originally.
        for r in reqs {
            self.insert(r);
        }
    }

    fn oldest_online(&mut self) -> Option<QueuedReq> {
        let queue = &self.queue;
        self.online_peek
            .get(|| oldest_online_in(queue.iter().map(|(_, r)| r)))
    }

    fn drain_follows_urgency(&self) -> bool {
        // The drain order *is* deadline order: an urgent requeued
        // request re-enters at the front and dispatches ahead of the
        // work it preempted, so preemption buys real latency here.
        true
    }

    fn overhead_ns(&self) -> u64 {
        self.overhead_ns
    }

    fn lineage_summary(&self) -> Vec<(u64, u32)> {
        // O(queued) walk, paid only when the prefix subsystem is armed
        // and only at steal cadence (mirrors the bucket planner).
        let mut out: Vec<(u64, u32)> = Vec::new();
        for (_, r) in &self.queue {
            if r.prefix.prefix_id == 0 {
                continue;
            }
            let shareable = r.prefix.prefix_len.min(r.len);
            match out.iter_mut().find(|(id, _)| *id == r.prefix.prefix_id) {
                Some((_, len)) => *len = (*len).max(shareable),
                None => out.push((r.prefix.prefix_id, shareable)),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::sim::SimEngine;
    use crate::config::PlannerFamily;
    use crate::coordinator::scheduler::PdScheduler;
    use crate::workload::{Dataset, Request, RequestClass, Trace};

    fn req(id: u64, class: RequestClass, len: u32, arrival: Micros) -> Request {
        Request::new(id, class, len, 10, arrival)
    }

    #[test]
    fn completes_all_requests() {
        let mut cfg = SystemConfig::default();
        cfg.planner.family = PlannerFamily::Lookahead;
        let trace = Trace::mixed_classes(
            Dataset::Alpaca, 40, 8.0, Dataset::LongBench, 20, cfg.model.max_seq,
            7,
        );
        let mut engine = SimEngine::new(&cfg);
        let mut sched =
            PdScheduler::new(&cfg, || Box::new(LookaheadPlanner::new(&cfg)));
        let report = sched.run(&trace, &mut engine);
        assert!(report.error.is_none(), "{:?}", report.error);
        assert_eq!(report.completions.len(), 60);
    }

    #[test]
    fn drains_in_deadline_order_online_before_offline() {
        let cfg = SystemConfig::default();
        let mut p = LookaheadPlanner::new(&cfg);
        // Offline arrived first but its aging anchor (10 s) is far
        // beyond the online TTFT deadline (400 ms).
        p.admit(&req(0, RequestClass::Offline, 100, 0), 0);
        p.admit(&req(1, RequestClass::Online, 100, 1000), 1000);
        p.admit(&req(2, RequestClass::Online, 100, 500), 1000);
        let fb = p.plan(cfg.slo.ttft_us, u64::MAX / 4).unwrap();
        assert_eq!(
            fb.reqs.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![2, 1, 0],
            "earliest deadline first: online by arrival, offline last"
        );
        assert_eq!(p.queued(), 0);
    }

    #[test]
    fn holds_unsaturated_batch_until_commit_margin() {
        let cfg = SystemConfig::default();
        let mut p = LookaheadPlanner::new(&cfg);
        p.admit(&req(0, RequestClass::Online, 100, 0), 0);
        // Deadline 400 ms, prefill of one 100-token request is a few ms:
        // at t=0 the slack is far beyond the 50 ms commit margin.
        assert!(
            p.plan(0, u64::MAX / 4).is_none(),
            "far-from-deadline singleton is held for accumulation"
        );
        assert_eq!(p.queued(), 1, "held, not dropped");
        // At the deadline the batch must commit.
        let fb = p.plan(cfg.slo.ttft_us, u64::MAX / 4).unwrap();
        assert_eq!(fb.reqs.len(), 1);
        // And a batch the queue saturates (here: max_batch) commits
        // immediately even with slack to spare.
        let mut cfg2 = SystemConfig::default();
        cfg2.scheduler.max_batch = 2;
        let mut p = LookaheadPlanner::new(&cfg2);
        p.admit(&req(0, RequestClass::Online, 100, 0), 0);
        p.admit(&req(1, RequestClass::Online, 100, 0), 0);
        assert!(
            p.plan(0, u64::MAX / 4).is_some(),
            "max_batch-saturated batch commits at once"
        );
    }

    #[test]
    fn held_batch_accumulates_then_commits_fuller() {
        let cfg = SystemConfig::default();
        let mut p = LookaheadPlanner::new(&cfg);
        p.admit(&req(0, RequestClass::Online, 100, 0), 0);
        assert!(p.plan(0, u64::MAX / 4).is_none());
        // Two more arrivals land while the first is held; the eventual
        // commit carries all three in one batch.
        p.admit(&req(1, RequestClass::Online, 120, 10_000), 10_000);
        p.admit(&req(2, RequestClass::Online, 90, 20_000), 20_000);
        let fb = p.plan(cfg.slo.ttft_us, u64::MAX / 4).unwrap();
        assert_eq!(fb.reqs.len(), 3, "held batch accumulated arrivals");
        assert_eq!(fb.batch.padded_len, 120);
    }

    #[test]
    fn oversized_member_is_skipped_not_a_barrier() {
        let cfg = SystemConfig::default();
        let mut p = LookaheadPlanner::new(&cfg);
        // Earliest deadline belongs to a request too big for the
        // headroom; the two due requests behind it must still form.
        p.admit(&req(0, RequestClass::Online, 4000, 0), 0);
        p.admit(&req(1, RequestClass::Online, 100, 10), 0);
        p.admit(&req(2, RequestClass::Online, 100, 20), 0);
        let fb = p.plan(cfg.slo.ttft_us, 300).unwrap();
        assert_eq!(
            fb.reqs.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![1, 2],
            "oversized head skipped, due work behind it still forms"
        );
        assert_eq!(p.queued(), 1, "the oversized request stays queued");
        assert_eq!(p.oldest_online().unwrap().id, 0);
    }

    #[test]
    fn window_bounds_the_examination() {
        let mut cfg = SystemConfig::default();
        cfg.planner.window = 4;
        let mut p = LookaheadPlanner::new(&cfg);
        for i in 0..10u64 {
            p.admit(&req(i, RequestClass::Online, 100, i), 0);
        }
        let fb = p.plan(cfg.slo.ttft_us, u64::MAX / 4).unwrap();
        assert_eq!(
            fb.reqs.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![0, 1, 2, 3],
            "one round admits at most `window` members"
        );
        assert_eq!(p.queued(), 6);
    }

    #[test]
    fn steal_tail_takes_farthest_deadlines_kv_capped() {
        let cfg = SystemConfig::default();
        let mut p = LookaheadPlanner::new(&cfg);
        for i in 0..8u64 {
            p.admit(&req(i, RequestClass::Online, 100, i * 100), 0);
        }
        assert_eq!(p.oldest_online().unwrap().id, 0);
        // Footprint 110/request: a 250-token cap admits only 2 of the 4
        // the half-queue rule would otherwise surrender.
        let stolen = p.steal_tail(4, 250, 800);
        assert_eq!(
            stolen.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![6, 7],
            "farthest-deadline tail"
        );
        assert_eq!(p.queued(), 6);
        assert_eq!(p.oldest_online().unwrap().id, 0, "head never stolen");
        assert_eq!(p.queued_tokens(), 6 * 110);
    }

    #[test]
    fn absorb_reinserts_in_deadline_order() {
        let cfg = SystemConfig::default();
        let mut victim = LookaheadPlanner::new(&cfg);
        let mut thief = LookaheadPlanner::new(&cfg);
        for i in 0..6u64 {
            victim.admit(&req(i, RequestClass::Online, 100, i * 100), 0);
        }
        thief.admit(&req(99, RequestClass::Online, 100, 450), 0);
        let stolen = victim.steal_tail(2, u64::MAX / 4, 800);
        assert_eq!(
            stolen.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![4, 5]
        );
        thief.absorb(stolen, 800);
        let fb = thief.plan(1_000_000, u64::MAX / 4).unwrap();
        assert_eq!(
            fb.reqs.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![4, 99, 5],
            "absorbed requests interleave by deadline"
        );
    }

    #[test]
    fn force_pop_is_the_earliest_deadline() {
        let cfg = SystemConfig::default();
        let mut p = LookaheadPlanner::new(&cfg);
        p.admit(&req(0, RequestClass::Offline, 100, 0), 0);
        p.admit(&req(1, RequestClass::Online, 100, 700), 0);
        p.admit(&req(2, RequestClass::Online, 100, 300), 0);
        assert_eq!(p.force_pop(0).unwrap().id, 2);
        assert_eq!(p.force_pop(0).unwrap().id, 1);
        assert_eq!(p.force_pop(0).unwrap().id, 0);
        assert!(p.force_pop(0).is_none());
    }

    #[test]
    fn lineage_summary_dedupes_by_prefix() {
        let cfg = SystemConfig::default();
        let mut p = LookaheadPlanner::new(&cfg);
        let mut a = req(0, RequestClass::Online, 200, 0);
        a.prefix_id = 7;
        a.prefix_len = 64;
        let mut b = req(1, RequestClass::Online, 200, 10);
        b.prefix_id = 7;
        b.prefix_len = 128;
        p.admit(&a, 0);
        p.admit(&b, 10);
        assert_eq!(p.lineage_summary(), vec![(7, 128)]);
    }
}
