//! Simulated radix-style KV prefix cache, one per decode instance.
//!
//! Real chat traffic is dominated by shared prefixes — system prompts and
//! growing multi-turn histories — and a decode instance that still holds
//! a session's KV blocks can skip recomputing them (Apt-Serve's hybrid
//! cache observation, arxiv 2504.07494). This module models that reuse at
//! token-block granularity so the scheduler can price prefill on the
//! *uncached suffix* only and deduplicate the KV reservation of shared
//! blocks, all under the existing per-instance token budget.
//!
//! # Model
//!
//! A request's shareable prefix is identified by its lineage
//! ([`PrefixStamp::prefix_id`], stamped by `Trace::multi_turn` or loaded
//! from trace JSON) rather than by hashing literal token content — the
//! simulator carries no token ids, and a lineage id is exactly what a
//! content hash of the shared prefix would collapse to. Each lineage's
//! resident blocks form a contiguous chain (the radix-trie path for that
//! prefix, collapsed): block `k` can only be resident if blocks
//! `0..k` are, acquisitions pin whole chain prefixes, and eviction peels
//! unpinned chain *tails* — so the radix invariant (a resident node's
//! ancestors are resident, a pinned node's ancestors are pinned) holds by
//! construction.
//!
//! # Bookkeeping contract
//!
//! The cache owns the KV reservation of every resident block, charged
//! against the owning decode instance when a block is first inserted and
//! released when LRU eviction peels it. Requests therefore *exclude*
//! their pinned tokens ([`PrefixStamp::shared_len`]) from their own
//! full-context reservation — that is the deduplication: ten session
//! turns pinning one system prompt reserve its blocks once, not ten
//! times. Pins (per-block refcounts) only gate eviction; pin/unpin moves
//! no bytes. All mutation happens on the scheduler's merge loop (dispatch
//! acquire, boundary release, eviction release), so the parallel executor
//! needs no synchronization here.

use std::collections::HashMap;

/// Prefix lineage carried by a request through every scheduling layer.
///
/// `prefix_id`/`prefix_len` are workload facts (stamped by the trace):
/// which shared prefix the prompt starts with and how many of its tokens
/// are shareable. `cached_len`/`shared_len` are scheduler stamps written
/// at admission (estimate) and dispatch (actual acquisition):
///
/// * `cached_len` — tokens served from cache, i.e. prefill-compute
///   savings; the bucket key and the engine's priced batch subtract it.
/// * `shared_len` — tokens pinned in the cache on this request's behalf
///   and excluded from its own KV reservation (the cache holds their
///   reservation once, however many requests pin them).
///
/// `PrefixStamp::default()` (all zeros) is a request with no lineage;
/// every footprint/bucket computation then degenerates to the legacy
/// form, which is what keeps disabled runs byte-identical.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PrefixStamp {
    /// Shared-prefix lineage id (0 = none).
    pub prefix_id: u64,
    /// Leading tokens of the prompt that belong to the shared prefix.
    pub prefix_len: u32,
    /// Tokens served from a resident prefix (prefill-compute savings).
    pub cached_len: u32,
    /// Cache-pinned tokens excluded from this request's KV reservation.
    pub shared_len: u32,
}

/// One resident KV block of a lineage chain.
#[derive(Debug, Clone, Copy)]
struct Block {
    /// In-flight requests pinning this block (eviction gate).
    refs: u32,
    /// Logical LRU clock of the last acquisition touching this block.
    last_used: u64,
}

/// Result of one [`PrefixCache::acquire`]: what the scheduler folds into
/// the request's stamp and the KV books.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Acquired {
    /// Tokens already resident (the prefill-compute saving).
    pub hit_tokens: u32,
    /// Tokens newly inserted — charge them to the instance's KV books.
    pub inserted_tokens: u64,
    /// Tokens LRU-evicted to make room — release them from the books.
    pub evicted_tokens: u64,
    /// Tokens pinned for the caller (hit + inserted); pass back to
    /// [`PrefixCache::release`] when the request leaves the instance.
    pub pinned_len: u32,
}

/// Hit/miss/eviction counters surfaced in `RunReport`/Summary JSON.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefixStats {
    /// Acquisitions that found at least one resident block.
    pub hits: u64,
    /// Acquisitions that found none (including lineage-less requests).
    pub misses: u64,
    /// Total tokens served from cache across all hits.
    pub hit_tokens: u64,
    /// Blocks peeled by LRU eviction.
    pub evictions: u64,
    /// Tokens those evictions released.
    pub evicted_tokens: u64,
}

/// The per-decode-instance prefix cache: lineage chains of refcounted
/// blocks under a token budget, peeled LRU-tail-first when full.
#[derive(Debug)]
pub struct PrefixCache {
    /// Cache granularity in tokens (whole blocks only).
    block: u32,
    /// Resident-token ceiling (a fraction of the instance KV budget).
    budget: u64,
    /// Lineage id → contiguous resident chain. Iterated only during
    /// eviction, where candidates are totally ordered by
    /// `(last_used, lineage id)` — map order cannot reach the schedule.
    chains: HashMap<u64, Vec<Block>>,
    resident_tokens: u64,
    /// Logical LRU clock, bumped once per acquisition.
    tick: u64,
    stats: PrefixStats,
}

impl PrefixCache {
    /// `block` tokens per cache block (clamped to ≥ 1), `budget` resident
    /// tokens total.
    pub fn new(block: u32, budget: u64) -> PrefixCache {
        PrefixCache {
            block: block.max(1),
            budget,
            chains: HashMap::new(),
            resident_tokens: 0,
            tick: 0,
            stats: PrefixStats::default(),
        }
    }

    /// Tokens of `prefix_id`'s chain resident right now that a request
    /// with `shareable` prefix tokens could reuse — the affinity-placement
    /// probe. Pure: no pins, no LRU touch, no counters.
    pub fn match_len(&self, prefix_id: u64, shareable: u32) -> u32 {
        if prefix_id == 0 {
            return 0;
        }
        let want = (shareable / self.block) as usize;
        let resident =
            self.chains.get(&prefix_id).map_or(0, |c| c.len()).min(want);
        resident as u32 * self.block
    }

    /// Acquire the first `shareable` tokens of `prefix_id` for a request
    /// being dispatched: pin what is resident (the hit), insert and pin
    /// what is missing while budget allows — peeling LRU unpinned chain
    /// tails to make room — and report the KV-book deltas. A lineage-less
    /// or sub-block request is a plain miss.
    pub fn acquire(&mut self, prefix_id: u64, shareable: u32) -> Acquired {
        let want = (shareable / self.block) as usize;
        if prefix_id == 0 || want == 0 {
            self.stats.misses += 1;
            return Acquired::default();
        }
        self.tick += 1;
        let tick = self.tick;
        let chain = self.chains.entry(prefix_id).or_default();
        let hit = chain.len().min(want);
        for b in chain.iter_mut().take(hit) {
            b.refs += 1;
            b.last_used = tick;
        }
        if hit > 0 {
            self.stats.hits += 1;
        } else {
            self.stats.misses += 1;
        }
        let hit_tokens = hit as u32 * self.block;
        self.stats.hit_tokens += hit_tokens as u64;
        let mut inserted_tokens = 0u64;
        let mut evicted_tokens = 0u64;
        let mut pinned = hit;
        for _ in hit..want {
            while self.resident_tokens + self.block as u64 > self.budget {
                match self.evict_lru_tail() {
                    Some(freed) => evicted_tokens += freed,
                    None => break,
                }
            }
            if self.resident_tokens + self.block as u64 > self.budget {
                break; // everything left is pinned — cap the insertion
            }
            self.chains
                .get_mut(&prefix_id)
                .expect("chain entry created above")
                .push(Block { refs: 1, last_used: tick });
            self.resident_tokens += self.block as u64;
            inserted_tokens += self.block as u64;
            pinned += 1;
        }
        Acquired {
            hit_tokens,
            inserted_tokens,
            evicted_tokens,
            pinned_len: pinned as u32 * self.block,
        }
    }

    /// Unpin the first `pinned_len` tokens of `prefix_id` (a request
    /// leaving the instance: completion, eviction, or prefill abort).
    /// Blocks stay resident — and reserved — until LRU eviction peels
    /// them; unpinning moves no bytes.
    pub fn release(&mut self, prefix_id: u64, pinned_len: u32) {
        if prefix_id == 0 || pinned_len == 0 {
            return;
        }
        let k = (pinned_len / self.block) as usize;
        if let Some(chain) = self.chains.get_mut(&prefix_id) {
            for b in chain.iter_mut().take(k) {
                b.refs = b.refs.saturating_sub(1);
            }
        }
    }

    /// Peel one evictable block: among chain tails with no pins (pins are
    /// prefix-monotone, so the tail always carries a chain's minimum
    /// refcount), the least recently used, ties on lineage id. Returns
    /// the tokens freed, or `None` when every tail is pinned.
    fn evict_lru_tail(&mut self) -> Option<u64> {
        let victim = self
            .chains
            .iter()
            .filter_map(|(&id, chain)| {
                let tail = chain.last()?;
                (tail.refs == 0).then_some((tail.last_used, id))
            })
            .min()?;
        let chain = self.chains.get_mut(&victim.1).expect("victim resident");
        chain.pop();
        if chain.is_empty() {
            self.chains.remove(&victim.1);
        }
        self.resident_tokens -= self.block as u64;
        self.stats.evictions += 1;
        self.stats.evicted_tokens += self.block as u64;
        Some(self.block as u64)
    }

    /// Tokens currently resident (each carrying a live KV reservation).
    pub fn resident_tokens(&self) -> u64 {
        self.resident_tokens
    }

    /// Counter snapshot for report folding.
    pub fn stats(&self) -> PrefixStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_stamp_is_lineage_less() {
        let s = PrefixStamp::default();
        assert_eq!(s.prefix_id, 0);
        assert_eq!((s.prefix_len, s.cached_len, s.shared_len), (0, 0, 0));
    }

    #[test]
    fn acquire_miss_then_hit_grows_and_reuses_the_chain() {
        let mut c = PrefixCache::new(32, 1000);
        // Cold: whole prefix inserted, nothing hit.
        let a = c.acquire(7, 96);
        assert_eq!(a.hit_tokens, 0);
        assert_eq!(a.inserted_tokens, 96);
        assert_eq!(a.pinned_len, 96);
        assert_eq!(c.resident_tokens(), 96);
        assert_eq!((c.stats().hits, c.stats().misses), (0, 1));
        // Warm: same lineage, longer shareable prefix → hit on the
        // resident chain, insert only the extension.
        let b = c.acquire(7, 160);
        assert_eq!(b.hit_tokens, 96);
        assert_eq!(b.inserted_tokens, 64);
        assert_eq!(b.pinned_len, 160);
        assert_eq!(c.resident_tokens(), 160);
        assert_eq!((c.stats().hits, c.stats().misses), (1, 1));
        assert_eq!(c.stats().hit_tokens, 96);
        // A shorter turn of the same session hits without inserting.
        let d = c.acquire(7, 64);
        assert_eq!(d.hit_tokens, 64);
        assert_eq!(d.inserted_tokens, 0);
        assert_eq!(d.pinned_len, 64);
    }

    #[test]
    fn sub_block_and_lineage_less_requests_are_plain_misses() {
        let mut c = PrefixCache::new(32, 1000);
        assert_eq!(c.acquire(0, 500), Acquired::default());
        assert_eq!(c.acquire(9, 31), Acquired::default(), "below one block");
        assert_eq!(c.stats().misses, 2);
        assert_eq!(c.resident_tokens(), 0);
        assert_eq!(c.match_len(0, 500), 0);
        // Partial blocks never count: 95 shareable → 2 whole blocks.
        c.acquire(9, 95);
        assert_eq!(c.resident_tokens(), 64);
        assert_eq!(c.match_len(9, 95), 64);
        assert_eq!(c.match_len(9, 32), 32, "capped by the probe's own want");
    }

    #[test]
    fn release_unpins_without_freeing_and_eviction_peels_lru_tails() {
        let mut c = PrefixCache::new(32, 128); // 4 blocks total
        let a = c.acquire(1, 64); // blocks: chain 1 → 2
        let b = c.acquire(2, 64); // chain 2 → 2; cache full
        assert_eq!(c.resident_tokens(), 128);
        // Full and everything pinned: a third lineage cannot insert.
        let d = c.acquire(3, 64);
        assert_eq!(d.inserted_tokens, 0);
        assert_eq!(d.pinned_len, 0);
        // Unpin chain 1 — still resident (free hits for its session)...
        c.release(1, a.pinned_len);
        assert_eq!(c.resident_tokens(), 128);
        assert_eq!(c.match_len(1, 64), 64);
        // ...until a new lineage needs the space: LRU peels chain 1
        // (older last_used than chain 2), not the still-pinned chain 2.
        let e = c.acquire(4, 64);
        assert_eq!(e.inserted_tokens, 64);
        assert_eq!(e.evicted_tokens, 64);
        assert_eq!(c.match_len(1, 64), 0, "chain 1 evicted");
        assert_eq!(c.match_len(2, 64), 64, "pinned chain 2 survives");
        assert_eq!(c.stats().evictions, 2);
        assert_eq!(c.stats().evicted_tokens, 64);
        c.release(2, b.pinned_len);
        c.release(4, e.pinned_len);
    }

    #[test]
    fn eviction_is_deterministic_on_lru_ties() {
        // Two unpinned chains inserted by the same acquisition clock
        // ordering; ties break on lineage id, lowest first.
        let mut c = PrefixCache::new(32, 64);
        let a = c.acquire(5, 32);
        c.release(5, a.pinned_len);
        let b = c.acquire(3, 32);
        c.release(3, b.pinned_len);
        // Chain 5 is older → evicted first even though 3 < 5.
        let d = c.acquire(9, 64);
        assert_eq!(d.inserted_tokens, 64);
        assert_eq!(d.evicted_tokens, 64, "both chains peeled");
        assert_eq!(c.match_len(5, 32), 0);
        assert_eq!(c.match_len(3, 32), 0);
    }

    #[test]
    fn pinned_prefix_keeps_its_ancestors_resident() {
        // Radix invariant: a later turn pins a *longer* chain; releasing
        // the short pin leaves the deep pin protecting the whole path.
        let mut c = PrefixCache::new(32, 128);
        let short = c.acquire(1, 32);
        let long = c.acquire(1, 128); // pins blocks 0..4
        c.release(1, short.pinned_len);
        // Budget pressure from another lineage cannot peel chain 1: its
        // tail is pinned, and pins are prefix-monotone.
        let d = c.acquire(2, 64);
        assert_eq!(d.inserted_tokens, 0, "no unpinned tail to evict");
        assert_eq!(c.match_len(1, 128), 128);
        c.release(1, long.pinned_len);
        // Now the whole chain is unpinned and the insert succeeds.
        let e = c.acquire(2, 64);
        assert_eq!(e.inserted_tokens, 64);
        assert_eq!(e.evicted_tokens, 64);
    }

    #[test]
    fn books_balance_inserted_minus_evicted() {
        // The scheduler charges `inserted - evicted` net per acquisition;
        // summed over any sequence of operations that must equal the
        // resident total, or the monitor's KV books would drift.
        let mut c = PrefixCache::new(16, 160);
        let mut net = 0i64;
        let mut pins: Vec<(u64, u32)> = Vec::new();
        for (id, share) in
            [(1u64, 64u32), (2, 48), (1, 96), (3, 160), (2, 32), (4, 80)]
        {
            let a = c.acquire(id, share);
            net += a.inserted_tokens as i64 - a.evicted_tokens as i64;
            pins.push((id, a.pinned_len));
            if pins.len() % 2 == 0 {
                let (rid, plen) = pins.remove(0);
                c.release(rid, plen);
            }
        }
        assert_eq!(net, c.resident_tokens() as i64);
        assert!(c.resident_tokens() <= 160, "budget respected");
    }
}
