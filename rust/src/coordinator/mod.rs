//! The paper's contribution: bucket-based dynamic batching.
//!
//! * [`bucket`] — the Request Bucketing Manager (Algorithm 1): adaptive
//!   split/merge of sequence-length buckets.
//! * [`batcher`] — the Dynamic Batching Controller (Eqs. 1–6): memory-safe
//!   batch sizing and longest-wait prioritization.
//! * [`monitor`] — the Global Monitor: sliding-window system metrics that
//!   feed the batcher and scheduler.
//! * [`scheduler`] — the P/D serving loop shared by BucketServe and the
//!   disaggregated baseline: FCFS prefill workers, NVLink hand-off, and
//!   continuous-batching decode instances.
//!
//! [`BucketServe`] ties them together behind a single façade used by the
//! CLI, the examples, and every figure bench.

pub mod bucket;
pub mod batcher;
pub mod monitor;
pub mod scheduler;

pub use bucket::{Bucket, BucketManager};
pub use batcher::{DynamicBatcher, KvMemoryModel};
pub use monitor::GlobalMonitor;
pub use scheduler::{PdScheduler, RunReport, PrefillPlanner};

use crate::cluster::Engine;
use crate::config::SystemConfig;
use crate::workload::Trace;

/// The BucketServe system façade: bucket planner + P/D serving loop.
pub struct BucketServe {
    cfg: SystemConfig,
}

impl BucketServe {
    pub fn new(cfg: SystemConfig) -> BucketServe {
        BucketServe { cfg }
    }

    /// Serve a trace on `engine`, returning the full run report.
    pub fn run(&self, trace: &Trace, engine: &mut dyn Engine) -> RunReport {
        let planner = scheduler::BucketPlanner::new(&self.cfg);
        let mut sched = PdScheduler::new(&self.cfg, Box::new(planner));
        sched.run(trace, engine)
    }

    pub fn config(&self) -> &SystemConfig {
        &self.cfg
    }
}
