//! The paper's contribution: bucket-based dynamic batching with
//! priority-aware, preemptive, event-driven scheduling.
//!
//! * [`bucket`] — the Request Bucketing Manager (Algorithm 1): adaptive
//!   split/merge of sequence-length buckets.
//! * [`batcher`] — the Dynamic Batching Controller (Eqs. 1–6): memory-safe
//!   batch sizing; drains by priority score (or policy order) per bucket.
//! * [`priority`] — SLO-deadline urgency scoring: online slack to
//!   `arrival + slo.ttft_us`, offline throughput class with starvation
//!   aging; replaces pure earliest-arrival drain when enabled.
//! * [`events`] — the typed event queue the serving loop pops in
//!   timestamp order, with tombstone cancellation for retracting
//!   scheduled completions.
//! * [`fleet`] — instance state machines: prefill busy slots and decode
//!   continuous-batching instances with KV reservations.
//! * [`preempt`] — the preemption subsystem: urgency-triggered prefill
//!   abort-and-requeue and decode KV eviction with
//!   checkpoint-and-restore (off by default, `PreemptSpec`-gated).
//! * [`admission`] — TBT-aware decode admission: per-iteration deferral
//!   of batches whose projected iteration time would blow a resident
//!   online sequence's inter-token budget, and TBT-triggered eviction of
//!   offline actives through the preemption machinery (off by default,
//!   `AdmissionSpec`-gated).
//! * [`prefix`] — the simulated radix-style KV prefix cache, one per
//!   decode instance: lineage chains of refcounted token blocks under a
//!   budget, LRU-peeled; prefill is priced on the uncached suffix and
//!   shared blocks reserve KV once (off by default, `PrefixSpec`-gated).
//! * [`lookahead`] — the deadline-lookahead planner family
//!   (`planner.family = lookahead`): deadline-sorted queue, batches
//!   formed backwards from the earliest deadline over a bounded window,
//!   held until their latest feasible start while slack allows.
//! * [`shard`] — per-decode-instance scheduler shards: each owns its own
//!   bucket queue, KV admission, and priority state; KV-aware
//!   work-stealing pulls backlog onto idle shards at decode-iteration
//!   boundaries.
//! * [`balance`] — the placement layer: arrival→shard routing policies
//!   (least-loaded / join-shortest-KV / hash), per-shard decode
//!   targeting, and steal-victim selection.
//! * [`monitor`] — the Global Monitor: per-shard sliding-window metrics
//!   aggregated into the system view that feeds the batcher and
//!   scheduler.
//! * [`executor`] — the thread-per-shard parallel executor: same-instant
//!   decode-iteration boundaries *and* per-shard prefill planning
//!   (snapshot → speculate → commit, `executor.plan_offload`) fan out to
//!   per-shard worker threads as pure jobs and merge back in
//!   deterministic `(virtual_time, event_id)` order; for any seed, any
//!   `executor.threads`, and either `plan_offload` setting the Summary
//!   JSON is byte-identical to the sequential run (`threads = 1`, the
//!   default).
//! * [`scheduler`] — the thin P/D orchestrator shared by BucketServe and
//!   the disaggregated baseline: pops events, dispatches to the fleet,
//!   plans batches through per-shard [`PrefillPlanner`] plug-ins.
//! * [`live`] — the realtime-serving protocol between a front end and
//!   [`PdScheduler::run_realtime`]: the [`live::LiveCmd`] command
//!   channel (submit/abort/health/loads/shutdown) and the bounded
//!   [`live::StreamSink`] per-request delivery buffers that carry
//!   streamed token lines without ever blocking the scheduler.
//!
//! # Event flow
//!
//! A request moves through the system as a chain of typed events and
//! state-driven phases:
//!
//! ```text
//! Arrival ─▶ placement ─▶ shard queue ─▶ plan (Eq. 6) ─▶ TBT admission
//!                             ▲              ▲          gate (defer?) ─▶
//!                             │              │(deferred)     │ prefill
//!                             │              └───◀───────────┤ in flight
//!                             │              PrefillDone ◀───┘         │
//!   (abort: completion event  │                   │      PreemptPrefill│
//!    tombstoned, waste        ├───────────────────│──────◀─────────────┘
//!    charged, KV released,    │                   ▼
//!    requests requeued)       │         HandoffReady (NVLink)
//!                             │                   ▼
//!   (evict-with-checkpoint:   │        decode pending ─▶ active
//!    KV released, generated   │                   │
//!    tokens checkpointed,     │       DecodeIterEnd (token++, gap vs TBT
//!    RestoreReady requeues    │                   │    budget, completions,
//!    recompute work whose     ├──────◀────────────┤    KV release)
//!    prefill replays the      │                   ├─▶ TBT evict pass
//!    full context)            ├──────◀────────────┘   (shed offline)
//!                             │                   └─▶ work-stealing
//!                             │                       rebalance (KV-capped)
//! ```
//!
//! Preemption states: an in-flight prefill batch is either *completed*
//! (`PrefillDone` fires) or *aborted* (`PreemptPrefill` fires first and
//! tombstones the completion); an active decode sequence is either
//! *finished* (at an iteration boundary) or *evicted* (checkpointed,
//! requeued at `RestoreReady`, and resumed after its recompute prefill
//! with its original TTFT intact). Both preemption paths trigger only
//! while an online request has burned past `preempt.urgency_threshold`
//! of its TTFT budget, and at most one preemption is outstanding at a
//! time (see [`preempt::PreemptionEngine`]).
//!
//! Admission decision points (off by default, `AdmissionSpec`-gated):
//! at *dispatch*, a formed batch only commits to a decode instance whose
//! projected next iteration keeps every resident online sequence inside
//! its inter-token (TBT) budget — otherwise it retargets to the shard's
//! next-best instance or defers back to the queue; at every
//! *DecodeIterEnd*, each produced token's gap is scored against its
//! sequence's budget and, when the next projected iteration would blow
//! an online budget, least-urgent offline actives are shed through the
//! same evict-with-checkpoint path (see [`admission::AdmissionEngine`]).
//! The full knob-by-knob table lives in `docs/ARCHITECTURE.md`.
//!
//! [`BucketServe`] ties them together behind a single façade used by the
//! CLI, the examples, and every figure bench.

pub mod admission;
pub mod bucket;
pub mod batcher;
pub mod balance;
pub mod events;
pub mod executor;
pub mod fleet;
pub mod live;
pub mod lookahead;
pub mod monitor;
pub mod preempt;
pub mod prefix;
pub mod priority;
pub mod scheduler;
pub mod shard;

pub use admission::AdmissionEngine;
pub use bucket::{Bucket, BucketManager};
pub use batcher::{DynamicBatcher, KvMemoryModel};
pub use balance::{Router, ShardLoad};
pub use events::{Event, EventId, EventKind, EventQueue};
pub use executor::ExecutorPool;
pub use fleet::{DecodeFleet, PrefillFleet};
pub use live::{HealthInfo, LiveCmd, LoadsInfo, StreamMsg, StreamSink};
pub use lookahead::LookaheadPlanner;
pub use monitor::{GlobalMonitor, MonitorView, ShardView};
pub use preempt::{PreemptionEngine, RestoreInfo};
pub use prefix::{PrefixCache, PrefixStamp};
pub use priority::PriorityScorer;
pub use scheduler::{PdScheduler, RunReport, PrefillPlanner};
pub use shard::{SchedulerShard, ShardSet, ShardStats};

use crate::cluster::Engine;
use crate::config::{PlannerFamily, SystemConfig};
use crate::workload::Trace;

/// The BucketServe system façade: planner family + P/D serving loop.
pub struct BucketServe {
    cfg: SystemConfig,
}

impl BucketServe {
    pub fn new(cfg: SystemConfig) -> BucketServe {
        BucketServe { cfg }
    }

    /// Serve a trace on `engine`, returning the full run report. Each
    /// scheduler shard gets its own planner of the configured family
    /// (`planner.family`; `bucket`, the default, is the paper's planner
    /// and keeps output byte-identical to the pre-planner-block system).
    pub fn run(&self, trace: &Trace, engine: &mut dyn Engine) -> RunReport {
        let mut sched = match self.cfg.planner.family {
            PlannerFamily::Bucket => PdScheduler::new(&self.cfg, || {
                Box::new(scheduler::BucketPlanner::new(&self.cfg))
            }),
            PlannerFamily::Fcfs => PdScheduler::new(&self.cfg, || {
                Box::new(crate::baselines::distserve::FcfsPlanner::new(&self.cfg))
            }),
            PlannerFamily::Lookahead => PdScheduler::new(&self.cfg, || {
                Box::new(LookaheadPlanner::new(&self.cfg))
            }),
        };
        sched.run(trace, engine)
    }

    pub fn config(&self) -> &SystemConfig {
        &self.cfg
    }
}
