//! The paper's contribution: bucket-based dynamic batching with
//! priority-aware, event-driven scheduling.
//!
//! * [`bucket`] — the Request Bucketing Manager (Algorithm 1): adaptive
//!   split/merge of sequence-length buckets.
//! * [`batcher`] — the Dynamic Batching Controller (Eqs. 1–6): memory-safe
//!   batch sizing; drains by priority score (or policy order) per bucket.
//! * [`priority`] — SLO-deadline urgency scoring: online slack to
//!   `arrival + slo.ttft_us`, offline throughput class with starvation
//!   aging; replaces pure earliest-arrival drain when enabled.
//! * [`events`] — the typed event queue (arrivals, prefill completions,
//!   KV hand-off landings, decode iteration boundaries) the serving loop
//!   pops in timestamp order.
//! * [`fleet`] — instance state machines: prefill busy slots and decode
//!   continuous-batching instances with KV reservations.
//! * [`monitor`] — the Global Monitor: sliding-window system metrics that
//!   feed the batcher and scheduler.
//! * [`scheduler`] — the thin P/D orchestrator shared by BucketServe and
//!   the disaggregated baseline: pops events, dispatches to the fleet,
//!   plans batches through the [`PrefillPlanner`] plug-in.
//!
//! [`BucketServe`] ties them together behind a single façade used by the
//! CLI, the examples, and every figure bench.

pub mod bucket;
pub mod batcher;
pub mod events;
pub mod fleet;
pub mod monitor;
pub mod priority;
pub mod scheduler;

pub use bucket::{Bucket, BucketManager};
pub use batcher::{DynamicBatcher, KvMemoryModel};
pub use events::{Event, EventKind, EventQueue};
pub use fleet::{DecodeFleet, PrefillFleet};
pub use monitor::GlobalMonitor;
pub use priority::PriorityScorer;
pub use scheduler::{PdScheduler, RunReport, PrefillPlanner};

use crate::cluster::Engine;
use crate::config::SystemConfig;
use crate::workload::Trace;

/// The BucketServe system façade: bucket planner + P/D serving loop.
pub struct BucketServe {
    cfg: SystemConfig,
}

impl BucketServe {
    pub fn new(cfg: SystemConfig) -> BucketServe {
        BucketServe { cfg }
    }

    /// Serve a trace on `engine`, returning the full run report.
    pub fn run(&self, trace: &Trace, engine: &mut dyn Engine) -> RunReport {
        let planner = scheduler::BucketPlanner::new(&self.cfg);
        let mut sched = PdScheduler::new(&self.cfg, Box::new(planner));
        sched.run(trace, engine)
    }

    pub fn config(&self) -> &SystemConfig {
        &self.cfg
    }
}
