//! Request Bucketing Manager — Algorithm 1 of the paper.
//!
//! Requests are grouped into contiguous sequence-length buckets
//! `[low, up)` that always partition `[0, L_max)`:
//!
//! * **Assign** (Alg. 1 lines 2–9): each arriving request lands in the
//!   bucket covering its prompt length (binary search over the sorted
//!   boundary array — the "binary tree" optimization the paper lists as
//!   future work; the linear scan it analyses as `O(n·k)` is kept for the
//!   ablation bench).
//! * **AdjustBuckets** (lines 10–31): when the total queued count is below
//!   `N_max`, all buckets merge back into the single `[0, L_max)` bucket
//!   (minimal scheduling overhead). Otherwise any bucket where more than
//!   θ = 0.5 of requests sit below the midpoint *and* which holds more
//!   than `m = N_max` requests is bisected, approximating the optimal
//!   conditional-expectation boundary of Eq. 4.
//!
//! Every call's wall-clock cost is accumulated in [`BucketManager::overhead_ns`]
//! — that is the red "bucketing overhead" bar of Fig. 6.

use super::prefix::PrefixStamp;
use crate::workload::{RequestClass, RequestId};
use crate::Micros;
use std::time::Instant;

/// A queued request as the bucketing layer sees it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QueuedReq {
    pub id: RequestId,
    pub len: u32,
    pub output_len: u32,
    pub arrival: Micros,
    pub class: RequestClass,
    /// Per-token TBT budget override in µs (0 = class default), carried
    /// from [`crate::workload::Request::tbt_deadline_us`] so the
    /// TBT-aware admission layer sees stamped budgets through requeues,
    /// steals, and checkpoint-restores.
    pub tbt_us: u64,
    /// Prefix-cache lineage and acquisition state
    /// ([`crate::coordinator::prefix`]); all-zero (the default) unless
    /// the prefix subsystem is armed, which keeps every computation below
    /// byte-identical to the pre-prefix forms.
    pub prefix: PrefixStamp,
}

impl QueuedReq {
    /// KV token footprint this request reserves for itself: full context
    /// (prompt + expected generation) minus the tokens pinned in the
    /// owning instance's prefix cache, whose reservation the cache holds
    /// once on behalf of every sharer. The single definition every
    /// reserve/admission/steal/eviction site must share, or the KV
    /// reserve/release books stop balancing.
    pub fn footprint(&self) -> u64 {
        ((self.len + self.output_len) as u64)
            .saturating_sub(self.prefix.shared_len as u64)
    }

    /// The bucketing key: the prompt length that will actually be
    /// *computed* — the uncached suffix when a prefix hit is stamped, the
    /// raw length otherwise. Keying on this keeps size-homogeneous
    /// buckets homogeneous in real prefill compute once cached prefixes
    /// stop costing FLOPs.
    pub fn bucket_len(&self) -> u32 {
        self.len.saturating_sub(self.prefix.cached_len)
    }
}

/// One sequence-length bucket `[low, up)`.
#[derive(Debug, Clone)]
pub struct Bucket {
    pub low: u32,
    pub up: u32,
    pub requests: Vec<QueuedReq>,
}

impl Bucket {
    pub fn new(low: u32, up: u32) -> Bucket {
        assert!(low < up, "bucket [{low},{up}) empty range");
        Bucket { low, up, requests: Vec::new() }
    }

    pub fn covers(&self, len: u32) -> bool {
        self.low <= len && len < self.up
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    pub fn midpoint(&self) -> u32 {
        self.low + (self.up - self.low) / 2
    }

    /// Earliest arrival among queued requests (for online bucket priority).
    pub fn earliest_arrival(&self) -> Option<Micros> {
        self.requests.iter().map(|r| r.arrival).min()
    }
}

/// The adaptive bucketing manager.
#[derive(Debug, Clone)]
pub struct BucketManager {
    /// Sorted by `low`; always a contiguous partition of [0, l_max).
    buckets: Vec<Bucket>,
    l_max: u32,
    theta: f64,
    min_width: u32,
    /// Cumulative wall-clock nanoseconds spent in assign + adjust — the
    /// paper's "bucketing overhead" (Fig. 6).
    pub overhead_ns: u64,
    /// Number of adjust() invocations that split at least one bucket.
    pub splits: u64,
    /// Number of adjust() invocations that merged back to one bucket.
    pub merges: u64,
    /// Use the O(k) linear scan from the paper's complexity analysis
    /// instead of binary search (ablation knob).
    pub linear_scan: bool,
}

impl BucketManager {
    pub fn new(l_max: u32, theta: f64, min_width: u32) -> BucketManager {
        assert!(l_max > 0);
        BucketManager {
            buckets: vec![Bucket::new(0, l_max)],
            l_max,
            theta,
            min_width: min_width.max(1),
            overhead_ns: 0,
            splits: 0,
            merges: 0,
            linear_scan: false,
        }
    }

    /// Assign one request to its covering bucket (Alg. 1 lines 2–9).
    /// Keyed on [`QueuedReq::bucket_len`] (the uncached suffix; the raw
    /// length when no prefix hit is stamped). Lengths ≥ L_max clamp into
    /// the last bucket.
    pub fn assign(&mut self, req: QueuedReq) {
        let t0 = Instant::now();
        let len = req.bucket_len().min(self.l_max - 1);
        let idx = if self.linear_scan {
            self.buckets
                .iter()
                .position(|b| b.covers(len))
                .expect("buckets partition [0, l_max)")
        } else {
            // Binary search on lower bounds: last bucket with low <= len.
            match self.buckets.binary_search_by(|b| b.low.cmp(&len)) {
                Ok(i) => i,
                Err(i) => i - 1, // i >= 1 because buckets[0].low == 0
            }
        };
        debug_assert!(self.buckets[idx].covers(len));
        self.buckets[idx].requests.push(req);
        self.overhead_ns += t0.elapsed().as_nanos() as u64;
    }

    /// AdjustBuckets (Alg. 1 lines 10–31). `n_max` is the current
    /// memory-safe batch size from Eq. 6 (both the merge threshold and the
    /// minimum split size `m`).
    pub fn adjust(&mut self, n_max: usize) {
        let t0 = Instant::now();
        let total = self.total();
        if total < n_max.max(1) {
            // Lines 11–13: merge everything back into [0, L_max).
            if self.buckets.len() > 1 {
                let runs: Vec<Vec<QueuedReq>> = self
                    .buckets
                    .iter_mut()
                    .map(|b| std::mem::take(&mut b.requests))
                    .collect();
                self.buckets = vec![Bucket::new(0, self.l_max)];
                self.buckets[0].requests = merge_by_arrival(runs, total);
                self.merges += 1;
            }
        } else {
            // Lines 15–29: bisect skewed, oversized buckets.
            let mut split_any = false;
            let mut next: Vec<Bucket> = Vec::with_capacity(self.buckets.len() + 4);
            for bucket in self.buckets.drain(..) {
                let width = bucket.up - bucket.low;
                let mid = bucket.midpoint();
                let n = bucket.len();
                let c_s = bucket
                    .requests
                    .iter()
                    .filter(|r| r.bucket_len().min(self.l_max - 1) < mid)
                    .count();
                let skewed = n > 0 && (c_s as f64 / n as f64) > self.theta;
                if skewed && n > n_max && width >= 2 * self.min_width {
                    let mut lo = Bucket::new(bucket.low, mid);
                    let mut hi = Bucket::new(mid, bucket.up);
                    for r in bucket.requests {
                        if r.bucket_len().min(self.l_max - 1) < mid {
                            lo.requests.push(r);
                        } else {
                            hi.requests.push(r);
                        }
                    }
                    next.push(lo);
                    next.push(hi);
                    split_any = true;
                } else {
                    next.push(bucket);
                }
            }
            self.buckets = next;
            if split_any {
                self.splits += 1;
            }
        }
        self.overhead_ns += t0.elapsed().as_nanos() as u64;
    }

    pub fn total(&self) -> usize {
        self.buckets.iter().map(|b| b.len()).sum()
    }

    /// Σ full-context (prompt + expected generation) footprint of every
    /// queued request, as one integer-exact u64 sum. Feeds the mean-
    /// length estimate in Eq. 6's `N_max` and KV-aware placement weights;
    /// kept in integer space so the value is independent of bucket
    /// iteration order (an f64 accumulation would not be).
    pub fn total_footprint(&self) -> u64 {
        self.buckets
            .iter()
            .flat_map(|b| b.requests.iter())
            .map(|r| r.footprint())
            .sum()
    }

    pub fn n_buckets(&self) -> usize {
        self.buckets.len()
    }

    pub fn buckets(&self) -> &[Bucket] {
        &self.buckets
    }

    pub fn buckets_mut(&mut self) -> &mut [Bucket] {
        &mut self.buckets
    }

    pub fn l_max(&self) -> u32 {
        self.l_max
    }

    /// Expected waste rate (Eq. 3) over the currently queued requests,
    /// treating the queue as the empirical length distribution f(S):
    /// each request in bucket b wastes (1 − S/U_b).
    pub fn expected_waste(&self) -> f64 {
        let total = self.total();
        if total == 0 {
            return 0.0;
        }
        let mut acc = 0.0;
        for b in &self.buckets {
            for r in &b.requests {
                let s = r.len.min(b.up - 1) as f64;
                acc += 1.0 - s / b.up as f64;
            }
        }
        acc / total as f64
    }

    /// Check the structural invariant: buckets sorted, contiguous, and
    /// exactly covering [0, l_max); every request inside its bucket range.
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.buckets.is_empty() {
            return Err("no buckets".into());
        }
        if self.buckets[0].low != 0 {
            return Err("first bucket must start at 0".into());
        }
        if self.buckets.last().unwrap().up != self.l_max {
            return Err("last bucket must end at l_max".into());
        }
        for w in self.buckets.windows(2) {
            if w[0].up != w[1].low {
                return Err(format!(
                    "gap/overlap between [{},{}) and [{},{})",
                    w[0].low, w[0].up, w[1].low, w[1].up
                ));
            }
        }
        for b in &self.buckets {
            for r in &b.requests {
                if !b.covers(r.bucket_len().min(self.l_max - 1)) {
                    return Err(format!(
                        "request bucket_len {} outside bucket [{},{})",
                        r.bucket_len(),
                        b.low,
                        b.up
                    ));
                }
            }
        }
        Ok(())
    }

    /// Drain every queued request (used on shutdown paths and by tests).
    pub fn drain_all(&mut self) -> Vec<QueuedReq> {
        let total = self.total();
        let runs: Vec<Vec<QueuedReq>> = self
            .buckets
            .iter_mut()
            .map(|b| std::mem::take(&mut b.requests))
            .collect();
        merge_by_arrival(runs, total)
    }
}

/// K-way merge of per-bucket queues into one arrival-ordered (FCFS)
/// queue. Buckets are arrival-ordered by construction — assignment
/// appends in arrival order, splits and FCFS drains preserve it — so the
/// merge is `O(n·k)` with tiny `k` instead of the old full `O(n log n)`
/// re-sort of the concatenation. A run that a policy sort (SJF / LJF /
/// priority drain) left out of order is normalized first, which is a
/// no-op `is_sorted` scan on the common path. Ties pop from the
/// lowest-index run with intra-run order intact — exactly the order the
/// old concatenate-then-stable-sort produced.
fn merge_by_arrival(mut runs: Vec<Vec<QueuedReq>>, total: usize) -> Vec<QueuedReq> {
    for run in &mut runs {
        if !run.windows(2).all(|w| w[0].arrival <= w[1].arrival) {
            run.sort_by_key(|r| r.arrival); // stable: intra-run ties keep order
        }
    }
    let mut cursors = vec![0usize; runs.len()];
    let mut out = Vec::with_capacity(total);
    for _ in 0..total {
        let mut best: Option<usize> = None;
        for (i, run) in runs.iter().enumerate() {
            if cursors[i] >= run.len() {
                continue;
            }
            let better = match best {
                None => true,
                Some(j) => run[cursors[i]].arrival < runs[j][cursors[j]].arrival,
            };
            if better {
                best = Some(i);
            }
        }
        match best {
            Some(i) => {
                out.push(runs[i][cursors[i]]);
                cursors[i] += 1;
            }
            None => break,
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn req(id: u64, len: u32) -> QueuedReq {
        QueuedReq {
            id,
            len,
            output_len: 10,
            arrival: id * 10,
            class: RequestClass::Online,
            tbt_us: 0,
            prefix: PrefixStamp::default(),
        }
    }

    #[test]
    fn bucket_keying_uses_uncached_length_and_dedupes_footprint() {
        let mut m = BucketManager::new(1024, 0.5, 16);
        // Force a split so short and long buckets exist.
        for i in 0..8 {
            m.assign(req(i, 100));
        }
        for i in 8..10 {
            m.assign(req(i, 800));
        }
        m.adjust(4);
        assert_eq!(m.n_buckets(), 2);
        // A long prompt whose stamped hit leaves only a short suffix to
        // compute must land in the *short* bucket.
        let mut r = req(100, 900);
        r.prefix = PrefixStamp {
            prefix_id: 7,
            prefix_len: 800,
            cached_len: 800,
            shared_len: 800,
        };
        assert_eq!(r.bucket_len(), 100);
        assert_eq!(r.footprint(), (900 + 10 - 800) as u64);
        m.assign(r);
        assert!(m.buckets()[0].requests.iter().any(|q| q.id == 100));
        m.check_invariants().unwrap();
    }

    #[test]
    fn starts_with_single_full_bucket() {
        let m = BucketManager::new(4096, 0.5, 16);
        assert_eq!(m.n_buckets(), 1);
        assert_eq!(m.buckets()[0].low, 0);
        assert_eq!(m.buckets()[0].up, 4096);
        m.check_invariants().unwrap();
    }

    #[test]
    fn assign_routes_by_length() {
        let mut m = BucketManager::new(1024, 0.5, 16);
        // Force a split so there are multiple buckets.
        for i in 0..20 {
            m.assign(req(i, 10)); // all short → skewed
        }
        for i in 20..24 {
            m.assign(req(i, 900));
        }
        m.adjust(8); // total 24 >= 8 → split [0,1024) at 512
        assert!(m.n_buckets() >= 2);
        m.check_invariants().unwrap();
        m.assign(req(100, 700));
        let b = m
            .buckets()
            .iter()
            .find(|b| b.covers(700))
            .unwrap();
        assert!(b.requests.iter().any(|r| r.id == 100));
    }

    #[test]
    fn clamps_overlong_requests_into_last_bucket() {
        let mut m = BucketManager::new(256, 0.5, 16);
        m.assign(req(1, 10_000));
        assert_eq!(m.total(), 1);
        m.check_invariants().unwrap();
    }

    #[test]
    fn merges_below_n_max() {
        let mut m = BucketManager::new(1024, 0.5, 16);
        // Skewed: 30 short + 10 long so the split condition (> θ) holds.
        for i in 0..40 {
            m.assign(req(i, if i % 4 != 0 { 10 } else { 800 }));
        }
        m.adjust(8);
        assert!(m.n_buckets() > 1, "split should have happened");
        // Drain most requests, then adjust again → must merge to 1 bucket.
        for b in m.buckets_mut() {
            b.requests.truncate(1);
        }
        m.adjust(8);
        assert_eq!(m.n_buckets(), 1);
        m.check_invariants().unwrap();
        assert!(m.merges >= 1);
    }

    #[test]
    fn splits_skewed_bucket_at_midpoint() {
        let mut m = BucketManager::new(1024, 0.5, 16);
        // 10 requests, 8 below midpoint 512 → skew 0.8 > θ=0.5, n=10 > n_max=4.
        for i in 0..8 {
            m.assign(req(i, 100));
        }
        for i in 8..10 {
            m.assign(req(i, 800));
        }
        m.adjust(4);
        assert_eq!(m.n_buckets(), 2);
        assert_eq!(m.buckets()[0].up, 512);
        assert_eq!(m.buckets()[0].len(), 8);
        assert_eq!(m.buckets()[1].len(), 2);
        assert!(m.splits >= 1);
        m.check_invariants().unwrap();
    }

    #[test]
    fn does_not_split_balanced_bucket() {
        let mut m = BucketManager::new(1024, 0.5, 16);
        // Exactly half below midpoint → C_s/n == 0.5, NOT > θ → no split.
        for i in 0..5 {
            m.assign(req(i, 100));
        }
        for i in 5..10 {
            m.assign(req(i, 800));
        }
        m.adjust(4);
        assert_eq!(m.n_buckets(), 1);
    }

    #[test]
    fn does_not_split_small_bucket() {
        let mut m = BucketManager::new(1024, 0.5, 16);
        for i in 0..4 {
            m.assign(req(i, 100));
        }
        // total 4 >= n_max 2, but each bucket must hold > n_max=4 → no.
        m.adjust(4);
        assert_eq!(m.n_buckets(), 1);
    }

    #[test]
    fn respects_min_width() {
        let mut m = BucketManager::new(64, 0.5, 32);
        for i in 0..50 {
            m.assign(req(i, 1));
        }
        m.adjust(4); // [0,64) splits to [0,32),[32,64)
        m.adjust(4); // [0,32) width 32 < 2*min_width → stop
        assert_eq!(m.n_buckets(), 2);
        m.check_invariants().unwrap();
    }

    #[test]
    fn repeated_adjust_converges() {
        let mut m = BucketManager::new(4096, 0.5, 16);
        let mut id = 0;
        for &len in &[10u32, 20, 50, 80, 120, 300, 700, 1500, 3000] {
            for _ in 0..30 {
                m.assign(req(id, len));
                id += 1;
            }
        }
        let mut prev = 0;
        for _ in 0..20 {
            m.adjust(16);
            m.check_invariants().unwrap();
            let n = m.n_buckets();
            if n == prev {
                break;
            }
            prev = n;
        }
        // Converged to a stable partition with several buckets.
        assert!(m.n_buckets() > 2);
        let before = m.n_buckets();
        m.adjust(16);
        assert_eq!(m.n_buckets(), before, "fixed point reached");
    }

    #[test]
    fn expected_waste_decreases_after_split() {
        let mut m = BucketManager::new(1024, 0.5, 16);
        for i in 0..20 {
            m.assign(req(i, 50));
        }
        for i in 20..28 {
            m.assign(req(i, 1000));
        }
        let before = m.expected_waste();
        m.adjust(8);
        let after = m.expected_waste();
        assert!(
            after < before,
            "waste should drop: before {before} after {after}"
        );
    }

    #[test]
    fn merge_restores_fcfs_order() {
        let mut m = BucketManager::new(1024, 0.5, 16);
        for i in 0..10 {
            m.assign(req(i, 100));
        }
        for i in 10..20 {
            m.assign(req(i, 900));
        }
        m.adjust(4); // split
        for b in m.buckets_mut() {
            b.requests.truncate(1);
        }
        m.adjust(100); // merge
        let arrivals: Vec<_> =
            m.buckets()[0].requests.iter().map(|r| r.arrival).collect();
        let mut sorted = arrivals.clone();
        sorted.sort();
        assert_eq!(arrivals, sorted);
    }

    #[test]
    fn merge_handles_policy_permuted_runs() {
        // An SJF/LJF/priority drain can leave a bucket's residue sorted by
        // length, not arrival; the k-way merge must normalize such runs
        // and still produce one globally FCFS queue.
        let mut m = BucketManager::new(1024, 0.5, 16);
        for i in 0..8 {
            m.assign(req(i, 100));
        }
        for i in 8..12 {
            m.assign(req(i, 900));
        }
        m.adjust(4); // split into short/long buckets
        assert!(m.n_buckets() >= 2);
        // Simulate a policy sort: reverse the short bucket's queue.
        m.buckets_mut()[0].requests.reverse();
        m.adjust(100); // merge back
        assert_eq!(m.n_buckets(), 1);
        let arrivals: Vec<_> =
            m.buckets()[0].requests.iter().map(|r| r.arrival).collect();
        let mut sorted = arrivals.clone();
        sorted.sort();
        assert_eq!(arrivals, sorted, "merge must restore FCFS order");
        assert_eq!(m.total(), 12);
        m.check_invariants().unwrap();
    }

    #[test]
    fn total_footprint_sums_queued_requests_across_buckets() {
        let mut m = BucketManager::new(1024, 0.5, 16);
        assert_eq!(m.total_footprint(), 0);
        for i in 0..8 {
            m.assign(req(i, 100));
        }
        for i in 8..12 {
            m.assign(req(i, 900));
        }
        m.adjust(4); // split — the sum must span every bucket
        assert!(m.n_buckets() >= 2);
        let expected: u64 = (0..8)
            .map(|_| (100 + 10) as u64)
            .chain((8..12).map(|_| (900 + 10) as u64))
            .sum();
        assert_eq!(m.total_footprint(), expected);
        // Prefix-stamped requests contribute their deduplicated
        // (uncached-suffix) footprint, same as placement weighing.
        let mut r = req(100, 900);
        r.prefix = PrefixStamp {
            prefix_id: 7,
            prefix_len: 800,
            cached_len: 800,
            shared_len: 800,
        };
        m.assign(r);
        assert_eq!(m.total_footprint(), expected + (900 + 10 - 800) as u64);
    }

    #[test]
    fn overhead_is_tracked() {
        let mut m = BucketManager::new(1024, 0.5, 16);
        for i in 0..100 {
            m.assign(req(i, (i * 7 % 1000) as u32));
        }
        m.adjust(8);
        assert!(m.overhead_ns > 0);
    }

    #[test]
    fn linear_and_binary_assignment_agree() {
        let mut a = BucketManager::new(2048, 0.5, 16);
        let mut b = BucketManager::new(2048, 0.5, 16);
        b.linear_scan = true;
        for i in 0..200 {
            let r = req(i, (i * 37 % 2500) as u32);
            a.assign(r);
            b.assign(r);
            if i % 50 == 49 {
                a.adjust(16);
                b.adjust(16);
            }
        }
        assert_eq!(a.n_buckets(), b.n_buckets());
        for (x, y) in a.buckets().iter().zip(b.buckets()) {
            assert_eq!(x.low, y.low);
            assert_eq!(x.len(), y.len());
        }
    }

    #[test]
    fn prop_invariants_hold_under_random_workloads() {
        prop::check("bucket invariants", 200, |g| {
            let l_max = *g.pick(&[64u32, 256, 1024, 4096]);
            let mut m = BucketManager::new(l_max, 0.5, 16);
            let n_ops = g.usize(1, 120);
            let mut id = 0u64;
            for _ in 0..n_ops {
                if g.chance(0.8) {
                    let len = g.u64(0, l_max as u64 * 2) as u32;
                    m.assign(QueuedReq {
                        id,
                        len,
                        output_len: 1,
                        arrival: id,
                        class: RequestClass::Offline,
                        tbt_us: 0,
                        prefix: PrefixStamp::default(),
                    });
                    id += 1;
                } else {
                    let n_max = g.usize(1, 64);
                    m.adjust(n_max);
                }
                m.check_invariants().unwrap();
            }
            // Conservation: nothing lost or duplicated.
            assert_eq!(m.total(), id as usize);
            let drained = m.drain_all();
            let mut ids: Vec<_> = drained.iter().map(|r| r.id).collect();
            ids.sort();
            assert_eq!(ids, (0..id).collect::<Vec<_>>());
        });
    }
}
