//! Thread-per-shard parallel executor: deterministic fan-out of
//! decode-iteration boundaries (phase 1) and per-shard prefill planning
//! (phase 2).
//!
//! The sharding refactor (PR 2) left the coordinator with no shared queue
//! state between shards; this module removes the last global serialization
//! points — the event loop itself — for the two kinds of per-shard work
//! that dominate scheduler CPU time: decode-iteration boundary accounting
//! and prefill planning (bucket adjust, drain sorts, batch formation).
//! Both run behind the same three-stage discipline:
//!
//! 1. **Snapshot / capture** (merge loop): the shared state a worker needs
//!    is captured into a self-contained job keyed by a [`SyncKey`] —
//!    [`BoundaryJob`] moves the instance's drained active set out;
//!    [`PlanJob`] carries a deep copy of the shard's planner
//!    ([`super::scheduler::PrefillPlanner::clone_box`]) plus the planner
//!    inputs (clock, target-instance KV headroom).
//! 2. **Compute / speculate** (worker thread): a *pure* function of the
//!    job — [`boundary_outcome`] for boundaries, [`speculate_plan`] for
//!    planning. Speculation mutates only the job's private snapshot; the
//!    live planner is untouched until commit.
//! 3. **Apply / commit** (merge loop): outcomes merge back **sorted by
//!    [`SyncKey`]** and are folded in exactly the order the sequential
//!    loop would have produced them. A [`PlanProposal`] commits by
//!    *installing* its speculated planner state — but only after
//!    [`proposal_valid`] re-checks the captured inputs against the live
//!    ones; a stale proposal is discarded and the shard re-plans inline.
//!    A proposal never consumed (an earlier shard won the dispatch round)
//!    simply drops: speculation left no trace on the live planner.
//!
//! The determinism contract rests on two facts. First, the sequential
//! scheduler runs the *same* snapshot → speculate → commit pipeline
//! inline (lazily, at the moment a shard's plan is consumed), so the two
//! modes share every instruction of boundary accounting and planning —
//! there is no second implementation to drift. Second, the merge key
//! orders outcomes by `(virtual_time, event_id)` where event ids come
//! from the event queue's single global counter ([`SyncKey::event`] for
//! plan jobs is allocated by `EventQueue::stamp` from the same counter),
//! i.e. the key *is* the sequential order; worker interleaving, thread
//! count, and OS scheduling can therefore never reach the schedule. For
//! any seed, any `executor.threads`, and either `executor.plan_offload`
//! setting, the Summary JSON is byte-identical to the sequential run —
//! pinned by the determinism matrix in `tests/integration.rs`. (Executor
//! counters live on [`super::scheduler::RunReport`] only and are
//! deliberately kept *out* of Summary JSON so that contract can hold
//! exactly.)
//!
//! A synchronization point is either a maximal consecutive run of due
//! `DecodeIterEnd` events at one virtual instant (collected with
//! [`super::events::EventQueue::pop_due_if`], which refuses to reorder
//! across an interleaved event of another kind) or one prefill dispatch
//! round's eager speculation fan-out. Jobs route to workers by owner
//! shard (`shard % threads`, thread-per-shard when `executor.threads =
//! 0`). Everything decision-making — the dispatch commit order,
//! preemption, admission gating, stealing — stays on the merge loop:
//! those paths *choose between* shards, and running them speculatively
//! would perturb state the sequential schedule never touched.
//!
//! Steady-state boundary sync points are allocation-free: a job's
//! `active` buffer is compacted in place (survivors travel back to the
//! fleet in the same `Vec` the capture stage moved out), and the
//! `gaps`/`done` buffers recycle through the scheduler's scratch pool
//! after each apply.
//!
//! Worker lifecycle: workers are plain channel consumers; dropping the
//! pool closes the job channels and joins every thread, so a shard whose
//! event partition drains early just idles until shutdown. A panic
//! inside a worker computation is caught and delivered as an `Err`
//! outcome that the merge loop re-raises — never a deadlock, even while
//! sibling workers hold the outcome channel open.

use super::batcher::FormedBatch;
use super::fleet::DecodeSeqState;
use super::prefix::PrefixStamp;
use super::scheduler::PrefillPlanner;
use crate::workload::request::Completion;
use crate::workload::RequestClass;
use crate::Micros;
use std::sync::mpsc;
use std::thread;
use std::time::Instant;

/// Deterministic merge key of one executor job: ordered by
/// `(virtual_time, event_id)` — event ids are issued by one global
/// counter (boundary jobs use their event's id, plan jobs an id stamped
/// from the same counter), so this is exactly the sequential order. The
/// owner shard rides along for worker routing and diagnostics (per
/// shard, the triple `(virtual_time, shard, event_id)` sorts
/// identically).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct SyncKey {
    /// Virtual timestamp the job belongs to.
    pub at: Micros,
    /// Global event-queue counter id (the FIFO tie-break).
    pub event: u64,
    /// Scheduler shard owning the work.
    pub shard: usize,
}

/// One captured decode-iteration boundary, self-contained so it can cross
/// a thread boundary: the instance's drained active set plus the
/// iteration end time every member's token lands at. The `gaps`/`done`
/// buffers arrive empty (recycled from previous boundaries, capacity
/// retained) and come back filled in the [`BoundaryOutcome`].
#[derive(Debug)]
pub struct BoundaryJob {
    pub key: SyncKey,
    /// Decode instance the boundary belongs to.
    pub di: usize,
    /// End of the iteration (the boundary instant).
    pub iter_end: Micros,
    /// The instance's active set, moved out for the duration of the
    /// computation and compacted in place into the outcome's survivors.
    pub active: Vec<DecodeSeqState>,
    /// Recycled output buffer for gap samples (empty on entry).
    pub gaps: Vec<GapSample>,
    /// Recycled output buffer for finished sequences (empty on entry).
    pub done: Vec<FinishedSeq>,
    /// Test-only adversarial delay (µs) a worker sleeps before computing,
    /// so the sync-point tests can force hostile interleavings. Always 0
    /// on the serving path.
    pub stall_us: u64,
}

/// One observed inter-token gap, in active-set order, carrying what the
/// merge loop needs to classify it against the per-class TBT budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GapSample {
    pub class: RequestClass,
    /// Per-token budget override (0 = class default).
    pub tbt_us: u64,
    /// Observed inter-token gap, µs.
    pub gap: Micros,
}

/// A sequence that finished at this boundary, with the KV footprint its
/// reservation releases and the prefix-cache stamp whose pins the merge
/// loop must drop (all-zero when the prefix subsystem is off).
#[derive(Debug, Clone)]
pub struct FinishedSeq {
    pub completion: Completion,
    pub footprint: u64,
    pub prefix: PrefixStamp,
}

/// The pure result of one boundary: what [`boundary_outcome`] computes on
/// a worker and the merge loop folds back in [`SyncKey`] order.
#[derive(Debug)]
pub struct BoundaryOutcome {
    pub key: SyncKey,
    pub di: usize,
    /// Members that still have tokens to generate, in original order,
    /// with their token counts and gap anchors advanced. Same buffer the
    /// job's `active` arrived in, compacted in place.
    pub still_active: Vec<DecodeSeqState>,
    /// One gap sample per member, in active-set order.
    pub gaps: Vec<GapSample>,
    /// Members that completed at this boundary, in active-set order.
    pub done: Vec<FinishedSeq>,
}

/// The boundary computation itself — a pure function of the job, shared
/// verbatim by the sequential path (called inline) and the worker threads
/// (called behind a channel). Every member produced one token at
/// `iter_end`: measure its inter-token gap from its last anchor, advance
/// the anchor and the token count, and split finishers from survivors.
/// Survivors compact in place (order-preserving) so steady state
/// allocates nothing: the active buffer, the gap buffer, and the done
/// buffer all recycle through the scheduler's scratch pool.
pub fn boundary_outcome(job: BoundaryJob) -> BoundaryOutcome {
    let BoundaryJob {
        key,
        di,
        iter_end,
        mut active,
        mut gaps,
        mut done,
        stall_us: _,
    } = job;
    debug_assert!(gaps.is_empty() && done.is_empty(), "dirty scratch buffer");
    let mut write = 0usize;
    for read in 0..active.len() {
        let s = &mut active[read];
        let gap = iter_end.saturating_sub(s.last_token_at);
        s.last_token_at = iter_end;
        gaps.push(GapSample { class: s.class, tbt_us: s.tbt_us, gap });
        s.generated += 1;
        if s.generated >= s.output_len {
            done.push(FinishedSeq {
                footprint: s.footprint(),
                prefix: s.prefix,
                completion: Completion {
                    id: s.id,
                    class: s.class,
                    input_len: s.input_len,
                    output_len: s.output_len,
                    arrival: s.arrival,
                    first_token: s.first_token,
                    finished: iter_end,
                    padded_len: s.padded_len,
                },
            });
        } else {
            // Order-preserving compaction: every slot below `write` holds
            // a survivor; slots between `write` and `read` hold only
            // already-finished members, safe to overwrite.
            active.swap(write, read);
            write += 1;
        }
    }
    active.truncate(write);
    BoundaryOutcome { key, di, still_active: active, gaps, done }
}

/// Snapshot stage of one shard's prefill planning: the planner inputs the
/// merge loop captured (clock, the shard's target decode instance's KV
/// headroom) plus a deep copy of the shard's planner for the worker to
/// speculate on. Self-contained — the live planner never leaves the
/// merge loop.
pub struct PlanJob {
    /// Merge key; `key.shard` is the scheduler shard being planned and
    /// `key.event` an id stamped from the event queue's global counter.
    pub key: SyncKey,
    /// Virtual clock at capture.
    pub now: Micros,
    /// KV headroom (tokens) of the shard's dispatch-order target.
    pub headroom: u64,
    /// Deep copy of the shard's planner state (the speculation
    /// substrate).
    pub snapshot: Box<dyn PrefillPlanner>,
}

/// Speculate-stage output: the formed batch (if any) plus the
/// post-planning planner state. Committing a proposal means *installing*
/// `speculated` as the shard's planner — exactly the state an inline
/// `plan` call would have left — and taking `formed`; discarding it
/// leaves the live planner untouched.
pub struct PlanProposal {
    pub key: SyncKey,
    /// Captured inputs, re-validated at commit time by
    /// [`proposal_valid`].
    pub now: Micros,
    pub headroom: u64,
    /// Planner state after speculation (bucket adjust, drain sort, and
    /// batch drain applied).
    pub speculated: Box<dyn PrefillPlanner>,
    /// The speculated batch; `None` when the planner had nothing
    /// admissible under `headroom`.
    pub formed: Option<FormedBatch>,
    /// Wall-clock the speculation took on the worker, ns (RunReport
    /// diagnostics only — never Summary JSON).
    pub spec_ns: u64,
}

/// Speculate stage — a pure function of the job, shared verbatim by the
/// worker threads and the sequential path's inline (lazy) speculation.
/// Runs bucket adjust + drain sort + batch formation against the job's
/// private planner snapshot.
pub fn speculate_plan(mut job: PlanJob) -> PlanProposal {
    let t0 = Instant::now();
    let formed = job.snapshot.plan(job.now, job.headroom);
    PlanProposal {
        key: job.key,
        now: job.now,
        headroom: job.headroom,
        speculated: job.snapshot,
        formed,
        spec_ns: t0.elapsed().as_nanos() as u64,
    }
}

/// Commit-time validation: a proposal may be installed only when the
/// inputs it speculated over still hold. `now` drifts never inside one
/// dispatch round; `headroom` changes when the same shard already
/// committed a batch this round (its target's reservations grew), in
/// which case the proposal describes a drain the live planner no longer
/// matches and the shard must re-plan inline. The scheduler additionally
/// drops a shard's proposal outright after any commit on that shard
/// (belt and braces: a zero-footprint commit would leave `headroom`
/// unchanged while the queue did change).
pub fn proposal_valid(p: &PlanProposal, now: Micros, headroom: u64) -> bool {
    p.now == now && p.headroom == headroom
}

/// A unit of worker work: one captured boundary or one plan speculation.
enum Job {
    Boundary(BoundaryJob),
    Plan(PlanJob),
}

/// A worker's answer, mirroring [`Job`].
enum Outcome {
    Boundary(BoundaryOutcome),
    Plan(PlanProposal),
}

/// The worker pool: `threads` plain threads consuming jobs (captured
/// boundaries or plan speculations) from per-worker channels and
/// answering on one shared outcome channel.
/// [`ExecutorPool::process`] (boundaries) and [`ExecutorPool::plan`]
/// (speculations) are the synchronization points — each blocks for every
/// submitted job and hands the outcomes back in [`SyncKey`] order,
/// whatever order the workers finished in.
///
/// Workers answer with `Result`: a panic inside a computation is caught
/// and delivered as an `Err`, which the merge loop re-raises. Delivering
/// the failure (rather than letting the worker die) matters with more
/// than one worker — the survivors keep outcome senders alive, so a
/// silently lost outcome would park the merge thread in `recv` forever
/// instead of failing fast.
#[derive(Debug)]
pub struct ExecutorPool {
    txs: Vec<mpsc::Sender<Job>>,
    rx: mpsc::Receiver<Result<Outcome, &'static str>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ExecutorPool {
    /// Spawn `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> ExecutorPool {
        let threads = threads.max(1);
        let (out_tx, rx) = mpsc::channel();
        let mut txs = Vec::with_capacity(threads);
        let mut workers = Vec::with_capacity(threads);
        for _ in 0..threads {
            let (tx, job_rx) = mpsc::channel::<Job>();
            let out = out_tx.clone();
            workers.push(thread::spawn(move || {
                while let Ok(job) = job_rx.recv() {
                    let outcome = std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(|| match job {
                            Job::Boundary(b) => {
                                if b.stall_us > 0 {
                                    thread::sleep(
                                        std::time::Duration::from_micros(
                                            b.stall_us,
                                        ),
                                    );
                                }
                                Outcome::Boundary(boundary_outcome(b))
                            }
                            Job::Plan(p) => Outcome::Plan(speculate_plan(p)),
                        }),
                    )
                    .map_err(|_| "executor computation panicked on a worker");
                    if out.send(outcome).is_err() {
                        break;
                    }
                }
            }));
            txs.push(tx);
        }
        // Workers hold the only outcome senders: if they all die, recv
        // errors instead of blocking forever.
        drop(out_tx);
        ExecutorPool { txs, rx, workers }
    }

    pub fn threads(&self) -> usize {
        self.txs.len()
    }

    /// Worker a shard's jobs run on (thread-per-shard, wrapping when
    /// shards outnumber workers).
    pub fn worker_of(&self, shard: usize) -> usize {
        shard % self.txs.len()
    }

    /// Fan a batch of jobs out to their owner-shard workers, block for
    /// every outcome, and unwrap with `extract`, sorted by `key` — the
    /// deterministic merge order.
    fn round<T>(
        &self,
        jobs: Vec<Job>,
        shard_of: impl Fn(&Job) -> usize,
        extract: impl Fn(Outcome) -> T,
        key: impl Fn(&T) -> SyncKey,
    ) -> Vec<T> {
        let n = jobs.len();
        for job in jobs {
            let w = self.worker_of(shard_of(&job));
            self.txs[w].send(job).expect("executor worker hung up");
        }
        let mut outs: Vec<T> = (0..n)
            .map(|_| {
                extract(
                    self.rx
                        .recv()
                        .expect("executor worker died")
                        .unwrap_or_else(|e| panic!("{e}")),
                )
            })
            .collect();
        outs.sort_by_key(&key);
        outs
    }

    /// Fan one boundary synchronization point's jobs out, block for
    /// every outcome, and return them sorted by [`SyncKey`].
    pub fn process(&self, jobs: Vec<BoundaryJob>) -> Vec<BoundaryOutcome> {
        self.round(
            jobs.into_iter().map(Job::Boundary).collect(),
            |j| match j {
                Job::Boundary(b) => b.key.shard,
                Job::Plan(_) => unreachable!(),
            },
            |o| match o {
                Outcome::Boundary(b) => b,
                Outcome::Plan(_) => panic!("plan outcome in a boundary round"),
            },
            |b| b.key,
        )
    }

    /// Fan one dispatch round's plan speculations out, block for every
    /// proposal, and return them sorted by [`SyncKey`].
    pub fn plan(&self, jobs: Vec<PlanJob>) -> Vec<PlanProposal> {
        self.round(
            jobs.into_iter().map(Job::Plan).collect(),
            |j| match j {
                Job::Plan(p) => p.key.shard,
                Job::Boundary(_) => unreachable!(),
            },
            |o| match o {
                Outcome::Plan(p) => p,
                Outcome::Boundary(_) => {
                    panic!("boundary outcome in a plan round")
                }
            },
            |p| p.key,
        )
    }
}

impl Drop for ExecutorPool {
    /// Clean shutdown: close every job channel (a partition that drained
    /// early has simply been idle on its channel) and join the threads.
    fn drop(&mut self) {
        self.txs.clear();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(
        id: u64,
        class: RequestClass,
        generated: u32,
        output_len: u32,
        last_token_at: Micros,
    ) -> DecodeSeqState {
        DecodeSeqState {
            id,
            class,
            arrival: 0,
            input_len: 100,
            padded_len: 128,
            output_len,
            generated,
            first_token: 50,
            ready_at: 0,
            tbt_us: 7_000,
            last_token_at,
            prefix: PrefixStamp::default(),
        }
    }

    fn key(event: u64, shard: usize) -> SyncKey {
        SyncKey { at: 1_000, event, shard }
    }

    fn bjob(
        key: SyncKey,
        di: usize,
        iter_end: Micros,
        active: Vec<DecodeSeqState>,
        stall_us: u64,
    ) -> BoundaryJob {
        BoundaryJob {
            key,
            di,
            iter_end,
            active,
            gaps: Vec::new(),
            done: Vec::new(),
            stall_us,
        }
    }

    #[test]
    fn boundary_outcome_splits_finishers_and_advances_anchors() {
        let job = bjob(
            key(3, 0),
            2,
            1_000,
            vec![
                seq(10, RequestClass::Online, 5, 50, 970), // survives
                seq(11, RequestClass::Offline, 9, 10, 940), // finishes
            ],
            0,
        );
        let o = boundary_outcome(job);
        assert_eq!((o.key, o.di), (key(3, 0), 2));
        // Gaps in active-set order, measured from each member's anchor.
        assert_eq!(
            o.gaps,
            vec![
                GapSample { class: RequestClass::Online, tbt_us: 7_000, gap: 30 },
                GapSample { class: RequestClass::Offline, tbt_us: 7_000, gap: 60 },
            ]
        );
        // Survivor: token counted, anchor re-set to the boundary.
        assert_eq!(o.still_active.len(), 1);
        let s = &o.still_active[0];
        assert_eq!((s.id, s.generated, s.last_token_at), (10, 6, 1_000));
        // Finisher: completion carries the original prompt/output split
        // and its first-token time; footprint releases the reservation.
        assert_eq!(o.done.len(), 1);
        let f = &o.done[0];
        assert_eq!(f.footprint, 110); // input 100 + output 10
        assert_eq!(f.completion.id, 11);
        assert_eq!(f.completion.finished, 1_000);
        assert_eq!(f.completion.first_token, 50);
        assert_eq!(f.completion.output_len, 10);
    }

    #[test]
    fn boundary_outcome_compacts_in_place_and_reuses_buffers() {
        // Satellite: steady-state sync points are allocation-free. The
        // survivors come back in the same buffer the job carried in, and
        // pre-sized gap/done scratch never reallocates.
        let active: Vec<DecodeSeqState> = (0..8u64)
            .map(|i| {
                // Every odd member finishes at this boundary.
                let left = if i % 2 == 1 { 1 } else { 10 };
                seq(i, RequestClass::Online, 20 - left, 20, 980)
            })
            .collect();
        let active_ptr = active.as_ptr();
        let mut job = bjob(key(0, 0), 0, 1_000, active, 0);
        job.gaps = Vec::with_capacity(8);
        job.done = Vec::with_capacity(8);
        let gaps_ptr = job.gaps.as_ptr();
        let done_ptr = job.done.as_ptr();
        let o = boundary_outcome(job);
        // Order-preserving compaction of survivors, same allocation.
        assert_eq!(
            o.still_active.iter().map(|s| s.id).collect::<Vec<_>>(),
            vec![0, 2, 4, 6]
        );
        assert_eq!(o.still_active.as_ptr(), active_ptr);
        assert_eq!(o.done.iter().map(|f| f.completion.id).collect::<Vec<_>>(),
            vec![1, 3, 5, 7]);
        assert_eq!(o.gaps.len(), 8);
        assert_eq!(o.gaps.as_ptr(), gaps_ptr);
        assert_eq!(o.done.as_ptr(), done_ptr);
    }

    #[test]
    fn empty_boundary_is_a_clean_no_op() {
        let o = boundary_outcome(bjob(key(0, 1), 0, 5, vec![], 0));
        assert!(o.still_active.is_empty() && o.gaps.is_empty());
        assert!(o.done.is_empty());
    }

    #[test]
    fn outcomes_merge_in_event_order_despite_worker_delays() {
        // The sync-point merge must be independent of worker
        // interleaving: stall the workers so that jobs *finish* in
        // reverse submission order, and check the merge still hands back
        // ascending (virtual_time, event_id) order.
        let pool = ExecutorPool::new(3);
        assert_eq!(pool.threads(), 3);
        let jobs: Vec<BoundaryJob> = (0..6u64)
            .map(|i| {
                bjob(
                    key(i, i as usize % 3),
                    i as usize,
                    1_000,
                    vec![seq(i, RequestClass::Online, 1, 50, 990)],
                    (6 - i) * 3_000, // earliest key stalls longest
                )
            })
            .collect();
        let outs = pool.process(jobs);
        let order: Vec<u64> = outs.iter().map(|o| o.key.event).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4, 5]);
        // Same pool again with the stalls inverted — order unchanged.
        let jobs: Vec<BoundaryJob> = (0..6u64)
            .map(|i| {
                bjob(key(i, i as usize % 3), i as usize, 1_000, vec![], i * 3_000)
            })
            .collect();
        let order: Vec<u64> =
            pool.process(jobs).iter().map(|o| o.key.event).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn plan_proposals_merge_in_key_order_and_validate() {
        use crate::config::SystemConfig;
        use crate::coordinator::scheduler::BucketPlanner;
        use crate::workload::Request;
        let cfg = SystemConfig::default();
        let pool = ExecutorPool::new(2);
        let jobs: Vec<PlanJob> = (0..4usize)
            .map(|si| {
                let mut p = BucketPlanner::new(&cfg);
                for i in 0..3u64 {
                    let r = Request::new(
                        si as u64 * 10 + i,
                        RequestClass::Online,
                        100,
                        10,
                        i,
                    );
                    p.admit(&r, i);
                }
                PlanJob {
                    // Event ids deliberately descending in shard order so
                    // the merge has to reorder across workers.
                    key: SyncKey { at: 1_000, event: (4 - si) as u64, shard: si },
                    now: 1_000,
                    headroom: 100_000,
                    snapshot: p.clone_box(),
                }
            })
            .collect();
        let props = pool.plan(jobs);
        let events: Vec<u64> = props.iter().map(|p| p.key.event).collect();
        assert_eq!(events, vec![1, 2, 3, 4], "proposals sorted by SyncKey");
        for p in &props {
            // Validation: exactly the captured inputs pass.
            assert!(proposal_valid(p, 1_000, 100_000));
            assert!(!proposal_valid(p, 1_000, 99_999), "stale headroom");
            assert!(!proposal_valid(p, 1_001, 100_000), "stale clock");
            // Speculation drained the snapshot, not any live planner:
            // the formed members and the speculated residue add up.
            let f = p.formed.as_ref().expect("queued work must form");
            assert_eq!(f.reqs.len() + p.speculated.queued(), 3);
        }
    }

    #[test]
    fn sync_key_orders_by_time_then_event_id() {
        let a = SyncKey { at: 10, event: 5, shard: 9 };
        let b = SyncKey { at: 10, event: 6, shard: 0 };
        let c = SyncKey { at: 11, event: 0, shard: 0 };
        assert!(a < b && b < c);
        let mut v = vec![c, a, b];
        v.sort();
        assert_eq!(v, vec![a, b, c]);
    }

    #[test]
    fn pool_shuts_down_cleanly_when_partitions_drain_unevenly() {
        // Workers 1..3 never receive a job (their shards' partitions
        // "drained early"); dropping the pool must close their channels
        // and join them without hanging. The test passes by terminating.
        let pool = ExecutorPool::new(4);
        let jobs: Vec<BoundaryJob> = (0..3u64)
            .map(|i| bjob(key(i, 0), 0, 10, vec![], 0)) // all → worker 0
            .collect();
        assert_eq!(pool.worker_of(0), 0);
        assert_eq!(pool.worker_of(5), 1);
        let outs = pool.process(jobs);
        assert_eq!(outs.len(), 3);
        drop(pool);
    }
}
