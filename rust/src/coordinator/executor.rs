//! Thread-per-shard parallel executor: deterministic fan-out of
//! decode-iteration boundaries.
//!
//! The sharding refactor (PR 2) left the coordinator with no shared queue
//! state between shards; this module removes the last global serialization
//! point — the event loop itself — for the work that dominates event
//! counts: decode-iteration boundary accounting. The design splits every
//! boundary into three strictly separated stages:
//!
//! 1. **Capture** (merge loop): `RunCore::take_boundary_job` snapshots the
//!    instance's active set and iteration end into a self-contained
//!    [`BoundaryJob`] keyed by a [`SyncKey`].
//! 2. **Compute** (worker thread): [`boundary_outcome`] — a *pure*
//!    function of the job — produces the per-token gap samples, finished
//!    completions, and surviving active set.
//! 3. **Apply** (merge loop): outcomes are merged back **sorted by
//!    [`SyncKey`]** and folded into the report/monitor/fleet in exactly
//!    the order the sequential loop would have produced them.
//!
//! The determinism contract rests on two facts. First, the sequential
//! scheduler runs the *same* capture → [`boundary_outcome`] → apply
//! pipeline inline, so the two modes share every instruction of boundary
//! accounting — there is no second implementation to drift. Second, the
//! merge key orders outcomes by `(virtual_time, event_id)` where event
//! ids come from the event queue's single global push counter, i.e. the
//! key *is* the sequential pop order; worker interleaving, thread count,
//! and OS scheduling can therefore never reach the schedule. For any seed
//! and any `executor.threads`, the Summary JSON is byte-identical to the
//! sequential run — pinned by the determinism matrix in
//! `tests/integration.rs`. (Executor counters live on
//! [`super::scheduler::RunReport`] only and are deliberately kept *out*
//! of Summary JSON so that contract can hold exactly.)
//!
//! A synchronization point is a maximal consecutive run of due
//! `DecodeIterEnd` events at one virtual instant (collected with
//! [`super::events::EventQueue::pop_due_if`], which refuses to reorder
//! across an interleaved event of another kind). Runs fan out to workers
//! by owner shard (`shard % threads`, thread-per-shard when
//! `executor.threads = 0`). Everything decision-making — prefill
//! dispatch, preemption, admission, stealing — stays on the merge loop:
//! those paths *choose between* shards, and running them speculatively
//! would perturb planner state the sequential schedule never touched.
//! Cross-shard traffic created while applying a sync point (steal moves,
//! preemption requeues, checkpoint restores) is likewise applied
//! merge-side, at the member's ordinal position in the sorted order.
//!
//! Worker lifecycle: workers are plain channel consumers; dropping the
//! pool closes the job channels and joins every thread, so a shard whose
//! event partition drains early just idles until shutdown. A panic
//! inside a boundary computation is caught on the worker and delivered
//! as an `Err` outcome that [`ExecutorPool::process`] re-raises on the
//! merge thread — never a deadlock, even while sibling workers hold the
//! outcome channel open.

use super::fleet::DecodeSeqState;
use super::prefix::PrefixStamp;
use crate::workload::request::Completion;
use crate::workload::RequestClass;
use crate::Micros;
use std::sync::mpsc;
use std::thread;

/// Deterministic merge key of one boundary event: ordered by
/// `(virtual_time, event_id)` — event ids are issued by one global
/// counter, so this is exactly the sequential pop order. The owner shard
/// rides along for worker routing and diagnostics (per shard, the triple
/// `(virtual_time, shard, event_id)` sorts identically).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct SyncKey {
    /// Virtual timestamp the boundary fires at.
    pub at: Micros,
    /// Global event-queue push id (the FIFO tie-break).
    pub event: u64,
    /// Scheduler shard owning the decode instance.
    pub shard: usize,
}

/// One captured decode-iteration boundary, self-contained so it can cross
/// a thread boundary: the instance's drained active set plus the
/// iteration end time every member's token lands at.
#[derive(Debug)]
pub struct BoundaryJob {
    pub key: SyncKey,
    /// Decode instance the boundary belongs to.
    pub di: usize,
    /// End of the iteration (the boundary instant).
    pub iter_end: Micros,
    /// The instance's active set, moved out for the duration of the
    /// computation.
    pub active: Vec<DecodeSeqState>,
    /// Test-only adversarial delay (µs) a worker sleeps before computing,
    /// so the sync-point tests can force hostile interleavings. Always 0
    /// on the serving path.
    pub stall_us: u64,
}

/// One observed inter-token gap, in active-set order, carrying what the
/// merge loop needs to classify it against the per-class TBT budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GapSample {
    pub class: RequestClass,
    /// Per-token budget override (0 = class default).
    pub tbt_us: u64,
    /// Observed inter-token gap, µs.
    pub gap: Micros,
}

/// A sequence that finished at this boundary, with the KV footprint its
/// reservation releases and the prefix-cache stamp whose pins the merge
/// loop must drop (all-zero when the prefix subsystem is off).
#[derive(Debug, Clone)]
pub struct FinishedSeq {
    pub completion: Completion,
    pub footprint: u64,
    pub prefix: PrefixStamp,
}

/// The pure result of one boundary: what [`boundary_outcome`] computes on
/// a worker and the merge loop folds back in [`SyncKey`] order.
#[derive(Debug)]
pub struct BoundaryOutcome {
    pub key: SyncKey,
    pub di: usize,
    /// Members that still have tokens to generate, in original order,
    /// with their token counts and gap anchors advanced.
    pub still_active: Vec<DecodeSeqState>,
    /// One gap sample per member, in active-set order.
    pub gaps: Vec<GapSample>,
    /// Members that completed at this boundary, in active-set order.
    pub done: Vec<FinishedSeq>,
}

/// The boundary computation itself — a pure function of the job, shared
/// verbatim by the sequential path (called inline) and the worker threads
/// (called behind a channel). Every member produced one token at
/// `iter_end`: measure its inter-token gap from its last anchor, advance
/// the anchor and the token count, and split finishers from survivors.
pub fn boundary_outcome(job: BoundaryJob) -> BoundaryOutcome {
    let mut still_active = Vec::with_capacity(job.active.len());
    let mut gaps = Vec::with_capacity(job.active.len());
    let mut done = Vec::new();
    for mut s in job.active {
        let gap = job.iter_end.saturating_sub(s.last_token_at);
        s.last_token_at = job.iter_end;
        gaps.push(GapSample { class: s.class, tbt_us: s.tbt_us, gap });
        s.generated += 1;
        if s.generated >= s.output_len {
            done.push(FinishedSeq {
                footprint: s.footprint(),
                prefix: s.prefix,
                completion: Completion {
                    id: s.id,
                    class: s.class,
                    input_len: s.input_len,
                    output_len: s.output_len,
                    arrival: s.arrival,
                    first_token: s.first_token,
                    finished: job.iter_end,
                    padded_len: s.padded_len,
                },
            });
        } else {
            still_active.push(s);
        }
    }
    BoundaryOutcome { key: job.key, di: job.di, still_active, gaps, done }
}

/// The worker pool: `threads` plain threads consuming [`BoundaryJob`]s
/// from per-worker channels and answering on one shared outcome channel.
/// [`ExecutorPool::process`] is the synchronization point — it blocks for
/// every submitted job and hands the outcomes back in [`SyncKey`] order,
/// whatever order the workers finished in.
///
/// Workers answer with `Result`: a panic inside [`boundary_outcome`] is
/// caught and delivered as an `Err`, which `process` re-raises on the
/// merge thread. Delivering the failure (rather than letting the worker
/// die) matters with more than one worker — the survivors keep outcome
/// senders alive, so a silently lost outcome would park `process` in
/// `recv` forever instead of failing fast.
#[derive(Debug)]
pub struct ExecutorPool {
    txs: Vec<mpsc::Sender<BoundaryJob>>,
    rx: mpsc::Receiver<Result<BoundaryOutcome, &'static str>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ExecutorPool {
    /// Spawn `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> ExecutorPool {
        let threads = threads.max(1);
        let (out_tx, rx) = mpsc::channel();
        let mut txs = Vec::with_capacity(threads);
        let mut workers = Vec::with_capacity(threads);
        for _ in 0..threads {
            let (tx, job_rx) = mpsc::channel::<BoundaryJob>();
            let out = out_tx.clone();
            workers.push(thread::spawn(move || {
                while let Ok(job) = job_rx.recv() {
                    if job.stall_us > 0 {
                        thread::sleep(std::time::Duration::from_micros(
                            job.stall_us,
                        ));
                    }
                    let outcome = std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(|| boundary_outcome(job)),
                    )
                    .map_err(|_| "boundary computation panicked on a worker");
                    if out.send(outcome).is_err() {
                        break;
                    }
                }
            }));
            txs.push(tx);
        }
        // Workers hold the only outcome senders: if they all die, recv
        // errors instead of blocking forever.
        drop(out_tx);
        ExecutorPool { txs, rx, workers }
    }

    pub fn threads(&self) -> usize {
        self.txs.len()
    }

    /// Worker a shard's boundaries run on (thread-per-shard, wrapping
    /// when shards outnumber workers).
    pub fn worker_of(&self, shard: usize) -> usize {
        shard % self.txs.len()
    }

    /// Fan one synchronization point's jobs out to their owner-shard
    /// workers, block for every outcome, and return them sorted by
    /// [`SyncKey`] — the deterministic merge order.
    pub fn process(&self, jobs: Vec<BoundaryJob>) -> Vec<BoundaryOutcome> {
        let n = jobs.len();
        for job in jobs {
            let w = self.worker_of(job.key.shard);
            self.txs[w].send(job).expect("executor worker hung up");
        }
        let mut outs: Vec<BoundaryOutcome> = (0..n)
            .map(|_| {
                self.rx
                    .recv()
                    .expect("executor worker died")
                    .unwrap_or_else(|e| panic!("{e}"))
            })
            .collect();
        outs.sort_by_key(|o| o.key);
        outs
    }
}

impl Drop for ExecutorPool {
    /// Clean shutdown: close every job channel (a partition that drained
    /// early has simply been idle on its channel) and join the threads.
    fn drop(&mut self) {
        self.txs.clear();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(
        id: u64,
        class: RequestClass,
        generated: u32,
        output_len: u32,
        last_token_at: Micros,
    ) -> DecodeSeqState {
        DecodeSeqState {
            id,
            class,
            arrival: 0,
            input_len: 100,
            padded_len: 128,
            output_len,
            generated,
            first_token: 50,
            ready_at: 0,
            tbt_us: 7_000,
            last_token_at,
            prefix: PrefixStamp::default(),
        }
    }

    fn key(event: u64, shard: usize) -> SyncKey {
        SyncKey { at: 1_000, event, shard }
    }

    #[test]
    fn boundary_outcome_splits_finishers_and_advances_anchors() {
        let job = BoundaryJob {
            key: key(3, 0),
            di: 2,
            iter_end: 1_000,
            active: vec![
                seq(10, RequestClass::Online, 5, 50, 970), // survives
                seq(11, RequestClass::Offline, 9, 10, 940), // finishes
            ],
            stall_us: 0,
        };
        let o = boundary_outcome(job);
        assert_eq!((o.key, o.di), (key(3, 0), 2));
        // Gaps in active-set order, measured from each member's anchor.
        assert_eq!(
            o.gaps,
            vec![
                GapSample { class: RequestClass::Online, tbt_us: 7_000, gap: 30 },
                GapSample { class: RequestClass::Offline, tbt_us: 7_000, gap: 60 },
            ]
        );
        // Survivor: token counted, anchor re-set to the boundary.
        assert_eq!(o.still_active.len(), 1);
        let s = &o.still_active[0];
        assert_eq!((s.id, s.generated, s.last_token_at), (10, 6, 1_000));
        // Finisher: completion carries the original prompt/output split
        // and its first-token time; footprint releases the reservation.
        assert_eq!(o.done.len(), 1);
        let f = &o.done[0];
        assert_eq!(f.footprint, 110); // input 100 + output 10
        assert_eq!(f.completion.id, 11);
        assert_eq!(f.completion.finished, 1_000);
        assert_eq!(f.completion.first_token, 50);
        assert_eq!(f.completion.output_len, 10);
    }

    #[test]
    fn empty_boundary_is_a_clean_no_op() {
        let o = boundary_outcome(BoundaryJob {
            key: key(0, 1),
            di: 0,
            iter_end: 5,
            active: vec![],
            stall_us: 0,
        });
        assert!(o.still_active.is_empty() && o.gaps.is_empty());
        assert!(o.done.is_empty());
    }

    #[test]
    fn outcomes_merge_in_event_order_despite_worker_delays() {
        // The sync-point merge must be independent of worker
        // interleaving: stall the workers so that jobs *finish* in
        // reverse submission order, and check the merge still hands back
        // ascending (virtual_time, event_id) order.
        let pool = ExecutorPool::new(3);
        assert_eq!(pool.threads(), 3);
        let jobs: Vec<BoundaryJob> = (0..6u64)
            .map(|i| BoundaryJob {
                key: key(i, i as usize % 3),
                di: i as usize,
                iter_end: 1_000,
                active: vec![seq(i, RequestClass::Online, 1, 50, 990)],
                stall_us: (6 - i) * 3_000, // earliest key stalls longest
            })
            .collect();
        let outs = pool.process(jobs);
        let order: Vec<u64> = outs.iter().map(|o| o.key.event).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4, 5]);
        // Same pool again with the stalls inverted — order unchanged.
        let jobs: Vec<BoundaryJob> = (0..6u64)
            .map(|i| BoundaryJob {
                key: key(i, i as usize % 3),
                di: i as usize,
                iter_end: 1_000,
                active: vec![],
                stall_us: i * 3_000,
            })
            .collect();
        let order: Vec<u64> =
            pool.process(jobs).iter().map(|o| o.key.event).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn sync_key_orders_by_time_then_event_id() {
        let a = SyncKey { at: 10, event: 5, shard: 9 };
        let b = SyncKey { at: 10, event: 6, shard: 0 };
        let c = SyncKey { at: 11, event: 0, shard: 0 };
        assert!(a < b && b < c);
        let mut v = vec![c, a, b];
        v.sort();
        assert_eq!(v, vec![a, b, c]);
    }

    #[test]
    fn pool_shuts_down_cleanly_when_partitions_drain_unevenly() {
        // Workers 1..3 never receive a job (their shards' partitions
        // "drained early"); dropping the pool must close their channels
        // and join them without hanging. The test passes by terminating.
        let pool = ExecutorPool::new(4);
        let jobs: Vec<BoundaryJob> = (0..3u64)
            .map(|i| BoundaryJob {
                key: key(i, 0), // all shard 0 → worker 0 only
                di: 0,
                iter_end: 10,
                active: vec![],
                stall_us: 0,
            })
            .collect();
        assert_eq!(pool.worker_of(0), 0);
        assert_eq!(pool.worker_of(5), 1);
        let outs = pool.process(jobs);
        assert_eq!(outs.len(), 3);
        drop(pool);
    }
}
