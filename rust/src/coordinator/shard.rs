//! Scheduler shards: per-decode-instance scheduling state.
//!
//! The seed funneled every decision through one global bucket queue and
//! one global max-headroom scan. This module splits the coordinator into
//! **N shards, one per decode instance** (or any coarser grouping): each
//! [`SchedulerShard`] owns its own planner — bucket manager, dynamic
//! batcher admitting against the shard's KV budget, and priority state —
//! plus the slice of decode instances it fronts. The pieces compose as:
//!
//! ```text
//! arrival ─▶ Router (balance.rs) ─▶ shard queue ─▶ plan() ─▶ owned decode
//!                 ▲                      │
//!                 └── work-stealing ◀────┘  (idle shard pulls the tail of
//!                      at decode-iteration   the most-loaded shard's
//!                      boundaries            highest-urgency bucket)
//! ```
//!
//! With `sharding.shards = 1` (the default) a single shard owns the whole
//! decode fleet and every path reduces to the seed's global behavior
//! exactly; with one shard per decode instance the scheduler has no
//! global scans left on the dispatch path. That is the boundary the
//! parallel executor ([`super::executor`]) runs on: each shard's
//! decode-iteration accounting executes on its own worker thread
//! (`executor.threads`, thread-per-shard at `0`), with the event queue
//! partitioned by owner shard and cross-shard effects — steals,
//! preemption requeues, checkpoint restores — applied by the merge loop
//! in deterministic order, so parallel runs stay byte-identical to
//! sequential ones.
//!
//! Placement and victim-selection policy live in [`super::balance`]; the
//! serving loop drives shards from [`super::scheduler`]. Two later
//! subsystems ride on the shard boundary: preemption requeues aborted
//! and evicted work into the *owning* shard's planner (never a global
//! queue), and the TBT-aware admission layer walks a shard's owned
//! decode instances in headroom order when deferring or retargeting a
//! batch ([`super::admission`]) — both therefore need no shard-layer
//! state of their own.

use super::balance::{self, Router, ShardLoad};
use super::fleet::{DecodeFleet, ParkedPrefill};
use super::scheduler::PrefillPlanner;
use crate::config::{Placement, ShardingSpec};
use crate::workload::RequestId;
use crate::Micros;
use std::collections::VecDeque;

/// Per-shard counters surfaced in `RunReport` / Summary JSON.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardStats {
    /// Arrivals the placement policy routed here.
    pub routed: u64,
    /// Requests pulled in by work-stealing.
    pub stolen_in: u64,
    /// Requests other shards stole from here.
    pub stolen_out: u64,
    /// Prefill batches dispatched from this shard's queue.
    pub batches: u64,
}

/// One scheduler shard: a planner plus the decode instances it fronts.
pub struct SchedulerShard {
    pub planner: Box<dyn PrefillPlanner>,
    /// Decode instances this shard targets (stride partition of the
    /// fleet: instance `d` belongs to shard `d % n_shards`).
    pub owned: Vec<usize>,
    /// Sliced prefill batches that yielded their slot at a slice
    /// boundary (chunked prefill only; always empty otherwise). FIFO
    /// per shard, so the front is always this shard's oldest parked
    /// batch (by original dispatch `started_at`); dispatch compares
    /// fronts *across* shards and resumes the globally oldest first.
    pub parked: VecDeque<ParkedPrefill>,
    pub stats: ShardStats,
}

/// The shard collection plus the balancing configuration.
pub struct ShardSet {
    shards: Vec<SchedulerShard>,
    router: Router,
    steal: bool,
    /// Decode instance → owning shard.
    owner: Vec<usize>,
}

impl ShardSet {
    /// Build shards per `spec` over a fleet of `n_decode` decode
    /// instances, constructing one planner per shard via `factory`.
    /// `spec.shards == 0` means one shard per decode instance; any value
    /// clamps to `[1, n_decode]` (a shard owning no decode instance could
    /// never dispatch).
    pub fn new(
        spec: &ShardingSpec,
        n_decode: usize,
        mut factory: impl FnMut() -> Box<dyn PrefillPlanner>,
    ) -> ShardSet {
        let n_decode = n_decode.max(1);
        let n = if spec.shards == 0 {
            n_decode
        } else {
            (spec.shards as usize).min(n_decode)
        };
        let shards = (0..n)
            .map(|i| SchedulerShard {
                planner: factory(),
                owned: (0..n_decode).filter(|d| d % n == i).collect(),
                parked: VecDeque::new(),
                stats: ShardStats::default(),
            })
            .collect();
        ShardSet {
            shards,
            router: Router::new(spec.placement),
            steal: spec.steal,
            owner: (0..n_decode).map(|d| d % n).collect(),
        }
    }

    pub fn n(&self) -> usize {
        self.shards.len()
    }

    /// The shard fronting decode instance `di`.
    pub fn owner_of(&self, di: usize) -> usize {
        self.owner[di]
    }

    pub fn get(&self, si: usize) -> &SchedulerShard {
        &self.shards[si]
    }

    pub fn get_mut(&mut self, si: usize) -> &mut SchedulerShard {
        &mut self.shards[si]
    }

    pub fn iter(&self) -> std::slice::Iter<'_, SchedulerShard> {
        self.shards.iter()
    }

    /// Requests queued across every shard.
    pub fn queued_total(&self) -> usize {
        self.shards.iter().map(|s| s.planner.queued()).sum()
    }

    /// Work-stealing is active (configured on and more than one shard).
    pub fn steal_enabled(&self) -> bool {
        self.steal && self.shards.len() > 1
    }

    /// Route one arrival: the placement policy picks the shard, the
    /// caller admits into its planner. Single-shard fast path skips the
    /// load snapshot entirely; multi-shard paths compute only the load
    /// fields the active policy reads (this runs once per arrival, and
    /// `queued_tokens` is an O(queue) walk per shard that only
    /// join-shortest-KV is willing to pay for).
    pub fn route(
        &mut self,
        id: RequestId,
        decode: &DecodeFleet,
        per_budget: u64,
    ) -> usize {
        let si = if self.shards.len() == 1 {
            0
        } else {
            let placement = self.router.placement();
            let loads: Vec<ShardLoad> = self
                .shards
                .iter()
                .map(|s| {
                    let mut l = ShardLoad::default();
                    match placement {
                        Placement::Hash => {}
                        Placement::LeastLoaded => l.queued = s.planner.queued(),
                        // Prefix-affinity arrivals with a resident match
                        // never reach this policy (the scheduler routes
                        // them via `route_to`); the rest fall back to
                        // join-shortest-KV.
                        Placement::JoinShortestKv | Placement::PrefixAffinity => {
                            l.queued_tokens = s.planner.queued_tokens();
                            l.kv_reserved = s
                                .owned
                                .iter()
                                .map(|&d| decode.get(d).reserved_tokens)
                                .sum();
                        }
                    }
                    l
                })
                .collect();
            self.router.choose(id, &loads)
        };
        self.shards[si].stats.routed += 1;
        si
    }

    /// Route one arrival to an explicitly chosen shard, keeping the
    /// `routed` accounting consistent with [`ShardSet::route`]. Used by
    /// the prefix-affinity intercept, which picks the shard owning the
    /// longest resident prefix match before the load policies run.
    pub fn route_to(&mut self, si: usize) -> usize {
        self.shards[si].stats.routed += 1;
        si
    }

    /// Full per-shard load snapshots (monitoring / debugging — the
    /// routing hot path builds policy-trimmed snapshots instead).
    pub fn loads(&self, decode: &DecodeFleet, per_budget: u64) -> Vec<ShardLoad> {
        self.shards
            .iter()
            .map(|s| {
                let (_, best_headroom) =
                    balance::best_decode_in(&s.owned, decode, per_budget);
                ShardLoad {
                    queued: s.planner.queued(),
                    queued_tokens: s.planner.queued_tokens(),
                    kv_reserved: s
                        .owned
                        .iter()
                        .map(|&d| decode.get(d).reserved_tokens)
                        .sum(),
                    best_headroom,
                }
            })
            .collect()
    }

    /// Shards in dispatch-preference order for an idle prefill worker:
    /// descending best-owned-decode headroom, shard id breaking ties.
    /// Each entry carries the shard, its target decode instance, and that
    /// instance's headroom — `RunCore::dispatch_prefill` tries them in
    /// order until a shard's planner yields a batch. With one shard this
    /// is exactly the seed's single global `best_target` scan.
    pub fn dispatch_order(
        &self,
        decode: &DecodeFleet,
        per_budget: u64,
    ) -> Vec<(usize, usize, u64)> {
        let mut order: Vec<(usize, usize, u64)> = self
            .shards
            .iter()
            .enumerate()
            .map(|(si, s)| {
                let (ti, headroom) =
                    balance::best_decode_in(&s.owned, decode, per_budget);
                (si, ti, headroom)
            })
            .collect();
        order.sort_by(|a, b| b.2.cmp(&a.2).then(a.0.cmp(&b.0)));
        order
    }

    /// Repair one shard's entry in a cached [`ShardSet::dispatch_order`]
    /// after a commit changed its target's headroom, instead of
    /// recomputing the whole order from scratch: remove the stale entry,
    /// re-resolve the shard's best owned decode instance, and re-insert
    /// at the sorted position. The insertion predicate mirrors the sort
    /// comparator exactly (descending headroom, ascending shard id on
    /// ties), so the repaired vector is byte-identical to a full
    /// recompute — pinned by `repair_matches_full_recompute` below.
    pub fn repair_dispatch_order(
        &self,
        order: &mut Vec<(usize, usize, u64)>,
        si: usize,
        decode: &DecodeFleet,
        per_budget: u64,
    ) {
        if let Some(pos) = order.iter().position(|&(s, _, _)| s == si) {
            order.remove(pos);
        }
        let (ti, headroom) =
            balance::best_decode_in(&self.shards[si].owned, decode, per_budget);
        let at = order.partition_point(|&(s, _, h)| {
            h > headroom || (h == headroom && s < si)
        });
        order.insert(at, (si, ti, headroom));
    }

    /// Shard holding the globally oldest parked sliced batch: minimum
    /// head `started_at` (the batch's original dispatch instant; each
    /// shard's FIFO keeps its own front oldest), shard id breaking exact
    /// ties deterministically. The scheduler's resume paths must pick
    /// through this — not dispatch (headroom) order — or a younger
    /// parked batch on a high-headroom shard resumes ahead of an older
    /// one elsewhere, violating the oldest-first resume contract. A
    /// resume targets the batch's own original decode instance anyway,
    /// so headroom preference bought nothing there.
    pub fn oldest_parked_shard(&self) -> Option<usize> {
        self.shards
            .iter()
            .enumerate()
            .filter_map(|(si, s)| s.parked.front().map(|p| (p.started_at, si)))
            .min()
            .map(|(_, si)| si)
    }

    /// Work-stealing pass, run at decode-iteration boundaries: every
    /// shard with an empty queue and free KV pulls up to half of the
    /// most-loaded shard's queue — specifically the *tail* of its
    /// highest-urgency bucket, never more than half of that bucket, so
    /// the victim keeps the urgent head it would drain next and the
    /// thief absorbs backlog. The steal is KV-aware: the donor also caps
    /// the surrendered full-context tokens at the thief's best decode
    /// instance's current admission headroom, so an over-greedy steal
    /// can no longer move work the thief could not dispatch anyway.
    /// Returns the moves as `(victim, thief, n)` so the caller can
    /// update monitors. No-op unless stealing is enabled and there are
    /// at least two shards.
    pub fn rebalance(
        &mut self,
        now: Micros,
        decode: &DecodeFleet,
        per_budget: u64,
    ) -> Vec<(usize, usize, usize)> {
        self.rebalance_with_affinity(now, decode, per_budget, None)
    }

    /// [`ShardSet::rebalance`] with an optional locality score for victim
    /// selection: `steal_gain(victim, thief)` values what moving the
    /// victim's stolen tail onto the thief is worth to the prefix caches
    /// (see [`balance::steal_victim_with_affinity`]). `None` — the
    /// prefix subsystem off, or no lineage in any queue — is exactly the
    /// queue-depth policy.
    pub fn rebalance_with_affinity(
        &mut self,
        now: Micros,
        decode: &DecodeFleet,
        per_budget: u64,
        steal_gain: Option<&dyn Fn(usize, usize) -> i64>,
    ) -> Vec<(usize, usize, usize)> {
        if !self.steal_enabled() {
            return Vec::new();
        }
        let mut moves = Vec::new();
        for thief in 0..self.shards.len() {
            if self.shards[thief].planner.queued() > 0 {
                continue;
            }
            let (_, headroom) = balance::best_decode_in(
                &self.shards[thief].owned,
                decode,
                per_budget,
            );
            if headroom == 0 {
                continue; // nowhere to put stolen work anyway
            }
            let queued: Vec<usize> =
                self.shards.iter().map(|s| s.planner.queued()).collect();
            let gains: Vec<i64> = match steal_gain {
                Some(f) => (0..self.shards.len())
                    .map(|v| if v == thief { 0 } else { f(v, thief) })
                    .collect(),
                None => Vec::new(),
            };
            let Some(victim) = balance::steal_victim_with_affinity(
                thief, &queued, 2, &gains,
            ) else {
                continue;
            };
            let want = queued[victim] / 2;
            let stolen =
                self.shards[victim].planner.steal_tail(want, headroom, now);
            let n = stolen.len();
            if n == 0 {
                continue;
            }
            self.shards[victim].stats.stolen_out += n as u64;
            self.shards[thief].stats.stolen_in += n as u64;
            self.shards[thief].planner.absorb(stolen, now);
            moves.push((victim, thief, n));
        }
        moves
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Placement, SystemConfig};
    use crate::coordinator::scheduler::BucketPlanner;
    use crate::util::prop;
    use crate::workload::{Request, RequestClass};

    fn planner(cfg: &SystemConfig) -> Box<dyn PrefillPlanner> {
        Box::new(BucketPlanner::new(cfg))
    }

    fn req(id: u64, len: u32, arrival: Micros) -> Request {
        Request::new(id, RequestClass::Online, len, 10, arrival)
    }

    #[test]
    fn shard_count_resolution_and_ownership() {
        let cfg = SystemConfig::default();
        let mut spec = ShardingSpec::default();
        // Default: one shard owning every decode instance.
        let set = ShardSet::new(&spec, 4, || planner(&cfg));
        assert_eq!(set.n(), 1);
        assert_eq!(set.get(0).owned, vec![0, 1, 2, 3]);
        // 0 = one shard per decode instance (stride partition).
        spec.shards = 0;
        let set = ShardSet::new(&spec, 4, || planner(&cfg));
        assert_eq!(set.n(), 4);
        for d in 0..4 {
            assert_eq!(set.owner_of(d), d);
            assert_eq!(set.get(d).owned, vec![d]);
        }
        // Coarser than the fleet: stride ownership, every decode covered.
        spec.shards = 2;
        let set = ShardSet::new(&spec, 5, || planner(&cfg));
        assert_eq!(set.n(), 2);
        assert_eq!(set.get(0).owned, vec![0, 2, 4]);
        assert_eq!(set.get(1).owned, vec![1, 3]);
        assert_eq!(set.owner_of(3), 1);
        // More shards than decode instances clamps down.
        spec.shards = 8;
        let set = ShardSet::new(&spec, 2, || planner(&cfg));
        assert_eq!(set.n(), 2);
    }

    #[test]
    fn single_shard_routes_everything_to_zero() {
        let cfg = SystemConfig::default();
        let spec = ShardingSpec { placement: Placement::Hash, ..Default::default() };
        let mut set = ShardSet::new(&spec, 3, || planner(&cfg));
        let decode = DecodeFleet::new(3);
        for id in 0..10u64 {
            assert_eq!(set.route(id, &decode, 1000), 0);
        }
        assert_eq!(set.get(0).stats.routed, 10);
    }

    #[test]
    fn least_loaded_routing_balances_queue_depth() {
        let cfg = SystemConfig::default();
        let spec = ShardingSpec { shards: 2, ..Default::default() };
        let mut set = ShardSet::new(&spec, 2, || planner(&cfg));
        let decode = DecodeFleet::new(2);
        for id in 0..8u64 {
            let si = set.route(id, &decode, 10_000);
            let r = req(id, 100, id);
            set.get_mut(si).planner.admit(&r, id);
        }
        assert_eq!(set.get(0).planner.queued(), 4);
        assert_eq!(set.get(1).planner.queued(), 4);
        assert_eq!(set.queued_total(), 8);
    }

    #[test]
    fn idle_shard_steals_half_the_loaded_shards_queue() {
        let cfg = SystemConfig::default();
        let spec = ShardingSpec { shards: 2, steal: true, ..Default::default() };
        let mut set = ShardSet::new(&spec, 2, || planner(&cfg));
        let decode = DecodeFleet::new(2);
        for id in 0..10u64 {
            let r = req(id, 100, id);
            set.get_mut(0).planner.admit(&r, id);
        }
        let moves = set.rebalance(100, &decode, 10_000);
        assert_eq!(moves, vec![(0, 1, 5)]);
        assert_eq!(set.get(0).planner.queued(), 5);
        assert_eq!(set.get(1).planner.queued(), 5);
        assert_eq!(set.get(0).stats.stolen_out, 5);
        assert_eq!(set.get(1).stats.stolen_in, 5);
        // The victim keeps the head of the drain order (earliest
        // arrivals); the thief got the tail.
        let fb = set.get_mut(0).planner.plan(100, u64::MAX / 4).unwrap();
        assert_eq!(
            fb.reqs.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![0, 1, 2, 3, 4]
        );
    }

    #[test]
    fn steal_sizing_respects_thief_kv_headroom() {
        // Each queued request's full-context footprint is 110 tokens
        // (len 100 + output 10). The thief's only decode instance has
        // 250 tokens of headroom left: the old fixed-half steal would
        // grab 5 requests (550 tokens, overshooting by 300); KV-aware
        // sizing stops at 2 (220 ≤ 250).
        let cfg = SystemConfig::default();
        let spec = ShardingSpec { shards: 2, steal: true, ..Default::default() };
        let mut set = ShardSet::new(&spec, 2, || planner(&cfg));
        let mut decode = DecodeFleet::new(2);
        for id in 0..10u64 {
            let r = req(id, 100, id);
            set.get_mut(0).planner.admit(&r, id);
        }
        decode.get_mut(1).reserved_tokens = 10_000 - 250;
        let moves = set.rebalance(100, &decode, 10_000);
        assert_eq!(moves, vec![(0, 1, 2)], "steal capped by thief headroom");
        assert_eq!(set.get(0).planner.queued(), 8);
        assert_eq!(set.get(1).planner.queued(), 2);
        // The thief got the least-urgent tail, in order.
        let fb = set.get_mut(1).planner.plan(100, u64::MAX / 4).unwrap();
        assert_eq!(
            fb.reqs.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![8, 9]
        );
    }

    #[test]
    fn affinity_gain_redirects_the_steal_victim() {
        // Shards 0 and 1 both have backlog; idle shard 2 would steal from
        // the deeper queue (shard 0) by default, but a gain function that
        // says shard 1's tail belongs on the thief redirects the steal.
        let cfg = SystemConfig::default();
        let spec = ShardingSpec { shards: 3, steal: true, ..Default::default() };
        let mut set = ShardSet::new(&spec, 3, || planner(&cfg));
        let decode = DecodeFleet::new(3);
        for id in 0..8u64 {
            let r = req(id, 100, id);
            set.get_mut(0).planner.admit(&r, id);
        }
        for id in 8..12u64 {
            let r = req(id, 100, id);
            set.get_mut(1).planner.admit(&r, id);
        }
        let gain = |victim: usize, _thief: usize| -> i64 {
            if victim == 1 { 500 } else { 0 }
        };
        let moves =
            set.rebalance_with_affinity(100, &decode, 10_000, Some(&gain));
        assert_eq!(moves, vec![(1, 2, 2)], "gain overrides queue depth");
        // And with no gain function the same setup steals from shard 0.
        let mut set = ShardSet::new(&spec, 3, || planner(&cfg));
        for id in 0..8u64 {
            let r = req(id, 100, id);
            set.get_mut(0).planner.admit(&r, id);
        }
        for id in 8..12u64 {
            let r = req(id, 100, id);
            set.get_mut(1).planner.admit(&r, id);
        }
        let moves = set.rebalance(100, &decode, 10_000);
        assert_eq!(moves, vec![(0, 2, 4)]);
    }

    #[test]
    fn route_to_counts_like_route() {
        let cfg = SystemConfig::default();
        let spec = ShardingSpec { shards: 2, ..Default::default() };
        let mut set = ShardSet::new(&spec, 2, || planner(&cfg));
        assert_eq!(set.route_to(1), 1);
        assert_eq!(set.route_to(1), 1);
        assert_eq!(set.get(1).stats.routed, 2);
        assert_eq!(set.get(0).stats.routed, 0);
    }

    #[test]
    fn stealing_respects_gates() {
        let cfg = SystemConfig::default();
        // Disabled: no moves even with skew.
        let spec = ShardingSpec { shards: 2, steal: false, ..Default::default() };
        let mut set = ShardSet::new(&spec, 2, || planner(&cfg));
        let decode = DecodeFleet::new(2);
        for id in 0..6u64 {
            let r = req(id, 100, id);
            set.get_mut(0).planner.admit(&r, id);
        }
        assert!(set.rebalance(10, &decode, 10_000).is_empty());
        // Enabled but the thief has zero KV headroom: still no move.
        let spec = ShardingSpec { shards: 2, steal: true, ..Default::default() };
        let mut set = ShardSet::new(&spec, 2, || planner(&cfg));
        let mut decode = DecodeFleet::new(2);
        for id in 0..6u64 {
            let r = req(id, 100, id);
            set.get_mut(0).planner.admit(&r, id);
        }
        decode.get_mut(1).reserved_tokens = 10_000; // thief's instance full
        assert!(set.rebalance(10, &decode, 10_000).is_empty());
        // Victim below the minimum queue: nothing worth moving.
        let mut set = ShardSet::new(&spec, 2, || planner(&cfg));
        let decode = DecodeFleet::new(2);
        let r = req(0, 100, 0);
        set.get_mut(0).planner.admit(&r, 0);
        assert!(set.rebalance(10, &decode, 10_000).is_empty());
    }

    #[test]
    fn dispatch_order_prefers_headroom_then_shard_id() {
        let cfg = SystemConfig::default();
        let spec = ShardingSpec { shards: 0, ..Default::default() };
        let set = ShardSet::new(&spec, 3, || planner(&cfg));
        let mut decode = DecodeFleet::new(3);
        decode.get_mut(0).reserved_tokens = 500;
        decode.get_mut(1).reserved_tokens = 100;
        decode.get_mut(2).reserved_tokens = 100;
        let order = set.dispatch_order(&decode, 1000);
        // Shards 1 and 2 tie at 900 headroom → shard id order; shard 0 last.
        assert_eq!(order, vec![(1, 1, 900), (2, 2, 900), (0, 0, 500)]);
    }

    #[test]
    fn repair_matches_full_recompute() {
        // Satellite: dispatch_prefill caches the round's order and only
        // repairs entries a commit changed. The repaired vector must be
        // byte-identical to a from-scratch dispatch_order, including on
        // headroom ties (where shard id breaks), so exercise random
        // reservation changes across random fleets.
        prop::check("repair_dispatch_order ≡ full recompute", 60, |g| {
            let cfg = SystemConfig::default();
            let n_decode = g.usize(1, 6);
            let spec = ShardingSpec {
                shards: g.usize(0, 4) as u32,
                ..Default::default()
            };
            let set = ShardSet::new(&spec, n_decode, || planner(&cfg));
            let per_budget = g.u64(500, 5_000);
            let mut decode = DecodeFleet::new(n_decode);
            for d in 0..n_decode {
                // Coarse quantization makes headroom ties likely.
                decode.get_mut(d).reserved_tokens =
                    g.u64(0, 4) * per_budget / 4;
            }
            let mut cached = set.dispatch_order(&decode, per_budget);
            // A sequence of commits, each changing one shard's target
            // reservations then repairing that shard's entry.
            for _ in 0..g.usize(1, 8) {
                let si = g.usize(0, set.n() - 1);
                let (_, ti, _) = *cached
                    .iter()
                    .find(|&&(s, _, _)| s == si)
                    .expect("every shard has an entry");
                let d = decode.get_mut(ti);
                d.reserved_tokens =
                    (d.reserved_tokens + g.u64(0, per_budget / 2))
                        .min(per_budget);
                set.repair_dispatch_order(
                    &mut cached,
                    si,
                    &decode,
                    per_budget,
                );
                assert_eq!(
                    cached,
                    set.dispatch_order(&decode, per_budget),
                    "repaired order diverged from full recompute"
                );
            }
        });
    }

    /// A minimal parked sliced batch: only `started_at` matters to
    /// resume-order selection.
    fn parked_at(started_at: Micros) -> ParkedPrefill {
        use crate::cluster::PrefillBatch;
        use crate::coordinator::batcher::FormedBatch;
        ParkedPrefill {
            formed: FormedBatch {
                batch: PrefillBatch { items: vec![], padded_len: 1 },
                reqs: vec![],
                bucket_up: 1,
            },
            target_decode: 0,
            started_at,
            cursor: 0,
            width: 1,
            reserved_so_far: 0,
            exec_us: 0,
        }
    }

    #[test]
    fn parked_resume_picks_globally_oldest_across_shards() {
        // Regression: the resume paths used to walk shards in dispatch
        // (headroom) order and take the first one with anything parked.
        // Park two batches in age-inverted headroom order — the *younger*
        // batch on the shard dispatch order visits first — and assert
        // selection still lands on the older batch's shard.
        let cfg = SystemConfig::default();
        let spec = ShardingSpec { shards: 0, ..Default::default() };
        let mut set = ShardSet::new(&spec, 2, || planner(&cfg));
        let mut decode = DecodeFleet::new(2);
        // Shard 0 fronts the roomier decode instance...
        decode.get_mut(0).reserved_tokens = 100;
        decode.get_mut(1).reserved_tokens = 900;
        let order = set.dispatch_order(&decode, 1000);
        assert_eq!(
            order[0].0, 0,
            "setup: dispatch order must visit shard 0 first for the \
             inversion to be exercised"
        );
        // ...but holds the younger parked batch. The buggy first-in-
        // dispatch-order scan would resume shard 0's batch here.
        set.get_mut(0).parked.push_back(parked_at(2_000));
        set.get_mut(1).parked.push_back(parked_at(1_000));
        assert_eq!(set.oldest_parked_shard(), Some(1), "older batch wins");
        // Once the older batch is gone the younger one is next.
        set.get_mut(1).parked.pop_front();
        assert_eq!(set.oldest_parked_shard(), Some(0));
        set.get_mut(0).parked.pop_front();
        assert_eq!(set.oldest_parked_shard(), None, "nothing parked");
        // Exact started_at ties break on shard id, deterministically.
        set.get_mut(0).parked.push_back(parked_at(5_000));
        set.get_mut(1).parked.push_back(parked_at(5_000));
        assert_eq!(set.oldest_parked_shard(), Some(0));
    }

    #[test]
    fn parked_fifo_front_is_per_shard_oldest() {
        // Within one shard, parks happen in dispatch order, so the
        // VecDeque front (what `oldest_parked_shard` inspects and
        // `resume_parked` pops) is always that shard's oldest batch.
        let cfg = SystemConfig::default();
        let spec = ShardingSpec::default();
        let mut set = ShardSet::new(&spec, 1, || planner(&cfg));
        for t in [100, 200, 300] {
            set.get_mut(0).parked.push_back(parked_at(t));
        }
        let front = set.get(0).parked.front().unwrap().started_at;
        assert_eq!(front, 100);
        assert_eq!(set.get_mut(0).parked.pop_front().unwrap().started_at, 100);
        assert_eq!(set.get_mut(0).parked.pop_front().unwrap().started_at, 200);
        assert_eq!(set.get_mut(0).parked.pop_front().unwrap().started_at, 300);
        assert!(set.get(0).parked.is_empty());
    }

    #[test]
    fn prop_sharded_planner_conserves_requests() {
        // The sharded mirror of PR 1's planner-conservation property:
        // every admitted request survives any interleaving of routing,
        // draining, force-pops, and work-stealing, and is drained exactly
        // once across all shards.
        prop::check("sharded route/steal/drain conserves requests", 40, |g| {
            let mut cfg = SystemConfig::default();
            cfg.priority.enabled = g.bool();
            let n_decode = g.usize(1, 4);
            let spec = ShardingSpec {
                shards: g.usize(0, 4) as u32,
                placement: *g.pick(&[
                    Placement::LeastLoaded,
                    Placement::JoinShortestKv,
                    Placement::Hash,
                    Placement::PrefixAffinity,
                ]),
                steal: true,
            };
            let mut set = ShardSet::new(&spec, n_decode, || planner(&cfg));
            let mut decode = DecodeFleet::new(n_decode);
            let per_budget = g.u64(1_000, 50_000);
            let mut admitted = 0u64;
            let mut drained: Vec<u64> = Vec::new();
            let mut now: Micros = 0;
            let n_ops = g.usize(1, 100);
            for _ in 0..n_ops {
                now += g.u64(0, 50_000);
                match g.usize(0, 9) {
                    0..=4 => {
                        let r = Request::new(
                            admitted,
                            if g.bool() {
                                RequestClass::Online
                            } else {
                                RequestClass::Offline
                            },
                            g.u64(1, 4000) as u32,
                            g.u64(1, 400) as u32,
                            now,
                        );
                        let si = set.route(r.id, &decode, per_budget);
                        set.get_mut(si).planner.admit(&r, now);
                        admitted += 1;
                    }
                    5..=7 => {
                        let si = g.usize(0, set.n() - 1);
                        let budget = g.u64(0, 20_000);
                        if let Some(fb) =
                            set.get_mut(si).planner.plan(now, budget)
                        {
                            drained.extend(fb.reqs.iter().map(|r| r.id));
                        }
                    }
                    8 => {
                        // Perturb decode load, then steal.
                        for d in 0..n_decode {
                            decode.get_mut(d).reserved_tokens =
                                g.u64(0, per_budget + 1000);
                        }
                        set.rebalance(now, &decode, per_budget);
                    }
                    _ => {
                        let si = g.usize(0, set.n() - 1);
                        if let Some(r) = set.get_mut(si).planner.force_pop(now)
                        {
                            drained.push(r.id);
                        }
                    }
                }
            }
            // Drain everything left, shard by shard.
            for si in 0..set.n() {
                while let Some(fb) =
                    set.get_mut(si).planner.plan(now, u64::MAX / 4)
                {
                    drained.extend(fb.reqs.iter().map(|r| r.id));
                    now += 1;
                }
                while let Some(r) = set.get_mut(si).planner.force_pop(now) {
                    drained.push(r.id);
                }
            }
            assert_eq!(set.queued_total(), 0);
            drained.sort();
            assert_eq!(
                drained,
                (0..admitted).collect::<Vec<_>>(),
                "requests lost or duplicated across shards"
            );
        });
    }
}
