//! TBT-aware decode admission and deferral (the two-sided SLO layer).
//!
//! The priority (PR 1) and preemption (PR 3) subsystems protect the
//! *first* token: they reorder the queue and reclaim capacity when a
//! queued online request's TTFT budget burns down. But the paper's SLO
//! model — like the UELLM comparison it cites — is two-sided: once a
//! sequence is decoding, every further token carries its own
//! time-between-tokens (TBT) budget, and a decode instance oversubscribed
//! with offline context can stretch its iteration time past that budget
//! with nobody watching. The [`AdmissionEngine`] closes that gap with two
//! triggers, both evaluated only when
//! [`crate::config::AdmissionSpec::enabled`] (the default is off and the
//! subsystem is then completely inert — disabled Summary JSON is pinned
//! byte-identical):
//!
//! * **(a) Admission deferral** — before a formed prefill batch is
//!   committed to a decode instance, the scheduler asks the engine for a
//!   pure projection of that instance's next iteration time *with the
//!   batch aboard* ([`crate::cluster::Engine::projected_decode_us`]). If
//!   the projection would land any resident online sequence past its
//!   effective inter-token deadline, the batch retargets to the shard's
//!   next-best owned instance; if none can absorb it, the batch returns
//!   to the shard's queue and waits (`admission_deferrals` counts these).
//! * **(b) TBT eviction** — at a decode-iteration boundary, if the next
//!   projected iteration would blow a resident online sequence's budget,
//!   least-urgent *offline* actives are shed through the preemption
//!   subsystem's checkpoint-and-restore machinery (KV released, generated
//!   progress checkpointed, recompute requeued) until the projection
//!   fits, bounded by `max_evictions` per trigger. Victim order is the
//!   canonical priority comparator extended with a TBT-slack term
//!   ([`PriorityScorer::compare_tbt`]), so a victim can never be more
//!   TBT-urgent than an equal-priority survivor.
//!
//! Budgets are per class — the SLO's `tbt_us` for online, a lax
//! `offline_tbt_factor ×` multiple for offline — with per-request
//! overrides stamped by [`crate::workload::Trace::stamp_tbt`] carried all
//! the way into decode state ([`DecodeSeqState::tbt_us`]). Both triggers
//! compare against a margin-derated *effective* budget
//! (`(1 − slack_margin) × budget`) so they fire a little before the
//! deadline, not on it.
//!
//! This engine is pure policy (budget resolution, risk predicates, victim
//! ordering); all fleet/queue mutation and the projection plumbing stay
//! in [`super::scheduler`]. Inter-token gaps themselves are measured at
//! iteration boundaries from [`DecodeSeqState::last_token_at`] and
//! reported per class (p50/p99 gap, violations, attainment) in
//! `RunReport`/Summary JSON.

use super::bucket::QueuedReq;
use super::fleet::DecodeSeqState;
use super::preempt::evictable_entry;
use super::priority::PriorityScorer;
use crate::config::{AdmissionSpec, PrioritySpec, SloSpec};
use crate::workload::request::class_tbt_budget_us;
use crate::workload::{RequestClass, RequestId};
use crate::Micros;

/// The TBT-admission decision engine: budget resolution, deadline-risk
/// predicates, and eviction-victim ordering.
#[derive(Debug)]
pub struct AdmissionEngine {
    spec: AdmissionSpec,
    scorer: PriorityScorer,
    slo: SloSpec,
}

impl AdmissionEngine {
    pub fn new(
        spec: AdmissionSpec,
        priority: PrioritySpec,
        slo: SloSpec,
    ) -> AdmissionEngine {
        AdmissionEngine {
            spec,
            scorer: PriorityScorer::new(priority, slo.clone()),
            slo,
        }
    }

    pub fn enabled(&self) -> bool {
        self.spec.enabled
    }

    /// Trigger (a) armed: master switch plus the defer knob.
    pub fn defer_enabled(&self) -> bool {
        self.spec.enabled && self.spec.defer
    }

    /// Trigger (b) armed: master switch plus the evict knob.
    pub fn evict_enabled(&self) -> bool {
        self.spec.enabled && self.spec.evict
    }

    pub fn max_evictions(&self) -> u32 {
        self.spec.max_evictions
    }

    /// Per-token TBT budget (µs) of a sequence: its stamped override or
    /// the class default (see
    /// [`crate::workload::request::class_tbt_budget_us`]).
    pub fn budget_us(&self, class: RequestClass, override_us: u64) -> u64 {
        class_tbt_budget_us(
            class,
            override_us,
            &self.slo,
            self.spec.offline_tbt_factor,
        )
    }

    /// The margin-derated budget the triggers compare against: firing at
    /// `(1 − slack_margin) ×` the budget converts near-misses into
    /// deferrals/evictions *before* the deadline instead of violations
    /// after it.
    pub fn effective_budget_us(&self, class: RequestClass, override_us: u64) -> u64 {
        let b = self.budget_us(class, override_us) as f64;
        (b * (1.0 - self.spec.slack_margin).max(0.0)) as u64
    }

    /// Signed slack (µs) of `s` to its effective next-token deadline at
    /// `now` (negative = already past it).
    pub fn slack_us(&self, s: &DecodeSeqState, now: Micros) -> i64 {
        let deadline = s
            .last_token_at
            .saturating_add(self.effective_budget_us(s.class, s.tbt_us));
        deadline as i64 - now as i64
    }

    /// Boundary-to-boundary form of the risk predicate, used by the
    /// deferral trigger: would an iteration of `projected_us` — the gap a
    /// resident actually observes, boundary to boundary — blow any
    /// *online* member's effective budget? Inter-token gaps are anchored
    /// at iteration boundaries (and re-anchored at admission), so for a
    /// continuously-busy instance the next gap *is* the next iteration's
    /// duration. The mid-iteration form below additionally charges time
    /// already elapsed since the member's last anchor, but that time is
    /// re-anchored away at the boundary the batch actually joins — using
    /// it against a dispatch-time decision double-charges and defers
    /// spuriously (the ROADMAP follow-up this predicate closes; the
    /// regression tests pin the difference).
    pub fn iteration_at_risk<'a>(
        &self,
        members: impl Iterator<Item = &'a DecodeSeqState>,
        projected_us: Micros,
    ) -> bool {
        members
            .filter(|s| s.class == RequestClass::Online)
            .any(|s| projected_us > self.effective_budget_us(s.class, s.tbt_us))
    }

    /// True when an iteration of `projected_us` starting at `now` would
    /// land any *online* member past its effective next-token deadline —
    /// the eviction trigger's predicate, evaluated *at* a boundary where
    /// active members' anchors equal `now` (for them this degenerates to
    /// [`AdmissionEngine::iteration_at_risk`], while members already
    /// behind their anchor tighten it). Offline members never gate
    /// admission: their lax budget exists for metrics, not for blocking
    /// throughput work on its own behalf.
    pub fn deadline_at_risk<'a>(
        &self,
        members: impl Iterator<Item = &'a DecodeSeqState>,
        projected_us: Micros,
        now: Micros,
    ) -> bool {
        members
            .filter(|s| s.class == RequestClass::Online)
            .any(|s| projected_us as i64 > self.slack_us(s, now))
    }

    /// Trigger (b) victim order over one instance's active set:
    /// reclaimable sequences under the eligibility rule shared with the
    /// preemption engine (`evictable_entry`: never online, never
    /// within one token of done), least urgent first under the canonical
    /// comparator extended with the TBT-slack term, ties on id. The
    /// scheduler evicts down this list, re-projecting after each shed,
    /// so the engine returns the full ordering rather than a prefix.
    pub fn victim_order(
        &self,
        active: &[DecodeSeqState],
        now: Micros,
    ) -> Vec<RequestId> {
        let mut pool: Vec<(QueuedReq, i64)> = active
            .iter()
            .filter_map(|s| {
                Some((evictable_entry(s)?, self.slack_us(s, now)))
            })
            .collect();
        pool.sort_by(|a, b| {
            self.scorer
                .compare_tbt(&b.0, b.1, &a.0, a.1, now)
                .then(a.0.id.cmp(&b.0.id))
        });
        pool.into_iter().map(|(q, _)| q.id).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn engine(enabled: bool) -> AdmissionEngine {
        let cfg = SystemConfig::default();
        let mut spec = cfg.admission.clone();
        spec.enabled = enabled;
        AdmissionEngine::new(spec, cfg.priority.clone(), cfg.slo.clone())
    }

    fn seq(
        id: u64,
        class: RequestClass,
        arrival: Micros,
        generated: u32,
        output: u32,
        last_token_at: Micros,
    ) -> DecodeSeqState {
        DecodeSeqState {
            id,
            class,
            arrival,
            input_len: 1000,
            padded_len: 1000,
            output_len: output,
            generated,
            first_token: arrival + 1000,
            ready_at: 0,
            tbt_us: 0,
            last_token_at,
            prefix: crate::coordinator::prefix::PrefixStamp::default(),
        }
    }

    #[test]
    fn trigger_gates_follow_spec_knobs() {
        let off = engine(false);
        assert!(!off.enabled() && !off.defer_enabled() && !off.evict_enabled());
        let on = engine(true);
        assert!(on.enabled() && on.defer_enabled() && on.evict_enabled());
        let cfg = SystemConfig::default();
        let mut spec = cfg.admission.clone();
        spec.enabled = true;
        spec.defer = false;
        let e = AdmissionEngine::new(spec, cfg.priority.clone(), cfg.slo.clone());
        assert!(!e.defer_enabled() && e.evict_enabled());
    }

    #[test]
    fn budgets_resolve_class_defaults_margin_and_overrides() {
        let e = engine(true);
        let slo = SystemConfig::default().slo;
        assert_eq!(e.budget_us(RequestClass::Online, 0), slo.tbt_us);
        assert_eq!(
            e.budget_us(RequestClass::Offline, 0),
            (slo.tbt_us as f64 * 8.0) as u64
        );
        assert_eq!(e.budget_us(RequestClass::Online, 30_000), 30_000);
        // Default margin 0.1: effective = 0.9 × budget.
        assert_eq!(
            e.effective_budget_us(RequestClass::Online, 0),
            (slo.tbt_us as f64 * 0.9) as u64
        );
        assert_eq!(e.effective_budget_us(RequestClass::Online, 30_000), 27_000);
    }

    #[test]
    fn deadline_risk_weighs_online_members_only() {
        let e = engine(true);
        // Effective online budget = 90 ms (100 ms × 0.9 margin). A
        // sequence whose last token landed at t=0 has 90 ms of slack at
        // t=0; a 100 ms projected iteration blows it, an 80 ms one fits.
        let online = seq(1, RequestClass::Online, 0, 5, 100, 0);
        let offline = seq(2, RequestClass::Offline, 0, 5, 100, 0);
        assert_eq!(e.slack_us(&online, 0), 90_000);
        assert!(e.deadline_at_risk([online.clone()].iter(), 100_000, 0));
        assert!(!e.deadline_at_risk([online.clone()].iter(), 80_000, 0));
        // A pure-offline instance is never at risk, whatever the
        // projection — offline budgets exist for metrics, not gating.
        assert!(!e.deadline_at_risk([offline.clone()].iter(), 10_000_000, 0));
        // Mid-budget: 40 ms after the last token, 50 ms of slack remains.
        assert_eq!(e.slack_us(&online, 40_000), 50_000);
        assert!(e.deadline_at_risk([online.clone()].iter(), 60_000, 40_000));
        assert!(!e.deadline_at_risk([online].iter(), 40_000, 40_000));
    }

    #[test]
    fn deferral_predicate_uses_boundary_to_boundary_accounting() {
        let e = engine(true);
        // Effective online budget = 90 ms. A resident whose last token
        // landed 40 ms ago faces a 60 ms projected iteration:
        //  * mid-iteration accounting charges the elapsed 40 ms too
        //    (60 > 90 − 40) and would defer — spuriously, because the
        //    batch joins at the boundary where the gap clock re-anchors;
        //  * boundary-to-boundary accounting admits (60 ≤ 90).
        let s = seq(1, RequestClass::Online, 0, 5, 100, 0);
        let now = 40_000;
        assert!(
            e.deadline_at_risk([s.clone()].iter(), 60_000, now),
            "the mid-iteration form double-charges elapsed boundary time"
        );
        assert!(
            !e.iteration_at_risk([s.clone()].iter(), 60_000),
            "boundary form must admit an iteration inside the budget"
        );
        // A projection past the budget itself still defers...
        assert!(e.iteration_at_risk([s].iter(), 95_000));
        // ...and offline members never gate, as with the old form.
        let off = seq(2, RequestClass::Offline, 0, 5, 100, 0);
        assert!(!e.iteration_at_risk([off].iter(), 10_000_000));
    }

    #[test]
    fn predicates_agree_exactly_at_a_boundary() {
        // The eviction trigger evaluates at the boundary, where active
        // members' anchors equal `now`: there the two forms coincide, so
        // tightening the deferral predicate cannot shift the evict pass.
        let e = engine(true);
        let now = 5_000_000;
        let s = seq(3, RequestClass::Online, 0, 10, 100, now);
        for projected in [0u64, 50_000, 89_000, 90_001, 200_000] {
            assert_eq!(
                e.deadline_at_risk([s.clone()].iter(), projected, now),
                e.iteration_at_risk([s.clone()].iter(), projected),
                "divergence at projected={projected}"
            );
        }
    }

    #[test]
    fn evict_pass_splits_predicates_by_anchor_freshness() {
        // The evict pass runs at a boundary, but its membership is
        // actives ∪ due-pending. Actives were just re-anchored
        // (`last_token_at == now`) — for them the two predicate forms
        // coincide (see `predicates_agree_exactly_at_a_boundary`). A due
        // pending member still carries its *hand-off* anchor from before
        // the boundary: charging that pre-admission span against the next
        // iteration is the same double-count the deferral fix removed,
        // because `admit_due` re-anchors the member the instant it joins.
        // The scheduler therefore scores actives with `deadline_at_risk`
        // and due-pending members with `iteration_at_risk`.
        let e = engine(true);
        let now = 5_000_000;
        // Pending member: online, hand-off landed 40 ms before the
        // boundary, so its stale anchor shows 40 ms already "elapsed".
        let pending = seq(1, RequestClass::Online, 0, 0, 100, now - 40_000);
        // A 60 ms projected iteration fits the 90 ms effective budget…
        assert!(
            !e.iteration_at_risk([pending.clone()].iter(), 60_000),
            "boundary form admits: the member re-anchors on admission"
        );
        // …but the anchor-charged form double-counts the pre-boundary
        // 40 ms (60 > 90 − 40) and would evict spuriously.
        assert!(
            e.deadline_at_risk([pending.clone()].iter(), 60_000, now),
            "anchor-charged form over-triggers on stale pending anchors"
        );
        // A genuinely oversized iteration still trips both forms.
        assert!(e.iteration_at_risk([pending].iter(), 95_000));
    }

    #[test]
    fn victim_order_sheds_least_urgent_offline_first() {
        let e = engine(true);
        let now = 10_000_000;
        let active = vec![
            // Online: never a victim.
            seq(0, RequestClass::Online, 0, 5, 100, now),
            // Offline, aged most (t=0 arrival) → most urgent → last.
            seq(1, RequestClass::Offline, 0, 5, 100, now),
            // Offline, freshest arrival → least urgent → first.
            seq(2, RequestClass::Offline, 9_000_000, 5, 100, now),
            seq(3, RequestClass::Offline, 5_000_000, 5, 100, now),
            // Offline but within one token of done → not reclaimable.
            seq(4, RequestClass::Offline, 8_000_000, 99, 100, now),
        ];
        assert_eq!(e.victim_order(&active, now), vec![2, 3, 1]);
    }

    #[test]
    fn victim_order_breaks_backlog_ties_by_tbt_slack() {
        let e = engine(true);
        let now = 1_000_000;
        // Two offline sequences from the same t=0 backlog: identical
        // class, arrival, and hence score — the canonical comparator
        // ties. Stamped budgets differ, so the TBT-slack term decides:
        // the looser budget (more slack) is shed first.
        let mut tight = seq(7, RequestClass::Offline, 0, 5, 100, now);
        tight.tbt_us = 50_000;
        let mut loose = seq(8, RequestClass::Offline, 0, 5, 100, now);
        loose.tbt_us = 500_000;
        assert_eq!(e.victim_order(&[tight, loose], now), vec![8, 7]);
    }
}
