//! Global Monitor (paper §III): sliding-window system metrics.
//!
//! Since the coordinator sharding refactor the monitor is an
//! **aggregation over per-shard monitors**: each scheduler shard tracks
//! its own arrival window, queue depth, and KV accounting against its own
//! token budget, and [`GlobalMonitor::view`] folds them into the same
//! system-wide [`MonitorView`] the Dynamic Batching Controller and the
//! P/D scheduler always consumed, plus a [`ShardView`] per shard (KV
//! pressure, queue depth, arrival rate) for placement debugging and the
//! shard-scaling bench. Batch latency and the decode active count are
//! engine-side quantities, tracked globally. All windows are driven by
//! the run's clock (virtual or wall), so simulated and real runs share
//! the code.
//!
//! Concurrency note: under the parallel executor
//! ([`super::executor`]) every monitor mutation still happens on the
//! merge loop — worker threads compute pure boundary outcomes and the
//! merge loop folds their per-shard KV releases and decode exits in
//! deterministic order. One writer, no locks, and the per-shard views
//! stay exactly what a sequential run would have recorded.

use crate::util::stats::{Online, RateWindow};
use crate::Micros;

/// One shard's slice of the monitor state.
#[derive(Debug)]
struct ShardMonitor {
    arrivals: RateWindow,
    prefill_queue: usize,
    kv_tokens_in_use: u64,
    kv_token_budget: u64,
}

/// Per-shard load snapshot surfaced in [`MonitorView::shards`].
#[derive(Debug, Clone, Default)]
pub struct ShardView {
    pub arrival_rps: f64,
    pub queue_depth: usize,
    pub kv_tokens_in_use: u64,
    pub kv_token_budget: u64,
}

impl ShardView {
    /// KV pressure of this shard in [0,1].
    pub fn pressure(&self) -> f64 {
        if self.kv_token_budget == 0 {
            return 1.0;
        }
        self.kv_tokens_in_use as f64 / self.kv_token_budget as f64
    }
}

/// Snapshot handed to the batching controller / scheduler.
#[derive(Debug, Clone, Default)]
pub struct MonitorView {
    pub arrival_rps: f64,
    pub mean_input_len: f64,
    pub mean_batch_latency_us: f64,
    pub prefill_queue: usize,
    pub decode_active: usize,
    pub kv_tokens_in_use: u64,
    pub kv_token_budget: u64,
    /// Per-shard load views (one entry when unsharded).
    pub shards: Vec<ShardView>,
}

impl MonitorView {
    /// Remaining KV headroom in tokens (what Eq. 6 admits against).
    pub fn kv_headroom(&self) -> u64 {
        self.kv_token_budget.saturating_sub(self.kv_tokens_in_use)
    }

    /// Memory pressure in [0,1].
    pub fn pressure(&self) -> f64 {
        if self.kv_token_budget == 0 {
            return 1.0;
        }
        self.kv_tokens_in_use as f64 / self.kv_token_budget as f64
    }
}

/// The Global Monitor: per-shard trackers plus system-wide aggregates.
#[derive(Debug)]
pub struct GlobalMonitor {
    shards: Vec<ShardMonitor>,
    input_len: Online,
    batch_latency: Online,
    decode_active: usize,
}

impl GlobalMonitor {
    /// Unsharded constructor: one shard owning the whole budget.
    /// `window_us`: the arrival-rate estimation window (paper uses
    /// real-time views; 10 s keeps estimates stable at low RPS).
    pub fn new(window_us: Micros, kv_token_budget: u64) -> GlobalMonitor {
        GlobalMonitor::sharded(window_us, &[kv_token_budget])
    }

    /// One monitor slice per scheduler shard, each with its own KV token
    /// budget (the sum is the fleet budget the aggregate view reports).
    pub fn sharded(window_us: Micros, shard_budgets: &[u64]) -> GlobalMonitor {
        assert!(!shard_budgets.is_empty());
        GlobalMonitor {
            shards: shard_budgets
                .iter()
                .map(|&b| ShardMonitor {
                    arrivals: RateWindow::new(window_us),
                    prefill_queue: 0,
                    kv_tokens_in_use: 0,
                    kv_token_budget: b,
                })
                .collect(),
            input_len: Online::new(),
            batch_latency: Online::new(),
            decode_active: 0,
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn on_arrival(&mut self, shard: usize, now: Micros, input_len: u32) {
        let s = &mut self.shards[shard];
        s.arrivals.record(now);
        s.prefill_queue += 1;
        self.input_len.push(input_len as f64);
    }

    pub fn on_prefill_dispatch(&mut self, shard: usize, n: usize) {
        let s = &mut self.shards[shard];
        s.prefill_queue = s.prefill_queue.saturating_sub(n);
    }

    /// Work-stealing moved `n` queued requests from `from` to `to`.
    pub fn on_steal(&mut self, from: usize, to: usize, n: usize) {
        self.shards[from].prefill_queue =
            self.shards[from].prefill_queue.saturating_sub(n);
        self.shards[to].prefill_queue += n;
    }

    /// Preemption returned `n` requests to `shard`'s queue (an aborted
    /// prefill batch or checkpoint-restored evictees). A requeue is not
    /// an arrival: the rate window must not double-count it.
    pub fn on_requeue(&mut self, shard: usize, n: usize) {
        self.shards[shard].prefill_queue += n;
    }

    pub fn on_batch_done(&mut self, latency_us: Micros) {
        self.batch_latency.push(latency_us as f64);
    }

    pub fn on_decode_enter(&mut self, n: usize) {
        self.decode_active += n;
    }

    pub fn on_decode_exit(&mut self, n: usize) {
        self.decode_active = self.decode_active.saturating_sub(n);
    }

    /// KV accounting: reserve a request's context footprint against the
    /// shard fronting the target decode instance. With the prefix cache
    /// armed ([`crate::config::PrefixSpec`]) requests reserve only their
    /// *deduplicated* footprint (shared cached blocks excluded) while the
    /// cache itself reserves each resident block exactly once at insert
    /// and releases it here on LRU eviction — so `kv_tokens_in_use` stays
    /// the true physical occupancy either way.
    pub fn kv_reserve(&mut self, shard: usize, tokens: u64) {
        self.shards[shard].kv_tokens_in_use += tokens;
    }

    pub fn kv_release(&mut self, shard: usize, tokens: u64) {
        let s = &mut self.shards[shard];
        s.kv_tokens_in_use = s.kv_tokens_in_use.saturating_sub(tokens);
    }

    pub fn view(&mut self, now: Micros) -> MonitorView {
        let shards: Vec<ShardView> = self
            .shards
            .iter_mut()
            .map(|s| ShardView {
                arrival_rps: s.arrivals.rate(now),
                queue_depth: s.prefill_queue,
                kv_tokens_in_use: s.kv_tokens_in_use,
                kv_token_budget: s.kv_token_budget,
            })
            .collect();
        MonitorView {
            arrival_rps: shards.iter().map(|s| s.arrival_rps).sum(),
            mean_input_len: self.input_len.mean(),
            mean_batch_latency_us: self.batch_latency.mean(),
            prefill_queue: shards.iter().map(|s| s.queue_depth).sum(),
            decode_active: self.decode_active,
            kv_tokens_in_use: shards.iter().map(|s| s.kv_tokens_in_use).sum(),
            kv_token_budget: shards.iter().map(|s| s.kv_token_budget).sum(),
            shards,
        }
    }
}

/// Observed decode-iteration latency model for real engines.
///
/// The virtual-time scheduler projects the next iteration's duration
/// straight from the roofline cost model
/// ([`crate::cluster::gpu::CostModel::decode_step_time`]) — that is what
/// arms TBT admission and preemption. A real engine has no cost model,
/// but its iteration latency in the bandwidth-bound decode regime is
/// close to affine in the batch's total resident context (weight read +
/// KV read over memory bandwidth, plus a fixed step overhead). So the
/// realtime path fits exactly that shape online: exponentially-weighted
/// first and second moments of `(total_ctx, duration)` give an
/// EWMA-weighted least-squares line whose slope is the per-context-token
/// cost and whose intercept is the weight-read floor. Until the first
/// observation lands, [`ObservedDecodeModel::projected_us`] returns 0 —
/// the same "no projection available" sentinel as the
/// [`crate::cluster::Engine`] default, which admission treats as
/// projection-off rather than "iterations are free".
#[derive(Debug, Clone)]
pub struct ObservedDecodeModel {
    alpha: f64,
    n: u64,
    ex: f64,
    ey: f64,
    exx: f64,
    exy: f64,
}

impl ObservedDecodeModel {
    /// `alpha`: EWMA smoothing in (0, 1]; higher adapts faster.
    pub fn new(alpha: f64) -> ObservedDecodeModel {
        let alpha = alpha.clamp(1e-3, 1.0);
        ObservedDecodeModel { alpha, n: 0, ex: 0.0, ey: 0.0, exx: 0.0, exy: 0.0 }
    }

    /// Record one completed decode iteration: the batch's total resident
    /// context (tokens) and the observed wall duration (µs).
    pub fn observe(&mut self, total_ctx: u64, duration_us: Micros) {
        let x = total_ctx as f64;
        let y = duration_us as f64;
        if self.n == 0 {
            self.ex = x;
            self.ey = y;
            self.exx = x * x;
            self.exy = x * y;
        } else {
            let a = self.alpha;
            self.ex += a * (x - self.ex);
            self.ey += a * (y - self.ey);
            self.exx += a * (x * x - self.exx);
            self.exy += a * (x * y - self.exy);
        }
        self.n += 1;
    }

    pub fn samples(&self) -> u64 {
        self.n
    }

    /// Projected duration (µs) of an iteration over `total_ctx` resident
    /// context tokens; 0 until at least one observation has landed.
    pub fn projected_us(&self, total_ctx: u64) -> Micros {
        if self.n == 0 {
            return 0;
        }
        let var = self.exx - self.ex * self.ex;
        // Degenerate spread (all samples at ~one context size): the mean
        // is the whole model.
        let y = if var <= f64::EPSILON * self.exx.max(1.0) {
            self.ey
        } else {
            // Iteration time cannot shrink with more resident context;
            // a transient negative slope from noisy early samples falls
            // back to the mean rather than extrapolating nonsense.
            let slope = (self.exy - self.ex * self.ey) / var;
            if slope < 0.0 {
                self.ey
            } else {
                (self.ey - slope * self.ex) + slope * total_ctx as f64
            }
        };
        y.max(1.0).round() as Micros
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_arrivals_and_lengths() {
        let mut m = GlobalMonitor::new(1_000_000, 1000);
        for i in 0..10 {
            m.on_arrival(0, i * 100_000, 100 + i as u32);
        }
        let v = m.view(1_000_000);
        assert!(v.arrival_rps > 5.0);
        assert!((v.mean_input_len - 104.5).abs() < 1e-9);
        assert_eq!(v.prefill_queue, 10);
        assert_eq!(v.shards.len(), 1);
        assert_eq!(v.shards[0].queue_depth, 10);
    }

    #[test]
    fn kv_accounting_saturates() {
        let mut m = GlobalMonitor::new(1_000_000, 1000);
        m.kv_reserve(0, 600);
        assert_eq!(m.view(0).kv_headroom(), 400);
        m.kv_release(0, 10_000); // over-release clamps at zero
        assert_eq!(m.view(0).kv_tokens_in_use, 0);
        assert_eq!(m.view(0).kv_headroom(), 1000);
    }

    #[test]
    fn pressure_bounds() {
        let mut m = GlobalMonitor::new(1_000_000, 100);
        assert_eq!(m.view(0).pressure(), 0.0);
        m.kv_reserve(0, 100);
        assert_eq!(m.view(0).pressure(), 1.0);
    }

    #[test]
    fn queue_counters_saturate() {
        let mut m = GlobalMonitor::new(1_000_000, 100);
        m.on_prefill_dispatch(0, 5); // more than queued
        assert_eq!(m.view(0).prefill_queue, 0);
        m.on_decode_enter(3);
        m.on_decode_exit(5);
        assert_eq!(m.view(0).decode_active, 0);
    }

    #[test]
    fn sharded_view_aggregates_and_exposes_per_shard() {
        let mut m = GlobalMonitor::sharded(1_000_000, &[600, 400]);
        assert_eq!(m.n_shards(), 2);
        for i in 0..6 {
            m.on_arrival(0, i * 100_000, 100);
        }
        for i in 0..2 {
            m.on_arrival(1, i * 100_000, 200);
        }
        m.kv_reserve(0, 300);
        m.kv_reserve(1, 400);
        let v = m.view(1_000_000);
        assert_eq!(v.prefill_queue, 8);
        assert_eq!(v.kv_tokens_in_use, 700);
        assert_eq!(v.kv_token_budget, 1000);
        assert_eq!(v.shards[0].queue_depth, 6);
        assert_eq!(v.shards[1].queue_depth, 2);
        assert!((v.shards[1].pressure() - 1.0).abs() < 1e-12);
        assert!(v.shards[0].pressure() < 1.0);
        assert!(v.arrival_rps > v.shards[1].arrival_rps);
        // Mean input length is a global aggregate: (6·100 + 2·200) / 8.
        assert!((v.mean_input_len - 125.0).abs() < 1e-9);
    }

    #[test]
    fn requeue_restores_queue_depth_without_counting_an_arrival() {
        let mut m = GlobalMonitor::new(1_000_000, 1000);
        m.on_arrival(0, 0, 100);
        m.on_prefill_dispatch(0, 1);
        let before = m.view(500_000).arrival_rps;
        m.on_requeue(0, 1);
        let v = m.view(500_000);
        assert_eq!(v.prefill_queue, 1, "requeued work is queued again");
        assert_eq!(v.arrival_rps, before, "requeue is not an arrival");
    }

    #[test]
    fn observed_model_recovers_cost_model_projection() {
        use crate::cluster::gpu::CostModel;
        use crate::config::{GpuSpec, ModelSpec};
        // Feed the estimator iterations priced by the simulator's cost
        // model (bandwidth-bound regime: duration is affine in total
        // resident context) and check the fitted line projects within a
        // few percent of the model it never saw.
        let cm = CostModel::new(ModelSpec::llama2_13b(), GpuSpec::a100_40g(), 1);
        let mut m = ObservedDecodeModel::new(0.2);
        assert_eq!(m.projected_us(4096), 0, "no samples -> no projection");
        for i in 0..200u64 {
            let ctx = 1_000 + (i * 137) % 28_000;
            let n = 1 + (i % 16) as usize;
            m.observe(ctx, cm.decode_step_time(n, ctx));
        }
        assert_eq!(m.samples(), 200);
        for &ctx in &[2_000u64, 8_000, 16_000, 24_000] {
            let want = cm.decode_step_time(8, ctx) as f64;
            let got = m.projected_us(ctx) as f64;
            assert!(
                (got - want).abs() / want < 0.05,
                "ctx {ctx}: observed {got} vs model {want}"
            );
        }
        assert!(
            m.projected_us(24_000) > m.projected_us(2_000),
            "more resident context must project slower iterations"
        );
    }

    #[test]
    fn observed_model_degenerate_spread_falls_back_to_mean() {
        let mut m = ObservedDecodeModel::new(0.5);
        for _ in 0..10 {
            m.observe(4_096, 30_000);
        }
        // All samples at one context size: projection is the mean
        // everywhere, never an extrapolated line.
        assert_eq!(m.projected_us(4_096), 30_000);
        assert_eq!(m.projected_us(100_000), 30_000);
    }

    #[test]
    fn steal_moves_queue_depth_between_shards() {
        let mut m = GlobalMonitor::sharded(1_000_000, &[500, 500]);
        for i in 0..6 {
            m.on_arrival(0, i, 10);
        }
        m.on_steal(0, 1, 4);
        let v = m.view(10);
        assert_eq!(v.shards[0].queue_depth, 2);
        assert_eq!(v.shards[1].queue_depth, 4);
        assert_eq!(v.prefill_queue, 6, "stealing must not change the total");
    }
}
