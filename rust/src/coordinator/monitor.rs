//! Global Monitor (paper §III): sliding-window system metrics.
//!
//! Aggregates GPU memory pressure, queue lengths, arrival rate, mean
//! sequence length, and batch latency, and feeds them to the Dynamic
//! Batching Controller (N_max estimation) and the P/D scheduler (queue
//! statistics). All windows are driven by the run's clock (virtual or
//! wall), so simulated and real runs share the code.

use crate::util::stats::{Online, RateWindow};
use crate::Micros;

/// Snapshot handed to the batching controller / scheduler.
#[derive(Debug, Clone, Default)]
pub struct MonitorView {
    pub arrival_rps: f64,
    pub mean_input_len: f64,
    pub mean_batch_latency_us: f64,
    pub prefill_queue: usize,
    pub decode_active: usize,
    pub kv_tokens_in_use: u64,
    pub kv_token_budget: u64,
}

impl MonitorView {
    /// Remaining KV headroom in tokens (what Eq. 6 admits against).
    pub fn kv_headroom(&self) -> u64 {
        self.kv_token_budget.saturating_sub(self.kv_tokens_in_use)
    }

    /// Memory pressure in [0,1].
    pub fn pressure(&self) -> f64 {
        if self.kv_token_budget == 0 {
            return 1.0;
        }
        self.kv_tokens_in_use as f64 / self.kv_token_budget as f64
    }
}

/// The Global Monitor.
#[derive(Debug)]
pub struct GlobalMonitor {
    arrivals: RateWindow,
    input_len: Online,
    batch_latency: Online,
    prefill_queue: usize,
    decode_active: usize,
    kv_tokens_in_use: u64,
    kv_token_budget: u64,
}

impl GlobalMonitor {
    /// `window_us`: the arrival-rate estimation window (paper uses
    /// real-time views; 10 s keeps estimates stable at low RPS).
    pub fn new(window_us: Micros, kv_token_budget: u64) -> GlobalMonitor {
        GlobalMonitor {
            arrivals: RateWindow::new(window_us),
            input_len: Online::new(),
            batch_latency: Online::new(),
            prefill_queue: 0,
            decode_active: 0,
            kv_tokens_in_use: 0,
            kv_token_budget,
        }
    }

    pub fn on_arrival(&mut self, now: Micros, input_len: u32) {
        self.arrivals.record(now);
        self.input_len.push(input_len as f64);
        self.prefill_queue += 1;
    }

    pub fn on_prefill_dispatch(&mut self, n: usize) {
        self.prefill_queue = self.prefill_queue.saturating_sub(n);
    }

    pub fn on_batch_done(&mut self, latency_us: Micros) {
        self.batch_latency.push(latency_us as f64);
    }

    pub fn on_decode_enter(&mut self, n: usize) {
        self.decode_active += n;
    }

    pub fn on_decode_exit(&mut self, n: usize) {
        self.decode_active = self.decode_active.saturating_sub(n);
    }

    /// KV accounting: reserve a request's full-context footprint.
    pub fn kv_reserve(&mut self, tokens: u64) {
        self.kv_tokens_in_use += tokens;
    }

    pub fn kv_release(&mut self, tokens: u64) {
        self.kv_tokens_in_use = self.kv_tokens_in_use.saturating_sub(tokens);
    }

    pub fn view(&mut self, now: Micros) -> MonitorView {
        MonitorView {
            arrival_rps: self.arrivals.rate(now),
            mean_input_len: self.input_len.mean(),
            mean_batch_latency_us: self.batch_latency.mean(),
            prefill_queue: self.prefill_queue,
            decode_active: self.decode_active,
            kv_tokens_in_use: self.kv_tokens_in_use,
            kv_token_budget: self.kv_token_budget,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_arrivals_and_lengths() {
        let mut m = GlobalMonitor::new(1_000_000, 1000);
        for i in 0..10 {
            m.on_arrival(i * 100_000, 100 + i as u32);
        }
        let v = m.view(1_000_000);
        assert!(v.arrival_rps > 5.0);
        assert!((v.mean_input_len - 104.5).abs() < 1e-9);
        assert_eq!(v.prefill_queue, 10);
    }

    #[test]
    fn kv_accounting_saturates() {
        let mut m = GlobalMonitor::new(1_000_000, 1000);
        m.kv_reserve(600);
        assert_eq!(m.view(0).kv_headroom(), 400);
        m.kv_release(10_000); // over-release clamps at zero
        assert_eq!(m.view(0).kv_tokens_in_use, 0);
        assert_eq!(m.view(0).kv_headroom(), 1000);
    }

    #[test]
    fn pressure_bounds() {
        let mut m = GlobalMonitor::new(1_000_000, 100);
        assert_eq!(m.view(0).pressure(), 0.0);
        m.kv_reserve(100);
        assert_eq!(m.view(0).pressure(), 1.0);
    }

    #[test]
    fn queue_counters_saturate() {
        let mut m = GlobalMonitor::new(1_000_000, 100);
        m.on_prefill_dispatch(5); // more than queued
        assert_eq!(m.view(0).prefill_queue, 0);
        m.on_decode_enter(3);
        m.on_decode_exit(5);
        assert_eq!(m.view(0).decode_active, 0);
    }
}
