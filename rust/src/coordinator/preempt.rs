//! Preemption subsystem: urgency-triggered prefill abort and decode KV
//! eviction with checkpoint-and-restore.
//!
//! The priority layer (PR 1) reorders work at *plan time* only: once an
//! offline prefill batch is dispatched, or a decode instance's KV fills
//! up, a deadline-critical online request can do nothing but wait. The
//! [`PreemptionEngine`] converts priority scores into action after that
//! point, driven by two triggers evaluated each scheduling round (only
//! when [`crate::config::PreemptSpec::enabled`] — the default is off and
//! the subsystem is then completely inert):
//!
//! * **(a) Prefill abort-and-requeue** — a queued online request has
//!   consumed more than `urgency_threshold` of its TTFT budget while
//!   every prefill slot is held by a lower-urgency batch. The least
//!   urgent in-flight batch (canonical [`PriorityScorer`] order on its
//!   most-urgent member) whose progress is still below
//!   `max_abort_progress` is cancelled via an event tombstone; its
//!   elapsed FLOP-time is charged as waste and its requests return to the
//!   owning shard's bucket manager (drain order restores arrival order).
//! * **(b) Decode evict-with-checkpoint** — the same urgent request
//!   cannot be admitted because its full-context KV footprint exceeds its
//!   shard's best decode headroom while *offline* sequences hold
//!   reclaimable KV there. The least-urgent offline victims checkpoint
//!   their generated-token progress ([`RestoreInfo`]), release their KV
//!   reservations, and re-enter the queue as recompute-from-checkpoint
//!   work: the requeued entry's prompt is `input + generated` (so its
//!   prefill time covers the replayed context) and its remaining
//!   generation shrinks by the tokens already produced. The original
//!   prompt/output split and the already-paid first token are restored
//!   when the recompute prefill completes.
//!
//! Anti-thrash guard: at most one preemption is outstanding at a time —
//! after a trigger fires for a candidate, no further preemption happens
//! until that candidate is dispatched ([`PreemptionEngine::on_dispatch`]).
//! This bounds the wasted work any single urgent request can cause to one
//! aborted batch plus one eviction pass. Drain orders that do not serve
//! by urgency (FCFS without priority, SJF/LJF, the FIFO baseline) would
//! hand every freed slot or KV token back to the very work that was
//! preempted, so the scheduler arms the subsystem only when the
//! planner's drain follows urgency and warns otherwise.
//!
//! A request stolen onto another shard needs no special handling: the
//! trigger scan walks *every* shard's most urgent queued online request
//! (served from each planner's cached min-arrival peek, so the scan is
//! O(shards) amortized), so an urgent request a thief shard absorbed
//! preempts the thief's in-flight work through the same two paths.
//!
//! The checkpoint-and-restore *mechanism* here is deliberately
//! trigger-agnostic: the TBT-aware admission layer
//! ([`super::admission`]) drives the same evict path (KV release,
//! [`RestoreInfo`] checkpoint, `RestoreReady` requeue) from its own
//! per-iteration inter-token-budget trigger, charged to its own
//! counters. Only trigger policy differs; conservation and TTFT
//! preservation are proved once, for both.

use super::bucket::QueuedReq;
use super::fleet::{DecodeSeqState, InFlightPrefill};
use super::priority::PriorityScorer;
use crate::config::{PreemptSpec, PrioritySpec, SloSpec};
use crate::workload::{RequestClass, RequestId};
use crate::Micros;
use std::cmp::Ordering;
use std::collections::HashMap;

/// The queue entry an active decode sequence would be evicted as, or
/// `None` when the sequence is not reclaimable — the single eligibility
/// rule shared by preemption's [`PreemptionEngine::pick_decode_victims`]
/// and the admission layer's TBT victim ordering, so the two trigger
/// policies can never drift apart on *who* may be evicted (only on the
/// order). Not reclaimable: online sequences (both subsystems exist to
/// protect them), and offline sequences within one token of done — a
/// finished one can sit in the active set with `generated == output_len`
/// until the boundary that formally completes it (evicting it would
/// requeue zero remaining generation, or underflow on a repeat), and a
/// one-token-remaining victim would pay a full-context recompute for KV
/// that frees at the very next boundary anyway.
pub(crate) fn evictable_entry(s: &DecodeSeqState) -> Option<QueuedReq> {
    if s.class != RequestClass::Offline || s.generated + 1 >= s.output_len {
        return None;
    }
    Some(QueuedReq {
        id: s.id,
        len: s.input_len,
        output_len: s.output_len,
        arrival: s.arrival,
        class: s.class,
        tbt_us: s.tbt_us,
        // Carry the full stamp so the deficit math in
        // `pick_decode_victims` sums the same deduplicated footprints the
        // eviction path will actually release.
        prefix: s.prefix,
    })
}

/// Checkpointed progress of an evicted decode sequence, keyed by request
/// id until its recompute prefill completes.
#[derive(Debug, Clone, Copy)]
pub struct RestoreInfo {
    /// When the sequence's first token originally landed (TTFT is paid
    /// once; eviction must not reset it).
    pub first_token: Micros,
    /// Original prompt length (the requeued entry's `len` grew by
    /// `generated` to cover the replayed context).
    pub input_len: u32,
    /// Original target generation length.
    pub output_len: u32,
    /// Tokens generated before eviction; decode resumes after them.
    pub generated: u32,
    /// Padded length of the sequence's *original* prefill batch, carried
    /// through so completion records (and their padding-waste metric)
    /// describe the prefill that actually served the prompt, not the
    /// recompute replay.
    pub padded_len: u32,
    /// When the sequence's last pre-eviction token landed. The recompute
    /// prefill's completion produces the *next* token, and the scheduler
    /// records that span as an inter-token gap — so the mid-stream stall
    /// an eviction inflicts shows up in the TBT metrics instead of being
    /// silently erased by the re-admission clock re-anchor.
    pub last_token_at: Micros,
}

/// The preemption decision engine: trigger detection, victim selection
/// (through the canonical priority comparator), and checkpoint storage.
/// Pure policy — all fleet/queue mutation stays in the scheduler.
#[derive(Debug)]
pub struct PreemptionEngine {
    spec: PreemptSpec,
    scorer: PriorityScorer,
    /// Queueing time (µs) at which an online request crosses the
    /// preemption urgency threshold: `urgency_threshold · slo.ttft_us`,
    /// rounded up so a wake at the crossing is never a hair early.
    threshold_wait_us: u64,
    /// Candidate with an outstanding preemption (anti-thrash guard);
    /// cleared when the candidate is dispatched.
    pending: Option<RequestId>,
    /// Checkpoints of evicted sequences awaiting recompute. Accessed only
    /// by key, so the map's hash order cannot affect scheduling.
    restore: HashMap<RequestId, RestoreInfo>,
}

impl PreemptionEngine {
    pub fn new(
        spec: PreemptSpec,
        priority: PrioritySpec,
        slo: SloSpec,
    ) -> PreemptionEngine {
        let threshold_wait_us =
            (spec.urgency_threshold * slo.ttft_us as f64).ceil() as u64;
        PreemptionEngine {
            spec,
            scorer: PriorityScorer::new(priority, slo),
            threshold_wait_us,
            pending: None,
            restore: HashMap::new(),
        }
    }

    /// The instant at which `r` (a queued online request) crosses the
    /// preemption urgency threshold — where the scheduler plants its
    /// wake-up when no candidate is ripe yet.
    pub fn crossing_at(&self, r: &QueuedReq) -> Micros {
        r.arrival.saturating_add(self.threshold_wait_us)
    }

    pub fn enabled(&self) -> bool {
        self.spec.enabled
    }

    /// The candidate whose outstanding preemption blocks further triggers.
    pub fn pending(&self) -> Option<RequestId> {
        self.pending
    }

    /// The preemption candidate: the globally most urgent queued online
    /// request across the per-shard `oldest_online` peeks (online urgency
    /// is monotone in waiting time, so earliest arrival = most urgent;
    /// ties break on id, then shard scan order). Returns the owning shard
    /// and the request, or None when disabled, a preemption is already
    /// outstanding, or nothing has burned past `urgency_threshold`.
    pub fn candidate(
        &self,
        oldest: &[Option<QueuedReq>],
        now: Micros,
    ) -> Option<(usize, QueuedReq)> {
        if !self.spec.enabled || self.pending.is_some() {
            return None;
        }
        let mut best: Option<(usize, QueuedReq)> = None;
        for (si, r) in oldest.iter().enumerate() {
            let Some(r) = r else { continue };
            debug_assert_eq!(r.class, RequestClass::Online);
            if self.scorer.urgency(r, now) < self.spec.urgency_threshold {
                continue;
            }
            let better = match &best {
                None => true,
                Some((_, cur)) => (r.arrival, r.id) < (cur.arrival, cur.id),
            };
            if better {
                best = Some((si, *r));
            }
        }
        best
    }

    /// Trigger (a) victim: among the in-flight prefill batches, the one
    /// whose *most urgent* member still ranks strictly below `cand` under
    /// the canonical comparator, choosing the least-urgent such batch.
    /// Batches holding any urgent member are never aborted, and neither
    /// is a batch whose progress passed `max_abort_progress` (finishing
    /// it wastes less than re-running it). Returns the instance index.
    pub fn pick_prefill_victim(
        &self,
        cand: &QueuedReq,
        running: &[(usize, &InFlightPrefill)],
        now: Micros,
    ) -> Option<usize> {
        let mut victim: Option<(usize, QueuedReq)> = None;
        for &(pi, p) in running {
            let elapsed = now.saturating_sub(p.started_at);
            if elapsed as f64 >= self.spec.max_abort_progress * p.duration as f64
            {
                continue;
            }
            let Some(best_member) = p
                .formed
                .reqs
                .iter()
                .min_by(|a, b| self.scorer.compare(a, b, now))
                .copied()
            else {
                continue;
            };
            if self.scorer.is_urgent(&best_member, now) {
                continue; // never abort urgent work
            }
            if self.scorer.compare(cand, &best_member, now) != Ordering::Less {
                continue; // the candidate does not outrank this batch
            }
            let less_urgent = match &victim {
                None => true,
                Some((_, cur)) => {
                    self.scorer.least_urgent_first(&best_member, cur, now)
                        == Ordering::Less
                }
            };
            if less_urgent {
                victim = Some((pi, best_member));
            }
        }
        victim.map(|(pi, _)| pi)
    }

    /// Trigger (b) victims on one decode instance: offline sequences in
    /// `active`, least urgent first (canonical order reversed, ties on
    /// id), until their freed full-context KV covers `deficit` tokens,
    /// capped at `max_evictions`. Eviction is all-or-nothing per trigger:
    /// if the deficit cannot be covered within the cap, nothing is
    /// evicted — a partial eviction would strand recompute debt without
    /// admitting the urgent request. Returns victim ids in eviction order.
    pub fn pick_decode_victims(
        &self,
        active: &[DecodeSeqState],
        deficit: u64,
        now: Micros,
    ) -> Vec<RequestId> {
        let mut pool: Vec<QueuedReq> =
            active.iter().filter_map(evictable_entry).collect();
        pool.sort_by(|a, b| {
            self.scorer
                .least_urgent_first(a, b, now)
                .then(a.id.cmp(&b.id))
        });
        let mut out = Vec::new();
        let mut freed = 0u64;
        for r in pool {
            if freed >= deficit || out.len() >= self.spec.max_evictions as usize
            {
                break;
            }
            freed += r.footprint();
            out.push(r.id);
        }
        if freed >= deficit {
            out
        } else {
            Vec::new()
        }
    }

    /// Checkpoint an evicted sequence's progress and hand back the queue
    /// entry it re-enters the scheduler as: the prompt grows to cover the
    /// replayed context (original prompt + tokens generated so far), the
    /// remaining generation shrinks by the same amount, so the entry's
    /// full-context footprint — and hence its KV reservation — is
    /// unchanged. Safe to call repeatedly for a sequence evicted more
    /// than once: the stored originals are taken from the restored
    /// [`DecodeSeqState`], which carries them forward.
    pub fn checkpoint_seq(&mut self, s: &DecodeSeqState) -> QueuedReq {
        debug_assert!(s.generated < s.output_len, "completed seqs never evict");
        self.restore.insert(
            s.id,
            RestoreInfo {
                first_token: s.first_token,
                input_len: s.input_len,
                output_len: s.output_len,
                generated: s.generated,
                padded_len: s.padded_len,
                last_token_at: s.last_token_at,
            },
        );
        QueuedReq {
            id: s.id,
            len: s.input_len + s.generated,
            output_len: s.output_len - s.generated,
            arrival: s.arrival,
            class: s.class,
            tbt_us: s.tbt_us,
            // Lineage survives the eviction (the recompute dispatch may
            // hit the cache again), but the acquisition state does not:
            // the evicting scheduler released this sequence's pins, so
            // the requeued entry starts unstamped and reserves — and
            // replays — its full context until re-acquired.
            prefix: super::prefix::PrefixStamp {
                prefix_id: s.prefix.prefix_id,
                prefix_len: s.prefix.prefix_len,
                cached_len: 0,
                shared_len: 0,
            },
        }
    }

    /// Take the checkpoint for a request whose recompute prefill just
    /// completed (None for requests that were never evicted).
    pub fn take_restore(&mut self, id: RequestId) -> Option<RestoreInfo> {
        self.restore.remove(&id)
    }

    /// Record that a preemption fired for `id`; blocks further triggers
    /// until the candidate is dispatched.
    pub fn note_preempt(&mut self, id: RequestId) {
        self.pending = Some(id);
    }

    /// A prefill batch was dispatched; if it carries the pending
    /// candidate, the outstanding preemption is resolved.
    pub fn on_dispatch(&mut self, reqs: &[QueuedReq]) {
        if let Some(id) = self.pending {
            if reqs.iter().any(|r| r.id == id) {
                self.pending = None;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{PrefillBatch, PrefillItem};
    use crate::config::SystemConfig;
    use crate::coordinator::batcher::FormedBatch;

    fn engine(enabled: bool) -> PreemptionEngine {
        let cfg = SystemConfig::default();
        let mut spec = cfg.preempt.clone();
        spec.enabled = enabled;
        PreemptionEngine::new(spec, cfg.priority.clone(), cfg.slo.clone())
    }

    fn req(id: u64, class: RequestClass, arrival: Micros) -> QueuedReq {
        QueuedReq {
            id,
            len: 100,
            output_len: 20,
            arrival,
            class,
            tbt_us: 0,
            prefix: crate::coordinator::prefix::PrefixStamp::default(),
        }
    }

    fn in_flight(
        reqs: Vec<QueuedReq>,
        started_at: Micros,
        duration: Micros,
    ) -> InFlightPrefill {
        let items = reqs
            .iter()
            .map(|r| PrefillItem { id: r.id, len: r.len, tokens: vec![] })
            .collect();
        InFlightPrefill {
            formed: FormedBatch {
                batch: PrefillBatch { items, padded_len: 100 },
                reqs,
                bucket_up: 128,
            },
            done_at: started_at + duration,
            duration,
            target_decode: 0,
            started_at,
            done_event: crate::coordinator::events::EventId::NONE,
            slice: None,
        }
    }

    fn seq(
        id: u64,
        class: RequestClass,
        arrival: Micros,
        input: u32,
        output: u32,
        generated: u32,
    ) -> DecodeSeqState {
        DecodeSeqState {
            id,
            class,
            arrival,
            input_len: input,
            padded_len: input,
            output_len: output,
            generated,
            first_token: arrival + 1000,
            ready_at: 0,
            tbt_us: 0,
            last_token_at: 0,
            prefix: crate::coordinator::prefix::PrefixStamp::default(),
        }
    }

    #[test]
    fn checkpoint_keeps_lineage_but_drops_acquisition_state() {
        let mut e = engine(true);
        let mut s = seq(11, RequestClass::Offline, 0, 800, 200, 60);
        s.prefix = crate::coordinator::prefix::PrefixStamp {
            prefix_id: 5,
            prefix_len: 512,
            cached_len: 512,
            shared_len: 512,
        };
        let qr = e.checkpoint_seq(&s);
        assert_eq!(qr.prefix.prefix_id, 5, "lineage survives eviction");
        assert_eq!(qr.prefix.prefix_len, 512);
        assert_eq!(qr.prefix.cached_len, 0, "pins were released: no hit");
        assert_eq!(qr.prefix.shared_len, 0, "full context reserves again");
        assert_eq!(qr.footprint(), (800 + 60 + 140) as u64);
    }

    #[test]
    fn candidate_requires_enabled_threshold_and_no_pending() {
        // Default TTFT budget 400 ms, preempt threshold 0.9 → urgent after
        // 360 ms of queueing.
        let now = 1_000_000;
        let urgent = req(7, RequestClass::Online, now - 500_000);
        let fresh = req(8, RequestClass::Online, now - 10_000);
        let oldest = vec![Some(fresh), Some(urgent)];

        assert!(engine(false).candidate(&oldest, now).is_none(), "disabled");
        let mut e = engine(true);
        let (si, c) = e.candidate(&oldest, now).unwrap();
        assert_eq!((si, c.id), (1, 7), "most urgent wins, not shard order");
        assert!(e.candidate(&[Some(fresh)], now).is_none(), "below threshold");
        e.note_preempt(7);
        assert!(e.candidate(&oldest, now).is_none(), "pending blocks");
        e.on_dispatch(&[urgent]);
        assert!(e.pending().is_none());
        assert!(e.candidate(&oldest, now).is_some(), "cleared on dispatch");
        // The wake point is exactly where the threshold check flips:
        // 0.9 × 400 ms TTFT budget = 360 ms after arrival.
        assert_eq!(e.crossing_at(&fresh), fresh.arrival + 360_000);
        assert!(e.candidate(&[Some(fresh)], e.crossing_at(&fresh)).is_some());
    }

    #[test]
    fn candidate_ties_break_on_arrival_then_id() {
        let now = 1_000_000;
        let a = req(3, RequestClass::Online, 100_000);
        let b = req(1, RequestClass::Online, 100_000);
        let e = engine(true);
        let (si, c) = e.candidate(&[Some(a), Some(b)], now).unwrap();
        assert_eq!((si, c.id), (1, 1), "equal arrival → lower id");
    }

    #[test]
    fn prefill_victim_is_least_urgent_eligible_batch() {
        let e = engine(true);
        let now = 1_000_000;
        let cand = req(99, RequestClass::Online, now - 500_000);
        // Batch 0: offline, barely started → eligible.
        let b0 = in_flight(
            vec![req(0, RequestClass::Offline, 0)],
            now - 10_000,
            1_000_000,
        );
        // Batch 1: offline that has aged less (later arrival) → even less
        // urgent, also eligible; the victim choice must prefer it.
        let b1 = in_flight(
            vec![req(1, RequestClass::Offline, now - 1_000)],
            now - 10_000,
            1_000_000,
        );
        // Batch 2: contains an urgent online member → protected.
        let b2 = in_flight(
            vec![
                req(2, RequestClass::Offline, 0),
                req(3, RequestClass::Online, now - 390_000),
            ],
            now - 10_000,
            1_000_000,
        );
        // Batch 3: past the abort-progress gate → protected.
        let b3 = in_flight(
            vec![req(4, RequestClass::Offline, now)],
            now - 900_000,
            1_000_000,
        );
        let running = vec![(0, &b0), (1, &b1), (2, &b2), (3, &b3)];
        assert_eq!(e.pick_prefill_victim(&cand, &running, now), Some(1));
        // A candidate that outranks no eligible batch → None (a fresh
        // offline request ranks below every aged offline member).
        let weak = req(98, RequestClass::Offline, now);
        assert_eq!(e.pick_prefill_victim(&weak, &running, now), None);
    }

    #[test]
    fn decode_victims_cover_deficit_least_urgent_first() {
        let e = engine(true);
        let now = 10_000_000;
        // Offline seqs: footprints 1100 each (1000 + 100); the online seq
        // must never be a victim. Aging makes the *latest* offline arrival
        // the least urgent.
        let active = vec![
            seq(0, RequestClass::Offline, 0, 1000, 100, 5),
            seq(1, RequestClass::Online, 0, 1000, 100, 5),
            seq(2, RequestClass::Offline, 5_000_000, 1000, 100, 5),
            seq(3, RequestClass::Offline, 2_000_000, 1000, 100, 5),
        ];
        // Deficit of 2000 tokens → two victims, least urgent first.
        let v = e.pick_decode_victims(&active, 2000, now);
        assert_eq!(v, vec![2, 3], "latest offline arrivals evict first");
        // Sequences at or within one token of done are never victims,
        // even as the least-urgent offline entries: a finished one is
        // only waiting for the boundary that completes it, and a
        // one-token-remaining one frees its KV at that same boundary
        // cheaper than any recompute could.
        let mut with_done = active.clone();
        with_done.push(seq(4, RequestClass::Offline, 9_000_000, 1000, 1, 1));
        with_done.push(seq(5, RequestClass::Offline, 9_500_000, 1000, 100, 99));
        assert_eq!(
            e.pick_decode_victims(&with_done, 2000, now),
            vec![2, 3],
            "finished or one-token-remaining seqs are not evictable"
        );
        // Deficit one victim covers.
        assert_eq!(e.pick_decode_victims(&active, 500, now), vec![2]);
        // Deficit the whole offline pool cannot cover → evict nothing.
        assert!(e.pick_decode_victims(&active, 10_000, now).is_empty());
        // Cap bounds the pass even when the deficit would need more.
        let cfg = SystemConfig::default();
        let mut spec = cfg.preempt.clone();
        spec.enabled = true;
        spec.max_evictions = 1;
        let capped =
            PreemptionEngine::new(spec, cfg.priority.clone(), cfg.slo.clone());
        assert!(
            capped.pick_decode_victims(&active, 2000, now).is_empty(),
            "cap of 1 cannot cover a 2-victim deficit → all-or-nothing"
        );
    }

    #[test]
    fn checkpoint_roundtrips_and_conserves_footprint() {
        let mut e = engine(true);
        let mut s = seq(9, RequestClass::Offline, 42, 800, 200, 60);
        s.tbt_us = 77_000;
        s.last_token_at = 9_000;
        let qr = e.checkpoint_seq(&s);
        assert_eq!(qr.id, 9);
        assert_eq!(qr.arrival, 42, "arrival (and aging credit) preserved");
        assert_eq!(qr.tbt_us, 77_000, "stamped TBT budget survives eviction");
        assert_eq!(qr.len, 860, "prefill replays prompt + generated context");
        assert_eq!(qr.output_len, 140, "remaining generation shrinks");
        assert_eq!(
            (qr.len + qr.output_len),
            (s.input_len + s.output_len),
            "full-context KV footprint unchanged by checkpointing"
        );
        let ri = e.take_restore(9).unwrap();
        assert_eq!(ri.input_len, 800);
        assert_eq!(ri.output_len, 200);
        assert_eq!(ri.generated, 60);
        assert_eq!(ri.first_token, 42 + 1000);
        assert_eq!(ri.padded_len, 800, "original batch padding preserved");
        assert_eq!(ri.last_token_at, 9_000, "pre-eviction token clock kept");
        assert!(e.take_restore(9).is_none(), "checkpoint consumed once");
        assert!(e.take_restore(123).is_none(), "never-evicted id is None");
    }
}
