//! Typed event queue: the heartbeat of the event-driven P/D scheduler.
//!
//! The serving loop is a discrete-event simulation: every future state
//! change is an [`Event`] in a min-ordered [`EventQueue`] (a
//! `BinaryHeap` with reversed ordering). The scheduler pops the earliest
//! event, advances the clock (virtual or wall), applies the handler for
//! its [`EventKind`], and then runs the state-driven phases (hand-off
//! admission, preemption, prefill dispatch, decode launch) that may
//! schedule further events. Ties on the timestamp pop in FIFO push order,
//! which keeps runs bit-for-bit deterministic for a given trace.
//!
//! Scheduled events can be **cancelled**: [`EventQueue::push`] returns an
//! [`EventId`], and [`EventQueue::cancel`] tombstones the entry so
//! `pop`/`pop_due` skip it lazily. The preemption subsystem relies on this
//! to retract the `PrefillDone` completion of a batch it aborts mid-flight.

use crate::Micros;
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::collections::HashSet;

/// What happens when an event fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// The trace's next request reaches the gateway.
    Arrival,
    /// Prefill instance `instance` finishes its in-flight batch.
    PrefillDone { instance: usize },
    /// A KV hand-off becomes consumable on decode instance `decode`
    /// (wake-up for an idle instance; admission itself is state-driven).
    HandoffReady { decode: usize },
    /// Decode instance `decode` reaches its iteration boundary.
    DecodeIterEnd { decode: usize },
    /// Preemption: abort the prefill batch in flight on `instance`,
    /// tombstone its completion, and requeue its requests.
    PreemptPrefill { instance: usize },
    /// Preemption: an evicted decode sequence's checkpoint has landed;
    /// its recompute-from-checkpoint work re-enters the owning shard's
    /// queue (the payload waits in the scheduler's restore buffer).
    RestoreReady { decode: usize },
    /// Preemption: wake-up at the instant the oldest queued online
    /// request crosses the urgency threshold, so a trigger cannot be
    /// missed in an otherwise event-free window (the check itself is
    /// state-driven and runs after every event).
    PreemptCheck,
}

/// Handle to a scheduled event, used only for cancellation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EventId(u64);

impl EventId {
    /// Placeholder for fixtures that never cancel (tests/benches).
    pub const NONE: EventId = EventId(u64::MAX);
}

/// A scheduled event. `seq` is a push counter used only for deterministic
/// FIFO tie-breaking at equal timestamps.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub at: Micros,
    pub kind: EventKind,
    seq: u64,
}

impl PartialEq for Event {
    fn eq(&self, other: &Event) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Event) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    // Reversed so that BinaryHeap (a max-heap) pops the earliest
    // timestamp, FIFO among equals.
    fn cmp(&self, other: &Event) -> Ordering {
        other.at.cmp(&self.at).then(other.seq.cmp(&self.seq))
    }
}

/// Min-ordered event queue with lazy cancellation.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    seq: u64,
    /// Cancelled-but-not-yet-popped sequence numbers. Never iterated, so
    /// the hash order cannot leak into scheduling decisions.
    tombstones: HashSet<u64>,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    /// Schedule `kind` to fire at `at`; the returned id can cancel it.
    pub fn push(&mut self, at: Micros, kind: EventKind) -> EventId {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Event { at, kind, seq });
        EventId(seq)
    }

    /// Tombstone a *pending* event so `pop`/`pop_due` skip it. Returns
    /// true when the id was newly cancelled. Cancelling an event that has
    /// already fired is a caller bug (it would desynchronize `len`);
    /// every live id is handed out by `push` exactly once and consumed by
    /// the pop that fires it.
    pub fn cancel(&mut self, id: EventId) -> bool {
        if id == EventId::NONE {
            return false;
        }
        debug_assert!(id.0 < self.seq, "cancelling an id never issued");
        self.tombstones.insert(id.0)
    }

    /// Drop cancelled entries sitting at the top of the heap.
    fn purge_cancelled_top(&mut self) {
        while matches!(
            self.heap.peek(),
            Some(ev) if self.tombstones.contains(&ev.seq)
        ) {
            let ev = self.heap.pop().unwrap();
            self.tombstones.remove(&ev.seq);
        }
    }

    /// Pop the earliest live event.
    pub fn pop(&mut self) -> Option<Event> {
        self.purge_cancelled_top();
        self.heap.pop()
    }

    /// Pop the earliest live event only if it is due at or before `now`.
    pub fn pop_due(&mut self, now: Micros) -> Option<Event> {
        self.purge_cancelled_top();
        match self.heap.peek() {
            Some(ev) if ev.at <= now => self.heap.pop(),
            _ => None,
        }
    }

    /// Timestamp of the earliest live scheduled event.
    pub fn peek_at(&mut self) -> Option<Micros> {
        self.purge_cancelled_top();
        self.heap.peek().map(|e| e.at)
    }

    /// Live (non-cancelled) scheduled events.
    pub fn len(&self) -> usize {
        self.heap.len() - self.tombstones.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30, EventKind::Arrival);
        q.push(10, EventKind::DecodeIterEnd { decode: 0 });
        q.push(20, EventKind::PrefillDone { instance: 1 });
        let order: Vec<Micros> = std::iter::from_fn(|| q.pop().map(|e| e.at)).collect();
        assert_eq!(order, vec![10, 20, 30]);
        assert!(q.is_empty());
    }

    #[test]
    fn equal_timestamps_pop_fifo() {
        let mut q = EventQueue::new();
        q.push(5, EventKind::PrefillDone { instance: 0 });
        q.push(5, EventKind::PrefillDone { instance: 1 });
        q.push(5, EventKind::PrefillDone { instance: 2 });
        let kinds: Vec<EventKind> =
            std::iter::from_fn(|| q.pop().map(|e| e.kind)).collect();
        assert_eq!(
            kinds,
            vec![
                EventKind::PrefillDone { instance: 0 },
                EventKind::PrefillDone { instance: 1 },
                EventKind::PrefillDone { instance: 2 },
            ]
        );
    }

    #[test]
    fn pop_due_respects_now() {
        let mut q = EventQueue::new();
        q.push(100, EventKind::Arrival);
        q.push(200, EventKind::Arrival);
        assert!(q.pop_due(50).is_none());
        assert_eq!(q.pop_due(150).unwrap().at, 100);
        assert!(q.pop_due(150).is_none());
        assert_eq!(q.peek_at(), Some(200));
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn cancelled_events_are_skipped_by_pop() {
        let mut q = EventQueue::new();
        q.push(10, EventKind::Arrival);
        let mid = q.push(20, EventKind::PrefillDone { instance: 0 });
        q.push(30, EventKind::DecodeIterEnd { decode: 0 });
        assert!(q.cancel(mid));
        let order: Vec<Micros> = std::iter::from_fn(|| q.pop().map(|e| e.at)).collect();
        assert_eq!(order, vec![10, 30], "tombstoned event must not fire");
        assert!(q.is_empty());
    }

    #[test]
    fn cancelled_events_are_skipped_by_pop_due() {
        let mut q = EventQueue::new();
        let first = q.push(100, EventKind::PreemptPrefill { instance: 0 });
        q.push(100, EventKind::RestoreReady { decode: 1 });
        q.push(300, EventKind::Arrival);
        q.cancel(first);
        // The due pop must see straight through the cancelled head.
        let ev = q.pop_due(150).unwrap();
        assert_eq!(ev.kind, EventKind::RestoreReady { decode: 1 });
        assert!(q.pop_due(150).is_none());
        assert_eq!(q.peek_at(), Some(300));
    }

    #[test]
    fn cancellation_preserves_fifo_ties() {
        let mut q = EventQueue::new();
        q.push(5, EventKind::PrefillDone { instance: 0 });
        let second = q.push(5, EventKind::PrefillDone { instance: 1 });
        q.push(5, EventKind::PrefillDone { instance: 2 });
        q.cancel(second);
        let kinds: Vec<EventKind> =
            std::iter::from_fn(|| q.pop().map(|e| e.kind)).collect();
        assert_eq!(
            kinds,
            vec![
                EventKind::PrefillDone { instance: 0 },
                EventKind::PrefillDone { instance: 2 },
            ],
            "survivors keep push order at equal timestamps"
        );
    }

    #[test]
    fn len_stays_consistent_under_cancellation() {
        let mut q = EventQueue::new();
        let a = q.push(1, EventKind::Arrival);
        let b = q.push(2, EventKind::Arrival);
        assert_eq!(q.len(), 2);
        assert!(q.cancel(a));
        assert_eq!(q.len(), 1);
        assert!(!q.is_empty());
        // Double-cancel is a no-op, not a double decrement.
        assert!(!q.cancel(a));
        assert_eq!(q.len(), 1);
        assert!(q.cancel(b));
        assert_eq!(q.len(), 0);
        assert!(q.is_empty());
        assert!(q.pop().is_none());
        // NONE is inert.
        assert!(!q.cancel(EventId::NONE));
        // The queue keeps working after a full drain of tombstones.
        q.push(7, EventKind::Arrival);
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop().unwrap().at, 7);
    }
}
