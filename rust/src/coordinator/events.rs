//! Typed event queue: the heartbeat of the event-driven P/D scheduler.
//!
//! The serving loop is a discrete-event simulation: every future state
//! change is an [`Event`] in a min-ordered [`EventQueue`] (a
//! `BinaryHeap` with reversed ordering). The scheduler pops the earliest
//! event, advances the clock (virtual or wall), applies the handler for
//! its [`EventKind`], and then runs the state-driven phases (hand-off
//! admission, preemption, prefill dispatch, decode launch) that may
//! schedule further events. Ties on the timestamp pop in FIFO push order,
//! which keeps runs bit-for-bit deterministic for a given trace.
//!
//! Scheduled events can be **cancelled**: [`EventQueue::push`] returns an
//! [`EventId`], and [`EventQueue::cancel`] tombstones the entry so
//! `pop`/`pop_due` skip it lazily. The preemption subsystem relies on this
//! to retract the `PrefillDone` completion of a batch it aborts mid-flight.
//!
//! Since the parallel-executor refactor the queue is **partitioned by
//! owner shard**: [`EventQueue::with_partitions`] builds one min-heap per
//! scheduler shard and [`EventQueue::push_owned`] tags each event with the
//! shard whose state its handler touches. Sequence numbers stay *global*
//! (one counter across every partition), and `pop`/`pop_due` always
//! return the minimum over all partition heads under the same
//! `(timestamp, push order)` key a single heap would use — so
//! partitioning is observably pop-order-neutral, which is what lets the
//! executor fan a partition's due events out to its worker thread without
//! perturbing the sequential schedule (pinned by
//! `partitioning_never_changes_pop_order` below).
//!
//! Cost trade-off, stated plainly: the merge loop still pops globally,
//! so each pop scans the `n_shards` partition heads — O(shards) instead
//! of a single heap's O(1) peek (shards are bounded by the decode fleet,
//! single digits in every configuration we run). The partitions are the
//! structure the executor's next phase needs — per-shard draining once
//! planners move onto their worker threads — and today they buy the
//! per-shard ownership invariant the fan-out routes by; a single heap
//! with owner tags would serve the current merge loop identically.

use crate::Micros;
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::collections::HashSet;

/// What happens when an event fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// The trace's next request reaches the gateway.
    Arrival,
    /// Prefill instance `instance` finishes its in-flight batch.
    PrefillDone { instance: usize },
    /// Chunked prefill: instance `instance` finishes one *slice* of its
    /// in-flight sliced batch (the final slice emits [`PrefillDone`]
    /// instead). The handler charges the slice's work, then either
    /// launches the next slice or yields the slot to urgent online work
    /// (parking the batch on its owning shard). Only scheduled when
    /// `chunk.enabled`.
    ///
    /// [`PrefillDone`]: EventKind::PrefillDone
    PrefillSliceEnd { instance: usize },
    /// A KV hand-off becomes consumable on decode instance `decode`
    /// (wake-up for an idle instance; admission itself is state-driven).
    HandoffReady { decode: usize },
    /// Decode instance `decode` reaches its iteration boundary.
    DecodeIterEnd { decode: usize },
    /// Preemption: abort the prefill batch in flight on `instance`,
    /// tombstone its completion, and requeue its requests.
    PreemptPrefill { instance: usize },
    /// Preemption: an evicted decode sequence's checkpoint has landed;
    /// its recompute-from-checkpoint work re-enters the owning shard's
    /// queue (the payload waits in the scheduler's restore buffer).
    RestoreReady { decode: usize },
    /// Preemption: wake-up at the instant the oldest queued online
    /// request crosses the urgency threshold, so a trigger cannot be
    /// missed in an otherwise event-free window (the check itself is
    /// state-driven and runs after every event).
    PreemptCheck,
}

/// Handle to a scheduled event, used only for cancellation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EventId(u64);

impl EventId {
    /// Placeholder for fixtures that never cancel (tests/benches).
    pub const NONE: EventId = EventId(u64::MAX);
}

/// A scheduled event. `seq` is a push counter used only for deterministic
/// FIFO tie-breaking at equal timestamps; `owner` is the scheduler shard
/// whose state the handler touches (0 for shard-agnostic events), which
/// names the heap partition the event queues in and the worker thread the
/// parallel executor hands it to.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub at: Micros,
    pub kind: EventKind,
    pub owner: usize,
    seq: u64,
}

impl Event {
    /// Global push-order id — the deterministic tie-break at equal
    /// timestamps, and the `event_id` component of the executor's
    /// synchronization-point merge key.
    pub(crate) fn seq_id(&self) -> u64 {
        self.seq
    }
}

impl PartialEq for Event {
    fn eq(&self, other: &Event) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Event) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    // Reversed so that BinaryHeap (a max-heap) pops the earliest
    // timestamp, FIFO among equals.
    fn cmp(&self, other: &Event) -> Ordering {
        other.at.cmp(&self.at).then(other.seq.cmp(&self.seq))
    }
}

/// Min-ordered event queue with lazy cancellation, partitioned into one
/// heap per owner shard. Pop order is the global `(at, push order)`
/// minimum across partitions — identical to a single heap, whatever the
/// partition count.
#[derive(Debug)]
pub struct EventQueue {
    parts: Vec<BinaryHeap<Event>>,
    /// Global push counter shared by every partition: the FIFO tie-break
    /// (and the executor's `event_id`) is a property of the whole queue,
    /// not of any one shard's slice of it.
    seq: u64,
    /// Cancelled-but-not-yet-popped sequence numbers. Never iterated, so
    /// the hash order cannot leak into scheduling decisions.
    tombstones: HashSet<u64>,
}

impl Default for EventQueue {
    fn default() -> EventQueue {
        EventQueue::with_partitions(1)
    }
}

impl EventQueue {
    /// Single-partition queue (fixtures/tests; the serving loop uses one
    /// partition per scheduler shard).
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    /// A queue with `n` owner-shard partitions (clamped to at least 1).
    pub fn with_partitions(n: usize) -> EventQueue {
        EventQueue {
            parts: (0..n.max(1)).map(|_| BinaryHeap::new()).collect(),
            seq: 0,
            tombstones: HashSet::new(),
        }
    }

    pub fn n_partitions(&self) -> usize {
        self.parts.len()
    }

    /// Schedule `kind` to fire at `at` in the shard-agnostic partition;
    /// the returned id can cancel it.
    pub fn push(&mut self, at: Micros, kind: EventKind) -> EventId {
        self.push_owned(at, kind, 0)
    }

    /// Schedule `kind` to fire at `at`, tagged with (and queued in the
    /// partition of) `owner` — the scheduler shard whose state the
    /// handler touches.
    pub fn push_owned(
        &mut self,
        at: Micros,
        kind: EventKind,
        owner: usize,
    ) -> EventId {
        let seq = self.seq;
        self.seq += 1;
        let part = owner % self.parts.len();
        self.parts[part].push(Event { at, kind, owner, seq });
        EventId(seq)
    }

    /// Allocate an id from the global push counter *without* scheduling
    /// anything — the executor stamps plan-round [`SyncKey`]s from the
    /// same counter boundary events use, so one total `(at, id)` order
    /// covers both job kinds. The resulting gap in queued events' seq
    /// numbers is harmless: pop order depends only on the *relative*
    /// order of issued ids, never on their density.
    ///
    /// [`SyncKey`]: super::executor::SyncKey
    pub fn stamp(&mut self) -> u64 {
        let seq = self.seq;
        self.seq += 1;
        seq
    }

    /// Tombstone a *pending* event so `pop`/`pop_due` skip it. Returns
    /// true when the id was newly cancelled. Cancelling an event that has
    /// already fired is a caller bug (it would desynchronize `len`);
    /// every live id is handed out by `push` exactly once and consumed by
    /// the pop that fires it.
    pub fn cancel(&mut self, id: EventId) -> bool {
        if id == EventId::NONE {
            return false;
        }
        debug_assert!(id.0 < self.seq, "cancelling an id never issued");
        self.tombstones.insert(id.0)
    }

    /// Drop cancelled entries sitting at the top of every partition heap,
    /// then return the partition holding the globally earliest live event
    /// under the `(at, seq)` key.
    fn earliest_part(&mut self) -> Option<usize> {
        let mut best: Option<(Micros, u64, usize)> = None;
        for (pi, part) in self.parts.iter_mut().enumerate() {
            while matches!(
                part.peek(),
                Some(ev) if self.tombstones.contains(&ev.seq)
            ) {
                let ev = part.pop().unwrap();
                self.tombstones.remove(&ev.seq);
            }
            if let Some(ev) = part.peek() {
                let key = (ev.at, ev.seq, pi);
                match best {
                    Some(b) if b <= key => {}
                    _ => best = Some(key),
                }
            }
        }
        best.map(|(_, _, pi)| pi)
    }

    /// Pop the earliest live event.
    pub fn pop(&mut self) -> Option<Event> {
        let pi = self.earliest_part()?;
        self.parts[pi].pop()
    }

    /// Pop the earliest live event only if it is due at or before `now`.
    pub fn pop_due(&mut self, now: Micros) -> Option<Event> {
        self.pop_due_if(now, |_| true)
    }

    /// Pop the earliest live event only if it is due at or before `now`
    /// *and* satisfies `pred` — how the parallel executor collects a
    /// maximal consecutive run of same-kind events (a synchronization
    /// point) without ever reordering across an interleaved event of
    /// another kind.
    pub fn pop_due_if(
        &mut self,
        now: Micros,
        pred: impl Fn(&Event) -> bool,
    ) -> Option<Event> {
        let pi = self.earliest_part()?;
        match self.parts[pi].peek() {
            Some(ev) if ev.at <= now && pred(ev) => self.parts[pi].pop(),
            _ => None,
        }
    }

    /// Timestamp of the earliest live scheduled event.
    pub fn peek_at(&mut self) -> Option<Micros> {
        let pi = self.earliest_part()?;
        self.parts[pi].peek().map(|e| e.at)
    }

    /// Live (non-cancelled) scheduled events.
    pub fn len(&self) -> usize {
        self.parts.iter().map(BinaryHeap::len).sum::<usize>()
            - self.tombstones.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30, EventKind::Arrival);
        q.push(10, EventKind::DecodeIterEnd { decode: 0 });
        q.push(20, EventKind::PrefillDone { instance: 1 });
        let order: Vec<Micros> = std::iter::from_fn(|| q.pop().map(|e| e.at)).collect();
        assert_eq!(order, vec![10, 20, 30]);
        assert!(q.is_empty());
    }

    #[test]
    fn equal_timestamps_pop_fifo() {
        let mut q = EventQueue::new();
        q.push(5, EventKind::PrefillDone { instance: 0 });
        q.push(5, EventKind::PrefillDone { instance: 1 });
        q.push(5, EventKind::PrefillDone { instance: 2 });
        let kinds: Vec<EventKind> =
            std::iter::from_fn(|| q.pop().map(|e| e.kind)).collect();
        assert_eq!(
            kinds,
            vec![
                EventKind::PrefillDone { instance: 0 },
                EventKind::PrefillDone { instance: 1 },
                EventKind::PrefillDone { instance: 2 },
            ]
        );
    }

    #[test]
    fn stamp_allocates_ids_without_perturbing_pop_order() {
        let mut q = EventQueue::new();
        let a = q.push(5, EventKind::PrefillDone { instance: 0 });
        let s1 = q.stamp(); // plan-round id between two pushes
        let b = q.push(5, EventKind::PrefillDone { instance: 1 });
        let s2 = q.stamp();
        let c = q.push(5, EventKind::PrefillDone { instance: 2 });
        // Stamped ids interleave the push ids in one total order...
        assert!(a.0 < s1 && s1 < b.0 && b.0 < s2 && s2 < c.0);
        // ...and the seq-number gaps they leave never change FIFO pops.
        let kinds: Vec<EventKind> =
            std::iter::from_fn(|| q.pop().map(|e| e.kind)).collect();
        assert_eq!(
            kinds,
            vec![
                EventKind::PrefillDone { instance: 0 },
                EventKind::PrefillDone { instance: 1 },
                EventKind::PrefillDone { instance: 2 },
            ]
        );
    }

    #[test]
    fn pop_due_respects_now() {
        let mut q = EventQueue::new();
        q.push(100, EventKind::Arrival);
        q.push(200, EventKind::Arrival);
        assert!(q.pop_due(50).is_none());
        assert_eq!(q.pop_due(150).unwrap().at, 100);
        assert!(q.pop_due(150).is_none());
        assert_eq!(q.peek_at(), Some(200));
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn cancelled_events_are_skipped_by_pop() {
        let mut q = EventQueue::new();
        q.push(10, EventKind::Arrival);
        let mid = q.push(20, EventKind::PrefillDone { instance: 0 });
        q.push(30, EventKind::DecodeIterEnd { decode: 0 });
        assert!(q.cancel(mid));
        let order: Vec<Micros> = std::iter::from_fn(|| q.pop().map(|e| e.at)).collect();
        assert_eq!(order, vec![10, 30], "tombstoned event must not fire");
        assert!(q.is_empty());
    }

    #[test]
    fn cancelled_events_are_skipped_by_pop_due() {
        let mut q = EventQueue::new();
        let first = q.push(100, EventKind::PreemptPrefill { instance: 0 });
        q.push(100, EventKind::RestoreReady { decode: 1 });
        q.push(300, EventKind::Arrival);
        q.cancel(first);
        // The due pop must see straight through the cancelled head.
        let ev = q.pop_due(150).unwrap();
        assert_eq!(ev.kind, EventKind::RestoreReady { decode: 1 });
        assert!(q.pop_due(150).is_none());
        assert_eq!(q.peek_at(), Some(300));
    }

    #[test]
    fn cancellation_preserves_fifo_ties() {
        let mut q = EventQueue::new();
        q.push(5, EventKind::PrefillDone { instance: 0 });
        let second = q.push(5, EventKind::PrefillDone { instance: 1 });
        q.push(5, EventKind::PrefillDone { instance: 2 });
        q.cancel(second);
        let kinds: Vec<EventKind> =
            std::iter::from_fn(|| q.pop().map(|e| e.kind)).collect();
        assert_eq!(
            kinds,
            vec![
                EventKind::PrefillDone { instance: 0 },
                EventKind::PrefillDone { instance: 2 },
            ],
            "survivors keep push order at equal timestamps"
        );
    }

    #[test]
    fn len_stays_consistent_under_cancellation() {
        let mut q = EventQueue::new();
        let a = q.push(1, EventKind::Arrival);
        let b = q.push(2, EventKind::Arrival);
        assert_eq!(q.len(), 2);
        assert!(q.cancel(a));
        assert_eq!(q.len(), 1);
        assert!(!q.is_empty());
        // Double-cancel is a no-op, not a double decrement.
        assert!(!q.cancel(a));
        assert_eq!(q.len(), 1);
        assert!(q.cancel(b));
        assert_eq!(q.len(), 0);
        assert!(q.is_empty());
        assert!(q.pop().is_none());
        // NONE is inert.
        assert!(!q.cancel(EventId::NONE));
        // The queue keeps working after a full drain of tombstones.
        q.push(7, EventKind::Arrival);
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop().unwrap().at, 7);
    }

    #[test]
    fn partitioning_never_changes_pop_order() {
        // The executor's load-bearing invariant: however the queue is
        // partitioned, pops come out in the exact global (at, push-order)
        // sequence a single heap would produce — including FIFO ties
        // across partitions and cancellations.
        let pushes: [(Micros, usize); 10] = [
            (50, 2), (10, 0), (50, 1), (10, 3), (30, 2),
            (10, 1), (30, 0), (70, 3), (10, 2), (30, 1),
        ];
        let run = |n_parts: usize| {
            let mut q = EventQueue::with_partitions(n_parts);
            let mut cancel_me = Vec::new();
            for (i, &(at, owner)) in pushes.iter().enumerate() {
                let id = q.push_owned(at, EventKind::Arrival, owner);
                if i % 4 == 3 {
                    cancel_me.push(id);
                }
            }
            for id in cancel_me {
                assert!(q.cancel(id));
            }
            std::iter::from_fn(|| q.pop().map(|e| (e.at, e.owner, e.seq)))
                .collect::<Vec<_>>()
        };
        let single = run(1);
        for n in [2, 4, 7] {
            assert_eq!(run(n), single, "{n} partitions reordered pops");
        }
        // Sanity on the reference stream itself: non-decreasing at, and
        // FIFO (ascending seq) within equal timestamps.
        for w in single.windows(2) {
            assert!(w[0].0 < w[1].0 || (w[0].0 == w[1].0 && w[0].2 < w[1].2));
        }
    }

    #[test]
    fn pop_due_if_stops_at_first_non_matching_event() {
        // The sync-point collector pops a maximal *consecutive* run: it
        // must stop at an interleaved event of another kind even when
        // matching events are due behind it, so the executor can never
        // reorder across it.
        let mut q = EventQueue::with_partitions(2);
        q.push_owned(5, EventKind::DecodeIterEnd { decode: 0 }, 0);
        q.push_owned(5, EventKind::HandoffReady { decode: 1 }, 1);
        q.push_owned(5, EventKind::DecodeIterEnd { decode: 1 }, 1);
        let is_boundary =
            |e: &Event| matches!(e.kind, EventKind::DecodeIterEnd { .. });
        let first = q.pop_due_if(5, is_boundary).unwrap();
        assert_eq!(first.kind, EventKind::DecodeIterEnd { decode: 0 });
        assert!(
            q.pop_due_if(5, is_boundary).is_none(),
            "a due non-matching head must block the run"
        );
        // Not due yet blocks too.
        assert!(q.pop_due_if(4, |_| true).is_none());
        let head = q.pop_due(5).unwrap();
        assert_eq!(head.kind, EventKind::HandoffReady { decode: 1 });
        let tail = q.pop_due_if(5, is_boundary).unwrap();
        assert_eq!(tail.kind, EventKind::DecodeIterEnd { decode: 1 });
        assert!(q.is_empty());
    }

    #[test]
    fn owner_tags_ride_along_and_default_to_zero() {
        let mut q = EventQueue::with_partitions(3);
        q.push(10, EventKind::Arrival);
        q.push_owned(20, EventKind::DecodeIterEnd { decode: 5 }, 2);
        let a = q.pop().unwrap();
        assert_eq!((a.owner, a.at), (0, 10));
        let b = q.pop().unwrap();
        assert_eq!((b.owner, b.at), (2, 20));
        // Owners beyond the partition count wrap instead of panicking
        // (partition index is a routing detail, the tag is preserved).
        let mut q = EventQueue::with_partitions(2);
        q.push_owned(1, EventKind::Arrival, 7);
        assert_eq!(q.pop().unwrap().owner, 7);
    }
}
