//! Typed event queue: the heartbeat of the event-driven P/D scheduler.
//!
//! The serving loop is a discrete-event simulation: every future state
//! change is an [`Event`] in a min-ordered [`EventQueue`] (a
//! `BinaryHeap` with reversed ordering). The scheduler pops the earliest
//! event, advances the clock (virtual or wall), applies the handler for
//! its [`EventKind`], and then runs the state-driven phases (hand-off
//! admission, prefill dispatch, decode launch) that may schedule further
//! events. Ties on the timestamp pop in FIFO push order, which keeps runs
//! bit-for-bit deterministic for a given trace.

use crate::Micros;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// What happens when an event fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// The trace's next request reaches the gateway.
    Arrival,
    /// Prefill instance `instance` finishes its in-flight batch.
    PrefillDone { instance: usize },
    /// A KV hand-off becomes consumable on decode instance `decode`
    /// (wake-up for an idle instance; admission itself is state-driven).
    HandoffReady { decode: usize },
    /// Decode instance `decode` reaches its iteration boundary.
    DecodeIterEnd { decode: usize },
}

/// A scheduled event. `seq` is a push counter used only for deterministic
/// FIFO tie-breaking at equal timestamps.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub at: Micros,
    pub kind: EventKind,
    seq: u64,
}

impl PartialEq for Event {
    fn eq(&self, other: &Event) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Event) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    // Reversed so that BinaryHeap (a max-heap) pops the earliest
    // timestamp, FIFO among equals.
    fn cmp(&self, other: &Event) -> Ordering {
        other.at.cmp(&self.at).then(other.seq.cmp(&self.seq))
    }
}

/// Min-ordered event queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    /// Schedule `kind` to fire at `at`.
    pub fn push(&mut self, at: Micros, kind: EventKind) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Event { at, kind, seq });
    }

    /// Pop the earliest event.
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }

    /// Pop the earliest event only if it is due at or before `now`.
    pub fn pop_due(&mut self, now: Micros) -> Option<Event> {
        match self.heap.peek() {
            Some(ev) if ev.at <= now => self.heap.pop(),
            _ => None,
        }
    }

    /// Timestamp of the earliest scheduled event.
    pub fn peek_at(&self) -> Option<Micros> {
        self.heap.peek().map(|e| e.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30, EventKind::Arrival);
        q.push(10, EventKind::DecodeIterEnd { decode: 0 });
        q.push(20, EventKind::PrefillDone { instance: 1 });
        let order: Vec<Micros> = std::iter::from_fn(|| q.pop().map(|e| e.at)).collect();
        assert_eq!(order, vec![10, 20, 30]);
        assert!(q.is_empty());
    }

    #[test]
    fn equal_timestamps_pop_fifo() {
        let mut q = EventQueue::new();
        q.push(5, EventKind::PrefillDone { instance: 0 });
        q.push(5, EventKind::PrefillDone { instance: 1 });
        q.push(5, EventKind::PrefillDone { instance: 2 });
        let kinds: Vec<EventKind> =
            std::iter::from_fn(|| q.pop().map(|e| e.kind)).collect();
        assert_eq!(
            kinds,
            vec![
                EventKind::PrefillDone { instance: 0 },
                EventKind::PrefillDone { instance: 1 },
                EventKind::PrefillDone { instance: 2 },
            ]
        );
    }

    #[test]
    fn pop_due_respects_now() {
        let mut q = EventQueue::new();
        q.push(100, EventKind::Arrival);
        q.push(200, EventKind::Arrival);
        assert!(q.pop_due(50).is_none());
        assert_eq!(q.pop_due(150).unwrap().at, 100);
        assert!(q.pop_due(150).is_none());
        assert_eq!(q.peek_at(), Some(200));
        assert_eq!(q.len(), 1);
    }
}
