//! Dynamic Batching Controller — Eqs. 1–6 of the paper.
//!
//! Computes memory-safe batch sizes from the KV-cache footprint model and
//! forms prefill batches out of the bucket queues:
//!
//! * Eq. 1  KV bytes = 2·L·H·D·S_max·B·N  → [`KvMemoryModel::kv_bytes`]
//! * Eq. 2  waste ratio                   → [`crate::cluster::PrefillBatch::waste_ratio`]
//! * Eq. 5  M_safe = 0.9·M_remain         → [`KvMemoryModel::safe_memory`]
//! * Eq. 6  N_max = max{N | Σ S_i ≤ M_safe/(2LHDB)} → [`KvMemoryModel::n_max`]
//!
//! Batch formation drains the highest-priority bucket (earliest arrival for
//! online traffic; shortest/longest bucket for offline SJF/LJF) in policy
//! order, admitting requests while the cumulative KV footprint of their
//! *full* context (prompt + expected generation) stays under the safe
//! token budget — that is what "prevents OOM" means here: a batch admitted
//! for prefill can always grow its KV to completion within M_safe.

use super::bucket::{BucketManager, QueuedReq};
use super::priority::PriorityScorer;
use crate::cluster::{PrefillBatch, PrefillItem};
use crate::config::{ModelSpec, Policy, SchedulerSpec};
use crate::Micros;

/// Eq. 1/5/6 calculator.
#[derive(Debug, Clone)]
pub struct KvMemoryModel {
    model: ModelSpec,
    mem_safety: f64,
}

impl KvMemoryModel {
    pub fn new(model: ModelSpec, mem_safety: f64) -> KvMemoryModel {
        assert!((0.0..=1.0).contains(&mem_safety));
        KvMemoryModel { model, mem_safety }
    }

    /// Eq. 1: KV-cache bytes of a batch of `n` sequences padded to `s_max`.
    pub fn kv_bytes(&self, s_max: u32, n: usize) -> u64 {
        self.model.kv_bytes_per_token() * s_max as u64 * n as u64
    }

    /// Eq. 5: safe memory after the reservation.
    pub fn safe_memory(&self, m_remain: u64) -> u64 {
        (m_remain as f64 * self.mem_safety) as u64
    }

    /// Token budget implied by Eq. 6's right-hand side:
    /// M_safe / (2·L·H·D·B) — the maximum Σ S_i the KV cache can hold.
    pub fn token_budget(&self, m_remain: u64) -> u64 {
        self.safe_memory(m_remain) / self.model.kv_bytes_per_token().max(1)
    }

    /// Eq. 6: largest prefix of `lens` whose cumulative length fits the
    /// token budget.
    pub fn n_max(&self, lens: impl Iterator<Item = u32>, budget_tokens: u64) -> usize {
        let mut acc = 0u64;
        let mut n = 0usize;
        for len in lens {
            acc += len as u64;
            if acc > budget_tokens {
                break;
            }
            n += 1;
        }
        n
    }

    /// Eq. 6 estimate used by Algorithm 1's merge/split threshold when no
    /// concrete batch is being formed: budget / mean sequence length.
    pub fn n_max_estimate(&self, mean_len: f64, m_remain: u64) -> usize {
        if mean_len <= 0.0 {
            return usize::MAX / 2;
        }
        (self.token_budget(m_remain) as f64 / mean_len).floor() as usize
    }
}

/// A formed batch: the engine-facing [`PrefillBatch`] plus the drained
/// queue entries (the scheduler keeps them for completion bookkeeping).
#[derive(Debug, Clone)]
pub struct FormedBatch {
    pub batch: PrefillBatch,
    pub reqs: Vec<QueuedReq>,
    /// Upper bound of the bucket the batch was drawn from.
    pub bucket_up: u32,
}

impl FormedBatch {
    /// Scheduling-relevant identity of the batch — member ids in drain
    /// order, the padded slot length, and the source bucket. Two batches
    /// with equal signatures dispatch identically; the plan/commit
    /// property tests compare speculated and inline plans through this.
    pub fn signature(&self) -> (Vec<u64>, u32, u32) {
        (
            self.reqs.iter().map(|r| r.id).collect(),
            self.batch.padded_len,
            self.bucket_up,
        )
    }
}

/// The Dynamic Batching Controller.
#[derive(Debug, Clone)]
pub struct DynamicBatcher {
    mem: KvMemoryModel,
    policy: Policy,
    max_batch: usize,
    priority: Option<PriorityScorer>,
}

impl DynamicBatcher {
    pub fn new(model: ModelSpec, sched: &SchedulerSpec) -> DynamicBatcher {
        DynamicBatcher {
            mem: KvMemoryModel::new(model, sched.mem_safety),
            policy: sched.policy,
            max_batch: if sched.max_batch == 0 {
                usize::MAX
            } else {
                sched.max_batch as usize
            },
            priority: None,
        }
    }

    /// Attach the SLO-urgency scorer: bucket selection and intra-bucket
    /// drain then follow priority scores instead of pure earliest arrival.
    /// Applies to the FCFS policy only — the SJF/LJF offline orientations
    /// keep their length ordering.
    pub fn with_priority(mut self, scorer: PriorityScorer) -> DynamicBatcher {
        self.priority = Some(scorer);
        self
    }

    pub fn memory_model(&self) -> &KvMemoryModel {
        &self.mem
    }

    /// The scorer, when it governs drain order under the current policy.
    /// `pub(crate)` so the planner's force-pop shares this exact gate
    /// instead of duplicating it.
    pub(crate) fn scorer(&self) -> Option<&PriorityScorer> {
        match (&self.priority, self.policy) {
            (Some(s), Policy::Fcfs) => Some(s),
            _ => None,
        }
    }

    /// Pick the next bucket to serve. Priority mode picks the bucket
    /// holding the highest-ranked request under
    /// [`PriorityScorer::compare`] (first bucket wins ties); for a
    /// single-class queue that degenerates to the legacy earliest-arrival
    /// choice. Otherwise: earliest arrival for FCFS (SLO protection),
    /// shortest/longest bucket for offline SJF/LJF. `pub(crate)` so the
    /// work-stealing donor path targets the same bucket the next drain
    /// would.
    pub(crate) fn pick_bucket(&self, mgr: &BucketManager, now: Micros) -> Option<usize> {
        if let Some(sc) = self.scorer() {
            return sc.best_position(mgr.buckets(), now).map(|(bi, _)| bi);
        }
        let non_empty = mgr
            .buckets()
            .iter()
            .enumerate()
            .filter(|(_, b)| !b.is_empty());
        match self.policy {
            Policy::Fcfs => non_empty
                .min_by_key(|(_, b)| b.earliest_arrival().unwrap_or(Micros::MAX))
                .map(|(i, _)| i),
            Policy::Sjf => non_empty.min_by_key(|(i, _)| *i).map(|(i, _)| i),
            Policy::Ljf => non_empty.max_by_key(|(i, _)| *i).map(|(i, _)| i),
        }
    }

    /// Put a bucket's queue into drain order: the scorer's canonical
    /// priority order when it governs (on a precomputed
    /// [`super::priority::DrainKey`] per request — `sort_by_cached_key`
    /// pays the float score once per element instead of once per
    /// comparison), else the policy's intra-bucket ordering (paper §IV):
    /// SJF / LJF for offline, longest-waiting (earliest arrival) first
    /// for online. Shared by batch formation and the work-stealing donor
    /// so the stolen tail is always the *least*-urgent end.
    pub(crate) fn sort_for_drain(&self, b: &mut super::bucket::Bucket, now: Micros) {
        if let Some(sc) = self.scorer() {
            b.requests.sort_by_cached_key(|r| sc.drain_key(r, now));
        } else {
            match self.policy {
                Policy::Fcfs => b.requests.sort_by_key(|r| r.arrival),
                Policy::Sjf => b.requests.sort_by_key(|r| (r.len, r.arrival)),
                Policy::Ljf => {
                    b.requests.sort_by_key(|r| (u32::MAX - r.len, r.arrival))
                }
            }
        }
    }

    /// Form the next prefill batch, draining its requests from `mgr`.
    ///
    /// `now` drives priority scoring; `budget_tokens` is the decode-side
    /// KV headroom in tokens (Eq. 6's right-hand side minus tokens already
    /// held by running sequences). Returns None when every bucket is empty
    /// or the budget admits nothing (the caller retries after decode frees
    /// memory).
    pub fn form_batch(
        &self,
        mgr: &mut BucketManager,
        now: Micros,
        budget_tokens: u64,
    ) -> Option<FormedBatch> {
        let idx = self.pick_bucket(mgr, now)?;
        let bucket_up = {
            let b = &mut mgr.buckets_mut()[idx];
            self.sort_for_drain(b, now);
            b.up
        };

        // Eq. 6 admission over full-context KV footprints.
        let b = &mut mgr.buckets_mut()[idx];
        let mut take = 0usize;
        let mut acc = 0u64;
        for r in b.requests.iter() {
            if take >= self.max_batch {
                break;
            }
            let footprint = r.footprint();
            if acc + footprint > budget_tokens {
                break;
            }
            acc += footprint;
            take += 1;
        }
        // Head-of-line request alone exceeds the whole budget: admit it
        // solo only when the budget equals the full (idle) capacity —
        // otherwise wait for decode to free memory.
        if take == 0 {
            return None;
        }

        let reqs: Vec<QueuedReq> = b.requests.drain(..take).collect();
        // Pad to the batch max. Bucketing's whole effect is that batch
        // members share a bucket, so this max is close to every member's
        // length (bounded by the bucket's upper bound); without bucketing
        // (the DistServe baseline) the same rule pads short requests up to
        // whatever long request shares the batch. On the real engine the
        // runtime rounds this up to the nearest compiled artifact shape.
        let padded_len = reqs.iter().map(|r| r.len).max().unwrap_or(1).max(1);

        let items = reqs
            .iter()
            .map(|r| PrefillItem { id: r.id, len: r.len.min(padded_len), tokens: vec![] })
            .collect();
        Some(FormedBatch {
            batch: PrefillBatch { items, padded_len },
            reqs,
            bucket_up,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::workload::RequestClass;

    fn mgr(l_max: u32) -> BucketManager {
        BucketManager::new(l_max, 0.5, 16)
    }

    fn req(id: u64, len: u32, out: u32, arrival: Micros) -> QueuedReq {
        QueuedReq {
            id,
            len,
            output_len: out,
            arrival,
            class: RequestClass::Online,
            tbt_us: 0,
            prefix: crate::coordinator::prefix::PrefixStamp::default(),
        }
    }

    fn batcher(policy: Policy, max_batch: u32) -> DynamicBatcher {
        let cfg = SystemConfig::default();
        let mut sched = cfg.scheduler.clone();
        sched.policy = policy;
        sched.max_batch = max_batch;
        DynamicBatcher::new(cfg.model.clone(), &sched)
    }

    #[test]
    fn eq1_kv_bytes() {
        let m = KvMemoryModel::new(ModelSpec::llama2_13b(), 0.9);
        // 2·40·40·128·2 bytes/token · 512 tokens · 4 seqs
        assert_eq!(m.kv_bytes(512, 4), 819_200 * 512 * 4);
    }

    #[test]
    fn eq5_safety_reserves_ten_percent() {
        let m = KvMemoryModel::new(ModelSpec::llama2_13b(), 0.9);
        assert_eq!(m.safe_memory(10_000_000_000), 9_000_000_000);
    }

    #[test]
    fn eq6_prefix_rule() {
        let m = KvMemoryModel::new(ModelSpec::llama2_13b(), 1.0);
        // budget 100 tokens, lens 40+30+20 fits (90), +20 would be 110.
        let n = m.n_max([40u32, 30, 20, 20].into_iter(), 100);
        assert_eq!(n, 3);
        assert_eq!(m.n_max([200u32].into_iter(), 100), 0);
        assert_eq!(m.n_max(std::iter::empty(), 100), 0);
    }

    #[test]
    fn token_budget_is_safe_memory_over_per_token_bytes() {
        let m = KvMemoryModel::new(ModelSpec::llama2_13b(), 0.9);
        let remain = 12 * (1u64 << 30);
        let expect = (remain as f64 * 0.9) as u64 / 819_200;
        assert_eq!(m.token_budget(remain), expect);
    }

    #[test]
    fn form_batch_respects_budget() {
        let mut m = mgr(1024);
        for i in 0..10 {
            m.assign(req(i, 100, 50, i));
        }
        let b = batcher(Policy::Fcfs, 0);
        // Each request's footprint is 150 tokens; budget 400 admits 2.
        let fb = b.form_batch(&mut m, 0, 400).unwrap();
        assert_eq!(fb.batch.n(), 2);
        assert_eq!(m.total(), 8);
        // Admitted in arrival order.
        assert_eq!(fb.reqs[0].id, 0);
        assert_eq!(fb.reqs[1].id, 1);
    }

    #[test]
    fn form_batch_respects_max_batch() {
        let mut m = mgr(1024);
        for i in 0..10 {
            m.assign(req(i, 10, 10, i));
        }
        let b = batcher(Policy::Fcfs, 3);
        let fb = b.form_batch(&mut m, 0, u64::MAX / 4).unwrap();
        assert_eq!(fb.batch.n(), 3);
    }

    #[test]
    fn zero_budget_returns_none() {
        let mut m = mgr(1024);
        m.assign(req(0, 100, 50, 0));
        let b = batcher(Policy::Fcfs, 0);
        assert!(b.form_batch(&mut m, 0, 10).is_none());
        assert_eq!(m.total(), 1, "request must not be lost");
    }

    #[test]
    fn empty_manager_returns_none() {
        let mut m = mgr(1024);
        let b = batcher(Policy::Fcfs, 0);
        assert!(b.form_batch(&mut m, 0, 1000).is_none());
    }

    #[test]
    fn sjf_orders_short_first() {
        let mut m = mgr(1024);
        m.assign(req(0, 500, 10, 0));
        m.assign(req(1, 50, 10, 1));
        m.assign(req(2, 200, 10, 2));
        let b = batcher(Policy::Sjf, 0);
        let fb = b.form_batch(&mut m, 0, u64::MAX / 4).unwrap();
        let lens: Vec<u32> = fb.reqs.iter().map(|r| r.len).collect();
        assert_eq!(lens, vec![50, 200, 500]);
    }

    #[test]
    fn ljf_orders_long_first() {
        let mut m = mgr(1024);
        m.assign(req(0, 50, 10, 0));
        m.assign(req(1, 500, 10, 1));
        m.assign(req(2, 200, 10, 2));
        let b = batcher(Policy::Ljf, 0);
        let fb = b.form_batch(&mut m, 0, u64::MAX / 4).unwrap();
        let lens: Vec<u32> = fb.reqs.iter().map(|r| r.len).collect();
        assert_eq!(lens, vec![500, 200, 50]);
    }

    #[test]
    fn fcfs_picks_bucket_with_earliest_arrival() {
        let mut m = mgr(1024);
        // Build two buckets via a skewed split.
        for i in 0..8 {
            m.assign(req(i, 100, 10, 100 + i));
        }
        for i in 8..10 {
            m.assign(req(i, 900, 10, i - 8)); // earlier arrivals, long bucket
        }
        m.adjust(4);
        assert!(m.n_buckets() >= 2);
        let b = batcher(Policy::Fcfs, 0);
        let fb = b.form_batch(&mut m, 0, u64::MAX / 4).unwrap();
        // The long bucket holds the earliest arrivals (0 and 1).
        assert!(fb.reqs.iter().all(|r| r.len == 900));
    }

    #[test]
    fn padded_len_is_batch_max_in_single_bucket() {
        let mut m = mgr(4096);
        m.assign(req(0, 120, 10, 0));
        m.assign(req(1, 80, 10, 1));
        let b = batcher(Policy::Fcfs, 0);
        let fb = b.form_batch(&mut m, 0, u64::MAX / 4).unwrap();
        // Merged single bucket: pad to the longest member, not L_max.
        assert_eq!(fb.batch.padded_len, 120);
    }

    #[test]
    fn padded_len_capped_by_bucket_bound_when_split() {
        let mut m = mgr(1024);
        for i in 0..8 {
            m.assign(req(i, 100 + i as u32, 10, i));
        }
        for i in 8..10 {
            m.assign(req(i, 800, 10, i));
        }
        m.adjust(4);
        assert!(m.n_buckets() >= 2);
        let b = batcher(Policy::Fcfs, 0);
        let fb = b.form_batch(&mut m, 0, u64::MAX / 4).unwrap();
        // FCFS picks the short bucket (earliest arrivals); padded to its
        // batch max (107), well under the bucket bound 512.
        assert_eq!(fb.batch.padded_len, 107);
        assert!(fb.bucket_up <= 512);
    }

    #[test]
    fn priority_drain_jumps_online_ahead_of_offline() {
        use crate::config::PrioritySpec;
        use crate::config::SloSpec;
        use crate::coordinator::priority::PriorityScorer;
        let mut m = mgr(1024);
        // Offline backlog arrived first…
        for i in 0..4 {
            m.assign(QueuedReq {
                id: i,
                len: 200,
                output_len: 50,
                arrival: 0,
                class: RequestClass::Offline,
                tbt_us: 0,
                prefix: crate::coordinator::prefix::PrefixStamp::default(),
            });
        }
        // …then an online request lands later.
        m.assign(QueuedReq {
            id: 9,
            len: 100,
            output_len: 20,
            arrival: 50_000,
            class: RequestClass::Online,
            tbt_us: 0,
            prefix: crate::coordinator::prefix::PrefixStamp::default(),
        });
        let b = batcher(Policy::Fcfs, 1).with_priority(PriorityScorer::new(
            PrioritySpec::default(),
            SloSpec::default(),
        ));
        let fb = b.form_batch(&mut m, 100_000, u64::MAX / 4).unwrap();
        assert_eq!(fb.reqs[0].id, 9, "online request must drain first");
    }

    #[test]
    fn priority_matches_fcfs_on_single_class_queue() {
        use crate::config::PrioritySpec;
        use crate::config::SloSpec;
        use crate::coordinator::priority::PriorityScorer;
        let mut fcfs_mgr = mgr(1024);
        let mut prio_mgr = mgr(1024);
        for i in 0..8 {
            let r = req(i, 100 + i as u32 * 30, 20, 1000 * (8 - i));
            fcfs_mgr.assign(r);
            prio_mgr.assign(r);
        }
        let fcfs = batcher(Policy::Fcfs, 0);
        let prio = batcher(Policy::Fcfs, 0).with_priority(PriorityScorer::new(
            PrioritySpec::default(),
            SloSpec::default(),
        ));
        let now = 20_000;
        let fa = fcfs.form_batch(&mut fcfs_mgr, now, u64::MAX / 4).unwrap();
        let fp = prio.form_batch(&mut prio_mgr, now, u64::MAX / 4).unwrap();
        let ids = |f: &FormedBatch| f.reqs.iter().map(|r| r.id).collect::<Vec<_>>();
        assert_eq!(ids(&fa), ids(&fp), "single-class order must be identical");
    }

    #[test]
    fn sjf_policy_ignores_priority_scorer() {
        use crate::config::PrioritySpec;
        use crate::config::SloSpec;
        use crate::coordinator::priority::PriorityScorer;
        let mut m = mgr(1024);
        m.assign(req(0, 500, 10, 0));
        m.assign(req(1, 50, 10, 1));
        let b = batcher(Policy::Sjf, 0).with_priority(PriorityScorer::new(
            PrioritySpec::default(),
            SloSpec::default(),
        ));
        let fb = b.form_batch(&mut m, 10_000, u64::MAX / 4).unwrap();
        assert_eq!(fb.reqs[0].len, 50, "SJF keeps shortest-first");
    }

    #[test]
    fn batch_kv_fits_safe_memory_invariant() {
        use crate::util::prop;
        prop::check("admitted batches fit Eq.6", 100, |g| {
            let cfg = SystemConfig::default();
            let mm = KvMemoryModel::new(cfg.model.clone(), 0.9);
            let mut m = mgr(4096);
            let n = g.usize(1, 60);
            for i in 0..n {
                m.assign(req(
                    i as u64,
                    g.u64(1, 4000) as u32,
                    g.u64(1, 500) as u32,
                    i as u64,
                ));
            }
            let remain = g.u64(1 << 28, 12 * (1u64 << 30));
            let budget = mm.token_budget(remain);
            let b = batcher(Policy::Fcfs, 0);
            if let Some(fb) = b.form_batch(&mut m, 0,budget) {
                let footprint: u64 = fb
                    .reqs
                    .iter()
                    .map(QueuedReq::footprint)
                    .sum();
                // Eq. 6: Σ S_i ≤ M_safe / (2LHDB).
                assert!(footprint <= budget);
                // Eq. 1 equivalent in bytes.
                assert!(
                    footprint * mm.kv_bytes(1, 1) <= mm.safe_memory(remain)
                );
            }
        });
    }
}
