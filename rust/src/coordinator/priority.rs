//! Priority-aware scheduling (paper §III): SLO-deadline urgency scoring.
//!
//! The seed drained buckets in pure earliest-arrival order, which lets a
//! backlog of offline throughput work head-of-line-block latency-bound
//! online requests. This module scores every queued request so the
//! [`DynamicBatcher`](super::batcher::DynamicBatcher) can drain by SLO
//! urgency instead:
//!
//! * **Online** — urgency is the fraction of the TTFT budget already
//!   consumed (`(now − arrival) / slo.ttft_us`, i.e. 1 − slack/budget).
//!   Score = `online_weight · (1 + urgency)`: a fresh online request
//!   already outranks the offline base weight, and the rank keeps rising
//!   toward (and past) the deadline.
//! * **Offline** — a throughput class with starvation aging: score =
//!   `offline_weight + aging_rate · waited_seconds`, so offline work
//!   eventually overtakes *non-urgent* online work instead of starving.
//! * **Urgency override** — once an online request consumes more than
//!   `urgency_threshold` of its TTFT budget it is *urgent* and ranks ahead
//!   of any non-urgent request regardless of aging.
//!
//! For a single-class queue the score order degenerates to exact
//! earliest-arrival order, so enabling priority changes nothing on the
//! seed's single-class workloads — the wins (and the ablation bench) are
//! on mixed online/offline traffic.

use super::bucket::{Bucket, QueuedReq};
use crate::config::{PrioritySpec, SloSpec};
use crate::workload::RequestClass;
use crate::Micros;
use std::cmp::Ordering;

/// Scores queued requests by SLO urgency; cheap enough to call per
/// comparison in the drain sort.
#[derive(Debug, Clone)]
pub struct PriorityScorer {
    spec: PrioritySpec,
    slo: SloSpec,
}

impl PriorityScorer {
    pub fn new(spec: PrioritySpec, slo: SloSpec) -> PriorityScorer {
        PriorityScorer { spec, slo }
    }

    /// Fraction of the TTFT budget an online request has consumed at
    /// `now` (0 at arrival, 1 at the deadline, > 1 overdue): the
    /// scorer-side view of [`crate::workload::Request::ttft_slack`],
    /// `1 − slack/budget` (a unit test pins the two to agree).
    pub fn urgency(&self, r: &QueuedReq, now: Micros) -> f64 {
        let waited = now.saturating_sub(r.arrival) as f64;
        waited / self.slo.ttft_us.max(1) as f64
    }

    /// Drain score — higher serves first.
    pub fn score(&self, r: &QueuedReq, now: Micros) -> f64 {
        match r.class {
            RequestClass::Online => {
                self.spec.online_weight * (1.0 + self.urgency(r, now))
            }
            RequestClass::Offline => {
                let waited_s = now.saturating_sub(r.arrival) as f64 / 1e6;
                self.spec.offline_weight + self.spec.aging_rate * waited_s
            }
        }
    }

    /// True when an online request is close enough to its TTFT deadline
    /// that it overrides offline aging entirely.
    pub fn is_urgent(&self, r: &QueuedReq, now: Micros) -> bool {
        r.class == RequestClass::Online
            && self.urgency(r, now) >= self.spec.urgency_threshold
    }

    /// The canonical total drain order — urgent first, then score, then
    /// earliest arrival; `Less` means `a` serves before `b`. Every
    /// priority-mode decision (bucket pick, intra-bucket sort, force-pop)
    /// goes through this single comparator so they can never disagree.
    pub fn compare(&self, a: &QueuedReq, b: &QueuedReq, now: Micros) -> Ordering {
        self.is_urgent(b, now)
            .cmp(&self.is_urgent(a, now))
            .then(
                self.score(b, now)
                    .partial_cmp(&self.score(a, now))
                    .unwrap_or(Ordering::Equal),
            )
            .then(a.arrival.cmp(&b.arrival))
    }

    /// The canonical order reversed: `Less` when `a` is *less* urgent
    /// than `b`. This is the victim-selection order of the preemption
    /// subsystem (evict/abort the least-urgent work first) — sharing the
    /// comparator with the drain order is what guarantees a victim can
    /// never outrank the request preempting it.
    pub fn least_urgent_first(
        &self,
        a: &QueuedReq,
        b: &QueuedReq,
        now: Micros,
    ) -> Ordering {
        self.compare(b, a, now)
    }

    /// The canonical order extended with a TBT-slack term — the victim
    /// comparator of the TBT-aware admission layer. It agrees with
    /// [`PriorityScorer::compare`] on every pair that order already
    /// separates; exact ties (e.g. two same-class sequences from the same
    /// t=0 backlog, whose scores are equal) break toward the smaller
    /// signed slack to the next-token deadline, so of two otherwise-equal
    /// offline actives the one *furthest* from blowing its own budget is
    /// shed first. The drain order never consults this method, which is
    /// what keeps admission-disabled schedules untouched.
    pub fn compare_tbt(
        &self,
        a: &QueuedReq,
        slack_a: i64,
        b: &QueuedReq,
        slack_b: i64,
        now: Micros,
    ) -> Ordering {
        self.compare(a, b, now).then(slack_a.cmp(&slack_b))
    }

    /// Precomputed drain key: a *stable* ascending sort on it reproduces
    /// the old stable `sort_by(compare)` exactly — urgent first, then
    /// score descending, then arrival, ties keeping queue order — while
    /// paying the float score computation once per request instead of
    /// once per comparison (`sort_by_cached_key` in the batcher's drain).
    /// Deliberately *no* id tie-break: the old comparator left full ties
    /// in queue order, and matching it bit-for-bit is what keeps the
    /// sharding refactor's `shards = 1` schedules byte-identical.
    pub fn drain_key(&self, r: &QueuedReq, now: Micros) -> DrainKey {
        DrainKey {
            not_urgent: !self.is_urgent(r, now),
            neg_score_bits: !f64_total_bits(self.score(r, now)),
            arrival: r.arrival,
        }
    }

    /// Position `(bucket, index)` of the highest-ranked queued request
    /// across `buckets` under [`PriorityScorer::compare`] (first match
    /// wins ties). Shared by bucket selection and the deadlock-break
    /// force-pop so the two scans cannot diverge.
    pub fn best_position(
        &self,
        buckets: &[Bucket],
        now: Micros,
    ) -> Option<(usize, usize)> {
        let mut best: Option<(usize, usize, QueuedReq)> = None;
        for (bi, b) in buckets.iter().enumerate() {
            for (ri, r) in b.requests.iter().enumerate() {
                let better = match &best {
                    None => true,
                    Some((_, _, cur)) => {
                        self.compare(r, cur, now) == Ordering::Less
                    }
                };
                if better {
                    best = Some((bi, ri, *r));
                }
            }
        }
        best.map(|(bi, ri, _)| (bi, ri))
    }

    pub fn spec(&self) -> &PrioritySpec {
        &self.spec
    }
}

/// The precomputed drain-sort key (see [`PriorityScorer::drain_key`]).
/// Field order *is* the comparison order, so the derived `Ord` is the
/// canonical drain order; full ties rely on sort stability, mirroring
/// [`PriorityScorer::compare`]'s `Ordering::Equal`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct DrainKey {
    /// `!is_urgent`, so urgent requests sort first.
    not_urgent: bool,
    /// Bit-inverted total-order image of the score (higher score first).
    neg_score_bits: u64,
    arrival: Micros,
}

/// Monotone map from `f64` to `u64`: for any non-NaN floats `a < b ⇔
/// f64_total_bits(a) < f64_total_bits(b)` (the IEEE-754 total-order bit
/// trick: flip all bits of negatives, flip only the sign bit of
/// non-negatives). Scores are finite and positive for every sane
/// [`PrioritySpec`], so this agrees exactly with the `partial_cmp` the
/// per-comparison path in [`PriorityScorer::compare`] uses.
fn f64_total_bits(x: f64) -> u64 {
    let b = x.to_bits();
    if b >> 63 == 1 {
        !b
    } else {
        b | (1 << 63)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scorer() -> PriorityScorer {
        PriorityScorer::new(PrioritySpec::default(), SloSpec::default())
    }

    fn req(class: RequestClass, arrival: Micros) -> QueuedReq {
        QueuedReq {
            id: 0,
            len: 100,
            output_len: 10,
            arrival,
            class,
            tbt_us: 0,
            prefix: crate::coordinator::prefix::PrefixStamp::default(),
        }
    }

    #[test]
    fn online_outranks_fresh_offline() {
        let s = scorer();
        let online = req(RequestClass::Online, 0);
        let offline = req(RequestClass::Offline, 0);
        assert!(s.score(&online, 0) > s.score(&offline, 0));
    }

    #[test]
    fn online_urgency_grows_toward_deadline() {
        let s = scorer();
        let r = req(RequestClass::Online, 0);
        let ttft = SloSpec::default().ttft_us;
        assert!(s.score(&r, 0) < s.score(&r, ttft / 2));
        assert!(s.score(&r, ttft / 2) < s.score(&r, ttft));
        assert!((s.urgency(&r, ttft) - 1.0).abs() < 1e-9);
        // Overdue requests keep climbing (no cliff at the deadline).
        assert!(s.score(&r, 2 * ttft) > s.score(&r, ttft));
    }

    #[test]
    fn same_class_score_order_is_arrival_order() {
        let s = scorer();
        let now = 1_000_000;
        for class in [RequestClass::Online, RequestClass::Offline] {
            let early = req(class, 100);
            let late = req(class, 900_000);
            assert!(
                s.score(&early, now) > s.score(&late, now),
                "{class:?}: earlier arrival must score higher"
            );
        }
    }

    #[test]
    fn offline_aging_eventually_overtakes_fresh_online() {
        let s = scorer();
        let spec = PrioritySpec::default();
        // A fresh online request scores online_weight; an offline request
        // that has waited long enough must exceed it (starvation-proof).
        let overtake_s =
            (spec.online_weight - spec.offline_weight) / spec.aging_rate;
        let now = (overtake_s * 1e6) as Micros + 2_000_000;
        let aged_offline = req(RequestClass::Offline, 0);
        let fresh_online = req(RequestClass::Online, now);
        assert!(s.score(&aged_offline, now) > s.score(&fresh_online, now));
        // ... but an *urgent* online request still overrides it.
        let urgent_online = req(RequestClass::Online, 0);
        assert!(s.is_urgent(&urgent_online, now));
        assert!(!s.is_urgent(&aged_offline, now));
        assert!(!s.is_urgent(&fresh_online, now));
    }

    #[test]
    fn compare_orders_urgent_then_score_then_arrival() {
        let s = scorer();
        let now = 1_000_000;
        let urgent_online = req(RequestClass::Online, 100_000); // 2.25 budgets in
        let fresh_online = req(RequestClass::Online, now);
        let offline = req(RequestClass::Offline, 0);
        assert_eq!(s.compare(&urgent_online, &fresh_online, now), Ordering::Less);
        assert_eq!(s.compare(&fresh_online, &offline, now), Ordering::Less);
        assert_eq!(s.compare(&offline, &urgent_online, now), Ordering::Greater);
        assert_eq!(s.compare(&offline, &offline, now), Ordering::Equal);
    }

    #[test]
    fn least_urgent_first_is_compare_reversed() {
        let s = scorer();
        let now = 1_000_000;
        let urgent = req(RequestClass::Online, 100_000);
        let offline = req(RequestClass::Offline, 0);
        assert_eq!(s.compare(&urgent, &offline, now), Ordering::Less);
        assert_eq!(s.least_urgent_first(&offline, &urgent, now), Ordering::Less);
        assert_eq!(s.least_urgent_first(&urgent, &offline, now), Ordering::Greater);
        assert_eq!(s.least_urgent_first(&offline, &offline, now), Ordering::Equal);
    }

    #[test]
    fn urgency_mirrors_request_ttft_slack() {
        // The scorer's urgency and the public Request::ttft_slack helper
        // must stay two views of the same deadline: urgency = 1 − slack/budget.
        let s = scorer();
        let slo = SloSpec::default();
        let q = req(RequestClass::Online, 100_000);
        let r = crate::workload::Request::new(
            0, RequestClass::Online, 100, 10, 100_000,
        );
        for now in [100_000u64, 300_000, 500_000, 900_000] {
            let expect = 1.0 - r.ttft_slack(&slo, now) as f64 / slo.ttft_us as f64;
            assert!(
                (s.urgency(&q, now) - expect).abs() < 1e-9,
                "urgency vs slack mismatch at now={now}"
            );
        }
    }

    #[test]
    fn compare_tbt_extends_ties_with_slack_only() {
        let s = scorer();
        let now = 1_000_000;
        // Where compare() separates, the slack term is ignored entirely —
        // here the aged offline request outranks the fresh one no matter
        // how dire the fresh one's slack looks.
        let aged = req(RequestClass::Offline, 0);
        let fresh = req(RequestClass::Offline, 900_000);
        assert_eq!(s.compare(&aged, &fresh, now), Ordering::Less);
        assert_eq!(
            s.compare_tbt(&aged, i64::MAX, &fresh, i64::MIN, now),
            Ordering::Less
        );
        // On an exact compare() tie (same class, same arrival), the
        // smaller remaining slack ranks more urgent.
        let a = req(RequestClass::Offline, 0);
        let b = req(RequestClass::Offline, 0);
        assert_eq!(s.compare(&a, &b, now), Ordering::Equal);
        assert_eq!(s.compare_tbt(&a, 10_000, &b, 50_000, now), Ordering::Less);
        assert_eq!(s.compare_tbt(&a, 50_000, &b, 10_000, now), Ordering::Greater);
        assert_eq!(s.compare_tbt(&a, 10_000, &b, 10_000, now), Ordering::Equal);
    }

    #[test]
    fn f64_total_bits_is_monotone() {
        let xs = [-1e30, -2.5, -1.0, -1e-9, 0.0, 1e-9, 0.1, 1.0, 2.5, 1e30];
        for w in xs.windows(2) {
            assert!(
                f64_total_bits(w[0]) < f64_total_bits(w[1]),
                "bits order broken between {} and {}",
                w[0],
                w[1]
            );
        }
        assert_eq!(f64_total_bits(1.5), f64_total_bits(1.5));
    }

    #[test]
    fn prop_drain_key_order_matches_compare() {
        // The precomputed Ord key must rank any pair exactly as the
        // per-comparison path does (modulo the id tail, which only breaks
        // ties compare() leaves Equal).
        crate::util::prop::check("drain key ≡ compare", 300, |g| {
            let s = scorer();
            let now = g.u64(0, 3_000_000);
            let mk = |g: &mut crate::util::prop::Gen, id: u64| QueuedReq {
                id,
                len: g.u64(1, 4000) as u32,
                output_len: g.u64(1, 400) as u32,
                arrival: g.u64(0, 3_000_000),
                class: if g.bool() {
                    RequestClass::Online
                } else {
                    RequestClass::Offline
                },
                tbt_us: 0,
                prefix: crate::coordinator::prefix::PrefixStamp::default(),
            };
            let a = mk(g, 0);
            let b = mk(g, 1);
            let (ka, kb) = (s.drain_key(&a, now), s.drain_key(&b, now));
            match s.compare(&a, &b, now) {
                Ordering::Less => assert!(ka < kb, "{a:?} vs {b:?} at {now}"),
                Ordering::Greater => assert!(ka > kb, "{a:?} vs {b:?} at {now}"),
                // Full ties map to equal keys: both the old comparator
                // sort and the cached-key sort are stable, so equal keys
                // preserve queue order identically.
                Ordering::Equal => {
                    assert_eq!(ka, kb, "tie must map to equal keys")
                }
            }
        });
    }

    #[test]
    fn urgency_threshold_gates_is_urgent() {
        let s = scorer();
        let ttft = SloSpec::default().ttft_us;
        let thresh = PrioritySpec::default().urgency_threshold;
        let r = req(RequestClass::Online, 0);
        let just_before = ((ttft as f64) * (thresh - 0.01)) as Micros;
        let just_after = ((ttft as f64) * (thresh + 0.01)) as Micros;
        assert!(!s.is_urgent(&r, just_before));
        assert!(s.is_urgent(&r, just_after));
    }
}
