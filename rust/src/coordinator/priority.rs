//! Priority-aware scheduling (paper §III): SLO-deadline urgency scoring.
//!
//! The seed drained buckets in pure earliest-arrival order, which lets a
//! backlog of offline throughput work head-of-line-block latency-bound
//! online requests. This module scores every queued request so the
//! [`DynamicBatcher`](super::batcher::DynamicBatcher) can drain by SLO
//! urgency instead:
//!
//! * **Online** — urgency is the fraction of the TTFT budget already
//!   consumed (`(now − arrival) / slo.ttft_us`, i.e. 1 − slack/budget).
//!   Score = `online_weight · (1 + urgency)`: a fresh online request
//!   already outranks the offline base weight, and the rank keeps rising
//!   toward (and past) the deadline.
//! * **Offline** — a throughput class with starvation aging: score =
//!   `offline_weight + aging_rate · waited_seconds`, so offline work
//!   eventually overtakes *non-urgent* online work instead of starving.
//! * **Urgency override** — once an online request consumes more than
//!   `urgency_threshold` of its TTFT budget it is *urgent* and ranks ahead
//!   of any non-urgent request regardless of aging.
//!
//! For a single-class queue the score order degenerates to exact
//! earliest-arrival order, so enabling priority changes nothing on the
//! seed's single-class workloads — the wins (and the ablation bench) are
//! on mixed online/offline traffic.

use super::bucket::{Bucket, QueuedReq};
use crate::config::{PrioritySpec, SloSpec};
use crate::workload::RequestClass;
use crate::Micros;
use std::cmp::Ordering;

/// Scores queued requests by SLO urgency; cheap enough to call per
/// comparison in the drain sort.
#[derive(Debug, Clone)]
pub struct PriorityScorer {
    spec: PrioritySpec,
    slo: SloSpec,
}

impl PriorityScorer {
    pub fn new(spec: PrioritySpec, slo: SloSpec) -> PriorityScorer {
        PriorityScorer { spec, slo }
    }

    /// Fraction of the TTFT budget an online request has consumed at
    /// `now` (0 at arrival, 1 at the deadline, > 1 overdue): the
    /// scorer-side view of [`crate::workload::Request::ttft_slack`],
    /// `1 − slack/budget` (a unit test pins the two to agree).
    pub fn urgency(&self, r: &QueuedReq, now: Micros) -> f64 {
        let waited = now.saturating_sub(r.arrival) as f64;
        waited / self.slo.ttft_us.max(1) as f64
    }

    /// Drain score — higher serves first.
    pub fn score(&self, r: &QueuedReq, now: Micros) -> f64 {
        match r.class {
            RequestClass::Online => {
                self.spec.online_weight * (1.0 + self.urgency(r, now))
            }
            RequestClass::Offline => {
                let waited_s = now.saturating_sub(r.arrival) as f64 / 1e6;
                self.spec.offline_weight + self.spec.aging_rate * waited_s
            }
        }
    }

    /// True when an online request is close enough to its TTFT deadline
    /// that it overrides offline aging entirely.
    pub fn is_urgent(&self, r: &QueuedReq, now: Micros) -> bool {
        r.class == RequestClass::Online
            && self.urgency(r, now) >= self.spec.urgency_threshold
    }

    /// The canonical total drain order — urgent first, then score, then
    /// earliest arrival; `Less` means `a` serves before `b`. Every
    /// priority-mode decision (bucket pick, intra-bucket sort, force-pop)
    /// goes through this single comparator so they can never disagree.
    pub fn compare(&self, a: &QueuedReq, b: &QueuedReq, now: Micros) -> Ordering {
        self.is_urgent(b, now)
            .cmp(&self.is_urgent(a, now))
            .then(
                self.score(b, now)
                    .partial_cmp(&self.score(a, now))
                    .unwrap_or(Ordering::Equal),
            )
            .then(a.arrival.cmp(&b.arrival))
    }

    /// Position `(bucket, index)` of the highest-ranked queued request
    /// across `buckets` under [`PriorityScorer::compare`] (first match
    /// wins ties). Shared by bucket selection and the deadlock-break
    /// force-pop so the two scans cannot diverge.
    pub fn best_position(
        &self,
        buckets: &[Bucket],
        now: Micros,
    ) -> Option<(usize, usize)> {
        let mut best: Option<(usize, usize, QueuedReq)> = None;
        for (bi, b) in buckets.iter().enumerate() {
            for (ri, r) in b.requests.iter().enumerate() {
                let better = match &best {
                    None => true,
                    Some((_, _, cur)) => {
                        self.compare(r, cur, now) == Ordering::Less
                    }
                };
                if better {
                    best = Some((bi, ri, *r));
                }
            }
        }
        best.map(|(bi, ri, _)| (bi, ri))
    }

    pub fn spec(&self) -> &PrioritySpec {
        &self.spec
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scorer() -> PriorityScorer {
        PriorityScorer::new(PrioritySpec::default(), SloSpec::default())
    }

    fn req(class: RequestClass, arrival: Micros) -> QueuedReq {
        QueuedReq { id: 0, len: 100, output_len: 10, arrival, class }
    }

    #[test]
    fn online_outranks_fresh_offline() {
        let s = scorer();
        let online = req(RequestClass::Online, 0);
        let offline = req(RequestClass::Offline, 0);
        assert!(s.score(&online, 0) > s.score(&offline, 0));
    }

    #[test]
    fn online_urgency_grows_toward_deadline() {
        let s = scorer();
        let r = req(RequestClass::Online, 0);
        let ttft = SloSpec::default().ttft_us;
        assert!(s.score(&r, 0) < s.score(&r, ttft / 2));
        assert!(s.score(&r, ttft / 2) < s.score(&r, ttft));
        assert!((s.urgency(&r, ttft) - 1.0).abs() < 1e-9);
        // Overdue requests keep climbing (no cliff at the deadline).
        assert!(s.score(&r, 2 * ttft) > s.score(&r, ttft));
    }

    #[test]
    fn same_class_score_order_is_arrival_order() {
        let s = scorer();
        let now = 1_000_000;
        for class in [RequestClass::Online, RequestClass::Offline] {
            let early = req(class, 100);
            let late = req(class, 900_000);
            assert!(
                s.score(&early, now) > s.score(&late, now),
                "{class:?}: earlier arrival must score higher"
            );
        }
    }

    #[test]
    fn offline_aging_eventually_overtakes_fresh_online() {
        let s = scorer();
        let spec = PrioritySpec::default();
        // A fresh online request scores online_weight; an offline request
        // that has waited long enough must exceed it (starvation-proof).
        let overtake_s =
            (spec.online_weight - spec.offline_weight) / spec.aging_rate;
        let now = (overtake_s * 1e6) as Micros + 2_000_000;
        let aged_offline = req(RequestClass::Offline, 0);
        let fresh_online = req(RequestClass::Online, now);
        assert!(s.score(&aged_offline, now) > s.score(&fresh_online, now));
        // ... but an *urgent* online request still overrides it.
        let urgent_online = req(RequestClass::Online, 0);
        assert!(s.is_urgent(&urgent_online, now));
        assert!(!s.is_urgent(&aged_offline, now));
        assert!(!s.is_urgent(&fresh_online, now));
    }

    #[test]
    fn compare_orders_urgent_then_score_then_arrival() {
        let s = scorer();
        let now = 1_000_000;
        let urgent_online = req(RequestClass::Online, 100_000); // 2.25 budgets in
        let fresh_online = req(RequestClass::Online, now);
        let offline = req(RequestClass::Offline, 0);
        assert_eq!(s.compare(&urgent_online, &fresh_online, now), Ordering::Less);
        assert_eq!(s.compare(&fresh_online, &offline, now), Ordering::Less);
        assert_eq!(s.compare(&offline, &urgent_online, now), Ordering::Greater);
        assert_eq!(s.compare(&offline, &offline, now), Ordering::Equal);
    }

    #[test]
    fn urgency_mirrors_request_ttft_slack() {
        // The scorer's urgency and the public Request::ttft_slack helper
        // must stay two views of the same deadline: urgency = 1 − slack/budget.
        let s = scorer();
        let slo = SloSpec::default();
        let q = req(RequestClass::Online, 100_000);
        let r = crate::workload::Request::new(
            0, RequestClass::Online, 100, 10, 100_000,
        );
        for now in [100_000u64, 300_000, 500_000, 900_000] {
            let expect = 1.0 - r.ttft_slack(&slo, now) as f64 / slo.ttft_us as f64;
            assert!(
                (s.urgency(&q, now) - expect).abs() < 1e-9,
                "urgency vs slack mismatch at now={now}"
            );
        }
    }

    #[test]
    fn urgency_threshold_gates_is_urgent() {
        let s = scorer();
        let ttft = SloSpec::default().ttft_us;
        let thresh = PrioritySpec::default().urgency_threshold;
        let r = req(RequestClass::Online, 0);
        let just_before = ((ttft as f64) * (thresh - 0.01)) as Micros;
        let just_after = ((ttft as f64) * (thresh + 0.01)) as Micros;
        assert!(!s.is_urgent(&r, just_before));
        assert!(s.is_urgent(&r, just_after));
    }
}
