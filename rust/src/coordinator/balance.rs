//! Placement and load-balancing policies for the sharded coordinator.
//!
//! Three decisions route work through the shard layer, and all three live
//! here so they can be swapped or extended in one place:
//!
//! * **Arrival placement** — [`Router::choose`] maps a new request to a
//!   shard under the configured [`Placement`] policy (least-loaded queue,
//!   join-shortest-KV, or a stateless hash). Policies are pure functions
//!   of the per-shard [`ShardLoad`] snapshot, so adding one is a new
//!   `Placement` variant plus a match arm — no scheduler changes.
//! * **Dispatch targeting** — [`best_decode_in`] picks the decode
//!   instance with the most KV headroom among those a shard owns. With a
//!   single shard owning the whole fleet this is exactly the seed's
//!   global `best_target` max-headroom scan (ties keep the highest
//!   index), which is what makes `shards = 1` behavior-preserving.
//! * **Steal victim selection** — [`steal_victim`] names the most-loaded
//!   shard an idle shard should pull from (ties keep the lowest id, so
//!   rebalancing is deterministic).
//!
//! The shard structures themselves live in [`super::shard`]; this module
//! is intentionally stateless.
//!
//! The TBT-aware admission layer layers a second opinion on top of
//! dispatch targeting: after [`best_decode_in`] names the max-headroom
//! instance, the scheduler may veto it (and walk the shard's remaining
//! owned instances in headroom order) when the projected iteration time
//! would blow a resident online sequence's inter-token budget — see
//! [`super::admission`]. Headroom stays the first-order signal; TBT
//! slack is a constraint, not a score.

use super::fleet::DecodeFleet;
use crate::config::Placement;
use crate::workload::RequestId;

/// One shard's load snapshot, as placement policies see it.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardLoad {
    /// Requests queued in the shard's planner.
    pub queued: usize,
    /// Full-context token footprint of those queued requests.
    pub queued_tokens: u64,
    /// KV tokens reserved on the shard's owned decode instances.
    pub kv_reserved: u64,
    /// Best single-instance KV headroom among owned decode instances.
    pub best_headroom: u64,
}

/// Interprets the configured [`Placement`] policy over load snapshots.
#[derive(Debug, Clone, Copy)]
pub struct Router {
    placement: Placement,
}

impl Router {
    pub fn new(placement: Placement) -> Router {
        Router { placement }
    }

    pub fn placement(&self) -> Placement {
        self.placement
    }

    /// Shard index for a new arrival. `loads` must be non-empty; ties go
    /// to the lowest shard id so routing is deterministic.
    pub fn choose(&self, id: RequestId, loads: &[ShardLoad]) -> usize {
        debug_assert!(!loads.is_empty());
        match self.placement {
            Placement::LeastLoaded => argmin(loads, |l| l.queued as u64),
            Placement::JoinShortestKv => {
                argmin(loads, |l| l.kv_reserved.saturating_add(l.queued_tokens))
            }
            Placement::Hash => (splitmix64(id) % loads.len() as u64) as usize,
        }
    }
}

/// First index minimizing `key` (strict `<`, so ties keep the lowest id).
fn argmin(loads: &[ShardLoad], key: impl Fn(&ShardLoad) -> u64) -> usize {
    let mut best = 0usize;
    for (i, l) in loads.iter().enumerate().skip(1) {
        if key(l) < key(&loads[best]) {
            best = i;
        }
    }
    best
}

/// SplitMix64 finalizer: spreads sequential request ids uniformly so hash
/// placement doesn't degenerate to round-robin on monotone ids.
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// The decode instance with the most KV headroom among `owned`, with that
/// headroom in tokens. Mirrors the seed's global max-headroom scan
/// exactly: iterate in ascending index order and keep `>=`, so ties
/// resolve to the highest owned index. `owned` must be non-empty.
pub fn best_decode_in(
    owned: &[usize],
    decode: &DecodeFleet,
    per_budget: u64,
) -> (usize, u64) {
    debug_assert!(!owned.is_empty());
    let mut best = (owned[0], 0u64);
    let mut first = true;
    for &di in owned {
        let headroom = per_budget.saturating_sub(decode.get(di).reserved_tokens);
        if first || headroom >= best.1 {
            best = (di, headroom);
            first = false;
        }
    }
    best
}

/// The shard an idle shard should steal from: most queued requests, ties
/// to the lowest id, excluding the thief itself. `None` when no other
/// shard has at least `min_queue` requests.
pub fn steal_victim(
    thief: usize,
    queued: &[usize],
    min_queue: usize,
) -> Option<usize> {
    let mut victim: Option<(usize, usize)> = None;
    for (i, &q) in queued.iter().enumerate() {
        if i == thief || q < min_queue {
            continue;
        }
        let better = match victim {
            None => true,
            Some((_, vq)) => q > vq,
        };
        if better {
            victim = Some((i, q));
        }
    }
    victim.map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loads(spec: &[(usize, u64, u64)]) -> Vec<ShardLoad> {
        spec.iter()
            .map(|&(queued, queued_tokens, kv_reserved)| ShardLoad {
                queued,
                queued_tokens,
                kv_reserved,
                best_headroom: 0,
            })
            .collect()
    }

    #[test]
    fn least_loaded_picks_min_queue_ties_low_id() {
        let r = Router::new(Placement::LeastLoaded);
        let l = loads(&[(3, 0, 0), (1, 0, 0), (1, 0, 0), (2, 0, 0)]);
        assert_eq!(r.choose(0, &l), 1);
    }

    #[test]
    fn join_shortest_kv_weighs_reserved_plus_queued_tokens() {
        let r = Router::new(Placement::JoinShortestKv);
        // Shard 0 has a short queue but heavy KV commitment; shard 1 wins.
        let l = loads(&[(1, 5_000, 20_000), (4, 8_000, 1_000)]);
        assert_eq!(r.choose(0, &l), 1);
    }

    #[test]
    fn hash_is_deterministic_in_range_and_spreads() {
        let r = Router::new(Placement::Hash);
        let l = loads(&[(0, 0, 0), (0, 0, 0), (0, 0, 0), (0, 0, 0)]);
        let mut hit = [false; 4];
        for id in 0..64u64 {
            let s = r.choose(id, &l);
            assert!(s < 4);
            assert_eq!(s, r.choose(id, &l), "deterministic");
            hit[s] = true;
        }
        assert!(hit.iter().all(|&h| h), "64 ids should reach all 4 shards");
    }

    #[test]
    fn best_decode_mirrors_seed_best_target() {
        // Ported from the seed's DecodeFleet::best_target test: max
        // headroom wins; over-subscribed instances saturate at zero and
        // ties keep the highest index.
        let mut f = DecodeFleet::new(3);
        f.get_mut(0).reserved_tokens = 800;
        f.get_mut(1).reserved_tokens = 100;
        f.get_mut(2).reserved_tokens = 500;
        assert_eq!(best_decode_in(&[0, 1, 2], &f, 1000), (1, 900));
        assert_eq!(best_decode_in(&[0, 1, 2], &f, 50), (2, 0));
        // A shard owning a subset scans only its own instances.
        assert_eq!(best_decode_in(&[0, 2], &f, 1000), (2, 500));
        assert_eq!(best_decode_in(&[0], &f, 1000), (0, 200));
    }

    #[test]
    fn steal_victim_prefers_most_loaded_excluding_thief() {
        assert_eq!(steal_victim(0, &[9, 4, 7], 2), Some(2));
        assert_eq!(steal_victim(2, &[4, 4, 0], 2), Some(0), "tie → low id");
        assert_eq!(steal_victim(1, &[1, 0, 1], 2), None, "below min_queue");
        assert_eq!(steal_victim(0, &[9], 2), None, "no other shard");
    }
}
