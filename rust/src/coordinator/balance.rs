//! Placement and load-balancing policies for the sharded coordinator.
//!
//! Three decisions route work through the shard layer, and all three live
//! here so they can be swapped or extended in one place:
//!
//! * **Arrival placement** — [`Router::choose`] maps a new request to a
//!   shard under the configured [`Placement`] policy (least-loaded queue,
//!   join-shortest-KV, or a stateless hash). Policies are pure functions
//!   of the per-shard [`ShardLoad`] snapshot, so adding one is a new
//!   `Placement` variant plus a match arm — no scheduler changes.
//! * **Dispatch targeting** — [`best_decode_in`] picks the decode
//!   instance with the most KV headroom among those a shard owns. With a
//!   single shard owning the whole fleet this is exactly the seed's
//!   global `best_target` max-headroom scan (ties keep the highest
//!   index), which is what makes `shards = 1` behavior-preserving.
//! * **Steal victim selection** — [`steal_victim`] names the most-loaded
//!   shard an idle shard should pull from (ties keep the lowest id, so
//!   rebalancing is deterministic).
//!
//! The shard structures themselves live in [`super::shard`]; this module
//! is intentionally stateless.
//!
//! The TBT-aware admission layer layers a second opinion on top of
//! dispatch targeting: after [`best_decode_in`] names the max-headroom
//! instance, the scheduler may veto it (and walk the shard's remaining
//! owned instances in headroom order) when the projected iteration time
//! would blow a resident online sequence's inter-token budget — see
//! [`super::admission`]. Headroom stays the first-order signal; TBT
//! slack is a constraint, not a score.

use super::fleet::DecodeFleet;
use crate::config::Placement;
use crate::workload::RequestId;

/// One shard's load snapshot, as placement policies see it.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardLoad {
    /// Requests queued in the shard's planner.
    pub queued: usize,
    /// Full-context token footprint of those queued requests.
    pub queued_tokens: u64,
    /// KV tokens reserved on the shard's owned decode instances.
    pub kv_reserved: u64,
    /// Best single-instance KV headroom among owned decode instances.
    pub best_headroom: u64,
}

/// Interprets the configured [`Placement`] policy over load snapshots.
#[derive(Debug, Clone, Copy)]
pub struct Router {
    placement: Placement,
}

impl Router {
    pub fn new(placement: Placement) -> Router {
        Router { placement }
    }

    pub fn placement(&self) -> Placement {
        self.placement
    }

    /// Shard index for a new arrival. `loads` must be non-empty; ties go
    /// to the lowest shard id so routing is deterministic.
    ///
    /// `PrefixAffinity` here is the *fallback* path: the scheduler
    /// intercepts arrivals that match a resident prefix and routes them
    /// to the owning shard directly; everything that reaches this policy
    /// function had no resident match, and joins the shortest KV queue
    /// exactly like `JoinShortestKv`.
    pub fn choose(&self, id: RequestId, loads: &[ShardLoad]) -> usize {
        debug_assert!(!loads.is_empty());
        match self.placement {
            Placement::LeastLoaded => argmin(loads, |l| l.queued as u64),
            Placement::JoinShortestKv | Placement::PrefixAffinity => {
                argmin(loads, |l| l.kv_reserved.saturating_add(l.queued_tokens))
            }
            Placement::Hash => (splitmix64(id) % loads.len() as u64) as usize,
        }
    }
}

/// First index minimizing `key` (strict `<`, so ties keep the lowest id).
fn argmin(loads: &[ShardLoad], key: impl Fn(&ShardLoad) -> u64) -> usize {
    let mut best = 0usize;
    for (i, l) in loads.iter().enumerate().skip(1) {
        if key(l) < key(&loads[best]) {
            best = i;
        }
    }
    best
}

/// SplitMix64 finalizer: spreads sequential request ids uniformly so hash
/// placement doesn't degenerate to round-robin on monotone ids.
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// The decode instance with the most KV headroom among `owned`, with that
/// headroom in tokens. Mirrors the seed's global max-headroom scan
/// exactly: iterate in ascending index order and keep `>=`, so ties
/// resolve to the highest owned index. `owned` must be non-empty.
pub fn best_decode_in(
    owned: &[usize],
    decode: &DecodeFleet,
    per_budget: u64,
) -> (usize, u64) {
    debug_assert!(!owned.is_empty());
    let mut best = (owned[0], 0u64);
    let mut first = true;
    for &di in owned {
        let headroom = per_budget.saturating_sub(decode.get(di).reserved_tokens);
        if first || headroom >= best.1 {
            best = (di, headroom);
            first = false;
        }
    }
    best
}

/// The shard an idle shard should steal from: most queued requests, ties
/// to the lowest id, excluding the thief itself. `None` when no other
/// shard has at least `min_queue` requests.
pub fn steal_victim(
    thief: usize,
    queued: &[usize],
    min_queue: usize,
) -> Option<usize> {
    steal_victim_with_affinity(thief, queued, min_queue, &[])
}

/// Locality-aware steal victim choice. `gains[i]` scores what moving
/// shard `i`'s stolen tail onto the thief is worth to the prefix cache:
/// the tail's resident-prefix affinity to the *thief*'s instances minus
/// its affinity to shard `i`'s own — so the tail least at home where it
/// is (and most at home on the thief) is preferred. Eligibility is
/// unchanged (never the thief, at least `min_queue` queued); among
/// eligible shards the order is max gain → max queued → lowest id.
/// Shards beyond `gains.len()` score 0, so an empty slice (`PrefixSpec`
/// off, or no lineage anywhere) degrades exactly to the queue-depth
/// policy above.
pub fn steal_victim_with_affinity(
    thief: usize,
    queued: &[usize],
    min_queue: usize,
    gains: &[i64],
) -> Option<usize> {
    let mut victim: Option<(usize, i64, usize)> = None;
    for (i, &q) in queued.iter().enumerate() {
        if i == thief || q < min_queue {
            continue;
        }
        let g = gains.get(i).copied().unwrap_or(0);
        let better = match victim {
            None => true,
            Some((_, vg, vq)) => (g, q) > (vg, vq),
        };
        if better {
            victim = Some((i, g, q));
        }
    }
    victim.map(|(i, _, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loads(spec: &[(usize, u64, u64)]) -> Vec<ShardLoad> {
        spec.iter()
            .map(|&(queued, queued_tokens, kv_reserved)| ShardLoad {
                queued,
                queued_tokens,
                kv_reserved,
                best_headroom: 0,
            })
            .collect()
    }

    #[test]
    fn least_loaded_picks_min_queue_ties_low_id() {
        let r = Router::new(Placement::LeastLoaded);
        let l = loads(&[(3, 0, 0), (1, 0, 0), (1, 0, 0), (2, 0, 0)]);
        assert_eq!(r.choose(0, &l), 1);
    }

    #[test]
    fn join_shortest_kv_weighs_reserved_plus_queued_tokens() {
        let r = Router::new(Placement::JoinShortestKv);
        // Shard 0 has a short queue but heavy KV commitment; shard 1 wins.
        let l = loads(&[(1, 5_000, 20_000), (4, 8_000, 1_000)]);
        assert_eq!(r.choose(0, &l), 1);
    }

    #[test]
    fn hash_is_deterministic_in_range_and_spreads() {
        let r = Router::new(Placement::Hash);
        let l = loads(&[(0, 0, 0), (0, 0, 0), (0, 0, 0), (0, 0, 0)]);
        let mut hit = [false; 4];
        for id in 0..64u64 {
            let s = r.choose(id, &l);
            assert!(s < 4);
            assert_eq!(s, r.choose(id, &l), "deterministic");
            hit[s] = true;
        }
        assert!(hit.iter().all(|&h| h), "64 ids should reach all 4 shards");
    }

    #[test]
    fn best_decode_mirrors_seed_best_target() {
        // Ported from the seed's DecodeFleet::best_target test: max
        // headroom wins; over-subscribed instances saturate at zero and
        // ties keep the highest index.
        let mut f = DecodeFleet::new(3);
        f.get_mut(0).reserved_tokens = 800;
        f.get_mut(1).reserved_tokens = 100;
        f.get_mut(2).reserved_tokens = 500;
        assert_eq!(best_decode_in(&[0, 1, 2], &f, 1000), (1, 900));
        assert_eq!(best_decode_in(&[0, 1, 2], &f, 50), (2, 0));
        // A shard owning a subset scans only its own instances.
        assert_eq!(best_decode_in(&[0, 2], &f, 1000), (2, 500));
        assert_eq!(best_decode_in(&[0], &f, 1000), (0, 200));
    }

    #[test]
    fn steal_victim_prefers_most_loaded_excluding_thief() {
        assert_eq!(steal_victim(0, &[9, 4, 7], 2), Some(2));
        assert_eq!(steal_victim(2, &[4, 4, 0], 2), Some(0), "tie → low id");
        assert_eq!(steal_victim(1, &[1, 0, 1], 2), None, "below min_queue");
        assert_eq!(steal_victim(0, &[9], 2), None, "no other shard");
    }

    #[test]
    fn affinity_gain_outranks_queue_depth_then_ties_fall_back() {
        let q = [0usize, 9, 4, 7];
        // No gains at all ≡ the legacy queue-depth policy.
        assert_eq!(steal_victim_with_affinity(0, &q, 2, &[]), Some(1));
        // All-zero gains ≡ legacy too.
        assert_eq!(steal_victim_with_affinity(0, &q, 2, &[0, 0, 0, 0]), Some(1));
        // A positive gain beats deeper queues: shard 2's tail belongs on
        // the thief (gain > 0) even though shard 1 has more queued.
        assert_eq!(steal_victim_with_affinity(0, &q, 2, &[0, 0, 5, 0]), Some(2));
        // Equal gains → deeper queue decides…
        assert_eq!(steal_victim_with_affinity(0, &q, 2, &[0, 3, 3, 3]), Some(1));
        // …and equal gain + equal depth → lowest id (the pinned
        // tie-break): shards 1 and 3 both gain 3 with depth 7.
        let q_tied = [0usize, 7, 4, 7];
        assert_eq!(
            steal_victim_with_affinity(0, &q_tied, 2, &[0, 3, 9, 3]),
            Some(2),
            "gain dominates first"
        );
        assert_eq!(
            steal_victim_with_affinity(0, &q_tied, 2, &[0, 3, 0, 3]),
            Some(1),
            "gain+depth tie → low id"
        );
        // Negative gain (tail at home where it is) ranks below zero-gain
        // shards regardless of depth.
        assert_eq!(
            steal_victim_with_affinity(0, &q, 2, &[0, -4, 0, 0]),
            Some(3)
        );
        // Eligibility is unchanged: a high-gain shard below min_queue is
        // still not a victim.
        assert_eq!(
            steal_victim_with_affinity(1, &[1, 0, 1], 2, &[9, 0, 9]),
            None
        );
    }
}
