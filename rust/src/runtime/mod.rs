//! PJRT runtime: load and execute the AOT-compiled JAX + Pallas artifacts.
//!
//! * [`artifacts`] — manifest + weights loader (the contract emitted by
//!   `python/compile/aot.py`).
//! * [`pjrt`] — the PJRT CPU client wrapper: HLO-text → compiled executable
//!   cache, weight device buffers.
//! * [`engine`] — [`engine::PjrtEngine`], the real-execution implementation
//!   of [`crate::cluster::Engine`]: bucket bounds select compiled shapes,
//!   prefill outputs feed per-request KV state, decode steps run true
//!   continuous batching on the compiled decode executables.
//!
//! Python never appears here: the artifacts directory is the entire
//! build-time → request-path interface.

pub mod artifacts;
pub mod pjrt;
pub mod engine;

pub use artifacts::Manifest;
pub use engine::PjrtEngine;
pub use pjrt::PjrtRuntime;

/// Default artifacts directory (relative to the repo root).
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// True when an artifacts directory looks complete (manifest present).
pub fn artifacts_available(dir: &str) -> bool {
    std::path::Path::new(dir).join("manifest.json").exists()
}
