//! `PjrtEngine`: real execution of the AOT artifacts behind the
//! [`Engine`](crate::cluster::Engine) trait.
//!
//! The scheduler's bucket bounds map directly onto the compiled prefill
//! shapes (`prefill_b{B}_s{S}`): a formed batch is rounded up to the
//! smallest covering artifact, dummy rows/columns are masked out by the
//! `lengths` input, and the KV cache comes back padded to the decode
//! capacity so any decode artifact can consume it. Per-request KV lives
//! host-side between steps (the CPU analogue of the paper's NVLink
//! hand-off between prefill and decode instances).

use super::pjrt::PjrtRuntime;
use crate::cluster::{DecodeBatch, Engine, PrefillBatch};
use crate::config::ModelSpec;
use crate::workload::RequestId;
use crate::Micros;
use std::collections::HashMap;
use std::time::Instant;

/// Host-side per-request KV state between engine calls.
struct KvState {
    /// (L, H, CAP, D) flattened, per layer contiguous.
    k: Vec<f32>,
    v: Vec<f32>,
    /// Valid cache entries (prompt + generated-so-far − 1).
    kv_valid: u32,
    last_token: i32,
    generated: Vec<i32>,
}

/// Real-execution engine over the PJRT CPU client.
pub struct PjrtEngine {
    rt: PjrtRuntime,
    spec: ModelSpec,
    states: HashMap<RequestId, KvState>,
    /// Per-layer KV chunk (H·CAP·D) and total per-request KV length.
    layer_chunk: usize,
    kv_len: usize,
    pub prefill_calls: u64,
    pub decode_calls: u64,
}

impl PjrtEngine {
    /// Load artifacts from `dir` and stand the engine up.
    pub fn load(dir: &str) -> anyhow::Result<PjrtEngine> {
        let rt = PjrtRuntime::load(dir)?;
        let m = &rt.manifest.model;
        let spec = ModelSpec {
            n_params: m.param_count as f64,
            n_layers: m.n_layers,
            n_heads: m.n_heads,
            head_dim: m.head_dim,
            bytes_per_el: 4,
            max_seq: m.max_prefill,
        };
        let layer_chunk =
            (m.n_heads * m.kv_capacity * m.head_dim) as usize;
        let kv_len = m.n_layers as usize * layer_chunk;
        Ok(PjrtEngine {
            rt,
            spec,
            states: HashMap::new(),
            layer_chunk,
            kv_len,
            prefill_calls: 0,
            decode_calls: 0,
        })
    }

    pub fn runtime(&self) -> &PjrtRuntime {
        &self.rt
    }

    pub fn runtime_mut(&mut self) -> &mut PjrtRuntime {
        &mut self.rt
    }

    /// Tokens generated so far for a live request.
    pub fn generated(&self, id: RequestId) -> Option<&[i32]> {
        self.states.get(&id).map(|s| s.generated.as_slice())
    }

    /// Deterministic filler prompt for requests without real tokens.
    fn synth_tokens(&self, id: RequestId, len: usize) -> Vec<i32> {
        let vocab = self.rt.manifest.model.vocab as u64;
        (0..len)
            .map(|j| {
                ((id.wrapping_mul(1315423911) ^ (j as u64).wrapping_mul(2654435761))
                    % vocab) as i32
            })
            .collect()
    }

    fn argmax_rows(logits: &[f32], rows: usize, cols: usize) -> Vec<i32> {
        (0..rows)
            .map(|r| {
                let row = &logits[r * cols..(r + 1) * cols];
                let mut best = 0usize;
                for (i, &x) in row.iter().enumerate() {
                    if x > row[best] {
                        best = i;
                    }
                }
                best as i32
            })
            .collect()
    }

    /// Run one compiled prefill for up to `artifact.batch` items.
    fn prefill_chunk(
        &mut self,
        items: &[crate::cluster::PrefillItem],
        padded_len: u32,
    ) -> anyhow::Result<()> {
        let n = items.len() as u32;
        let max_len = items.iter().map(|i| i.len).max().unwrap_or(1);
        let want_seq = padded_len.max(max_len);
        let entry = self
            .rt
            .manifest
            .pick_prefill(n, want_seq)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no prefill artifact covers batch {n} seq {want_seq}"
                )
            })?
            .clone();
        let (bsz, seq) = (entry.batch as usize, entry.seq as usize);
        let m = self.rt.manifest.model.clone();

        let mut tokens = vec![0i32; bsz * seq];
        let mut lengths = vec![1i32; bsz];
        for (i, item) in items.iter().enumerate() {
            let len = (item.len as usize).min(seq).max(1);
            lengths[i] = len as i32;
            let toks: Vec<i32> = if item.tokens.is_empty() {
                self.synth_tokens(item.id, len)
            } else {
                item.tokens.iter().map(|&t| t as i32).collect()
            };
            for (j, &t) in toks.iter().take(len).enumerate() {
                tokens[i * seq + j] = t % m.vocab as i32;
            }
        }

        self.rt.ensure_compiled(&entry)?;
        let tok_buf = self.rt.buffer_i32(&tokens, &[bsz, seq])?;
        let len_buf = self.rt.buffer_i32(&lengths, &[bsz])?;
        let exe = self.rt.get_executable(&entry.name).unwrap();
        let mut args: Vec<&xla::PjRtBuffer> = self.rt.weights.iter().collect();
        args.push(&tok_buf);
        args.push(&len_buf);
        let out = exe
            .execute_b(&args)
            .map_err(|e| anyhow::anyhow!("prefill execute: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("prefill fetch: {e:?}"))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("prefill untuple: {e:?}"))?;
        anyhow::ensure!(parts.len() == 3, "prefill output arity");
        let logits: Vec<f32> = parts[0]
            .to_vec()
            .map_err(|e| anyhow::anyhow!("logits: {e:?}"))?;
        let kall: Vec<f32> =
            parts[1].to_vec().map_err(|e| anyhow::anyhow!("k: {e:?}"))?;
        let vall: Vec<f32> =
            parts[2].to_vec().map_err(|e| anyhow::anyhow!("v: {e:?}"))?;

        let first = Self::argmax_rows(&logits, bsz, m.vocab as usize);
        // kall shape: (L, B, H, CAP, D) → per request (L, H, CAP, D).
        for (i, item) in items.iter().enumerate() {
            let mut k = vec![0f32; self.kv_len];
            let mut v = vec![0f32; self.kv_len];
            for l in 0..m.n_layers as usize {
                let src = (l * bsz + i) * self.layer_chunk;
                let dst = l * self.layer_chunk;
                k[dst..dst + self.layer_chunk]
                    .copy_from_slice(&kall[src..src + self.layer_chunk]);
                v[dst..dst + self.layer_chunk]
                    .copy_from_slice(&vall[src..src + self.layer_chunk]);
            }
            self.states.insert(
                item.id,
                KvState {
                    k,
                    v,
                    kv_valid: lengths[i] as u32,
                    last_token: first[i],
                    generated: vec![first[i]],
                },
            );
        }
        Ok(())
    }

    /// Run one compiled decode iteration for up to `artifact.batch` seqs.
    fn decode_chunk(&mut self, ids: &[RequestId]) -> anyhow::Result<()> {
        let n = ids.len() as u32;
        let entry = self
            .rt
            .manifest
            .pick_decode(n)
            .ok_or_else(|| anyhow::anyhow!("no decode artifact covers {n}"))?
            .clone();
        let bsz = entry.batch as usize;
        let m = self.rt.manifest.model.clone();
        let cap = m.kv_capacity;

        let mut kall = vec![0f32; m.n_layers as usize * bsz * self.layer_chunk];
        let mut vall = vec![0f32; m.n_layers as usize * bsz * self.layer_chunk];
        let mut tokens = vec![0i32; bsz];
        let mut pos = vec![0i32; bsz];
        for (i, id) in ids.iter().enumerate() {
            let st = self
                .states
                .get(id)
                .ok_or_else(|| anyhow::anyhow!("decode of unknown request {id}"))?;
            anyhow::ensure!(
                st.kv_valid < cap,
                "request {id} exceeded KV capacity {cap}"
            );
            tokens[i] = st.last_token;
            pos[i] = st.kv_valid as i32;
            for l in 0..m.n_layers as usize {
                let dst = (l * bsz + i) * self.layer_chunk;
                let src = l * self.layer_chunk;
                kall[dst..dst + self.layer_chunk]
                    .copy_from_slice(&st.k[src..src + self.layer_chunk]);
                vall[dst..dst + self.layer_chunk]
                    .copy_from_slice(&st.v[src..src + self.layer_chunk]);
            }
        }

        let kv_dims = [
            m.n_layers as usize,
            bsz,
            m.n_heads as usize,
            cap as usize,
            m.head_dim as usize,
        ];
        self.rt.ensure_compiled(&entry)?;
        let tok_buf = self.rt.buffer_i32(&tokens, &[bsz])?;
        let k_buf = self.rt.buffer_f32(&kall, &kv_dims)?;
        let v_buf = self.rt.buffer_f32(&vall, &kv_dims)?;
        let pos_buf = self.rt.buffer_i32(&pos, &[bsz])?;
        let exe = self.rt.get_executable(&entry.name).unwrap();
        let mut args: Vec<&xla::PjRtBuffer> = self.rt.weights.iter().collect();
        args.push(&tok_buf);
        args.push(&k_buf);
        args.push(&v_buf);
        args.push(&pos_buf);
        let out = exe
            .execute_b(&args)
            .map_err(|e| anyhow::anyhow!("decode execute: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("decode fetch: {e:?}"))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("decode untuple: {e:?}"))?;
        anyhow::ensure!(parts.len() == 3, "decode output arity");
        let logits: Vec<f32> = parts[0]
            .to_vec()
            .map_err(|e| anyhow::anyhow!("logits: {e:?}"))?;
        let knew: Vec<f32> =
            parts[1].to_vec().map_err(|e| anyhow::anyhow!("k': {e:?}"))?;
        let vnew: Vec<f32> =
            parts[2].to_vec().map_err(|e| anyhow::anyhow!("v': {e:?}"))?;

        let next = Self::argmax_rows(&logits, bsz, m.vocab as usize);
        for (i, id) in ids.iter().enumerate() {
            let st = self.states.get_mut(id).unwrap();
            for l in 0..m.n_layers as usize {
                let src = (l * bsz + i) * self.layer_chunk;
                let dst = l * self.layer_chunk;
                st.k[dst..dst + self.layer_chunk]
                    .copy_from_slice(&knew[src..src + self.layer_chunk]);
                st.v[dst..dst + self.layer_chunk]
                    .copy_from_slice(&vnew[src..src + self.layer_chunk]);
            }
            st.kv_valid += 1;
            st.last_token = next[i];
            st.generated.push(next[i]);
        }
        Ok(())
    }
}

impl Engine for PjrtEngine {
    fn model(&self) -> &ModelSpec {
        &self.spec
    }

    fn realtime(&self) -> bool {
        true
    }

    fn prefill(&mut self, batch: &PrefillBatch) -> anyhow::Result<Micros> {
        let t0 = Instant::now();
        self.prefill_calls += 1;
        let max_b = *self
            .rt
            .manifest
            .prefill_shapes()
            .iter()
            .map(|(b, _)| b)
            .max()
            .ok_or_else(|| anyhow::anyhow!("no prefill artifacts"))?
            as usize;
        for chunk in batch.items.chunks(max_b) {
            self.prefill_chunk(chunk, batch.padded_len)?;
        }
        Ok(t0.elapsed().as_micros() as Micros)
    }

    fn decode_step(&mut self, batch: &DecodeBatch) -> anyhow::Result<Micros> {
        let t0 = Instant::now();
        self.decode_calls += 1;
        let max_b = *self
            .rt
            .manifest
            .decode_batches()
            .iter()
            .max()
            .ok_or_else(|| anyhow::anyhow!("no decode artifacts"))?
            as usize;
        let ids: Vec<RequestId> = batch.seqs.iter().map(|s| s.id).collect();
        for chunk in ids.chunks(max_b) {
            self.decode_chunk(chunk)?;
        }
        Ok(t0.elapsed().as_micros() as Micros)
    }

    fn kv_transfer(&mut self, _tokens: u64) -> Micros {
        // Same-process hand-off: KV is already host-resident.
        0
    }

    fn decode_mem_budget(&self) -> u64 {
        // Host-side KV budget for the tiny model: cap concurrent context at
        // 64 full-length sequences' worth of cache.
        let m = &self.rt.manifest.model;
        64 * m.kv_capacity as u64 * self.spec.kv_bytes_per_token()
    }

    fn release(&mut self, id: RequestId) {
        self.states.remove(&id);
    }
}
