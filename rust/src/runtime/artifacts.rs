//! Artifact manifest + weights: the build-time contract with
//! `python/compile/aot.py`.

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Model hyper-parameters recorded in the manifest (mirrors
/// `python/compile/model.py::ModelConfig`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelInfo {
    pub vocab: u32,
    pub d_model: u32,
    pub n_layers: u32,
    pub n_heads: u32,
    pub head_dim: u32,
    pub ffn_dim: u32,
    pub kv_capacity: u32,
    pub max_prefill: u32,
    pub param_count: u64,
}

/// One weight tensor's layout inside `weights.bin`.
#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub bytes: usize,
}

/// One compiled executable's description.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub kind: ArtifactKind,
    pub batch: u32,
    /// Prefill: the bucket bound (padded sequence length).
    /// Decode: the KV capacity.
    pub seq: u32,
    pub file: String,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    Prefill,
    Decode,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelInfo,
    pub weights_file: String,
    pub weights_total_bytes: usize,
    pub weights: Vec<WeightEntry>,
    pub artifacts: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &str) -> anyhow::Result<Manifest> {
        let path = Path::new(dir).join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;

        let m = j.get("model");
        let model = ModelInfo {
            vocab: need_u32(m, "vocab")?,
            d_model: need_u32(m, "d_model")?,
            n_layers: need_u32(m, "n_layers")?,
            n_heads: need_u32(m, "n_heads")?,
            head_dim: need_u32(m, "head_dim")?,
            ffn_dim: need_u32(m, "ffn_dim")?,
            kv_capacity: need_u32(m, "kv_capacity")?,
            max_prefill: need_u32(m, "max_prefill")?,
            param_count: m.get("param_count").as_u64().unwrap_or(0),
        };

        let w = j.get("weights");
        let weights = w
            .get("tensors")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("manifest: weights.tensors missing"))?
            .iter()
            .map(|t| {
                Ok(WeightEntry {
                    name: t
                        .get("name")
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("weight name"))?
                        .to_string(),
                    shape: t
                        .get("shape")
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|v| v.as_usize())
                        .collect(),
                    offset: t.get("offset").as_usize().unwrap_or(0),
                    bytes: t.get("bytes").as_usize().unwrap_or(0),
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;

        let artifacts = j
            .get("artifacts")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("manifest: artifacts missing"))?
            .iter()
            .map(|a| {
                let kind = match a.get("kind").as_str() {
                    Some("prefill") => ArtifactKind::Prefill,
                    Some("decode") => ArtifactKind::Decode,
                    other => anyhow::bail!("unknown artifact kind {other:?}"),
                };
                Ok(ArtifactEntry {
                    name: a
                        .get("name")
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("artifact name"))?
                        .to_string(),
                    kind,
                    batch: a.get("batch").as_u64().unwrap_or(1) as u32,
                    seq: a.get("seq").as_u64().unwrap_or(0) as u32,
                    file: a
                        .get("file")
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("artifact file"))?
                        .to_string(),
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;

        Ok(Manifest {
            dir: PathBuf::from(dir),
            model,
            weights_file: w
                .get("file")
                .as_str()
                .unwrap_or("weights.bin")
                .to_string(),
            weights_total_bytes: w.get("total_bytes").as_usize().unwrap_or(0),
            weights,
            artifacts,
        })
    }

    /// Read the raw weights blob.
    pub fn read_weights(&self) -> anyhow::Result<Vec<u8>> {
        let path = self.dir.join(&self.weights_file);
        let blob = std::fs::read(&path)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        if self.weights_total_bytes != 0 && blob.len() != self.weights_total_bytes {
            anyhow::bail!(
                "weights.bin is {} bytes, manifest says {}",
                blob.len(),
                self.weights_total_bytes
            );
        }
        Ok(blob)
    }

    /// Available prefill shapes, sorted: (batch, seq).
    pub fn prefill_shapes(&self) -> Vec<(u32, u32)> {
        let mut v: Vec<_> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Prefill)
            .map(|a| (a.batch, a.seq))
            .collect();
        v.sort();
        v
    }

    /// Available decode batch sizes, sorted.
    pub fn decode_batches(&self) -> Vec<u32> {
        let mut v: Vec<_> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Decode)
            .map(|a| a.batch)
            .collect();
        v.sort();
        v
    }

    /// Smallest compiled prefill shape covering (n, seq_len), if any.
    pub fn pick_prefill(&self, n: u32, seq_len: u32) -> Option<&ArtifactEntry> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.kind == ArtifactKind::Prefill && a.batch >= n && a.seq >= seq_len
            })
            .min_by_key(|a| (a.batch, a.seq))
    }

    /// Smallest compiled decode batch covering n, if any.
    pub fn pick_decode(&self, n: u32) -> Option<&ArtifactEntry> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Decode && a.batch >= n)
            .min_by_key(|a| a.batch)
    }

    /// Prefill bucket bounds (the shape menu the scheduler buckets onto).
    pub fn bucket_bounds(&self) -> Vec<u32> {
        let mut v: Vec<u32> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Prefill)
            .map(|a| a.seq)
            .collect();
        v.sort();
        v.dedup();
        v
    }
}

fn need_u32(j: &Json, key: &str) -> anyhow::Result<u32> {
    j.get(key)
        .as_u64()
        .map(|v| v as u32)
        .ok_or_else(|| anyhow::anyhow!("manifest: model.{key} missing"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> Option<String> {
        // Tests run from the crate root; artifacts may not exist in CI.
        let dir = "artifacts";
        if crate::runtime::artifacts_available(dir) {
            Some(dir.to_string())
        } else {
            None
        }
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let Some(dir) = manifest_dir() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.model.vocab > 0);
        assert!(!m.weights.is_empty());
        assert!(!m.artifacts.is_empty());
        // Weight layout is contiguous and ordered.
        let mut expect = 0usize;
        for w in &m.weights {
            assert_eq!(w.offset, expect, "weight {} offset", w.name);
            let numel: usize = w.shape.iter().product();
            assert_eq!(w.bytes, numel * 4, "weight {} is f32", w.name);
            expect += w.bytes;
        }
        assert_eq!(expect, m.weights_total_bytes);
        let blob = m.read_weights().unwrap();
        assert_eq!(blob.len(), m.weights_total_bytes);
    }

    #[test]
    fn shape_selection_picks_smallest_cover() {
        let Some(dir) = manifest_dir() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let shapes = m.prefill_shapes();
        assert!(!shapes.is_empty());
        let a = m.pick_prefill(3, 100).unwrap();
        assert!(a.batch >= 3 && a.seq >= 100);
        // No strictly smaller covering artifact exists.
        for s in &shapes {
            if s.0 >= 3 && s.1 >= 100 {
                assert!((a.batch, a.seq) <= *s);
            }
        }
        assert!(m.pick_prefill(1000, 100).is_none());
        let d = m.pick_decode(3).unwrap();
        assert!(d.batch >= 3);
    }

    #[test]
    fn parses_synthetic_manifest() {
        let dir = std::env::temp_dir().join("bs_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = r#"{
            "model": {"vocab": 8, "d_model": 4, "n_layers": 1, "n_heads": 1,
                      "head_dim": 4, "ffn_dim": 8, "kv_capacity": 16,
                      "max_prefill": 8, "param_count": 100},
            "weights": {"file": "weights.bin", "total_bytes": 8,
                        "tensors": [{"name": "w", "shape": [2], "offset": 0, "bytes": 8}]},
            "artifacts": [
                {"name": "prefill_b1_s8", "kind": "prefill", "batch": 1,
                 "seq": 8, "file": "prefill_b1_s8.hlo.txt"},
                {"name": "decode_b1", "kind": "decode", "batch": 1,
                 "seq": 16, "file": "decode_b1.hlo.txt"}
            ]
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        std::fs::write(dir.join("weights.bin"), [0u8; 8]).unwrap();
        let m = Manifest::load(dir.to_str().unwrap()).unwrap();
        assert_eq!(m.model.vocab, 8);
        assert_eq!(m.bucket_bounds(), vec![8]);
        assert_eq!(m.decode_batches(), vec![1]);
        assert_eq!(m.read_weights().unwrap().len(), 8);
    }
}
