//! PJRT client wrapper: compiled-executable cache + device-resident weights.
//!
//! HLO **text** is the interchange format (`HloModuleProto::from_text_file`
//! reassigns instruction ids, which is what makes jax ≥ 0.5 output loadable
//! on xla_extension 0.5.1 — see DESIGN.md and /opt/xla-example/README.md).
//!
//! Weights are uploaded to device buffers once at startup and shared by
//! every executable via `execute_b`; the request path never re-uploads
//! them. Executables compile lazily on first use and are cached by
//! artifact name — the bucket → static-shape mapping means a warmed server
//! touches each shape once.

use super::artifacts::{ArtifactEntry, Manifest};
use std::collections::HashMap;
use std::time::Instant;

/// The runtime: client + manifest + weights + executable cache.
pub struct PjrtRuntime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    /// Device-resident weight buffers, in manifest order.
    pub weights: Vec<xla::PjRtBuffer>,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Cumulative compile time (startup cost, reported by examples).
    pub compile_us: u64,
}

impl PjrtRuntime {
    /// Create the CPU client, load the manifest, upload weights.
    pub fn load(dir: &str) -> anyhow::Result<PjrtRuntime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("pjrt cpu client: {e:?}"))?;
        let blob = manifest.read_weights()?;
        let mut weights = Vec::with_capacity(manifest.weights.len());
        for w in &manifest.weights {
            // NB: decode little-endian f32 and use the *typed* upload path;
            // the crate's raw-bytes path passes the ElementType discriminant
            // where XLA expects a PrimitiveType (F32 → F16), corrupting the
            // buffer size.
            let bytes = &blob[w.offset..w.offset + w.bytes];
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let buf = client
                .buffer_from_host_buffer(&data, &w.shape, None)
                .map_err(|e| anyhow::anyhow!("upload {}: {e:?}", w.name))?;
            weights.push(buf);
        }
        Ok(PjrtRuntime {
            client,
            manifest,
            weights,
            executables: HashMap::new(),
            compile_us: 0,
        })
    }

    /// Compile (once) the executable for an artifact.
    pub fn ensure_compiled(&mut self, entry: &ArtifactEntry) -> anyhow::Result<()> {
        if !self.executables.contains_key(&entry.name) {
            let path = self.manifest.dir.join(&entry.file);
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {}: {e:?}", entry.name))?;
            self.compile_us += t0.elapsed().as_micros() as u64;
            self.executables.insert(entry.name.clone(), exe);
        }
        Ok(())
    }

    /// Fetch a previously compiled executable by artifact name.
    pub fn get_executable(&self, name: &str) -> Option<&xla::PjRtLoadedExecutable> {
        self.executables.get(name)
    }

    /// Get (compiling on first use) the executable for an artifact.
    pub fn executable(
        &mut self,
        entry: &ArtifactEntry,
    ) -> anyhow::Result<&xla::PjRtLoadedExecutable> {
        self.ensure_compiled(entry)?;
        Ok(&self.executables[&entry.name])
    }

    /// Eagerly compile every artifact (server warm-up).
    pub fn warm_up(&mut self) -> anyhow::Result<()> {
        let entries: Vec<ArtifactEntry> = self.manifest.artifacts.clone();
        for e in &entries {
            self.ensure_compiled(e)?;
        }
        Ok(())
    }

    /// Number of compiled executables currently cached.
    pub fn compiled_count(&self) -> usize {
        self.executables.len()
    }

    /// Upload an i32 tensor.
    pub fn buffer_i32(
        &self,
        data: &[i32],
        dims: &[usize],
    ) -> anyhow::Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload i32: {e:?}"))
    }

    /// Upload an f32 tensor.
    pub fn buffer_f32(
        &self,
        data: &[f32],
        dims: &[usize],
    ) -> anyhow::Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload f32: {e:?}"))
    }
}
