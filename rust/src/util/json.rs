//! Minimal JSON: recursive-descent parser + writer.
//!
//! Used for the AOT artifact manifest, config files, experiment result
//! dumps, and the TCP gateway protocol. Supports the full JSON grammar
//! (objects, arrays, strings with escapes, numbers, bools, null); numbers
//! are held as f64 with an i64 fast path.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
#[error("json parse error at byte {at}: {msg}")]
pub struct ParseError {
    pub at: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- accessors ---------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| if n >= 0.0 { Some(n as u64) } else { None })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` convenience: returns Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index convenience.
    pub fn at(&self, idx: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(idx).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json { Json::Num(v) }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json { Json::Num(v as f64) }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json { Json::Num(v as f64) }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json { Json::Bool(v) }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json { Json::Str(v.to_string()) }
}
impl From<String> for Json {
    fn from(v: String) -> Json { Json::Str(v) }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { at: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.i += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.i = self.i.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => {
                    self.i = self.i.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => {
                    self.i = self.i.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\')
                                || self.bump() != Some(b'u')
                            {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("bad low surrogate"));
                            }
                            let c = 0x10000
                                + ((cp - 0xD800) << 10)
                                + (lo - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(ch.ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => {
                    return Err(self.err("control char in string"))
                }
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            0xF0..=0xF7 => 4,
                            _ => return Err(self.err("bad utf-8")),
                        };
                        if start + width > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = &self.b[start..start + width];
                        let st = std::str::from_utf8(chunk)
                            .map_err(|_| self.err("bad utf-8"))?;
                        s.push_str(st);
                        self.i = start + width;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = (v << 4) | d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("a").at(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("a").at(0).as_u64(), Some(1));
    }

    #[test]
    fn escapes_round_trip() {
        let orig = Json::Str("a\"b\\c\nd\té ☃".into());
        let text = orig.to_string();
        assert_eq!(Json::parse(&text).unwrap(), orig);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".into())
        );
        // Surrogate pair: 😀
        assert_eq!(
            Json::parse(r#""😀""#).unwrap(),
            Json::Str("😀".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"\\x\"").is_err());
    }

    #[test]
    fn writer_round_trips_structures() {
        let v = Json::obj(vec![
            ("nums", Json::from(vec![1u64, 2, 3])),
            ("nested", Json::obj(vec![("x", Json::from(true))])),
            ("f", Json::Num(1.25)),
            ("s", Json::from("hello")),
            ("n", Json::Null),
        ]);
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn integer_format_has_no_decimal_point() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }

    #[test]
    fn missing_key_is_null() {
        let v = Json::parse(r#"{"a":1}"#).unwrap();
        assert!(v.get("zzz").is_null());
        assert!(v.get("a").get("b").is_null());
    }

    #[test]
    fn deep_nesting() {
        let mut s = String::new();
        for _ in 0..100 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..100 {
            s.push(']');
        }
        let mut v = &Json::parse(&s).unwrap();
        for _ in 0..100 {
            v = v.at(0);
        }
        assert_eq!(v.as_u64(), Some(1));
    }
}
