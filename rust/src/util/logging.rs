//! Leveled stderr logger (tracing/log crates unavailable offline).
//!
//! Level is set once (from `--log-level` or `BUCKETSERVE_LOG`); the macros
//! are zero-cost when filtered out beyond an atomic load.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

impl Level {
    pub fn from_str_lossy(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" | "warning" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

/// Set the global level (also reads BUCKETSERVE_LOG on `init`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Initialize from the environment (call once from main).
pub fn init() {
    if let Ok(v) = std::env::var("BUCKETSERVE_LOG") {
        set_level(Level::from_str_lossy(&v));
    }
}

#[inline]
pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

/// Internal: emit one record.
pub fn emit(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{}] {}: {}", level.tag(), module, msg);
    }
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::emit(
            $crate::util::logging::Level::Error,
            module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::emit(
            $crate::util::logging::Level::Warn,
            module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::emit(
            $crate::util::logging::Level::Info,
            module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::emit(
            $crate::util::logging::Level::Debug,
            module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn level_parse() {
        assert_eq!(Level::from_str_lossy("DEBUG"), Level::Debug);
        assert_eq!(Level::from_str_lossy("bogus"), Level::Info);
    }
}
