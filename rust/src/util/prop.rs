//! Mini property-testing harness (proptest is unavailable offline).
//!
//! A property is a closure over a [`Gen`] (a seeded PRNG wrapper with
//! convenience draws). [`check`] runs it N times with derived seeds and, on
//! failure, retries the failing seed with progressively smaller size hints
//! (a lightweight stand-in for shrinking) before reporting the seed so the
//! failure is reproducible:
//!
//! ```ignore
//! prop::check("buckets partition the range", 256, |g| {
//!     let reqs = g.vec(0..g.size(), |g| g.u64(0, 4096));
//!     ... assert!(...);
//! });
//! ```

use super::rng::Pcg;

/// Generator handed to properties: a PRNG plus a "size" hint that shrinks
/// on failure replays.
pub struct Gen {
    rng: Pcg,
    size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Gen {
        Gen { rng: Pcg::seeded(seed), size }
    }

    /// Current size hint (collections should scale with this).
    pub fn size(&self) -> usize {
        self.size
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range_u64(lo, hi)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi)
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.f64()
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// A collection whose length scales with the size hint.
    pub fn vec<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let len = self.usize(0, max_len.min(self.size.max(1)));
        (0..len).map(|_| f(self)).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.usize(0, xs.len() - 1);
        &xs[i]
    }

    pub fn rng(&mut self) -> &mut Pcg {
        &mut self.rng
    }
}

/// Run `cases` random cases of the property. Panics (with the failing seed
/// and smallest failing size) if any case's assertions fail.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    const BASE_SIZE: usize = 64;
    for case in 0..cases {
        let seed = 0x5eed_0000 + case;
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed, BASE_SIZE);
            prop(&mut g);
        });
        if result.is_err() {
            // "Shrink": find the smallest size at which this seed still fails.
            let mut smallest = BASE_SIZE;
            for size in [1usize, 2, 4, 8, 16, 32] {
                let r = std::panic::catch_unwind(|| {
                    let mut g = Gen::new(seed, size);
                    prop(&mut g);
                });
                if r.is_err() {
                    smallest = size;
                    break;
                }
            }
            panic!(
                "property '{name}' failed: seed={seed:#x} size={smallest} \
                 (reproduce with Gen::new({seed:#x}, {smallest}))"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("addition commutes", 64, |g| {
            let a = g.u64(0, 1000);
            let b = g.u64(0, 1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails' failed")]
    fn failing_property_reports_seed() {
        check("always fails", 4, |g| {
            let v = g.u64(0, 10);
            assert!(v > 1000, "forced failure");
        });
    }

    #[test]
    fn gen_vec_respects_size() {
        let mut g = Gen::new(1, 8);
        for _ in 0..50 {
            let v = g.vec(100, |g| g.u64(0, 9));
            assert!(v.len() <= 8);
        }
    }

    #[test]
    fn gen_deterministic() {
        let mut a = Gen::new(42, 64);
        let mut b = Gen::new(42, 64);
        for _ in 0..20 {
            assert_eq!(a.u64(0, 1 << 40), b.u64(0, 1 << 40));
        }
    }
}
