//! Declarative `--flag value` argument parsing (clap is unavailable offline).
//!
//! ```no_run
//! use bucketserve::util::cli::Args;
//! let args = Args::from_env();
//! let rps: f64 = args.get_or("rps", 8.0);
//! let system: String = args.get_or("system", "bucketserve".to_string());
//! let verbose = args.flag("verbose");
//! ```

use std::collections::BTreeMap;
use std::str::FromStr;

/// Parsed command line: positional words plus `--key value` / `--key=value`
/// options and bare `--switch` flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse from an iterator (testable).
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(item) = it.next() {
            if let Some(stripped) = item.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(item);
            }
        }
        out
    }

    /// Typed lookup; None when absent.
    pub fn get<T: FromStr>(&self, key: &str) -> Option<T> {
        self.opts.get(key).and_then(|v| v.parse().ok())
    }

    /// Typed lookup with default.
    pub fn get_or<T: FromStr>(&self, key: &str, default: T) -> T {
        self.get(key).unwrap_or(default)
    }

    /// Was `--key` present (as a bare switch or with a value)?
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key) || self.opts.contains_key(key)
    }

    /// Raw string lookup.
    pub fn raw(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// All `--key value` pairs (for config overrides).
    pub fn overrides(&self) -> impl Iterator<Item = (&str, &str)> {
        self.opts.iter().map(|(k, v)| (k.as_str(), v.as_str()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("serve --rps 8.5 --system bucketserve trace.json");
        assert_eq!(a.positional, vec!["serve", "trace.json"]);
        assert_eq!(a.get::<f64>("rps"), Some(8.5));
        assert_eq!(a.raw("system"), Some("bucketserve"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("--n=42 --name=x=y");
        assert_eq!(a.get::<u64>("n"), Some(42));
        assert_eq!(a.raw("name"), Some("x=y"));
    }

    #[test]
    fn bare_flags() {
        let a = parse("run --verbose --count 3 --dry-run");
        assert!(a.flag("verbose"));
        assert!(a.flag("dry-run"));
        assert!(a.flag("count"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.get::<u32>("count"), Some(3));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--a --b value");
        assert!(a.flag("a"));
        assert_eq!(a.raw("b"), Some("value"));
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get_or::<f64>("rps", 1.5), 1.5);
    }

    #[test]
    fn negative_number_values() {
        // A value starting with '-' (not '--') is consumed as a value.
        let a = parse("--offset -5");
        assert_eq!(a.get::<i64>("offset"), Some(-5));
    }
}
