//! Bench harness (criterion is unavailable offline).
//!
//! Two pieces:
//! * [`time_it`] / [`Bencher`] — wall-clock micro-benchmarks with warmup,
//!   repetitions, and mean/p50/p99 reporting, used by `micro_hotpath`.
//! * [`Table`] — aligned-column experiment tables so every figure bench
//!   prints the same rows/series the paper reports.

use std::time::Instant;

/// One micro-benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl Measurement {
    pub fn print(&self) {
        println!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns)
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time a closure: auto-calibrated iteration count, `reps` timed samples.
pub fn time_it<R>(name: &str, mut f: impl FnMut() -> R) -> Measurement {
    // Warmup + calibration: aim for ~2 ms per sample.
    let mut iters = 1u64;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(f());
        }
        let el = t0.elapsed().as_nanos() as u64;
        if el > 2_000_000 || iters >= 1 << 22 {
            break;
        }
        iters = (iters * 4).min(1 << 22);
    }
    let reps = 15;
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(f());
        }
        samples.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    Measurement {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: samples[reps / 2],
        p99_ns: samples[reps - 1],
    }
}

/// Aligned experiment table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self, title: &str) {
        println!("\n=== {title} ===");
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:>width$}  ", c, width = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!(
            "{}",
            widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("--")
        );
        for row in &self.rows {
            line(row);
        }
    }
}

/// Format helper: `f(2.5)` → "2.50".
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

pub fn f0(x: f64) -> String {
    format!("{x:.0}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_measures_something() {
        let m = time_it("noop-ish", || std::hint::black_box(1 + 1));
        assert!(m.mean_ns > 0.0);
        assert!(m.p99_ns >= m.p50_ns * 0.5);
    }

    #[test]
    fn table_rejects_bad_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row(vec!["1".into()]);
        }));
        assert!(r.is_err());
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(5.0).contains("ns"));
        assert!(fmt_ns(5e3).contains("µs"));
        assert!(fmt_ns(5e6).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }
}
