//! Zero-dependency substrates.
//!
//! The build image is fully offline and only ships the `xla` crate's
//! dependency closure, so the conveniences a serving framework normally
//! pulls from crates.io (serde, rand, clap, tracing, proptest, criterion)
//! are implemented here from scratch:
//!
//! * [`json`] — recursive-descent JSON parser + writer (manifest/config/IPC).
//! * [`clock`] — injectable µs wall clock (manual in tests, monotonic in prod).
//! * [`rng`] — PCG-family PRNG with the distributions the workload models
//!   need (uniform, normal, log-normal, exponential, Pareto, Poisson).
//! * [`stats`] — streaming mean/variance, percentile sketches, histograms.
//! * [`cli`] — a small declarative `--flag value` argument parser.
//! * [`logging`] — leveled stderr logger.
//! * [`prop`] — mini property-testing harness (seeded generators + shrink-lite).
//! * [`bench`] — micro/throughput bench harness used by `cargo bench` targets.

pub mod clock;
pub mod json;
pub mod rng;
pub mod stats;
pub mod cli;
pub mod logging;
pub mod prop;
pub mod bench;
