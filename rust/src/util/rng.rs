//! Deterministic PRNG + sampling distributions.
//!
//! PCG-XSH-RR 64/32 (O'Neill 2014): small state, excellent statistical
//! quality, and — critically for reproducible experiments — a seedable,
//! stream-splittable generator whose sequences are identical across runs
//! and platforms.

/// PCG-XSH-RR 64/32 generator.
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Seed-only constructor (stream 54, the PCG reference default).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 54)
    }

    /// Derive an independent child generator (for splitting workloads).
    pub fn split(&mut self) -> Pcg {
        let seed = ((self.next_u32() as u64) << 32) | self.next_u32() as u64;
        let stream = self.next_u32() as u64;
        Pcg::new(seed, stream)
    }

    /// Next raw 32 bits.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64 bits.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in (0, 1] — safe as a log() argument.
    pub fn f64_open(&mut self) -> f64 {
        1.0 - self.f64()
    }

    /// Uniform integer in [lo, hi] inclusive (unbiased via rejection).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "range_u64: lo > hi");
        let span = hi - lo + 1;
        if span == 0 {
            return self.next_u64(); // full range
        }
        let zone = u64::MAX - (u64::MAX % span);
        loop {
            let v = self.next_u64();
            if v < zone {
                return lo + v % span;
            }
        }
    }

    /// Uniform usize in [lo, hi].
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64_open();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal: exp(N(mu, sigma)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate lambda (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -self.f64_open().ln() / lambda
    }

    /// Pareto (Lomax-shifted classic): xm * U^(-1/alpha); heavy tail for
    /// alpha near 1.
    pub fn pareto(&mut self, xm: f64, alpha: f64) -> f64 {
        xm * self.f64_open().powf(-1.0 / alpha)
    }

    /// Poisson count (Knuth for small lambda, normal approx for large).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let v = self.normal_ms(lambda, lambda.sqrt()).round();
            if v < 0.0 { 0 } else { v as u64 }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i);
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.range(0, xs.len() - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg::seeded(7);
        let mut b = Pcg::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg::seeded(1);
        let mut b = Pcg::seeded(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg::seeded(3);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn range_inclusive_bounds_hit() {
        let mut r = Pcg::seeded(4);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            match r.range(3, 5) {
                3 => lo_seen = true,
                5 => hi_seen = true,
                4 => {}
                v => panic!("out of range: {v}"),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::seeded(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg::seeded(6);
        let n = 50_000;
        let m: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.02, "mean {m}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Pcg::seeded(7);
        for &lam in &[0.5, 4.0, 50.0] {
            let n = 20_000;
            let m: f64 =
                (0..n).map(|_| r.poisson(lam) as f64).sum::<f64>() / n as f64;
            assert!((m - lam).abs() < lam.max(1.0) * 0.05, "lam {lam} m {m}");
        }
    }

    #[test]
    fn pareto_tail_heavier_than_exponential() {
        let mut r = Pcg::seeded(8);
        let n = 20_000;
        let p99_pareto = {
            let mut xs: Vec<f64> = (0..n).map(|_| r.pareto(1.0, 1.2)).collect();
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            xs[(n as f64 * 0.99) as usize]
        };
        assert!(p99_pareto > 20.0, "p99 {p99_pareto}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg::seeded(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Pcg::seeded(10);
        let mut a = root.split();
        let mut b = root.split();
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }
}
