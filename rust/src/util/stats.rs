//! Streaming statistics, percentiles, and histograms.
//!
//! Everything the metrics layer and the figure benches need: Welford online
//! mean/variance, exact percentiles over recorded samples, fixed-bucket
//! histograms for distribution figures (Fig. 2), and a sliding-window
//! rate estimator for the Global Monitor.

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn new() -> Self {
        Online { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

/// Sample recorder with exact percentiles (sorts on query).
#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Samples { xs: Vec::new(), sorted: true }
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            0.0
        } else {
            self.xs.iter().sum::<f64>() / self.xs.len() as f64
        }
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Exact percentile (nearest-rank; q in [0,100]).
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        let rank = ((q / 100.0) * (self.xs.len() as f64 - 1.0)).round() as usize;
        self.xs[rank.min(self.xs.len() - 1)]
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn max(&mut self) -> f64 {
        self.ensure_sorted();
        self.xs.last().copied().unwrap_or(0.0)
    }

    pub fn values(&self) -> &[f64] {
        &self.xs
    }
}

/// Fixed-edge histogram (for the Fig. 2 distribution benches).
#[derive(Debug, Clone)]
pub struct Histogram {
    edges: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
}

impl Histogram {
    /// `edges` must be strictly increasing; bins are [e_i, e_{i+1}), plus an
    /// overflow bin.
    pub fn new(edges: Vec<f64>) -> Self {
        assert!(edges.windows(2).all(|w| w[0] < w[1]), "edges not sorted");
        let n = edges.len();
        Histogram { edges, counts: vec![0; n + 1], total: 0 }
    }

    /// Uniform bins over [lo, hi).
    pub fn uniform(lo: f64, hi: f64, bins: usize) -> Self {
        let step = (hi - lo) / bins as f64;
        Self::new((0..=bins).map(|i| lo + step * i as f64).collect())
    }

    pub fn push(&mut self, x: f64) {
        self.total += 1;
        let idx = match self.edges.binary_search_by(|e| e.partial_cmp(&x).unwrap()) {
            Ok(i) => i + 1,     // exactly on edge e_i → bin [e_i, e_{i+1})
            Err(0) => 0,        // below the first edge → underflow-ish bin 0
            Err(i) => i,
        };
        let idx = idx.min(self.counts.len() - 1);
        self.counts[idx] = self.counts[idx].saturating_add(1);
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    /// (bin label, count, fraction) rows for printing.
    pub fn rows(&self) -> Vec<(String, u64, f64)> {
        let mut rows = Vec::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let label = if i == 0 {
                format!("< {:.0}", self.edges[0])
            } else if i < self.edges.len() {
                format!("[{:.0}, {:.0})", self.edges[i - 1], self.edges[i])
            } else {
                format!(">= {:.0}", self.edges[self.edges.len() - 1])
            };
            let frac = if self.total == 0 { 0.0 } else { c as f64 / self.total as f64 };
            rows.push((label, c, frac));
        }
        rows
    }
}

/// Sliding-window event-rate estimator (events/sec) for the Global Monitor.
#[derive(Debug, Clone)]
pub struct RateWindow {
    window_us: u64,
    events: std::collections::VecDeque<u64>, // event timestamps (µs)
}

impl RateWindow {
    pub fn new(window_us: u64) -> Self {
        RateWindow { window_us, events: Default::default() }
    }

    pub fn record(&mut self, now_us: u64) {
        self.events.push_back(now_us);
        self.evict(now_us);
    }

    fn evict(&mut self, now_us: u64) {
        let cutoff = now_us.saturating_sub(self.window_us);
        while matches!(self.events.front(), Some(&t) if t < cutoff) {
            self.events.pop_front();
        }
    }

    /// Events per second over the window ending at `now_us`.
    pub fn rate(&mut self, now_us: u64) -> f64 {
        self.evict(now_us);
        self.events.len() as f64 / (self.window_us as f64 / 1e6)
    }

    pub fn count(&self) -> usize {
        self.events.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_matches_naive() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / (xs.len() - 1) as f64;
        assert!((o.mean() - mean).abs() < 1e-12);
        assert!((o.var() - var).abs() < 1e-12);
        assert_eq!(o.min(), 1.0);
        assert_eq!(o.max(), 16.0);
        assert_eq!(o.count(), 5);
    }

    #[test]
    fn online_empty_is_zero() {
        let o = Online::new();
        assert_eq!(o.mean(), 0.0);
        assert_eq!(o.var(), 0.0);
    }

    #[test]
    fn percentiles_exact() {
        let mut s = Samples::new();
        for i in (1..=100).rev() {
            s.push(i as f64);
        }
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
        // Nearest-rank median of 1..=100 is 50 or 51.
        assert!((s.median() - 50.5).abs() <= 0.5, "median {}", s.median());
        assert!((s.percentile(99.0) - 99.0).abs() <= 1.0);
        assert_eq!(s.len(), 100);
    }

    #[test]
    fn histogram_bins() {
        let mut h = Histogram::new(vec![0.0, 10.0, 100.0]);
        for x in [0.0, 5.0, 9.9, 10.0, 50.0, 150.0, -1.0] {
            h.push(x);
        }
        // bins: <0 | [0,10) | [10,100) | >=100
        assert_eq!(h.counts(), &[1, 3, 2, 1]);
        assert_eq!(h.total(), 7);
        let rows = h.rows();
        assert_eq!(rows.len(), 4);
        assert!((rows[1].2 - 3.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_uniform_edges() {
        let h = Histogram::uniform(0.0, 100.0, 4);
        assert_eq!(h.counts().len(), 6); // 4 bins + under + over
    }

    #[test]
    fn rate_window_evicts() {
        let mut w = RateWindow::new(1_000_000); // 1 s
        for t in 0..10 {
            w.record(t * 100_000); // 10 events over 1 s
        }
        let r = w.rate(1_000_000);
        assert!((r - 9.0).abs() <= 1.0, "rate {r}");
        // 5 s later everything evicted.
        assert_eq!(w.rate(6_000_000), 0.0);
    }
}
