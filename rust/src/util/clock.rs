//! Injectable wall-clock abstraction.
//!
//! The gateway and the realtime server stamp arrivals and timeouts off a
//! [`Clock`] instead of calling [`std::time::Instant`] directly, so unit
//! tests drive time by hand ([`ManualClock`]) and never sleep, while
//! production uses the monotonic wall clock ([`WallClock`]).

use crate::Micros;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Microsecond clock with an arbitrary (per-instance) epoch.
pub trait Clock: Send {
    /// Microseconds elapsed since this clock's epoch. Monotone.
    fn now_us(&self) -> Micros;
}

/// Monotonic wall clock; epoch = construction time.
pub struct WallClock {
    epoch: Instant,
}

impl WallClock {
    pub fn new() -> WallClock {
        WallClock { epoch: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> WallClock {
        WallClock::new()
    }
}

impl Clock for WallClock {
    fn now_us(&self) -> Micros {
        self.epoch.elapsed().as_micros() as Micros
    }
}

/// Hand-driven clock for deterministic tests: clones share the same
/// time, so a test holds one handle and injects another.
#[derive(Clone, Default)]
pub struct ManualClock {
    now: Arc<AtomicU64>,
}

impl ManualClock {
    pub fn new() -> ManualClock {
        ManualClock::default()
    }

    /// Jump to an absolute time (µs since epoch).
    pub fn set(&self, us: Micros) {
        self.now.store(us, Ordering::SeqCst);
    }

    /// Move forward by `us` microseconds.
    pub fn advance(&self, us: Micros) {
        self.now.fetch_add(us, Ordering::SeqCst);
    }
}

impl Clock for ManualClock {
    fn now_us(&self) -> Micros {
        self.now.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_clock_is_shared_and_monotone_under_advance() {
        let c = ManualClock::new();
        let handle = c.clone();
        assert_eq!(c.now_us(), 0);
        handle.advance(250);
        assert_eq!(c.now_us(), 250);
        handle.set(1_000);
        assert_eq!(c.now_us(), 1_000);
    }

    #[test]
    fn wall_clock_advances() {
        let c = WallClock::new();
        let a = c.now_us();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(c.now_us() > a);
    }
}
