//! Loopback load bench for the realtime serving path: sustained-RPS
//! sweep with online TTFT/TBT attainment columns.
//!
//! Each row replays a generated arrival schedule against a live
//! `PdScheduler::run_realtime` loop over the wall-clock
//! `RealtimeEngine`, submitting through the same `LiveCmd` channel the
//! TCP front end uses and draining every request's stream sink. Time is
//! pace-compressed: engine durations are divided by `realtime.pace`,
//! the submitter compresses the trace's inter-arrival gaps by the same
//! factor, and the SLO budgets are scaled identically — so attainment
//! is measured against budgets that mean the same thing they mean at
//! `pace = 1.0`.
//!
//! Unlike the simulation benches, these rows are *wall-clock* numbers:
//! scheduler poll latency, thread wakeup jitter, and host load all leak
//! into TTFT/TBT, which is precisely what the realtime path exists to
//! measure. Expect run-to-run noise; the baseline snapshot records a
//! reference capture, not a deterministic contract (see
//! benches/baselines/BENCH_realtime_load.json).

use bucketserve::cluster::realtime::RealtimeEngine;
use bucketserve::config::SystemConfig;
use bucketserve::coordinator::scheduler::BucketPlanner;
use bucketserve::coordinator::{LiveCmd, PdScheduler, RunReport, StreamSink};
use bucketserve::metrics::Summary;
use bucketserve::util::bench::{f1, f2, Table};
use bucketserve::workload::{Dataset, RequestClass, Trace};
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

/// Wall-time compression: 1 simulated second runs in 0.5 ms.
const PACE: f64 = 2_000.0;

/// Drive one arrival schedule through the realtime loop; returns the
/// drained run report.
fn run_row(cfg: &SystemConfig, trace: &Trace) -> RunReport {
    let (tx, rx) = mpsc::channel::<LiveCmd>();
    thread::scope(|s| {
        let server = s.spawn(move || {
            let mut engine = RealtimeEngine::new(cfg);
            let mut sched =
                PdScheduler::new(cfg, || Box::new(BucketPlanner::new(cfg)));
            sched.run_realtime(&mut engine, rx)
        });
        let t0 = Instant::now();
        let mut sinks = Vec::with_capacity(trace.requests.len());
        for r in &trace.requests {
            let due = Duration::from_micros((r.arrival as f64 / PACE) as u64);
            if let Some(gap) = due.checked_sub(t0.elapsed()) {
                thread::sleep(gap);
            }
            let sink = StreamSink::new(cfg.realtime.stream_buf.max(1) as usize);
            let cmd = LiveCmd::Submit { req: r.clone(), sink: sink.clone() };
            tx.send(cmd).expect("serving loop alive");
            sinks.push(sink);
        }
        // Closed-loop drain: consume every stream to its final line.
        for sink in &sinks {
            while !sink.finished() {
                let _ = sink.recv_timeout(Duration::from_millis(20));
            }
        }
        tx.send(LiveCmd::Shutdown).expect("serving loop alive");
        drop(tx);
        server.join().expect("serving loop panicked")
    })
}

fn main() {
    println!(
        "realtime_load — wall-clock serving loop under sustained RPS \
         (pace {PACE})\n"
    );
    let mut cfg = SystemConfig::default();
    cfg.realtime.pace = PACE;
    // Budgets scaled with the pace so attainment is meaningful in
    // compressed time.
    cfg.slo.ttft_us = ((400_000.0 / PACE) as u64).max(1);
    cfg.slo.tbt_us = ((100_000.0 / PACE) as u64).max(1);
    let online = RequestClass::Online;
    let mut t = Table::new(&[
        "rps", "n", "done", "TTFT attain", "TBT attain", "mean TTFT ms",
        "p99 gap ms", "drops",
    ]);
    for &rps in &[2.0f64, 6.0, 12.0] {
        let trace = Trace::generate(
            Dataset::Alpaca, 48, rps, online, cfg.model.max_seq, cfg.seed,
        );
        let r = run_row(&cfg, &trace);
        let s = Summary::from_report(
            &format!("BucketServe/realtime/rps{rps}"),
            &r,
            &cfg.slo,
        );
        println!("{}", s.to_json());
        // Report latencies in *simulated* milliseconds (compressed wall
        // time re-expanded by the pace) so rows are comparable with the
        // virtual-time benches.
        t.row(vec![
            f1(rps),
            trace.len().to_string(),
            r.completions.len().to_string(),
            f2(r.slo_attainment_class(online, cfg.slo.ttft_us, u64::MAX)),
            f2(r.tbt_attainment_class(online)),
            f1(r.mean_ttft_class_us(online) * PACE / 1e3),
            f1(r.tbt_gap_percentile_us(online, 99.0) * PACE / 1e3),
            r.stream_drops.to_string(),
        ]);
    }
    t.print(
        "realtime loopback: 48 Alpaca online requests per row, arrival \
         schedule and SLO budgets pace-compressed together",
    );
}
