//! Ablation: priority-aware drain vs pure FCFS drain under a mixed
//! online/offline workload (the new `coordinator::priority` subsystem).
//!
//! An offline throughput backlog lands at t=0 while an online Poisson
//! stream arrives on top; we sweep the online rate and report per-class
//! SLO attainment, online TTFT, and total throughput for both drain
//! orders. The paper's §III claim is that deadline-aware ordering buys
//! online SLO compliance without giving up offline throughput — the
//! "offline tok/s" column quantifies the price of the jump-ahead.

use bucketserve::baselines::System;
use bucketserve::config::SystemConfig;
use bucketserve::util::bench::{f1, f2, Table};
use bucketserve::workload::{Dataset, RequestClass, Trace};

fn main() {
    let mut base = SystemConfig::default();
    // TTFT budget scaled to the offline-wave length this overload creates
    // (KV-bound LongBench waves run for seconds); with the interactive
    // 400 ms budget both drains round to zero online attainment and the
    // ablation shows nothing.
    base.slo.ttft_us = 10_000_000;
    let mut t = Table::new(&[
        "online rps", "drain", "online SLO", "offline SLO", "online TTFT ms",
        "tok/s",
    ]);
    for &rps in &[4.0, 8.0, 16.0] {
        let trace = Trace::mixed_classes(
            Dataset::Alpaca, 120, rps, Dataset::LongBench, 60,
            base.model.max_seq, base.seed,
        );
        for (label, enabled) in [("priority", true), ("fcfs", false)] {
            let mut cfg = base.clone();
            cfg.priority.enabled = enabled;
            let r = System::BucketServe.run_sim(&cfg, &trace);
            t.row(vec![
                f1(rps),
                label.to_string(),
                f2(r.slo_attainment_class(
                    RequestClass::Online, cfg.slo.ttft_us, cfg.slo.tbt_us,
                )),
                f2(r.slo_attainment_class(
                    RequestClass::Offline, cfg.slo.ttft_us, cfg.slo.tbt_us,
                )),
                f1(r.mean_ttft_class_us(RequestClass::Online) / 1e3),
                f1(r.throughput_tps()),
            ]);
        }
    }
    t.print(
        "ablation: priority-aware vs FCFS drain \
         (60 offline LongBench @ t=0 + online Alpaca stream)",
    );
}
