//! Prefix cache — hit rate and throughput vs placement policy on a
//! multi-turn chat workload.
//!
//! The sweep drives [`Trace::multi_turn`] (sessions with shared system
//! prompts and growing conversation prefixes) through a sharded fleet
//! and crosses two axes:
//!
//! * **placement** — `hash` (lineage-blind), `least_loaded`
//!   (join-shortest-queue, also lineage-blind), and `prefix_affinity`
//!   (route to the instance holding the longest resident prefix match).
//!   Affinity is the tentpole claim: keeping a session's turns on the
//!   instance that already holds their KV converts shared context into
//!   cache hits instead of recomputed prefill.
//! * **cache size** — `cache_frac` sweeps the per-instance budget share
//!   the cache may occupy, which moves the achievable hit rate: a small
//!   cache churns under LRU eviction, a large one keeps whole sessions
//!   resident.
//!
//! A `cache off` row per placement anchors the baseline (its Summary
//! JSON carries no prefix block at all, per the byte-identity contract).
//! Each row also emits its Summary JSON on stdout for trajectory
//! tooling.
//!
//! Expected shape: with the cache armed, `prefix_affinity` beats both
//! lineage-blind placements on hit rate *and* throughput (hits shrink
//! the priced prefill suffix), and hit rate rises with `cache_frac`.

use bucketserve::baselines::System;
use bucketserve::config::{Placement, SystemConfig};
use bucketserve::metrics::Summary;
use bucketserve::util::bench::{f1, f2, Table};
use bucketserve::workload::{Dataset, Trace};

fn main() {
    println!("prefix_cache — hit rate x placement on multi-turn sessions\n");
    let mut base = SystemConfig::default();
    base.fleet.n_prefill = 4;
    base.fleet.n_decode = 4;
    base.sharding.shards = 0; // one scheduler shard per decode instance
    base.slo.ttft_us = 10_000_000;
    let trace = Trace::multi_turn(
        Dataset::Alpaca,
        24,  // concurrent sessions
        6,   // turns each
        24.0,
        base.model.max_seq,
        base.seed,
    );
    let mut t = Table::new(&[
        "placement", "cache", "tok/s", "hit rate", "hit tokens",
        "evictions", "mean TTFT ms", "makespan s",
    ]);
    let placements = [
        ("hash", Placement::Hash),
        ("least_loaded", Placement::LeastLoaded),
        ("prefix_affinity", Placement::PrefixAffinity),
    ];
    // (label, enabled, cache_frac): the off rows anchor the baseline;
    // the frac axis moves the hit rate via LRU pressure.
    let cache_axis =
        [("off", false, 0.0), ("frac=0.1", true, 0.1), ("frac=0.5", true, 0.5)];
    let mut best: Vec<(String, f64, f64)> = Vec::new();
    for (pname, placement) in placements {
        for (clabel, enabled, frac) in cache_axis {
            // Affinity routing needs a resident cache to consult; the
            // off-row for it is identical to join-shortest-KV fallback
            // but still worth a row to pin that fallback's cost.
            let mut cfg = base.clone();
            cfg.sharding.placement = placement;
            cfg.prefix.enabled = enabled;
            if enabled {
                cfg.prefix.cache_frac = frac;
            }
            let r = System::BucketServe.run_sim(&cfg, &trace);
            let s = Summary::from_report(
                &format!("BucketServe/{pname}/{clabel}"),
                &r,
                &cfg.slo,
            );
            println!("{}", s.to_json());
            t.row(vec![
                pname.to_string(),
                clabel.to_string(),
                f1(r.throughput_tps()),
                if enabled { f2(s.prefix_hit_rate()) } else { "-".into() },
                if enabled {
                    r.prefix_hit_tokens.to_string()
                } else {
                    "-".into()
                },
                if enabled { r.prefix_evictions.to_string() } else { "-".into() },
                f1(r.mean_ttft_us() / 1e3),
                f2(r.makespan_us as f64 / 1e6),
            ]);
            if enabled && (frac - 0.5).abs() < 1e-9 {
                best.push((
                    pname.to_string(),
                    r.throughput_tps(),
                    s.prefix_hit_rate(),
                ));
            }
        }
    }
    t.print("prefix cache: 24 sessions x 6 turns, 4 decode instances");
    println!(
        "\nexpected shape: prefix_affinity > {{hash, least_loaded}} on both \
         hit rate and tok/s at equal cache size;"
    );
    println!("hit rate rises with cache_frac (less LRU churn).");
    for (name, tps, hr) in &best {
        println!("  frac=0.5  {name:<16} tok/s={} hit_rate={}", f1(*tps), f2(*hr));
    }
}
