//! Ablation: the three TTFT/TBT-protection mechanisms — abort-and-requeue
//! preemption, TBT-aware admission, and chunked (sliced) prefill — alone
//! and in combination, swept over online overload levels.
//!
//! The scenario is LongBench-heavy: an offline backlog at t=0 keeps the
//! prefill instances busy with multi-second monolithic waves while an
//! online Alpaca stream arrives on top. Each mechanism buys online
//! latency a different way and charges a different bill:
//!
//!  * preemption aborts the running wave — fast rescue, but the aborted
//!    FLOPs are discarded (`wasted tok`) and evicted KV is replayed
//!    (`redo tok`);
//!  * admission defers/evicts at decode boundaries — protects TBT, but
//!    cannot shorten a prefill wave that is already on the GPU;
//!  * chunking never discards work: waves run as bounded slices, online
//!    work interleaves at slice boundaries, and decode piggybacks on
//!    slices as hybrid batches — TTFT is bounded by one slice rather
//!    than one wave, at zero wasted FLOPs but longer offline makespan.
//!
//! The 2³ sweep maps the wasted-FLOP vs TTFT vs TBT frontier so the
//! combinations can be read against their parts. Each run also emits its
//! Summary JSON on stdout (one line per run) for trajectory tooling; the
//! per-subsystem JSON blocks appear only in the rows that arm them.

use bucketserve::baselines::System;
use bucketserve::config::SystemConfig;
use bucketserve::util::bench::{f1, f2, Table};
use bucketserve::metrics::Summary;
use bucketserve::workload::{Dataset, RequestClass, Trace};

fn main() {
    println!("chunk_slo — TTFT protection: preempt vs admission vs chunking\n");
    let mut base = SystemConfig::default();
    base.slo.ttft_us = 2_000_000;
    base.preempt.urgency_threshold = 0.6;
    base.chunk.slice_tokens = 512;
    let mut t = Table::new(&[
        "online rps", "combo", "online SLO", "online TTFT ms",
        "online TBT", "wasted tok", "redo tok", "slices", "yields",
        "hybrid", "tok/s",
    ]);
    for &rps in &[8.0, 16.0] {
        let trace = Trace::mixed_classes(
            Dataset::Alpaca, 120, rps, Dataset::LongBench, 60,
            base.model.max_seq, base.seed,
        );
        for mask in 0u32..8 {
            let (pre, adm, chk) =
                (mask & 1 != 0, mask & 2 != 0, mask & 4 != 0);
            let combo = format!(
                "{}{}{}",
                if pre { "P" } else { "-" },
                if adm { "A" } else { "-" },
                if chk { "C" } else { "-" },
            );
            let mut cfg = base.clone();
            cfg.preempt.enabled = pre;
            cfg.admission.enabled = adm;
            cfg.chunk.enabled = chk;
            let r = System::BucketServe.run_sim(&cfg, &trace);
            let s = Summary::from_report(
                &format!("BucketServe/{combo}/rps{rps}"),
                &r,
                &cfg.slo,
            );
            println!("{}", s.to_json());
            t.row(vec![
                f1(rps),
                combo,
                f2(r.slo_attainment_class(
                    RequestClass::Online, cfg.slo.ttft_us, cfg.slo.tbt_us,
                )),
                f1(r.mean_ttft_class_us(RequestClass::Online) / 1e3),
                f2(r.tbt_attainment_class(RequestClass::Online)),
                r.wasted_prefill_tokens.to_string(),
                (r.recompute_tokens + r.tbt_recompute_tokens).to_string(),
                r.chunk_slices.to_string(),
                r.chunk_yields.to_string(),
                r.chunk_hybrid_iters.to_string(),
                f1(r.throughput_tps()),
            ]);
        }
    }
    t.print(
        "frontier: P=preempt A=admission C=chunk \
         (60 offline LongBench @ t=0 + online Alpaca stream)",
    );
}
