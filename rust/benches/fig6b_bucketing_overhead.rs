//! Fig. 6b — Bucketing overhead vs. number of buckets.
//!
//! Paper claim: as the bucket count grows the algorithmic overhead stays
//! flat and negligible. We drive the BucketManager directly with synthetic
//! workloads shaped to stabilize at k buckets (uniform mass over k
//! length ranges) and measure wall-clock assign+adjust cost per request.

use bucketserve::coordinator::bucket::{BucketManager, QueuedReq};
use bucketserve::coordinator::prefix::PrefixStamp;
use bucketserve::util::bench::Table;
use bucketserve::util::rng::Pcg;
use bucketserve::workload::RequestClass;

fn drive(k_target: u32, n_requests: usize, linear: bool) -> (usize, f64) {
    let l_max = 4096u32;
    let mut mgr = BucketManager::new(l_max, 0.5, 1);
    mgr.linear_scan = linear;
    let mut rng = Pcg::seeded(7);
    // Keep per-bucket load high and skewed so splitting proceeds to depth
    // log2(k); n_max small to allow splits.
    let n_max = 8usize;
    for i in 0..n_requests {
        // Sample predominantly short-within-range so skew > θ persists.
        let range = rng.range(0, k_target as usize - 1) as u32;
        let width = l_max / k_target;
        let off = (rng.f64().powi(3) * width as f64) as u32; // skew low
        let len = (range * width + off).min(l_max - 1);
        mgr.assign(QueuedReq {
            id: i as u64,
            len,
            output_len: 1,
            arrival: i as u64,
            class: RequestClass::Offline,
            tbt_us: 0,
            prefix: PrefixStamp::default(),
        });
        if i % 16 == 15 {
            mgr.adjust(n_max);
        }
        // Keep the queue from growing unboundedly: drain old entries.
        if mgr.total() > 512 {
            for b in mgr.buckets_mut() {
                let keep = b.requests.len() / 2;
                b.requests.truncate(keep);
            }
        }
    }
    let per_request_ns = mgr.overhead_ns as f64 / n_requests as f64;
    (mgr.n_buckets(), per_request_ns)
}

fn main() {
    println!("Fig. 6b — bucketing overhead vs bucket count\n");
    let n = 200_000;
    let mut t = Table::new(&[
        "target buckets", "observed buckets", "binary ns/req", "linear ns/req",
    ]);
    for &k in &[1u32, 2, 4, 8, 16, 32, 64] {
        let (kb, tb) = drive(k.max(1), n, false);
        let (_, tl) = drive(k.max(1), n, true);
        t.row(vec![
            k.to_string(),
            kb.to_string(),
            format!("{tb:.1}"),
            format!("{tl:.1}"),
        ]);
    }
    t.print(&format!("per-request bucketing cost ({n} requests/level)"));
    println!("\npaper shape: overhead flat in bucket count; absolute cost ≪ 1% of any batch time.");
    println!("(binary = boundary binary-search; linear = the O(n·k) scan from the paper's analysis)");
}
