//! Ablations of the design choices DESIGN.md calls out.
//!
//! * θ (split-skew threshold) sweep — Algorithm 1's only tunable.
//! * Bucketing on/off at fixed memory policy (min_bucket_width = L_max
//!   disables splitting entirely).
//! * mem_safety sweep — Eq. 5's 10% reservation vs. none vs. aggressive.
//! * Intra-bucket policy sweep on offline throughput (SJF vs LJF vs FCFS).

use bucketserve::baselines::System;
use bucketserve::config::{Policy, SystemConfig};
use bucketserve::util::bench::{f1, f2, Table};
use bucketserve::workload::{Dataset, RequestClass, Trace};

fn main() {
    let base = SystemConfig::default();
    let online = Trace::generate(
        Dataset::Mixed, 300, 16.0, RequestClass::Online, base.model.max_seq, base.seed,
    );
    let offline = Trace::batch(
        Dataset::Mixed, 256, RequestClass::Offline, base.model.max_seq, base.seed,
    );

    // --- θ sweep ------------------------------------------------------------
    let mut t = Table::new(&["theta", "SLO", "tok/s", "max buckets", "waste"]);
    for &theta in &[0.25, 0.5, 0.75, 0.95] {
        let mut cfg = base.clone();
        cfg.scheduler.theta = theta;
        let r = System::BucketServe.run_sim(&cfg, &online);
        let waste = r.completions.iter().map(|c| c.waste_ratio()).sum::<f64>()
            / r.completions.len() as f64;
        t.row(vec![
            f2(theta),
            f2(r.slo_attainment(cfg.slo.ttft_us, cfg.slo.tbt_us)),
            f1(r.throughput_tps()),
            r.max_buckets.to_string(),
            f2(waste),
        ]);
    }
    t.print("ablation: split threshold θ (online Mixed @16 RPS)");

    // --- bucketing on/off ----------------------------------------------------
    let mut t = Table::new(&["variant", "tok/s", "SLO", "util", "waste"]);
    for (label, disable) in [("bucketing ON", false), ("bucketing OFF", true)] {
        let mut cfg = base.clone();
        if disable {
            cfg.scheduler.min_bucket_width = cfg.scheduler.l_max; // never split
        }
        let r = System::BucketServe.run_sim(&cfg, &online);
        let waste = r.completions.iter().map(|c| c.waste_ratio()).sum::<f64>()
            / r.completions.len() as f64;
        t.row(vec![
            label.to_string(),
            f1(r.throughput_tps()),
            f2(r.slo_attainment(cfg.slo.ttft_us, cfg.slo.tbt_us)),
            f2(r.gpu_util()),
            f2(waste),
        ]);
    }
    t.print("ablation: adaptive bucketing on/off (same batcher)");

    // --- mem_safety sweep ----------------------------------------------------
    let mut t = Table::new(&["mem_safety", "tok/s", "peak batch", "SLO"]);
    for &s in &[0.7, 0.9, 1.0] {
        let mut cfg = base.clone();
        cfg.scheduler.mem_safety = s;
        let r = System::BucketServe.run_sim(&cfg, &online);
        t.row(vec![
            f2(s),
            f1(r.throughput_tps()),
            r.peak_batch.to_string(),
            f2(r.slo_attainment(cfg.slo.ttft_us, cfg.slo.tbt_us)),
        ]);
    }
    t.print("ablation: Eq. 5 memory reservation");

    // --- policy sweep (offline) ----------------------------------------------
    let mut t = Table::new(&["policy", "tok/s", "mean E2E ms", "p99 E2E ms"]);
    for policy in [Policy::Fcfs, Policy::Sjf, Policy::Ljf] {
        let mut cfg = base.clone();
        cfg.scheduler.policy = policy;
        let r = System::BucketServe.run_sim(&cfg, &offline);
        let mut e2e: Vec<f64> =
            r.completions.iter().map(|c| c.e2e() as f64 / 1e3).collect();
        e2e.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p99 = e2e[(e2e.len() as f64 * 0.99) as usize - 1];
        t.row(vec![
            policy.name().to_string(),
            f1(r.throughput_tps()),
            f1(e2e.iter().sum::<f64>() / e2e.len() as f64),
            f1(p99),
        ]);
    }
    t.print("ablation: intra-bucket policy (offline Mixed batch)");
}
