//! Shard scaling — throughput and SLO attainment vs decode-instance
//! count, with the coordinator sharded one-scheduler-per-instance.
//!
//! Configurations per fleet size on the same skewed mixed-class trace
//! (an offline LongBench backlog at t=0 under an online Alpaca stream,
//! both scaled with the fleet):
//!
//! * `global`   — shards = 1: the seed's single global queue + global
//!   max-headroom scan (the scalability ceiling the refactor removes).
//! * `sharded`  — one shard per decode instance, hash placement
//!   (load-blind, so skew lands where it lands), no stealing.
//! * `sharded+steal` — same, with idle shards stealing the tail of the
//!   most-loaded shard's highest-urgency bucket at decode-iteration
//!   boundaries.
//! * `…/t2`, `…/tN` — the thread-count axis: the same sharded+steal run
//!   under the parallel executor (2 workers / one per shard) with plan
//!   offload on — boundary accounting *and* per-shard planning (bucket
//!   adjust, drain sorts, batch formation) run on the workers behind
//!   the plan/commit protocol.
//! * `…/tN-inline` — one worker per shard but `plan_offload = false`:
//!   boundaries stay parallel while planning runs inline on the merge
//!   loop. The contrast between this row's and `…/tN`'s `plan on µs/rd`
//!   column isolates what speculation takes *off* the merge loop.
//!
//! The Summary JSON of every executor-axis row is byte-identical to
//! `sharded+steal` by the determinism contract; what the axis measures
//! is **wall-clock** executor behavior. The `wall ms` and planning
//! µs/round columns are host-dependent and live in this table only;
//! `plan rds` / `sync pts` — and the `bench` sub-object appended to each
//! row's Summary JSON line (plan_rounds, parallel_plans,
//! plan_invalidations) — are deterministic functions of the schedule,
//! safe for the scraped baseline snapshots. Planning columns:
//!
//! * `plan rds`       — dispatch rounds in which ≥ 1 shard planned.
//! * `plan on µs/rd`  — merge-loop planning time per such round: the
//!   eager speculation block (snapshots + blocking on the worker
//!   fan-out) plus any inline plans/re-plans. This is the column
//!   parallel planning exists to shrink at n_decode ≥ 4.
//! * `plan off µs/rd` — worker-side speculation time per round (Σ over
//!   proposals): the work that left the merge loop. 0 when sequential
//!   or inline.
//!
//! Each row also emits its Summary JSON on stdout (one line per run) so
//! trajectory tooling can scrape the sweep.

use bucketserve::baselines::System;
use bucketserve::config::{Placement, SystemConfig};
use bucketserve::metrics::Summary;
use bucketserve::util::bench::{f1, f2, Table};
use bucketserve::util::json::Json;
use bucketserve::workload::{Dataset, RequestClass, Trace};
use std::time::Instant;

fn main() {
    println!("shard_scaling — sharded coordinator vs the global queue\n");
    let mut t = Table::new(&[
        "n_decode", "variant", "threads", "tok/s", "online SLO",
        "mean TTFT ms", "steals", "makespan s", "wall ms", "sync pts",
        "plan rds", "plan on µs/rd", "plan off µs/rd",
    ]);
    for &nd in &[1usize, 2, 4, 8] {
        let mut base = SystemConfig::default();
        base.fleet.n_prefill = nd as u32;
        base.fleet.n_decode = nd as u32;
        // TTFT budget on the offline-wave scale (see priority_slo).
        base.slo.ttft_us = 10_000_000;
        let trace = Trace::mixed_classes(
            Dataset::Alpaca,
            40 * nd,
            8.0 * nd as f64,
            Dataset::LongBench,
            30 * nd,
            base.model.max_seq,
            base.seed,
        );
        for (label, shards, placement, steal, threads, offload) in [
            ("global", 1u32, Placement::LeastLoaded, false, 1u32, true),
            ("sharded", 0, Placement::Hash, false, 1, true),
            ("sharded+steal", 0, Placement::Hash, true, 1, true),
            ("sharded+steal/t2", 0, Placement::Hash, true, 2, true),
            ("sharded+steal/tN", 0, Placement::Hash, true, 0, true),
            ("sharded+steal/tN-inline", 0, Placement::Hash, true, 0, false),
        ] {
            let mut cfg = base.clone();
            cfg.sharding.shards = shards;
            cfg.sharding.placement = placement;
            cfg.sharding.steal = steal;
            cfg.executor.threads = threads;
            cfg.executor.plan_offload = offload;
            let wall_start = Instant::now();
            let r = System::BucketServe.run_sim(&cfg, &trace);
            let wall_ms = wall_start.elapsed().as_secs_f64() * 1e3;
            let s = Summary::from_report(
                &format!("BucketServe/{label}/d{nd}"),
                &r,
                &cfg.slo,
            );
            // Augment the scraped line with the deterministic executor
            // counters (never the wall-clock fields — baseline rows must
            // stay host-independent).
            let mut j = s.to_json();
            if let Json::Obj(map) = &mut j {
                map.insert(
                    "bench".to_string(),
                    Json::obj(vec![
                        ("plan_rounds", Json::from(r.executor_plan_rounds)),
                        (
                            "parallel_plans",
                            Json::from(r.executor_parallel_plans),
                        ),
                        (
                            "plan_invalidations",
                            Json::from(r.executor_plan_invalidations),
                        ),
                    ]),
                );
            }
            println!("{j}");
            let per_round = |ns: u64| {
                if r.executor_plan_rounds == 0 {
                    0.0
                } else {
                    ns as f64 / r.executor_plan_rounds as f64 / 1e3
                }
            };
            t.row(vec![
                nd.to_string(),
                label.to_string(),
                r.executor_threads.to_string(),
                f1(r.throughput_tps()),
                f2(r.slo_attainment_class(
                    RequestClass::Online,
                    cfg.slo.ttft_us,
                    cfg.slo.tbt_us,
                )),
                f1(r.mean_ttft_class_us(RequestClass::Online) / 1e3),
                r.steals.to_string(),
                f2(r.makespan_us as f64 / 1e6),
                f2(wall_ms),
                r.executor_sync_points.to_string(),
                r.executor_plan_rounds.to_string(),
                f2(per_round(r.plan_merge_ns)),
                f2(per_round(r.plan_worker_ns)),
            ]);
        }
    }
    t.print(
        "shard scaling: skewed mixed-class trace, work scaled with the fleet",
    );
}
