//! Shard scaling — throughput and SLO attainment vs decode-instance
//! count, with the coordinator sharded one-scheduler-per-instance.
//!
//! Configurations per fleet size on the same skewed mixed-class trace
//! (an offline LongBench backlog at t=0 under an online Alpaca stream,
//! both scaled with the fleet):
//!
//! * `global`   — shards = 1: the seed's single global queue + global
//!   max-headroom scan (the scalability ceiling the refactor removes).
//! * `sharded`  — one shard per decode instance, hash placement
//!   (load-blind, so skew lands where it lands), no stealing.
//! * `sharded+steal` — same, with idle shards stealing the tail of the
//!   most-loaded shard's highest-urgency bucket at decode-iteration
//!   boundaries.
//! * `…/t2`, `…/tN` — the thread-count axis: the same sharded+steal run
//!   under the parallel executor (2 workers / one per shard). The
//!   Summary JSON of these rows is byte-identical to `sharded+steal` by
//!   the determinism contract; what the axis measures is **wall-clock**
//!   executor behavior (the `wall ms` and `sync pts` columns — executor
//!   counters live on `RunReport`, never in Summary JSON). Boundary
//!   handlers in simulation are cheap arithmetic, so expect bounded
//!   gains here; the axis exists to keep the fan-out/merge overhead
//!   honest as fleets scale.
//!
//! Each row also emits its Summary JSON on stdout (one line per run) so
//! trajectory tooling can scrape the sweep.

use bucketserve::baselines::System;
use bucketserve::config::{Placement, SystemConfig};
use bucketserve::metrics::Summary;
use bucketserve::util::bench::{f1, f2, Table};
use bucketserve::workload::{Dataset, RequestClass, Trace};
use std::time::Instant;

fn main() {
    println!("shard_scaling — sharded coordinator vs the global queue\n");
    let mut t = Table::new(&[
        "n_decode", "variant", "threads", "tok/s", "online SLO",
        "mean TTFT ms", "steals", "makespan s", "wall ms", "sync pts",
    ]);
    for &nd in &[1usize, 2, 4, 8] {
        let mut base = SystemConfig::default();
        base.fleet.n_prefill = nd as u32;
        base.fleet.n_decode = nd as u32;
        // TTFT budget on the offline-wave scale (see priority_slo).
        base.slo.ttft_us = 10_000_000;
        let trace = Trace::mixed_classes(
            Dataset::Alpaca,
            40 * nd,
            8.0 * nd as f64,
            Dataset::LongBench,
            30 * nd,
            base.model.max_seq,
            base.seed,
        );
        for (label, shards, placement, steal, threads) in [
            ("global", 1u32, Placement::LeastLoaded, false, 1u32),
            ("sharded", 0, Placement::Hash, false, 1),
            ("sharded+steal", 0, Placement::Hash, true, 1),
            ("sharded+steal/t2", 0, Placement::Hash, true, 2),
            ("sharded+steal/tN", 0, Placement::Hash, true, 0),
        ] {
            let mut cfg = base.clone();
            cfg.sharding.shards = shards;
            cfg.sharding.placement = placement;
            cfg.sharding.steal = steal;
            cfg.executor.threads = threads;
            let wall_start = Instant::now();
            let r = System::BucketServe.run_sim(&cfg, &trace);
            let wall_ms = wall_start.elapsed().as_secs_f64() * 1e3;
            let s = Summary::from_report(
                &format!("BucketServe/{label}/d{nd}"),
                &r,
                &cfg.slo,
            );
            println!("{}", s.to_json());
            t.row(vec![
                nd.to_string(),
                label.to_string(),
                r.executor_threads.to_string(),
                f1(r.throughput_tps()),
                f2(r.slo_attainment_class(
                    RequestClass::Online,
                    cfg.slo.ttft_us,
                    cfg.slo.tbt_us,
                )),
                f1(r.mean_ttft_class_us(RequestClass::Online) / 1e3),
                r.steals.to_string(),
                f2(r.makespan_us as f64 / 1e6),
                f2(wall_ms),
                r.executor_sync_points.to_string(),
            ]);
        }
    }
    t.print(
        "shard scaling: skewed mixed-class trace, work scaled with the fleet",
    );
}
